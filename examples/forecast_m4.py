"""End-to-end M4 forecasting driver (the paper's full workflow).

Per frequency: fit with checkpoint/restart (kill it any time and run again --
it resumes from the latest checkpoint), then report test sMAPE/MASE/OWA
against the Comb and Naive2 benchmarks. The whole per-frequency workflow is
a few lines against the unified Forecaster API:

    PYTHONPATH=src python examples/forecast_m4.py [--freq quarterly] [--steps 150]
"""

import argparse
import os

from repro.forecast import ESRNNForecaster


def run_frequency(freq: str, steps: int, ckpt_root: str):
    print(f"\n=== {freq} ===")
    f = ESRNNForecaster(f"esrnn-{freq}", n_steps=steps, batch_size=64,
                        rnn_lr=4e-3, hw_lr=4e-2, data_scale=0.004,
                        eval_every=max(steps // 5, 1))
    f.fit(ckpt_dir=os.path.join(ckpt_root, freq))
    if not f.history_["loss"]:
        print("(resumed from a finished checkpoint)")

    scores = f.evaluate(split="test")  # forecast from train+val, score on test
    print(f"test sMAPE: esrnn {scores['smape']:.3f} | "
          f"comb {scores['smape_comb']:.3f} | "
          f"naive2 {scores['smape_naive2']:.3f}")
    print(f"test OWA:   esrnn {scores['owa']:.3f} | comb {scores['owa_comb']:.3f}")
    return scores["smape"], scores["smape_comb"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--freq", default="all",
                    choices=["all", "yearly", "quarterly", "monthly"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-root", default="/tmp/esrnn_ckpts")
    args = ap.parse_args()
    freqs = ["yearly", "quarterly", "monthly"] if args.freq == "all" else [args.freq]
    results = {f: run_frequency(f, args.steps, args.ckpt_root) for f in freqs}
    print("\nsummary (test sMAPE, esrnn vs comb):")
    for f, (es, cb) in results.items():
        marker = "BEATS" if es < cb else "trails"
        print(f"  {f:10s} {es:7.3f} vs {cb:7.3f}  ({marker} Comb)")


if __name__ == "__main__":
    main()
