"""End-to-end M4 forecasting driver (the paper's full workflow).

Trains ES-RNN per frequency on synthetic M4 with checkpoint/restart, picks
the best checkpoint by validation sMAPE, reports test sMAPE/MASE/OWA against
the Comb benchmark, and demonstrates crash-resume (kill it any time and run
again: it restarts from the latest checkpoint).

    PYTHONPATH=src python examples/forecast_m4.py [--freq quarterly] [--steps 150]
"""

import argparse
import os

import jax.numpy as jnp

from repro.core import losses as L
from repro.core.comb import comb_forecast, naive2_forecast
from repro.core.esrnn import ESRNN, make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn


def run_frequency(freq: str, steps: int, ckpt_root: str):
    print(f"\n=== {freq} ===")
    data = prepare(generate(freq, scale=0.004, seed=0))
    model = ESRNN(make_config(freq))
    ckpt_dir = os.path.join(ckpt_root, freq)
    out = train_esrnn(model, data, TrainConfig(
        batch_size=64, n_steps=steps, lr=4e-3,
        eval_every=max(steps // 5, 1), ckpt_dir=ckpt_dir))
    if out["resumed_from"]:
        print(f"(resumed from checkpoint step {out['resumed_from']})")

    # final evaluation: forecast from train+val, score on test (Eq. 7)
    fc = model.forecast(out["params"], jnp.asarray(data.val_input),
                        jnp.asarray(data.cats))
    target = jnp.asarray(data.test_target)
    insample = jnp.asarray(data.val_input)
    m, h = data.seasonality, data.horizon

    fc_comb = jnp.asarray(comb_forecast(data.val_input, h, m), jnp.float32)
    fc_n2 = jnp.asarray(naive2_forecast(data.val_input, h, m), jnp.float32)

    def score(f):
        return (float(L.smape(f, target)), float(L.mase(f, target, insample, m)))

    s_es, m_es = score(fc)
    s_cb, m_cb = score(fc_comb)
    s_n2, m_n2 = score(fc_n2)
    owa_es = float(L.owa(s_es, m_es, s_n2, m_n2))
    owa_cb = float(L.owa(s_cb, m_cb, s_n2, m_n2))
    print(f"test sMAPE: esrnn {s_es:.3f} | comb {s_cb:.3f} | naive2 {s_n2:.3f}")
    print(f"test OWA:   esrnn {owa_es:.3f} | comb {owa_cb:.3f}")
    return s_es, s_cb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--freq", default="all",
                    choices=["all", "yearly", "quarterly", "monthly"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-root", default="/tmp/esrnn_ckpts")
    args = ap.parse_args()
    freqs = ["yearly", "quarterly", "monthly"] if args.freq == "all" else [args.freq]
    results = {f: run_frequency(f, args.steps, args.ckpt_root) for f in freqs}
    print("\nsummary (test sMAPE, esrnn vs comb):")
    for f, (es, cb) in results.items():
        marker = "BEATS" if es < cb else "trails"
        print(f"  {f:10s} {es:7.3f} vs {cb:7.3f}  ({marker} Comb)")


if __name__ == "__main__":
    main()
