"""Batched serving demo: prefill a batch of prompts, decode with KV caches.

Runs the same prefill/decode graphs the 32k dry-run cells compile, at
host-friendly sizes, across three architecture families (dense GQA, MLA,
and an attention-free SSM -- whose "cache" is an O(1) recurrent state).

    PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch.serve import serve

ARCHS = ["yi-6b", "deepseek-v2-lite-16b", "mamba2-1.3b"]


def main():
    for arch in ARCHS:
        out = serve(arch, smoke=True, batch=4, prompt_len=32, gen=12)
        print(f"{arch:24s} prefill {out['prefill_s']*1e3:8.1f} ms | "
              f"decode {out['decode_s_per_tok']*1e3:7.2f} ms/token | "
              f"sample {out['generated'][0][:6].tolist()}")


if __name__ == "__main__":
    main()
