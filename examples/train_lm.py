"""Train a ~100M-parameter transformer with the full distributed substrate.

Exercises the same train_step the 512-chip dry-run compiles: grad
accumulation, fp32 master + bf16 compute, AdamW, checkpointing, straggler
watchdog -- on whatever devices this host has.

    PYTHONPATH=src python examples/train_lm.py                 # quick demo
    PYTHONPATH=src python examples/train_lm.py --steps 300     # full run
"""

import argparse
import logging

from repro.models.config import ArchConfig

# ~100M params: 2*32000*512 embed/head + 12 layers (attn 4*512^2 + swiglu
# 3*512*2048) -- llama-style dense.
LM_100M = ArchConfig(
    name="lm-100m", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
    vocab_size=32000, dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    import repro.launch.train as T

    # register the 100M config under a temporary name
    import repro.configs as C
    C._MODULES["lm-100m"] = None  # sentinel; we monkey-patch get_config

    orig_get, orig_smoke = C.get_config, C.get_smoke_config
    C.get_config = lambda a: LM_100M if a == "lm-100m" else orig_get(a)
    C.get_smoke_config = lambda a: LM_100M if a == "lm-100m" else orig_smoke(a)
    T.get_config = C.get_config
    T.get_smoke_config = C.get_smoke_config

    print(f"params ~= {LM_100M.param_count()/1e6:.0f}M")
    out = T.train("lm-100m", smoke=False, steps=args.steps, batch=args.batch,
                  seq=args.seq, microbatch=max(args.batch // 4, 1),
                  lr=3e-4, ckpt_dir=args.ckpt_dir)
    losses = out["losses"]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
