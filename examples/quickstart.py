"""Quickstart: the unified Forecaster API on synthetic M4-quarterly data,
in ~a minute on CPU.

One estimator, five verbs -- fit / predict / predict_quantiles / evaluate /
save -- over the paper's vectorized ES-RNN:

    PYTHONPATH=src python examples/quickstart.py

The same surface drives the CLI (`python -m repro.launch.forecast ...`).
"""

from repro.forecast import ESRNNForecaster


def main():
    # one registry name resolves model + data + two-group training recipe
    f = ESRNNForecaster("esrnn-quarterly", n_steps=80, batch_size=64,
                        rnn_lr=4e-3, hw_lr=4e-2, data_scale=0.005)
    f.fit()  # spec-driven synthetic M4 (Tables 2/3 profile)

    losses = f.history_["loss"]
    print(f"{f.n_series_} series, horizon {f.horizon}; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    # point + quantile forecasts from the end of the training window
    fc = f.predict()
    bands = f.predict_quantiles(taus=(0.1, 0.5, 0.9))
    print("first series forecast:", [f"{v:.1f}" for v in fc[0][:4]])
    print("80% band (h=1):",
          f"[{bands[0.1][0, 0]:.1f}, {bands[0.9][0, 0]:.1f}]")

    # M4-style scoring against the competition benchmarks
    scores = f.evaluate(split="val")
    print(f"val sMAPE  ES-RNN: {scores['smape']:.3f}   "
          f"comb: {scores['smape_comb']:.3f}   "
          f"naive2: {scores['smape_naive2']:.3f}   OWA: {scores['owa']:.3f}")


if __name__ == "__main__":
    main()
