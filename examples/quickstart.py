"""Quickstart: train a vectorized ES-RNN on synthetic M4-quarterly data and
forecast, in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import losses as L
from repro.core.comb import seasonal_naive_forecast
from repro.core.esrnn import ESRNN, make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn


def main():
    # 1. data: synthetic M4 (Table 2/3-matched), section 5 preparation
    data = prepare(generate("quarterly", scale=0.005, seed=0))
    print(f"{data.n_series} series, train length {data.train.shape[1]}, "
          f"horizon {data.horizon}")

    # 2. model: the paper's hybrid, per-series HW params + shared dilated LSTM
    model = ESRNN(make_config("quarterly"))

    # 3. joint training (per-series params on a 10x LR group)
    out = train_esrnn(model, data, TrainConfig(
        batch_size=64, n_steps=80, lr=4e-3, eval_every=40))
    print(f"loss: {out['history']['loss'][0]:.4f} -> "
          f"{out['history']['loss'][-1]:.4f}")

    # 4. forecast + score on the held-out validation window
    fc = model.forecast(out["params"], jnp.asarray(data.train),
                        jnp.asarray(data.cats))
    val = jnp.asarray(data.val_target)
    snaive = seasonal_naive_forecast(data.train, data.horizon, data.seasonality)
    print(f"val sMAPE  ES-RNN: {float(L.smape(fc, val)):.3f}   "
          f"seasonal-naive: {float(L.smape(jnp.asarray(snaive), val)):.3f}")
    print("first series forecast:", [f"{v:.1f}" for v in fc[0][:4]])


if __name__ == "__main__":
    main()
