"""Repo-wide fixtures.

``compile_sentinel`` arms the process-wide XLA compile listener
(:mod:`repro.analysis.recompile`) for the duration of a test, so any suspect
region can be wrapped in ``sentinel.expect(budget=..., what=...)`` and fail
loudly when a hot path compiles more executables than it declared -- the
PR-6 ``fc[:n]`` partial-fill bug class.
"""

import pytest

from repro.analysis.recompile import CompileCounter


@pytest.fixture
def compile_sentinel():
    """An armed CompileCounter: every XLA backend compile in the test bumps it."""
    with CompileCounter() as counter:
        yield counter
