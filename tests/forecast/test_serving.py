"""Batched forecast serving tests: bucketing, jit-cache reuse, cold-start."""

import numpy as np
import pytest

from repro.forecast import (
    BatchedForecastServer, BucketDispatcher, ESRNNForecaster, ForecastRequest,
    get_smoke_spec, synthetic_request_stream,
)


@pytest.fixture(scope="module")
def server():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=5))
    f.fit(n_steps=3)
    srv = BucketDispatcher(
        f.config, f.params_,
        length_buckets=(32, 64, 128), batch_buckets=(1, 4, 16))
    return f, srv


def test_ragged_stream_served_in_order(server):
    f, srv = server
    reqs = synthetic_request_stream(f.config, 20, n_known=f.n_series_, seed=0)
    out = srv.forecast_batch(reqs)
    assert len(out) == 20
    for fc in out:
        assert fc.shape == (f.config.output_size,)
        assert np.isfinite(fc).all() and (fc > 0).all()


def test_jit_cache_reuse_across_waves(server):
    f, srv = server
    srv.forecast_batch(synthetic_request_stream(f.config, 24, seed=1))
    compiles_first = srv.stats.compiles
    hits_before = srv.stats.cache_hits
    srv.forecast_batch(synthetic_request_stream(f.config, 24, seed=1))
    # replaying the wave: every bucket shape is already compiled
    assert srv.stats.compiles == compiles_first
    assert srv.stats.cache_hits > hits_before
    # the cache can never exceed the bucket grid
    assert srv.stats.compiles <= 3 * 3


def test_length_bucketing_pads_and_trims():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly"))
    f.init_params(4)
    srv = BucketDispatcher(
        f.config, f.params_, length_buckets=(32, 64), batch_buckets=(1, 4))
    short = srv.shape_history(np.full(20, 7.0, np.float32), 32)
    assert short.shape == (32,) and (short[:12] == 7.0).all()  # left-pad
    long = srv.shape_history(np.arange(1, 101, dtype=np.float32), 64)
    assert long.shape == (64,) and long[-1] == 100.0           # keep recent


def test_cold_start_unknown_series_uses_primer(server):
    f, srv = server
    y = np.abs(np.random.default_rng(0).lognormal(3, 0.2, 40)).astype(np.float32) + 1
    known = ForecastRequest(y=y, category=1, series_id=0)
    unknown = ForecastRequest(y=y, category=1, series_id=None)
    fc_known, fc_unknown = srv.forecast_batch([known, unknown])
    assert np.isfinite(fc_known).all() and np.isfinite(fc_unknown).all()
    # different HW rows -> (generically) different forecasts for the same y
    assert not np.array_equal(fc_known, fc_unknown)


def test_batch_padding_dropped_on_return(server):
    f, srv = server
    reqs = synthetic_request_stream(f.config, 3, seed=4)  # pads 3 -> bucket 4
    out = srv.forecast_batch(reqs)
    assert len(out) == 3


def test_bad_category_degrades_to_cold_start_not_crash(server):
    f, srv = server
    y = np.abs(np.random.default_rng(1).lognormal(3, 0.2, 40)).astype(np.float32) + 1
    good = ForecastRequest(y=y, category=1)
    bad_hi = ForecastRequest(y=y, category=99)
    bad_lo = ForecastRequest(y=y, category=-1)
    out = srv.forecast_batch([good, bad_hi, bad_lo])
    assert all(np.isfinite(o).all() for o in out)
    # out-of-range categories share the all-zero one-hot
    np.testing.assert_array_equal(out[1], out[2])


def test_hw_table_is_host_resident(server):
    """Cold-start + sharding regression: per-request primer/known-row
    resolution happens against a HOST numpy snapshot of the (possibly
    mesh-sharded) fitted table -- a device-table gather per request would
    re-gather the whole sharded table through the mesh on the hot path."""
    import jax

    f, srv = server
    # the backing table is host numpy (ExtendedHWView over a HostStateTable,
    # no (N+1)-row concatenated copy)
    leaves = jax.tree_util.tree_leaves(srv._host_table.hw)
    assert leaves and all(isinstance(a, np.ndarray) for a in leaves)
    assert srv._hw_table.n_rows == srv._host_table.n_rows + 1
    rows = srv.hw_rows([ForecastRequest(y=np.ones(40, np.float32),
                                         series_id=0),
                         ForecastRequest(y=np.ones(40, np.float32),
                                         series_id=None)])
    # gathered rows stay numpy too: nothing touches a device until the
    # batched forecast itself runs
    assert all(isinstance(a, np.ndarray)
               for a in jax.tree_util.tree_leaves(rows))
    # row 1 is the primer (cold start), distinct from the fitted row 0
    assert not np.array_equal(np.asarray(rows.alpha_logit[0]),
                              np.asarray(rows.alpha_logit[1])) or \
        not np.array_equal(np.asarray(rows.init_seas_logit[0]),
                           np.asarray(rows.init_seas_logit[1]))


def test_one_device_mesh_degenerates_to_single_device(server):
    """mesh with 1 device == no mesh (identical path, identical numbers)."""
    from repro.sharding.series import make_series_mesh

    f, _ = server
    srv_plain = BucketDispatcher(
        f.config, f.params_, length_buckets=(32, 64), batch_buckets=(1, 4))
    srv_mesh = BucketDispatcher(
        f.config, f.params_, length_buckets=(32, 64), batch_buckets=(1, 4),
        mesh=make_series_mesh(1))
    assert srv_mesh.mesh is None
    reqs = synthetic_request_stream(f.config, 6, n_known=f.n_series_, seed=2)
    for a, b in zip(srv_plain.forecast_batch(reqs),
                    srv_mesh.forecast_batch(reqs)):
        np.testing.assert_array_equal(a, b)


def test_max_batch_clamped_to_largest_bucket():
    """max_batch beyond the bucket grid must not produce oversized chunks."""
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly"))
    f.init_params(4)
    srv = BucketDispatcher(
        f.config, f.params_, length_buckets=(32,), batch_buckets=(1, 4),
        max_batch=16)
    assert srv.max_batch == 4
    out = srv.forecast_batch(synthetic_request_stream(f.config, 10, seed=0))
    assert len(out) == 10 and all(np.isfinite(o).all() for o in out)
    assert srv.stats.padded_series >= 0


def test_batched_server_wrapper_deprecated_but_working(server):
    """The legacy wrapper warns once at construction and still serves."""
    f, _ = server
    with pytest.warns(DeprecationWarning, match="ForecastServer"):
        srv = BatchedForecastServer(
            f.config, f.params_, length_buckets=(32, 64),
            batch_buckets=(1, 4))
    reqs = synthetic_request_stream(f.config, 5, n_known=f.n_series_, seed=3)
    out = srv.forecast_batch(reqs)
    assert len(out) == 5 and all(np.isfinite(o).all() for o in out)
    assert srv.stats.requests == 5
