"""ForecastSpec registry tests: resolution, overrides, smoke variants."""

import dataclasses

import pytest

from repro.core.esrnn import PRESETS
from repro.forecast import ForecastSpec, get_smoke_spec, get_spec, list_specs


def test_registry_covers_all_presets():
    names = list_specs()
    for freq in PRESETS:
        assert f"esrnn-{freq}" in names


@pytest.mark.parametrize("freq", list(PRESETS))
def test_spec_subsumes_presets(freq):
    spec = get_spec(f"esrnn-{freq}")
    for field, value in PRESETS[freq].items():
        assert getattr(spec.model, field) == value
    assert spec.frequency == freq
    assert spec.horizon == spec.model.output_size


def test_name_aliases():
    for name in ("esrnn-quarterly", "m4-quarterly", "quarterly"):
        assert get_spec(name).name == "esrnn-quarterly"


def test_unknown_name_raises():
    with pytest.raises(KeyError, match="available"):
        get_spec("esrnn-weekly")


def test_overrides_route_by_field_name():
    spec = get_spec("esrnn-quarterly", hidden_size=16, n_steps=7, hw_lr=0.5)
    assert spec.model.hidden_size == 16     # model-config field
    assert spec.n_steps == 7                # spec field
    assert spec.hw_lr == 0.5
    # untouched fields keep preset values
    assert spec.model.seasonality == 4


def test_unknown_override_raises():
    with pytest.raises(TypeError, match="unknown"):
        get_spec("esrnn-quarterly", not_a_field=1)


def test_smoke_variant_is_smaller():
    full = get_spec("esrnn-quarterly")
    smoke = get_smoke_spec("esrnn-quarterly")
    assert smoke.smoke and not full.smoke
    assert smoke.n_steps < full.n_steps
    assert smoke.model.hidden_size < full.model.hidden_size
    assert smoke.data_scale < full.data_scale
    # smoke overrides still composable
    assert get_smoke_spec("esrnn-quarterly", n_steps=3).n_steps == 3


def test_specs_are_frozen_and_hashable():
    spec = get_spec("esrnn-quarterly")
    hash(spec.model)  # jit static-arg requirement
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.n_steps = 1


def test_dict_roundtrip():
    spec = get_spec("esrnn-hourly", n_steps=11)
    assert ForecastSpec.from_dict(spec.to_dict()) == spec


# ---------------------------------------------------------------------------
# Pluggable heads in the registry
# ---------------------------------------------------------------------------


def test_registry_has_a_family_per_head():
    names = list_specs()
    for freq in PRESETS:
        assert f"esn-{freq}" in names and f"ssm-{freq}" in names


def test_head_prefixed_names_resolve():
    s = get_spec("esn-quarterly")
    assert s.name == "esn-quarterly" and s.model.head == "esn"
    assert s.frequency == "quarterly" and s.horizon == 8
    assert get_spec("ssm-hourly").model.head == "ssm"


def test_head_override_equals_head_prefixed_name():
    assert get_spec("esrnn-quarterly", head="esn") == get_spec("esn-quarterly")


def test_unknown_head_override_raises():
    with pytest.raises(KeyError, match="available heads"):
        get_spec("esrnn-quarterly", head="tcn")


def test_typo_override_error_names_valid_fields():
    """A typo like hiden_size must fail loudly, naming the real fields --
    never be silently dropped into a default-width model."""
    with pytest.raises(TypeError) as exc:
        get_spec("esrnn-quarterly", hiden_size=64)
    msg = str(exc.value)
    assert "hiden_size" in msg
    assert "hidden_size" in msg          # the model field the user meant
    assert "n_steps" in msg              # spec fields are listed too
    assert "head" in msg


def test_head_spec_dict_roundtrip():
    spec = get_spec("esn-monthly", n_steps=9)
    back = ForecastSpec.from_dict(spec.to_dict())
    assert back == spec and back.model.head == "esn"
