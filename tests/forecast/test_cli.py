"""repro.launch.forecast CLI smoke: every subcommand end-to-end on CPU."""

import pytest

from repro.launch.forecast import main


@pytest.fixture(scope="module")
def saved_dir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("fq"))
    rc = main(["fit", "--spec", "esrnn-quarterly", "--smoke", "--steps", "3",
               "--out-dir", d])
    assert rc == 0
    return d


def test_fit_with_overrides(tmp_path, capsys):
    rc = main(["fit", "--smoke", "--steps", "2", "--set", "hidden_size=4"])
    assert rc == 0
    assert "loss" in capsys.readouterr().out


def test_fit_fused_superstep_engine(capsys):
    """--set scan_steps=K routes through the fused lax.scan engine."""
    rc = main(["fit", "--smoke", "--steps", "6", "--set", "scan_steps=4",
               "--set", "hidden_size=4"])
    assert rc == 0
    assert "6 steps" in capsys.readouterr().out


def test_fit_sparse_adam(capsys):
    rc = main(["fit", "--smoke", "--steps", "4", "--set", "sparse_adam=true",
               "--set", "scan_steps=2", "--set", "hidden_size=4"])
    assert rc == 0
    assert "4 steps" in capsys.readouterr().out


def test_set_parses_booleans():
    from repro.launch.forecast import _parse_overrides

    out = _parse_overrides(["use_pallas=false", "smoke=True", "n_steps=3",
                            "rnn_lr=0.5", "name=x"])
    assert out["use_pallas"] is False and out["smoke"] is True
    assert out["n_steps"] == 3 and out["rnn_lr"] == 0.5 and out["name"] == "x"


def test_fit_resume_from_finished_checkpoint(tmp_path, capsys):
    ck = str(tmp_path / "ck")
    assert main(["fit", "--smoke", "--steps", "2", "--ckpt-dir", ck]) == 0
    capsys.readouterr()
    assert main(["fit", "--smoke", "--steps", "2", "--ckpt-dir", ck]) == 0
    assert "resumed from a finished checkpoint" in capsys.readouterr().out


def test_predict_from_saved(saved_dir, capsys):
    assert main(["predict", "--dir", saved_dir]) == 0
    assert "forecast" in capsys.readouterr().out


def test_predict_quantiles(saved_dir, capsys):
    assert main(["predict", "--dir", saved_dir, "--quantiles", "0.1,0.9"]) == 0
    out = capsys.readouterr().out
    assert "tau=0.1" in out and "tau=0.9" in out


def test_eval_from_saved(saved_dir, capsys):
    assert main(["eval", "--dir", saved_dir, "--split", "val"]) == 0
    out = capsys.readouterr().out
    assert "esrnn" in out and "comb" in out and "naive2" in out


def test_backtest_from_saved(saved_dir, capsys):
    assert main(["backtest", "--dir", saved_dir]) == 0
    out = capsys.readouterr().out
    assert "rolling-origin backtest" in out and "overall" in out
    assert out.count("  origin ") == 2  # default: end-of-train + end-of-val


def test_backtest_explicit_origins(saved_dir, capsys):
    assert main(["backtest", "--dir", saved_dir, "--origins", "60,72,80"]) == 0
    out = capsys.readouterr().out
    assert out.count("  origin ") == 3


def test_serve_smoke(saved_dir, capsys):
    assert main(["serve", "--dir", saved_dir, "--requests", "8",
                 "--waves", "2", "--length-buckets", "32,64",
                 "--batch-buckets", "1,8"]) == 0
    out = capsys.readouterr().out
    assert "jit cache" in out and "compiles" in out


def test_specs_lists_every_head_family(capsys):
    assert main(["specs"]) == 0
    out = capsys.readouterr().out
    assert "esrnn-quarterly" in out and "esn-quarterly" in out
    assert "ssm-hourly" in out and "head" in out


def test_specs_json(capsys):
    import json

    assert main(["specs", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    by_name = {r["name"]: r for r in rows}
    assert by_name["esn-yearly"]["head"] == "esn"
    assert by_name["esrnn-monthly"] == dict(
        name="esrnn-monthly", frequency="monthly", horizon=18, head="lstm")


@pytest.mark.parametrize("args", [
    ["fit", "--spec", "esn-quarterly", "--smoke", "--steps", "2"],
    ["fit", "--smoke", "--steps", "2", "--set", "head=ssm",
     "--set", "hidden_size=8"],
])
def test_fit_alternative_heads(args, capsys):
    assert main(args) == 0
    assert "2 steps" in capsys.readouterr().out


def test_eval_alternative_head(capsys):
    assert main(["eval", "--spec", "esn-quarterly", "--smoke",
                 "--steps", "2"]) == 0
    out = capsys.readouterr().out
    assert "esn-quarterly" in out and "smape" in out
