"""Continuous-batching server tests: online HW state, queue, fine-tune.

The load-bearing claim is the online-state exactness: after ``observe``
rolls a series one step via ``hw_step``, the stored (level, rings) must
match a from-scratch ``hw_smooth`` pass over the extended history -- per
frequency, including the hourly dual-seasonality ring -- and a forecast
conditioned on the online history must equal a fresh forecast given the
extended series explicitly.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import esrnn_forecast, esrnn_init, make_config
from repro.core.holt_winters import hw_smooth
from repro.forecast import (
    BucketDispatcher, ESRNNForecaster, ForecastRequest, get_smoke_spec,
    synthetic_request_stream,
)
from repro.forecast.server import (
    ObserveWrite, OnlineStateStore, QueueFull, ServerConfig,
)


def _series(t, seed=0, m=4):
    rng = np.random.default_rng(seed)
    seas = np.tile(np.exp(rng.normal(0, 0.1, m)), t // m + 1)[:t]
    y = 100.0 * np.exp(rng.normal(0, 0.01, t).cumsum()) * seas
    return np.maximum(y, 1e-3).astype(np.float32)


def _store_for(cfg, params, n_known, cap=4096):
    return OnlineStateStore(
        cfg, lambda: params["hw"], n_known, history_cap=cap)


# ---------------------------------------------------------------------------
# online HW state exactness (the tentpole invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("freq,t_len", [
    ("yearly", 41), ("quarterly", 61), ("monthly", 77), ("hourly", 401),
])
def test_rolled_state_matches_from_scratch_scan(freq, t_len):
    """observe-by-observe rolling == one hw_smooth pass over full history."""
    cfg = make_config(freq, hidden_size=8, dilations=((1,),))
    params = esrnn_init(jax.random.PRNGKey(0), cfg, 3)
    store = _store_for(cfg, params, 3)
    y = _series(t_len, seed=3, m=max(cfg.seasonality, 1))

    st = store.seed(1, y, row=1, category=0)

    row = jax.tree_util.tree_map(lambda a: a[1:2], params["hw"])
    levels, seas = hw_smooth(
        jnp.asarray(y)[None], row,
        seasonality=cfg.seasonality, seasonality2=cfg.seasonality2)
    np.testing.assert_allclose(
        np.float32(st.level), np.asarray(levels)[0, -1], rtol=1e-6)
    m = max(cfg.seasonality, 1)
    np.testing.assert_allclose(
        st.future_seasonal(m), np.asarray(seas)[0, t_len:], rtol=1e-6)
    assert st.t == t_len


def test_rolled_state_exact_beyond_history_cap():
    """Truncating the stored tail never degrades the rolled state."""
    cfg = make_config("quarterly", hidden_size=8, dilations=((1,),))
    params = esrnn_init(jax.random.PRNGKey(1), cfg, 2)
    store = _store_for(cfg, params, 2, cap=16)
    y = _series(90, seed=7)
    st = store.seed(0, y, row=0)
    assert st.truncated and len(st.history) == 16

    levels, seas = hw_smooth(
        jnp.asarray(y)[None],
        jax.tree_util.tree_map(lambda a: a[:1], params["hw"]),
        seasonality=cfg.seasonality, seasonality2=cfg.seasonality2)
    np.testing.assert_allclose(
        np.float32(st.level), np.asarray(levels)[0, -1], rtol=1e-6)
    np.testing.assert_allclose(
        st.future_seasonal(cfg.seasonality), np.asarray(seas)[0, 90:],
        rtol=1e-6)


def test_vectorized_absorb_equals_scalar_rolls():
    """The batched single-write fast path is the same f32 arithmetic."""
    cfg = make_config("quarterly", hidden_size=8, dilations=((1,),))
    params = esrnn_init(jax.random.PRNGKey(2), cfg, 8)
    a = _store_for(cfg, params, 8)
    b = _store_for(cfg, params, 8)
    for sid in range(6):
        h = _series(30, seed=sid)
        a.seed(sid, h, row=sid)
        b.seed(sid, h, row=sid)

    # one new value per series: store a absorbs them as one vectorized
    # batch, store b rolls them one at a time
    writes = [ObserveWrite(sid, 100.0 + sid) for sid in range(6)]
    a.absorb(writes, resolve_row=lambda sid: int(sid))
    for w in writes:
        b.absorb([w], resolve_row=lambda sid: int(sid))

    for sid in range(6):
        sa, sb = a.get(sid), b.get(sid)
        assert np.float32(sa.level) == np.float32(sb.level)
        np.testing.assert_array_equal(sa.s_ring, sb.s_ring)
        np.testing.assert_array_equal(sa.s2_ring, sb.s2_ring)
        assert sa.history == sb.history


# ---------------------------------------------------------------------------
# server-level behaviour (fitted smoke estimator)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=11))
    f.fit(n_steps=3)
    return f


def test_post_observe_forecast_equals_fresh_predict(fitted):
    """A y=None forecast after observe() == the same request with the
    extended history passed explicitly -- and, when the extended history
    lands exactly on a length bucket, == the raw jitted forecast."""
    f = fitted
    srv = f.serve(seed_histories=True)
    sid = 0
    hist = srv.store.history(sid)
    new_val = float(hist[-1] * 1.02)
    srv.observe(sid, new_val)

    fut_online = srv.submit(ForecastRequest(series_id=sid))
    srv.drain()
    fc_online = fut_online.result(timeout=30)

    ext = np.concatenate([hist, [new_val]]).astype(np.float32)
    fut_explicit = srv.submit(ForecastRequest(y=ext, series_id=sid))
    srv.drain()
    np.testing.assert_array_equal(fc_online, fut_explicit.result(timeout=30))

    # exact-bucket-length history: the serving answer IS the raw forecast
    bucket = srv.dispatcher.length_buckets[0]
    srv2 = f.serve()
    srv2.store.seed(sid, ext[-bucket:], row=sid, category=0)
    fut = srv2.submit(ForecastRequest(series_id=sid))
    srv2.drain()
    row = jax.tree_util.tree_map(lambda a: a[sid:sid + 1], f.params_["hw"])
    cats = jnp.zeros((1, f.config.n_categories), jnp.float32)
    cats = cats.at[0, 0].set(1.0)
    raw = esrnn_forecast(
        f.config, dict(f.params_, hw=row),
        jnp.asarray(ext[-bucket:])[None], cats)
    np.testing.assert_array_equal(fut.result(timeout=30), np.asarray(raw)[0])


def test_cold_start_unknown_series_after_observe(fitted):
    """An observed unknown id resolves to the primer row, not a fitted one,
    and serves history-less forecasts once it has observations."""
    f = fitted
    srv = f.serve()
    unknown = f.n_series_ + 500

    # before any observe: no history -> the future carries the error
    fut = srv.submit(ForecastRequest(series_id=unknown))
    srv.drain()
    with pytest.raises(ValueError, match="no history"):
        fut.result(timeout=30)

    for k in range(20):
        srv.observe(unknown, 50.0 + k)
    fut = srv.submit(ForecastRequest(series_id=unknown))
    srv.drain()
    fc = fut.result(timeout=30)
    assert np.isfinite(fc).all() and fc.shape == (f.config.output_size,)

    st = srv.store.get(unknown)
    assert st.row == srv.dispatcher.n_known        # primer, no collision
    assert srv.store.get(unknown).t == 20
    assert srv.stats.observes == 20

    # a known id resolves to its own fitted row
    srv.observe(0, 60.0)
    srv.drain()
    assert srv.store.get(0).row == 0


def test_queue_bound_backpressure(fitted):
    f = fitted
    srv = f.serve(server_config=ServerConfig(max_queue=2))
    y = _series(40)
    srv.submit(ForecastRequest(y=y))
    srv.submit(ForecastRequest(y=y))
    with pytest.raises(QueueFull):
        srv.submit(ForecastRequest(y=y), timeout=0.01)
    srv.drain()
    fut = srv.submit(ForecastRequest(y=y))   # space again after the drain
    srv.drain()
    assert np.isfinite(fut.result(timeout=30)).all()
    assert srv.stats.queue_peak == 2


def test_threaded_deadline_dispatch_and_latency_stats(fitted):
    """A partial bucket dispatches once max_wait_ms expires (no force)."""
    f = fitted
    srv = f.serve(server_config=ServerConfig(max_wait_ms=5.0))
    with srv:
        futs = [srv.submit(ForecastRequest(y=_series(40, seed=s)))
                for s in range(3)]
        outs = [fut.result(timeout=60) for fut in futs]
    assert all(np.isfinite(o).all() for o in outs)
    s = srv.stats
    assert s.requests == 3 and s.batches >= 1
    assert len(s.latencies_s) == 3
    pct = s.latency_percentiles()
    assert np.isfinite(pct["p50_ms"]) and pct["p99_ms"] >= pct["p50_ms"] > 0


def test_idle_finetune_runs_and_updates_params(fitted):
    f = fitted
    srv = f.serve(
        server_config=ServerConfig(finetune_steps=1, finetune_batch=4),
        seed_histories=True)
    alpha_before = srv.dispatcher._hw_table.alpha_logit.copy()
    for sid in range(4):
        srv.observe(sid, float(srv.store.history(sid)[-1]))
    srv.drain()   # absorb -> queue empty -> idle hook fires
    assert srv.stats.finetunes == 1
    assert not np.array_equal(
        srv.dispatcher._hw_table.alpha_logit, alpha_before)
    # tuned rows got refreshed: state still equals a pass over the stored
    # tail under the NEW parameters
    st = srv.store.get(0)
    hist = st.history_array()
    row = srv.dispatcher._hw_table.rows(np.array([0]))
    levels, _ = hw_smooth(
        jnp.asarray(hist)[None], row,
        seasonality=f.config.seasonality,
        seasonality2=f.config.seasonality2)
    # rtol 5e-6, not 1e-6: the seeded histories are full-length smoke
    # series, and XLA's FMA contraction in the device scan drifts a few
    # ulps from the host f32 roll over ~100 steps
    np.testing.assert_allclose(
        np.float32(st.level), np.asarray(levels)[0, -1], rtol=5e-6)
    # serving still healthy after the swap
    fut = srv.submit(ForecastRequest(series_id=0))
    srv.drain()
    assert np.isfinite(fut.result(timeout=30)).all()


def test_finetune_skips_when_nothing_observed(fitted):
    f = fitted
    srv = f.serve(server_config=ServerConfig(finetune_steps=1))
    fut = srv.submit(ForecastRequest(y=_series(40)))
    srv.drain()
    fut.result(timeout=30)
    # requests ran but no series has online history -> no eligible batch
    assert srv.stats.finetunes == 0


# ---------------------------------------------------------------------------
# satellites: stream determinism, truncation counter
# ---------------------------------------------------------------------------


def test_synthetic_request_stream_deterministic():
    cfg = get_smoke_spec("esrnn-quarterly").model
    a = synthetic_request_stream(cfg, 32, n_known=10, seed=9)
    b = synthetic_request_stream(cfg, 32, n_known=10, seed=9)
    c = synthetic_request_stream(cfg, 32, n_known=10, seed=10)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.y, rb.y)
        assert ra.category == rb.category and ra.series_id == rb.series_id
    assert any(not np.array_equal(ra.y, rc.y) for ra, rc in zip(a, c))


def test_overlong_history_truncated_and_counted(fitted):
    f = fitted
    srv = BucketDispatcher(
        f.config, f.params_, length_buckets=(32, 64), batch_buckets=(1, 4))
    long_y = _series(100, seed=1)
    out = srv.forecast_batch([ForecastRequest(y=long_y)])
    assert np.isfinite(out[0]).all()
    assert srv.stats.truncated_series == 1
    # the served forecast is the truncated-tail forecast, visibly
    tail = srv.forecast_batch([ForecastRequest(y=long_y[-64:])])
    np.testing.assert_array_equal(out[0], tail[0])
    srv.forecast_batch([ForecastRequest(y=_series(80, seed=2))])
    assert srv.stats.truncated_series == 2
