"""ESRNNForecaster tests: golden equivalence, round-trip, quantiles, eval."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import esrnn_forecast, esrnn_init, esrnn_loss
from repro.forecast import ESRNNForecaster, get_smoke_spec
from repro.forecast.estimator import NotFittedError


@pytest.fixture(scope="module")
def fitted():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3))
    f.fit(n_steps=6)
    return f


def test_golden_matches_pure_loss_bit_for_bit(fitted):
    """The estimator's loss IS the pure esrnn_loss on a fixed seed.

    (The bit-for-bit goldens against the *pre-refactor* inline loss /
    forecast math live in tests/core/test_forward.py.)
    """
    f = fitted
    y = jnp.asarray(f.data_.train)
    c = jnp.asarray(f.data_.cats)
    new = f.loss(y, c)
    old = esrnn_loss(f.config, f.params_, y, c)
    assert float(new) == float(old)  # bit-for-bit, no tolerance
    # and from a freshly-initialized fixed seed, independently of fit()
    g = ESRNNForecaster(f.spec)
    g.init_params(f.n_series_, seed=123)
    old_init = esrnn_init(jax.random.PRNGKey(123), f.config, f.n_series_)
    assert float(g.loss(y, c)) == float(
        esrnn_loss(f.config, old_init, y, c))


def test_golden_matches_pure_forecast_bit_for_bit(fitted):
    f = fitted
    np.testing.assert_array_equal(
        f.predict(),
        np.asarray(esrnn_forecast(
            f.config, f.params_,
            jnp.asarray(f.data_.train), jnp.asarray(f.data_.cats))))


def test_fit_save_load_predict_equivalence(fitted, tmp_path):
    f = fitted
    fc = f.predict()
    f.save(str(tmp_path))
    g = ESRNNForecaster.load(str(tmp_path))
    assert g.spec == f.spec
    assert g.n_series_ == f.n_series_
    np.testing.assert_array_equal(fc, g.predict(f.data_.train, f.data_.cats))
    # fitted categories survive the round trip: predict(y) without explicit
    # cats must NOT silently degrade to zero one-hots on a loaded estimator
    np.testing.assert_array_equal(fc, g.predict(f.data_.train))


def test_save_can_share_dir_with_trainer_checkpoints(tmp_path):
    """out_dir == ckpt_dir must not clobber the trainer's resume state."""
    d = str(tmp_path)
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3))
    f.fit(n_steps=3, ckpt_dir=d)
    f.save(d)
    g = ESRNNForecaster(f.spec)
    g.fit(n_steps=3, ckpt_dir=d)  # resume must still restore (params, opt)
    assert g.history_["loss"] == []


def test_predict_series_subset(fitted):
    f = fitted
    full = f.predict()
    sub = f.predict(f.data_.train[2:5], f.data_.cats[2:5], series_idx=[2, 3, 4])
    np.testing.assert_array_equal(full[2:5], sub)


def test_predict_defaults_to_fitted_categories(fitted):
    """predict(y) without cats must use the fitted one-hots, not zeros."""
    f = fitted
    np.testing.assert_array_equal(
        f.predict(f.data_.val_input),
        f.predict(f.data_.val_input, f.data_.cats))
    np.testing.assert_array_equal(
        f.predict(f.data_.train[2:5], series_idx=[2, 3, 4]),
        f.predict(f.data_.train[2:5], f.data_.cats[2:5], series_idx=[2, 3, 4]))


def test_predict_shape_mismatch_raises(fitted):
    with pytest.raises(ValueError, match="per-series table"):
        fitted.predict(fitted.data_.train[:3], fitted.data_.cats[:3])


def test_predict_quantiles_monotone_and_median_is_point(fitted):
    f = fitted
    bands = f.predict_quantiles(taus=(0.05, 0.5, 0.95))
    point = f.predict()
    assert (bands[0.05] <= bands[0.5]).all()
    assert (bands[0.5] <= bands[0.95]).all()
    np.testing.assert_allclose(bands[0.5], point, rtol=1e-5)


def test_evaluate_reports_owa_vs_benchmarks(fitted):
    scores = fitted.evaluate(split="test")
    for key in ("smape", "mase", "owa", "smape_comb", "owa_comb",
                "smape_naive2", "mase_naive2"):
        assert np.isfinite(scores[key]), key
    assert scores["owa"] > 0
    val = fitted.evaluate(split="val")
    assert val["split"] == "val" and np.isfinite(val["smape"])


def test_unfitted_raises():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly"))
    with pytest.raises(NotFittedError):
        f.predict()
    with pytest.raises(NotFittedError):
        f.evaluate()


def test_fit_resumes_from_trainer_checkpoints(fitted, tmp_path):
    """fit(ckpt_dir=...) wires the spec through the shared Checkpointer."""
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3))
    f.fit(n_steps=4, ckpt_dir=str(tmp_path / "ck"))
    g = ESRNNForecaster(f.spec)
    out = g.fit(n_steps=4, ckpt_dir=str(tmp_path / "ck"))
    assert out.history_["loss"] == []  # resumed at step 4: nothing left to do
