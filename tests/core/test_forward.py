"""The unified state-space forward core (repro.core.forward).

Two families of guarantees:

1. **Golden bit-for-bit vs the pre-refactor core.** Before PR 5 the loss
   and the forecast each re-derived the smoothing/window/seasonal-index
   pipeline inline; the reference implementations below are verbatim copies
   of that pre-refactor code. The refactored path (one ``esrnn_states``
   pass consumed by both) must reproduce them with NO tolerance -- the
   refactor moved code, it must not move numbers.

2. **Rolling-origin causality.** ``forecast_at_origins`` reads the forecast
   of origin ``o`` off the full-series pass; because every state is causal,
   it must equal ``esrnn_forecast`` on the truncated history ``y[:, :o]``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L
from repro.core.drnn import drnn_apply
from repro.core.esrnn import (
    esrnn_forecast, esrnn_forecast_at, esrnn_init, esrnn_loss, make_config,
)
from repro.core.forward import hw_step
from repro.core.holt_winters import hw_init_params, hw_smooth


# ---------------------------------------------------------------------------
# Pre-refactor reference (frozen copy of the old core/esrnn.py internals)
# ---------------------------------------------------------------------------


def _ref_smooth(cfg, params, y):
    return hw_smooth(
        y, params["hw"], seasonality=cfg.seasonality,
        seasonality2=cfg.seasonality2, use_pallas=cfg.use_pallas)


def _ref_future_seasonal_idx(out_idx, t_len, m):
    return jnp.where(out_idx < t_len + m, out_idx,
                     t_len + jnp.mod(out_idx - t_len, m))


def _ref_input_windows(cfg, y, levels, seas):
    w = cfg.input_size
    _, t_len = y.shape
    pos = jnp.arange(cfg.input_size - 1, t_len)
    in_idx = pos[:, None] + jnp.arange(-w + 1, 1)[None, :]
    y_in = y[:, in_idx]
    s_in = seas[:, in_idx]
    lvl = levels[:, pos]
    x_in = jnp.log(jnp.maximum(y_in / (lvl[:, :, None] * s_in), 1e-8))
    return x_in, pos


def _ref_target_windows(cfg, y, levels, seas, pos):
    n, t_len = y.shape
    h = cfg.output_size
    out_idx = pos[:, None] + jnp.arange(1, h + 1)[None, :]
    out_valid = out_idx < t_len
    out_idx_c = jnp.minimum(out_idx, t_len - 1)
    lvl = levels[:, pos]
    y_out = y[:, out_idx_c]
    m = max(cfg.seasonality, 1)
    s_out = seas[:, _ref_future_seasonal_idx(out_idx, t_len, m)]
    y_out_n = jnp.log(jnp.maximum(y_out / (lvl[:, :, None] * s_out), 1e-8))
    out_mask = out_valid[None, :, :].astype(y.dtype) * jnp.ones(
        (n, 1, 1), y.dtype)
    return y_out_n, out_mask


def _ref_rnn_head(cfg, params, feats):
    hid, c_sq = drnn_apply(
        params["rnn"], feats, dilations=cfg.dilations,
        use_pallas=cfg.use_pallas)
    if cfg.attention:
        ap = params["attn"]
        q = hid @ ap["wq"]
        k = hid @ ap["wk"]
        v = hid @ ap["wv"]
        s = jnp.einsum("nph,nqh->npq", q, k) / jnp.sqrt(
            jnp.asarray(cfg.hidden_size, jnp.float32)).astype(hid.dtype)
        p_idx = jnp.arange(hid.shape[1])
        mask = p_idx[:, None] >= p_idx[None, :]
        s = jnp.where(mask[None], s.astype(jnp.float32), -jnp.inf)
        hid = hid + jnp.einsum(
            "npq,nqh->nph", jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
    head = params["head"]
    z = jnp.tanh(hid @ head["dense_w"] + head["dense_b"])
    return z @ head["out_w"] + head["out_b"], c_sq


def _ref_features(x_in, cats):
    n, p, _ = x_in.shape
    cat_feat = jnp.broadcast_to(cats[:, None, :], (n, p, cats.shape[-1]))
    return jnp.concatenate([x_in, cat_feat.astype(x_in.dtype)], axis=-1)


def reference_loss(cfg, params, y, cats, mask=None):
    """Verbatim pre-refactor esrnn_loss_fn (inline window pipeline)."""
    levels, seas = _ref_smooth(cfg, params, y)
    x_in, pos = _ref_input_windows(cfg, y, levels, seas)
    y_out_n, out_mask = _ref_target_windows(cfg, y, levels, seas, pos)
    if mask is not None:
        valid_in = mask[:, pos - cfg.input_size + 1]
        out_mask = out_mask * valid_in[:, :, None]
    feats = _ref_features(x_in, cats)
    yhat_n, c_sq = _ref_rnn_head(cfg, params, feats)
    pin_sum, pin_cnt = L.pinball_terms(yhat_n, y_out_n, tau=cfg.tau,
                                       mask=out_mask)
    penalties = (L.level_variability_penalty(levels, cfg.level_penalty)
                 + L.cstate_penalty(c_sq, cfg.cstate_penalty))
    return pin_sum / jnp.maximum(pin_cnt, 1.0) + penalties


def reference_forecast(cfg, params, y, cats):
    """Verbatim pre-refactor esrnn_forecast (second inline pipeline)."""
    n, t_len = y.shape
    levels, seas = _ref_smooth(cfg, params, y)
    x_in, _pos = _ref_input_windows(cfg, y, levels, seas)
    feats = _ref_features(x_in, cats)
    yhat_n, _ = _ref_rnn_head(cfg, params, feats)
    last = yhat_n[:, -1, :]
    m = max(cfg.seasonality, 1)
    fut_idx = t_len + jnp.arange(cfg.output_size)
    s_fut = seas[:, _ref_future_seasonal_idx(fut_idx, t_len, m)]
    return jnp.exp(last) * levels[:, -1:] * s_fut


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(11)
    n, t = 7, 64
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.4, (n, t))) + 1, jnp.float32)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    mask = np.ones((n, t), np.float32)
    for i in range(n):
        mask[i, : rng.integers(0, t // 3)] = 0.0
    return y, cats, jnp.asarray(mask)


# ---------------------------------------------------------------------------
# Golden bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["plain", "masked", "penalties",
                                     "attention"])
def test_loss_bit_for_bit_vs_pre_refactor(batch, variant):
    y, cats, mask = batch
    kw = {}
    if variant == "penalties":
        kw = dict(level_penalty=5.0, cstate_penalty=0.5)
    if variant == "attention":
        kw = dict(attention=True)
    cfg = make_config("quarterly", hidden_size=8, **kw)
    params = esrnn_init(jax.random.PRNGKey(2), cfg, y.shape[0])
    m = mask if variant == "masked" else None
    new = esrnn_loss(cfg, params, y, cats, m)
    old = reference_loss(cfg, params, y, cats, m)
    assert float(new) == float(old)  # NO tolerance: the refactor moved code


def test_loss_bit_for_bit_dual_seasonality():
    cfg = make_config("hourly", hidden_size=8)
    rng = np.random.default_rng(0)
    n, t = 3, 24 * 16
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.2, (n, t))) + 1, jnp.float32)
    cats = jnp.zeros((n, 6), jnp.float32)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    assert float(esrnn_loss(cfg, params, y, cats)) == float(
        reference_loss(cfg, params, y, cats))


def test_forecast_bit_for_bit_vs_pre_refactor(batch):
    y, cats, _ = batch
    cfg = make_config("quarterly", hidden_size=8)
    params = esrnn_init(jax.random.PRNGKey(2), cfg, y.shape[0])
    np.testing.assert_array_equal(
        np.asarray(esrnn_forecast(cfg, params, y, cats)),
        np.asarray(reference_forecast(cfg, params, y, cats)))


# ---------------------------------------------------------------------------
# Rolling origins: causality of the unified pass
# ---------------------------------------------------------------------------


def test_forecast_at_final_origin_is_the_forecast(batch):
    y, cats, _ = batch
    cfg = make_config("quarterly", hidden_size=8)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    fa = esrnn_forecast_at(cfg, params, y, cats, (y.shape[1],))
    np.testing.assert_array_equal(
        np.asarray(fa[:, 0]),
        np.asarray(esrnn_forecast(cfg, params, y, cats)))


@pytest.mark.parametrize("origin", [8, 23, 40, 63])
def test_forecast_at_origin_equals_truncated_predict(batch, origin):
    """The headline property: one pass == per-origin truncated re-runs."""
    y, cats, _ = batch
    cfg = make_config("quarterly", hidden_size=8)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    fa = esrnn_forecast_at(cfg, params, y, cats, (origin, y.shape[1]))
    trunc = esrnn_forecast(cfg, params, y[:, :origin], cats)
    np.testing.assert_allclose(np.asarray(fa[:, 0]), np.asarray(trunc),
                               rtol=1e-6)


def test_forecast_at_origin_causal_under_attention(batch):
    """The attentive head is causally masked, so origins stay sound."""
    y, cats, _ = batch
    cfg = make_config("quarterly", hidden_size=8, attention=True)
    params = esrnn_init(jax.random.PRNGKey(1), cfg, y.shape[0])
    o = 40
    fa = esrnn_forecast_at(cfg, params, y, cats, (o,))
    trunc = esrnn_forecast(cfg, params, y[:, :o], cats)
    np.testing.assert_allclose(np.asarray(fa[:, 0]), np.asarray(trunc),
                               rtol=1e-5, atol=1e-6)


def test_forecast_at_rejects_bad_origins(batch):
    y, cats, _ = batch
    cfg = make_config("quarterly", hidden_size=8)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    with pytest.raises(ValueError, match="origin"):
        esrnn_forecast_at(cfg, params, y, cats, (cfg.input_size - 1,))
    with pytest.raises(ValueError, match="origin"):
        esrnn_forecast_at(cfg, params, y, cats, (y.shape[1] + 1,))


def test_hw_step_composes_to_the_scan():
    """T host-side hw_step applications == one hw_smooth pass, bit-exact.

    This is the forecast server's online-observe rule: rolling state one
    observation at a time in numpy f32 must agree with the device scan,
    because both call the SAME hw_step body in the same expression order.
    """
    rng = np.random.default_rng(4)
    n, t, m = 3, 40, 4
    y = np.abs(rng.lognormal(2, 0.3, (n, t))).astype(np.float32) + 1
    p = hw_init_params(n, m)
    import dataclasses as _dc
    p = _dc.replace(
        p,
        alpha_logit=jnp.asarray(rng.normal(0, 1.5, n), jnp.float32),
        gamma_logit=jnp.asarray(rng.normal(0, 1.5, n), jnp.float32),
        init_seas_logit=jnp.asarray(rng.normal(0, 0.2, (n, m)), jnp.float32))
    levels, seas = hw_smooth(jnp.asarray(y), p, seasonality=m)

    c = {k: np.asarray(v, np.float32) for k, v in p.constrained().items()}
    level = y[:, 0] / c["init_seas"][:, 0]
    ring = c["init_seas"].copy()
    for step_t in range(t):
        level, s_new, _ = hw_step(
            y[:, step_t], level, ring[:, 0], np.float32(1.0),
            c["alpha"], c["gamma"], seasonal=True, dual=False)
        ring = np.concatenate([ring[:, 1:], s_new[:, None]], axis=1)
    np.testing.assert_allclose(level, np.asarray(levels)[:, -1], rtol=1e-6)
    np.testing.assert_allclose(ring, np.asarray(seas)[:, t:], rtol=1e-6)
