"""Holt-Winters property + equivalence tests (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.holt_winters import (
    hw_forecast, hw_init_params, hw_smooth, hw_smooth_loop_reference,
)


def _rand_params(rng, n, m):
    p = hw_init_params(n, m)
    return dataclasses.replace(
        p,
        alpha_logit=jnp.asarray(rng.normal(0, 1.5, n), jnp.float32),
        gamma_logit=jnp.asarray(rng.normal(0, 1.5, n), jnp.float32),
        init_seas_logit=jnp.asarray(rng.normal(0, 0.2, (n, m)), jnp.float32),
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 7),
    t=st.integers(5, 40),
    m=st.sampled_from([1, 4, 12]),
    seed=st.integers(0, 2**30),
)
def test_vectorized_equals_loop_reference(n, t, m, seed):
    """The paper's central claim: batched == per-series sequential."""
    rng = np.random.default_rng(seed)
    y = np.abs(rng.lognormal(2.0, 0.7, (n, t))).astype(np.float32) + 0.5
    p = _rand_params(rng, n, m)
    lv, ss = hw_smooth(jnp.asarray(y), p, seasonality=m)
    lv_ref, ss_ref = hw_smooth_loop_reference(y, p, seasonality=m)
    np.testing.assert_allclose(lv, lv_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ss, ss_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5), t=st.integers(4, 30), seed=st.integers(0, 2**30))
def test_levels_positive_and_bounded(n, t, seed):
    """For positive series, levels stay positive and below max(y)/min(seas)."""
    rng = np.random.default_rng(seed)
    y = np.abs(rng.lognormal(2.0, 0.5, (n, t))).astype(np.float32) + 0.5
    p = _rand_params(rng, n, 4)
    lv, ss = hw_smooth(jnp.asarray(y), p, seasonality=4)
    assert bool((lv > 0).all())
    assert bool((ss > 0).all())


def test_alpha_one_tracks_deseasonalized_signal():
    """alpha -> 1 makes the level exactly y_t / s_t."""
    rng = np.random.default_rng(0)
    n, t, m = 3, 20, 4
    y = np.abs(rng.lognormal(2, 0.4, (n, t))).astype(np.float32) + 1
    p = hw_init_params(n, m)
    p = dataclasses.replace(p, alpha_logit=jnp.full((n,), 30.0),
                            gamma_logit=jnp.full((n,), -30.0))
    lv, ss = hw_smooth(jnp.asarray(y), p, seasonality=m)
    np.testing.assert_allclose(lv, y / np.asarray(ss[:, :t]), rtol=1e-5)


def test_gamma_zero_freezes_seasonality():
    rng = np.random.default_rng(1)
    n, t, m = 2, 17, 4
    y = np.abs(rng.lognormal(2, 0.4, (n, t))).astype(np.float32) + 1
    p = _rand_params(rng, n, m)
    p = dataclasses.replace(p, gamma_logit=jnp.full((n,), -40.0))
    _, ss = hw_smooth(jnp.asarray(y), p, seasonality=m)
    init = np.exp(np.asarray(p.init_seas_logit))
    for k in range(t + m):
        np.testing.assert_allclose(ss[:, k], init[:, k % m], rtol=1e-5)


def test_constant_series_flat_forecast():
    """A constant series forecasts (approximately) itself."""
    n, t, m = 2, 40, 4
    y = jnp.full((n, t), 7.0)
    p = hw_init_params(n, m)
    lv, ss = hw_smooth(y, p, seasonality=m)
    fc = hw_forecast(lv, ss, 8, seasonality=m)
    np.testing.assert_allclose(fc, 7.0, rtol=1e-3)


def test_dual_seasonality_runs_and_reduces_to_single():
    """seasonality2=0 path == dual path with flat second ring."""
    rng = np.random.default_rng(2)
    n, t, m = 3, 48, 4
    y = np.abs(rng.lognormal(2, 0.4, (n, t))).astype(np.float32) + 1
    p1 = _rand_params(rng, n, m)
    lv1, ss1 = hw_smooth(jnp.asarray(y), p1, seasonality=m)
    p2 = hw_init_params(n, m, seasonality2=6)
    p2 = dataclasses.replace(
        p2, alpha_logit=p1.alpha_logit, gamma_logit=p1.gamma_logit,
        init_seas_logit=p1.init_seas_logit,
        gamma2_logit=jnp.full((n,), -40.0))  # frozen flat second ring
    lv2, ss2 = hw_smooth(jnp.asarray(y), p2, seasonality=m, seasonality2=6)
    np.testing.assert_allclose(lv1, lv2, rtol=1e-5)
    np.testing.assert_allclose(ss1[:, :t], ss2[:, :t], rtol=1e-5)


def test_gradients_flow_to_per_series_params():
    rng = np.random.default_rng(3)
    n, t, m = 4, 24, 4
    y = jnp.asarray(np.abs(rng.lognormal(2, 0.4, (n, t))) + 1, jnp.float32)
    p = _rand_params(rng, n, m)

    def loss(p):
        lv, ss = hw_smooth(y, p, seasonality=m)
        return jnp.mean(jnp.square(jnp.log(lv)))

    g = jax.grad(loss)(p)
    assert bool(jnp.any(g.alpha_logit != 0))
    assert bool(jnp.any(g.gamma_logit != 0))
    assert bool(jnp.any(g.init_seas_logit != 0))
