"""Dilated residual LSTM tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.drnn import drnn_apply, drnn_init, lstm_cell


def test_causality():
    """Output at position t is unaffected by inputs after t."""
    key = jax.random.PRNGKey(0)
    dil = ((1, 2), (4, 8))
    params = drnn_init(key, 5, 16, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 5))
    out1, _ = drnn_apply(params, x, dilations=dil)
    x2 = x.at[:, 8:, :].set(99.0)
    out2, _ = drnn_apply(params, x2, dilations=dil)
    np.testing.assert_allclose(out1[:, :8], out2[:, :8], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, 8:], out2[:, 8:])


def test_dilation_skips_state():
    """With a single layer of dilation d, steps t < d see only the zero
    initial state: outputs at t0 < d are independent of inputs before t0."""
    key = jax.random.PRNGKey(0)
    dil = ((4,),)
    params = drnn_init(key, 3, 8, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 3))
    out1, _ = drnn_apply(params, x, dilations=dil)
    x2 = x.at[:, 0, :].set(-5.0)  # perturb t=0
    out2, _ = drnn_apply(params, x2, dilations=dil)
    # t=1..3 use state from t-4 < 0 (zeros), so they can't see t=0
    np.testing.assert_allclose(out1[:, 1:4], out2[:, 1:4], rtol=1e-5, atol=1e-6)
    # t=4 uses state from t=0: must differ
    assert not np.allclose(out1[:, 4], out2[:, 4])


def test_residual_between_blocks():
    """Second block output includes identity path: zeroing its weights
    leaves the first block's output."""
    key = jax.random.PRNGKey(0)
    dil = ((1,), (2,))
    params = drnn_init(key, 4, 8, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 4))
    out_full, _ = drnn_apply(params, x, dilations=dil)
    zeroed = [params[0], jax.tree_util.tree_map(jnp.zeros_like, params[1])]
    out_zero, _ = drnn_apply(zeroed, x, dilations=dil)
    first_block, _ = drnn_apply([params[0]], x, dilations=((1,),))
    np.testing.assert_allclose(out_zero, first_block, rtol=1e-5, atol=1e-6)


def test_cell_matches_manual():
    rng = np.random.default_rng(0)
    B, I, H = 3, 4, 5
    p = {
        "wx": jnp.asarray(rng.normal(0, 0.3, (I, 4 * H)), jnp.float32),
        "wh": jnp.asarray(rng.normal(0, 0.3, (H, 4 * H)), jnp.float32),
        "b": jnp.asarray(rng.normal(0, 0.3, 4 * H), jnp.float32),
    }
    x = jnp.asarray(rng.normal(0, 1, (B, I)), jnp.float32)
    h = jnp.asarray(rng.normal(0, 1, (B, H)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1, (B, H)), jnp.float32)
    h2, c2 = lstm_cell(p, x, h, c)
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = np.split(np.asarray(gates), 4, axis=1)
    sig = lambda z: 1 / (1 + np.exp(-z))
    c_ref = sig(f) * np.asarray(c) + sig(i) * np.tanh(g)
    h_ref = sig(o) * np.tanh(c_ref)
    np.testing.assert_allclose(h2, h_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c2, c_ref, rtol=1e-5, atol=1e-6)


def test_interleaved_matches_ring_reference():
    """Production (interleaved) == ring-buffer oracle, several stacks."""
    from repro.core.drnn import drnn_apply_reference

    for dil, t in [(((1, 2), (4, 8)), 12), (((1, 3), (6, 12)), 25), (((2,),), 7)]:
        key = jax.random.PRNGKey(sum(map(sum, dil)))
        params = drnn_init(key, 5, 16, dil)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, t, 5))
        out_new, _ = drnn_apply(params, x, dilations=dil)
        out_ref, _ = drnn_apply_reference(params, x, dilations=dil)
        np.testing.assert_allclose(out_new, out_ref, rtol=1e-5, atol=1e-6)
