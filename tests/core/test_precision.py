"""Mixed-precision policy: bf16 compute, fp32 state, fp32-quality results.

Trajectory-TOLERANCE tests, deliberately not bit-exact goldens: the bf16
policy's contract is *equal-quality* convergence, not equal bits. Per head
the suite asserts a short fit under ``precision="bf16"`` tracks the fp32
trajectory within a small relative tolerance, forecasts stay finite, the
fp32-state half holds (master params, HW table, Adam moments, loss all
fp32), and the fp32 path is left bit-identical by construction (the policy
threading is a no-op under ``precision="fp32"`` -- the repo's pre-existing
golden suites enforce that side).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import (
    esrnn_forecast_fn, esrnn_init, esrnn_loss_fn, esrnn_predict_stats,
    make_config,
)
from repro.core.heads import available_heads, frozen_param_groups
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.engine import make_step_fn, split_frozen
from repro.train.optimizer import AdamConfig, adam_init

STEPS = 12
BATCH = 16


def _data():
    d = prepare(generate("quarterly", scale=0.002, seed=0))
    return jnp.asarray(d.train), jnp.asarray(d.cats)


def _fit(cfg, y, cats, steps=STEPS):
    n = y.shape[0]
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    frozen = frozen_param_groups(cfg)
    mask = jnp.ones(y.shape, jnp.float32)
    step = make_step_fn(cfg, AdamConfig(lr=1e-3), y, cats, mask, frozen=frozen)
    opt = adam_init(split_frozen(params, frozen)[0])
    losses = []
    for k in range(steps):
        idx = (jnp.arange(BATCH) + BATCH * k) % n
        params, opt, loss = step(params, opt, idx)
    losses.append(float(loss))
    return params, opt, losses


def test_compute_dtype_property():
    cfg = make_config("quarterly")
    assert cfg.compute_dtype == jnp.dtype(jnp.float32)
    assert dataclasses.replace(cfg, precision="bf16").compute_dtype \
        == jnp.dtype(jnp.bfloat16)
    with pytest.raises(ValueError, match="precision policy"):
        _ = dataclasses.replace(cfg, precision="fp16").compute_dtype


@pytest.mark.parametrize("head", sorted(available_heads()))
def test_bf16_fit_tracks_fp32_trajectory(head):
    y, cats = _data()
    cfg32 = make_config("quarterly", head=head)
    cfg16 = dataclasses.replace(cfg32, precision="bf16")
    _, _, l32 = _fit(cfg32, y, cats)
    _, _, l16 = _fit(cfg16, y, cats)
    assert np.isfinite(l16).all()
    # equal-quality, not equal-bits: the 12-step loss must track fp32
    np.testing.assert_allclose(l16, l32, rtol=0.05)


@pytest.mark.parametrize("head", sorted(available_heads()))
def test_bf16_predict_and_stats_finite_and_close(head):
    y, cats = _data()
    cfg32 = make_config("quarterly", head=head)
    cfg16 = dataclasses.replace(cfg32, precision="bf16")
    params = esrnn_init(jax.random.PRNGKey(0), cfg32, y.shape[0])
    fc32 = np.asarray(esrnn_forecast_fn(cfg32, params, y, cats))
    fc16 = np.asarray(esrnn_forecast_fn(cfg16, params, y, cats))
    assert fc16.dtype == np.float32          # readout re-emits fp32
    assert np.isfinite(fc16).all()
    np.testing.assert_allclose(fc16, fc32, rtol=0.05, atol=1e-3)
    mean, sigma = esrnn_predict_stats(cfg16, params, y, cats)
    assert np.isfinite(np.asarray(mean)).all()
    assert np.isfinite(np.asarray(sigma)).all()


def test_bf16_state_stays_fp32_through_training():
    """The fp32-accumulation half: table, moments, loss, master params."""
    y, cats = _data()
    cfg = make_config("quarterly", precision="bf16")
    params, opt, _ = _fit(cfg, y, cats, steps=4)
    assert all(jnp.dtype(l.dtype) == jnp.float32
               for l in jax.tree_util.tree_leaves(params["hw"]))
    # master copies of the shared weights stay fp32 (cast to bf16 at apply)
    assert all(jnp.dtype(l.dtype) == jnp.float32
               for l in jax.tree_util.tree_leaves(params)
               if jnp.issubdtype(l.dtype, jnp.floating))
    assert all(jnp.dtype(l.dtype) == jnp.float32
               for k in ("mu", "nu")
               for l in jax.tree_util.tree_leaves(opt[k]))
    from repro.core.esrnn import gather_series

    idx = jnp.arange(8)
    loss = esrnn_loss_fn(cfg, gather_series(params, idx), y[:8], cats[:8],
                         jnp.ones(y[:8].shape, jnp.float32))
    assert loss.dtype == jnp.float32


def test_bf16_gradients_arrive_fp32():
    """Grads flow through the policy cast back to the fp32 master leaves."""
    from repro.core.esrnn import gather_series

    y, cats = _data()
    cfg = make_config("quarterly", precision="bf16")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    idx = jnp.arange(8)
    g = jax.grad(lambda p: esrnn_loss_fn(
        cfg, gather_series(p, idx), y[:8], cats[:8],
        jnp.ones(y[:8].shape, jnp.float32)))(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(jnp.dtype(l.dtype) == jnp.float32 for l in leaves
               if jnp.issubdtype(l.dtype, jnp.floating))
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)


def test_spec_routes_precision_override():
    from repro.forecast.spec import get_smoke_spec

    spec = get_smoke_spec("esrnn-quarterly").replace(precision="bf16")
    assert spec.model.precision == "bf16"
    assert spec.model.compute_dtype == jnp.dtype(jnp.bfloat16)


def test_bf16_backtest_finite():
    from repro.core.esrnn import esrnn_forecast_at_fn

    y, cats = _data()
    cfg = make_config("quarterly", precision="bf16")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    t = y.shape[1]
    origins = (t - 2 * cfg.output_size, t - cfg.output_size)
    fc = np.asarray(esrnn_forecast_at_fn(cfg, params, y, cats, origins))
    assert np.isfinite(fc).all()
