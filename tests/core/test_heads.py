"""The pluggable head registry (repro.core.heads).

Four families of guarantees:

1. **Registry contract** -- lookup errors name the available heads; every
   head builds its declared param groups and nothing else.
2. **lstm golden** -- the registered lstm head IS the pre-registry math:
   init and apply are pinned bit-for-bit against frozen in-file copies of
   the old ``esrnn_init`` head block and ``forward.rnn_head`` (the broader
   pre-PR5 goldens in ``test_forward.py`` cover the full loss/forecast).
3. **esn frozen reservoir** -- a real fit moves the readout and the HW
   table while every reservoir leaf stays bit-identical, and the loss
   still decreases.
4. **ssm causality** -- the SSD-scan head keeps the rolling-origin
   contract of the unified forward pass (tolerance: the chunk partition
   q = min(32, P) can differ between the full and the truncated pass).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heads as H
from repro.core.drnn import drnn_apply, drnn_init
from repro.core.esrnn import (
    esrnn_forecast, esrnn_forecast_at, esrnn_init, esrnn_loss, make_config,
)
from repro.core.forward import features, input_windows, smooth


# ---------------------------------------------------------------------------
# Frozen pre-registry reference (the old esrnn_init head block + rnn_head)
# ---------------------------------------------------------------------------


def _ref_init(key, cfg):
    rnn_key, head_key1, head_key2 = jax.random.split(key, 3)
    feat = cfg.input_size + cfg.n_categories
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
    params = {
        "rnn": drnn_init(rnn_key, feat, cfg.hidden_size, cfg.dilations,
                         cfg.jdtype),
        "head": {
            "dense_w": (jax.random.uniform(
                head_key1, (cfg.hidden_size, cfg.hidden_size), jnp.float32,
                -1, 1) * scale).astype(cfg.jdtype),
            "dense_b": jnp.zeros((cfg.hidden_size,), cfg.jdtype),
            "out_w": (jax.random.uniform(
                head_key2, (cfg.hidden_size, cfg.output_size), jnp.float32,
                -1, 1) * scale).astype(cfg.jdtype),
            "out_b": jnp.zeros((cfg.output_size,), cfg.jdtype),
        },
    }
    if cfg.attention:
        ka, kb, kc = jax.random.split(head_key1, 3)
        h = cfg.hidden_size
        params["attn"] = {
            "wq": (jax.random.normal(ka, (h, h)) * scale).astype(cfg.jdtype),
            "wk": (jax.random.normal(kb, (h, h)) * scale).astype(cfg.jdtype),
            "wv": (jax.random.normal(kc, (h, h)) * scale).astype(cfg.jdtype),
        }
    return params


def _ref_apply(cfg, params, feats):
    hid, c_sq = drnn_apply(
        params["rnn"], feats, dilations=cfg.dilations,
        use_pallas=cfg.use_pallas)
    if cfg.attention:
        ap = params["attn"]
        q = hid @ ap["wq"]
        k = hid @ ap["wk"]
        v = hid @ ap["wv"]
        s = jnp.einsum("nph,nqh->npq", q, k) / jnp.sqrt(
            jnp.asarray(cfg.hidden_size, jnp.float32)).astype(hid.dtype)
        p_idx = jnp.arange(hid.shape[1])
        mask = p_idx[:, None] >= p_idx[None, :]
        s = jnp.where(mask[None], s.astype(jnp.float32), -jnp.inf)
        hid = hid + jnp.einsum(
            "npq,nqh->nph", jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
    head = params["head"]
    z = jnp.tanh(hid @ head["dense_w"] + head["dense_b"])
    return z @ head["out_w"] + head["out_b"], c_sq


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(13)
    n, t = 5, 48
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.3, (n, t))) + 1, jnp.float32)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    return y, cats


def _feats(cfg, params, y, cats):
    levels, seas = smooth(cfg, params, y)
    x_in, _pos = input_windows(cfg, y, levels, seas)
    return features(x_in, cats)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_three_heads_registered():
    assert H.available_heads() == ("esn", "lstm", "ssm")


def test_unknown_head_error_names_the_available_ones():
    with pytest.raises(KeyError, match=r"tcn.*esn.*lstm.*ssm"):
        H.get_head("tcn")


def test_frozen_declarations():
    assert H.frozen_param_groups(make_config("quarterly")) == frozenset()
    assert H.frozen_param_groups(
        make_config("quarterly", head="esn")) == frozenset({"rnn"})
    assert H.frozen_param_groups(
        make_config("quarterly", head="ssm")) == frozenset()


@pytest.mark.parametrize("head,keys", [
    ("lstm", {"hw", "rnn", "head"}),
    ("esn", {"hw", "rnn", "head"}),
    ("ssm", {"hw", "ssm", "head"}),
])
def test_param_groups_per_head(head, keys):
    cfg = make_config("quarterly", hidden_size=8, head=head)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, 4)
    assert set(params) == keys


def test_lstm_attention_adds_the_attn_group():
    cfg = make_config("quarterly", hidden_size=8, attention=True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, 4)
    assert set(params) == {"hw", "rnn", "head", "attn"}


# ---------------------------------------------------------------------------
# lstm golden: the registry moved code, it must not move numbers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attention", [False, True])
def test_lstm_init_bit_for_bit_vs_pre_registry(attention):
    cfg = make_config("quarterly", hidden_size=8, attention=attention)
    key = jax.random.PRNGKey(7)
    new = H.lstm_head_init(cfg, key)
    old = _ref_init(key, cfg)
    assert set(new) == set(old)
    for a, b in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("attention", [False, True])
def test_lstm_apply_bit_for_bit_vs_pre_registry(batch, attention):
    y, cats = batch
    cfg = make_config("quarterly", hidden_size=8, attention=attention)
    params = esrnn_init(jax.random.PRNGKey(3), cfg, y.shape[0])
    feats = _feats(cfg, params, y, cats)
    new_y, new_c = H.lstm_head_apply(cfg, params, feats)
    old_y, old_c = _ref_apply(cfg, params, feats)
    np.testing.assert_array_equal(np.asarray(new_y), np.asarray(old_y))
    assert float(new_c) == float(old_c)


def test_esn_forward_math_is_lstm_without_attention(batch):
    """Same init key, attention off: the two heads' forward passes agree
    exactly -- esn differs from lstm only in what trains."""
    y, cats = batch
    lo = esrnn_loss(make_config("quarterly", hidden_size=8),
                    esrnn_init(jax.random.PRNGKey(5),
                               make_config("quarterly", hidden_size=8),
                               y.shape[0]), y, cats)
    cfg_esn = make_config("quarterly", hidden_size=8, head="esn")
    le = esrnn_loss(cfg_esn,
                    esrnn_init(jax.random.PRNGKey(5), cfg_esn, y.shape[0]),
                    y, cats)
    assert float(lo) == float(le)


# ---------------------------------------------------------------------------
# Every head runs the whole core surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("head", ["lstm", "esn", "ssm"])
def test_loss_and_forecast_finite_for_every_head(batch, head):
    y, cats = batch
    cfg = make_config("quarterly", hidden_size=8, head=head)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    loss = esrnn_loss(cfg, params, y, cats)
    assert np.isfinite(float(loss))
    fc = np.asarray(esrnn_forecast(cfg, params, y, cats))
    assert fc.shape == (y.shape[0], cfg.output_size)
    assert np.isfinite(fc).all() and (fc > 0).all()


@pytest.mark.parametrize("head", ["lstm", "esn", "ssm"])
def test_rolling_origin_parity_per_head(batch, head):
    """forecast-at-origin off the full pass == truncated re-run.

    lstm/esn are strictly causal step recurrences; the ssm head's SSD
    chunk partition q = min(32, P) differs between the full and truncated
    pass, so exactness holds only to numerical tolerance there.
    """
    y, cats = batch
    cfg = make_config("quarterly", hidden_size=8, head=head)
    params = esrnn_init(jax.random.PRNGKey(1), cfg, y.shape[0])
    o = 30
    fa = esrnn_forecast_at(cfg, params, y, cats, (o,))
    trunc = esrnn_forecast(cfg, params, y[:, :o], cats)
    np.testing.assert_allclose(np.asarray(fa[:, 0]), np.asarray(trunc),
                               rtol=1e-5, atol=1e-6)


def test_heads_produce_distinct_forecasts(batch):
    y, cats = batch
    fcs = {}
    for head in ("lstm", "ssm"):
        cfg = make_config("quarterly", hidden_size=8, head=head)
        params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
        fcs[head] = np.asarray(esrnn_forecast(cfg, params, y, cats))
    assert not np.array_equal(fcs["lstm"], fcs["ssm"])


def test_ssm_dims_split_every_preset_width():
    for hid, want in [(8, (1, 8)), (30, (3, 10)), (40, (5, 8)),
                      (50, (5, 10))]:
        cfg = make_config("quarterly", hidden_size=hid)
        assert H.ssm_dims(cfg) == want
        nh, hd = H.ssm_dims(cfg)
        assert nh * hd == hid and hd >= 8


# ---------------------------------------------------------------------------
# esn: the reservoir never moves under a real fit, the loss still drops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparse_adam", [False, True])
def test_esn_reservoir_frozen_through_fit(sparse_adam):
    from repro.forecast import ESRNNForecaster, get_smoke_spec

    f = ESRNNForecaster(get_smoke_spec(
        "esn-quarterly", data_seed=2, n_steps=12, sparse_adam=sparse_adam))
    data = f.make_data()
    f.init_params(data.n_series)
    before = jax.tree_util.tree_map(np.asarray, f.params_["rnn"])
    head_before = np.asarray(f.params_["head"]["out_w"])
    f.fit(data)
    after = f.params_["rnn"]
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the trainable groups moved and training made progress
    assert not np.array_equal(head_before,
                              np.asarray(f.params_["head"]["out_w"]))
    losses = f.history_["loss"]
    assert losses[-1] < losses[0]


def test_lstm_trains_every_group():
    """Control for the invariance test: with the default head the same fit
    DOES move the recurrent stack."""
    from repro.forecast import ESRNNForecaster, get_smoke_spec

    f = ESRNNForecaster(get_smoke_spec(
        "esrnn-quarterly", data_seed=2, n_steps=6))
    data = f.make_data()
    f.init_params(data.n_series)
    before = jax.tree_util.tree_map(np.asarray, f.params_["rnn"])
    f.fit(data)
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(f.params_["rnn"])))
    assert moved
