"""ES-RNN hybrid model tests: vectorization equivalence, shapes, penalties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import (
    esrnn_forecast, esrnn_init, esrnn_loss, esrnn_loss_and_grad,
    esrnn_loss_loop_reference, make_config,
)
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate


@pytest.fixture(scope="module")
def quarterly():
    data = prepare(generate("quarterly", scale=0.002, seed=7))
    cfg = make_config("quarterly")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, data.n_series)
    return cfg, params, data


def test_batched_equals_per_series_loop(quarterly):
    cfg, params, data = quarterly
    n = min(6, data.n_series)
    pb = {"hw": jax.tree_util.tree_map(lambda a: a[:n], params["hw"]),
          "rnn": params["rnn"], "head": params["head"]}
    y = jnp.asarray(data.train[:n])
    c = jnp.asarray(data.cats[:n])
    batched = esrnn_loss(cfg, pb, y, c)
    looped = esrnn_loss_loop_reference(cfg, pb, y, c)
    np.testing.assert_allclose(batched, looped, rtol=1e-5)


def test_forecast_shape_and_positive(quarterly):
    cfg, params, data = quarterly
    fc = esrnn_forecast(cfg, params, jnp.asarray(data.train), jnp.asarray(data.cats))
    assert fc.shape == (data.n_series, cfg.output_size)
    assert bool(jnp.isfinite(fc).all())
    assert bool((fc > 0).all())  # multiplicative model on positive data


def test_grads_cover_all_param_groups(quarterly):
    cfg, params, data = quarterly
    y = jnp.asarray(data.train)
    c = jnp.asarray(data.cats)
    _, grads = esrnn_loss_and_grad(cfg, params, y, c)
    flat = jax.tree_util.tree_leaves_with_path(grads)
    for path, g in flat:
        assert bool(jnp.isfinite(g).all()), f"non-finite grad at {path}"
    assert bool(jnp.any(grads["hw"].alpha_logit != 0))
    assert bool(jnp.any(grads["head"]["out_w"] != 0))


def test_penalties_increase_loss(quarterly):
    cfg, params, data = quarterly
    y = jnp.asarray(data.train[:8])
    c = jnp.asarray(data.cats[:8])
    pb = {"hw": jax.tree_util.tree_map(lambda a: a[:8], params["hw"]),
          "rnn": params["rnn"], "head": params["head"]}
    base = float(esrnn_loss(cfg, pb, y, c))
    cfg_pen = make_config("quarterly", level_penalty=10.0, cstate_penalty=1.0)
    with_pen = float(esrnn_loss(cfg_pen, pb, y, c))
    assert with_pen >= base


def test_hourly_dual_seasonality_config():
    cfg = make_config("hourly")
    assert cfg.seasonality == 24 and cfg.seasonality2 == 168
    n, t = 3, 24 * 16
    rng = np.random.default_rng(0)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    hours = np.arange(t)
    y = (50 + 10 * np.sin(hours * 2 * np.pi / 24)
         + 5 * np.sin(hours * 2 * np.pi / 168)
         + rng.normal(0, 1, (n, t))).astype(np.float32)
    y = np.abs(y) + 1
    loss = esrnn_loss(cfg, params, jnp.asarray(y), jnp.zeros((n, 6), jnp.float32))
    assert bool(jnp.isfinite(loss))


def test_observation_mask_excludes_padded_windows(quarterly):
    """Section 8.1: left-padded positions must not contribute to the loss."""
    cfg, params, data = quarterly
    n = 4
    pb = {"hw": jax.tree_util.tree_map(lambda a: a[:n], params["hw"]),
          "rnn": params["rnn"], "head": params["head"]}
    y = np.asarray(data.train[:n]).copy()
    t = y.shape[1]
    pad = t // 2
    y[:, :pad] = y[:, pad:pad + 1]  # fake left-padding (constant fill)
    mask = np.ones_like(y)
    mask[:, :pad] = 0.0
    c = jnp.asarray(data.cats[:n])
    yj = jnp.asarray(y)
    masked = esrnn_loss(cfg, pb, yj, c, jnp.asarray(mask))
    unmasked = esrnn_loss(cfg, pb, yj, c)
    assert bool(jnp.isfinite(masked))
    assert float(masked) != float(unmasked)  # padding excluded vs trained-on
    # all-ones mask is bit-identical to no mask (the equalized default)
    ones = esrnn_loss(cfg, pb, yj, c, jnp.ones_like(yj))
    assert float(ones) == float(unmasked)


def test_attentive_variant_trains():
    """Section 7/8.5: the attentive head (the piece whose absence the paper
    blamed for its yearly deficit). One train step must run + improve loss
    locally; the accuracy effect is recorded in EXPERIMENTS.md."""
    import numpy as np

    cfg = make_config("yearly", attention=True)
    rng = np.random.default_rng(0)
    n, t = 6, 30
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.4, (n, t))) + 1, jnp.float32)
    c = jnp.zeros((n, 6), jnp.float32)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    assert "attn" in params
    loss, grads = esrnn_loss_and_grad(cfg, params, y, c)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.any(grads["attn"]["wq"] != 0))
    fc = esrnn_forecast(cfg, params, y, c)
    assert bool(jnp.isfinite(fc).all())
