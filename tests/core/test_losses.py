"""Loss/metric properties (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.losses import (
    cstate_penalty, level_variability_penalty, mase, owa, pinball_loss, smape,
)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), n=st.integers(1, 40))
def test_pinball_median_is_half_mae(seed, n):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    t = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    np.testing.assert_allclose(
        pinball_loss(p, t, tau=0.5),
        0.5 * jnp.mean(jnp.abs(p - t)), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), tau=st.floats(0.05, 0.95))
def test_pinball_nonnegative_and_zero_at_target(seed, tau):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(0, 1, 17), jnp.float32)
    assert float(pinball_loss(t, t, tau=tau)) == 0.0
    p = jnp.asarray(rng.normal(0, 1, 17), jnp.float32)
    assert float(pinball_loss(p, t, tau=tau)) >= 0.0


def test_pinball_asymmetry():
    """tau > 0.5 punishes under-prediction more."""
    t = jnp.zeros(5)
    under = jnp.full(5, -1.0)
    over = jnp.full(5, 1.0)
    assert float(pinball_loss(under, t, 0.9)) > float(pinball_loss(over, t, 0.9))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_smape_bounds(seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(np.abs(rng.normal(5, 2, (3, 8))), jnp.float32)
    t = jnp.asarray(np.abs(rng.normal(5, 2, (3, 8))), jnp.float32)
    s = float(smape(p, t))
    assert 0.0 <= s <= 200.0
    assert float(smape(t, t)) == 0.0


def test_mase_scaled_by_naive():
    """Seasonal-naive forecast on the training tail has MASE ~ 1."""
    rng = np.random.default_rng(0)
    m, t, h = 4, 48, 8
    y = np.abs(rng.lognormal(2, 0.3, (5, t + h))).astype(np.float32)
    insample, target = y[:, :t], y[:, t:]
    naive = y[:, t - m : t - m + h]  # season-ago values
    val = float(mase(jnp.asarray(naive), jnp.asarray(target), jnp.asarray(insample), m))
    assert 0.2 < val < 5.0


def test_owa_identity():
    assert float(owa(10.0, 1.0, 10.0, 1.0)) == 1.0
    assert float(owa(5.0, 0.5, 10.0, 1.0)) == 0.5


def test_level_penalty_zero_for_exponential_level():
    """Constant growth rate (log-linear level) has zero variability."""
    lv = jnp.exp(jnp.linspace(0, 3, 50))[None, :]
    assert float(level_variability_penalty(lv, 1.0)) < 1e-8
    rng = np.random.default_rng(0)
    bumpy = jnp.asarray(np.exp(rng.normal(0, 1, (1, 50))), jnp.float32)
    assert float(level_variability_penalty(bumpy, 1.0)) > 1e-3


def test_cstate_penalty_passthrough():
    assert float(cstate_penalty(jnp.asarray(2.0), 0.5)) == 1.0
