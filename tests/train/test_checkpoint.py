"""Fault-tolerance tests: atomic checkpoints, bit-exact resume, retention."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.esrnn import make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn


def test_save_restore_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    state = {"a": jnp.arange(5, dtype=jnp.float32),
             "b": {"c": jnp.ones((2, 3), jnp.bfloat16)},
             "step": jnp.asarray(7)}
    ckpt.save(7, state, metric=1.5)
    step, restored = ckpt.restore(state)
    assert step == 7
    for x, y in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_atomic_no_tmp_left(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros(3)})
    names = os.listdir(tmp_path)
    assert not any(".tmp" in n for n in names)


def test_retention_keeps_best(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for step, metric in [(1, 5.0), (2, 1.0), (3, 3.0), (4, 4.0), (5, 6.0)]:
        ckpt.save(step, {"x": jnp.full(2, step)}, metric=metric)
    steps = ckpt.all_steps()
    assert 2 in steps                      # best metric retained
    assert steps[-1] == 5                  # latest retained
    assert len(steps) <= 3
    assert ckpt.best_step() == 2


def test_structure_mismatch_rejected(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore({"y": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore({"x": jnp.zeros(4)})


def test_training_resume_bit_exact(tmp_path):
    """Train 20 steps straight vs 10 + restart + 10: identical params."""
    data = prepare(generate("quarterly", scale=0.002, seed=3))
    model = make_config("quarterly")

    base = dict(batch_size=8, lr=1e-3, eval_every=1000, ckpt_every=10, seed=5)
    out_a = train_esrnn(model, data,
                        TrainConfig(n_steps=20, ckpt_dir=None, **base))

    d = str(tmp_path / "resume")
    train_esrnn(model, data, TrainConfig(n_steps=10, ckpt_dir=d, **base))
    out_b = train_esrnn(model, data, TrainConfig(n_steps=20, ckpt_dir=d, **base))
    assert out_b["resumed_from"] == 10

    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(out_a["params"]),
        jax.tree_util.tree_leaves_with_path(out_b["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(pa))
