"""Fault-tolerance tests: atomic checkpoints, bit-exact resume, retention."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.esrnn import make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn


def test_save_restore_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    state = {"a": jnp.arange(5, dtype=jnp.float32),
             "b": {"c": jnp.ones((2, 3), jnp.bfloat16)},
             "step": jnp.asarray(7)}
    ckpt.save(7, state, metric=1.5)
    step, restored = ckpt.restore(state)
    assert step == 7
    for x, y in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_atomic_no_tmp_left(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros(3)})
    names = os.listdir(tmp_path)
    assert not any(".tmp" in n for n in names)


def test_retention_keeps_best(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for step, metric in [(1, 5.0), (2, 1.0), (3, 3.0), (4, 4.0), (5, 6.0)]:
        ckpt.save(step, {"x": jnp.full(2, step)}, metric=metric)
    steps = ckpt.all_steps()
    assert 2 in steps                      # best metric retained
    assert steps[-1] == 5                  # latest retained
    assert len(steps) <= 3
    assert ckpt.best_step() == 2


def test_structure_mismatch_rejected(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore({"y": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore({"x": jnp.zeros(4)})


def test_training_resume_bit_exact(tmp_path):
    """Train 20 steps straight vs 10 + restart + 10: identical params."""
    data = prepare(generate("quarterly", scale=0.002, seed=3))
    model = make_config("quarterly")

    base = dict(batch_size=8, lr=1e-3, eval_every=1000, ckpt_every=10, seed=5)
    out_a = train_esrnn(model, data,
                        TrainConfig(n_steps=20, ckpt_dir=None, **base))

    d = str(tmp_path / "resume")
    train_esrnn(model, data, TrainConfig(n_steps=10, ckpt_dir=d, **base))
    out_b = train_esrnn(model, data, TrainConfig(n_steps=20, ckpt_dir=d, **base))
    assert out_b["resumed_from"] == 10

    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(out_a["params"]),
        jax.tree_util.tree_leaves_with_path(out_b["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(pa))


def _table_state():
    """A sparse-Adam-shaped state with a 10-row per-series table."""
    return {
        "params": {"hw": {"a": jnp.arange(10.0),
                          "b": jnp.ones((10, 3)) * jnp.arange(10.0)[:, None]},
                   "rnn": jnp.arange(5.0)},
        "opt": {"mu": {"hw": {"a": jnp.full(10, 2.0),
                              "b": jnp.zeros((10, 3))},
                       "rnn": jnp.zeros(5)},
                "t_hw": jnp.arange(10, dtype=jnp.int32),
                "step": jnp.asarray(4, jnp.int32)},
    }


def _is_table(path):
    return any(getattr(e, "key", getattr(e, "name", None)) in ("hw", "t_hw")
               for e in path)


def test_shard_rows_roundtrip_both_directions(tmp_path):
    """Row-sharded and flat layouts restore into each other bit-for-bit."""
    state = _table_state()
    sharded = Checkpointer(str(tmp_path / "sharded"))
    flat = Checkpointer(str(tmp_path / "flat"))
    sharded.save(1, state, shard_rows=4)   # 10 rows -> shards of 4, 4, 2
    flat.save(1, state)
    files = os.listdir(os.path.join(str(tmp_path / "sharded"), "step_1"))
    # every table leaf (hw.a, hw.b, mu.hw.a, mu.hw.b, t_hw) split into 3
    # independent shard files; shared leaves and the step scalar stay flat
    assert sum(1 for f in files if ".shard_" in f) == 5 * 3
    assert not any(f == "leaf_0.bin" and ".shard_" in f for f in files)
    assert not any(".shard_" in f for f in
                   os.listdir(os.path.join(str(tmp_path / "flat"), "step_1")))
    for src in (sharded, flat):               # either layout, same answer
        step, restored = src.restore(state)
        assert step == 1
        for (pa, a), b in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_leaves(restored),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=str(pa))


def test_shard_rows_larger_than_table_stays_flat(tmp_path):
    """shard_rows >= n_rows writes plain leaf files (no degenerate shards)."""
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _table_state(), shard_rows=64)
    assert not any(".shard_" in f
                   for f in os.listdir(os.path.join(str(tmp_path), "step_1")))
    _, restored = ckpt.restore(_table_state())
    np.testing.assert_array_equal(np.asarray(restored["params"]["hw"]["a"]),
                                  np.arange(10.0))


def test_host_paths_restore_gives_writable_numpy(tmp_path):
    """Table leaves come back as writable host numpy under host_paths --
    the chunked resume adopts them straight into its HostStateTable --
    while shared leaves still land on device."""
    state = _table_state()
    for name, kw in (("sharded", {"shard_rows": 4}), ("flat", {})):
        ckpt = Checkpointer(str(tmp_path / name))
        ckpt.save(1, state, **kw)
        _, r = ckpt.restore(state, host_paths=_is_table)
        for leaf in jax.tree_util.tree_leaves((r["params"]["hw"],
                                               r["opt"]["mu"]["hw"],
                                               r["opt"]["t_hw"])):
            assert isinstance(leaf, np.ndarray) and leaf.flags.writeable, name
        r["params"]["hw"]["a"][0] = 99.0      # absorb-writability, in place
        assert not isinstance(r["params"]["rnn"], np.ndarray)
        np.testing.assert_array_equal(np.asarray(r["opt"]["t_hw"]),
                                      np.arange(10))
