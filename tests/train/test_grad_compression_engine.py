"""Engine-level integration of error-feedback int8 gradient compression.

``make_step_fn(compress=True)`` / ``TrainConfig.compress_grads`` route the
shared-weight gradients through ``compress_tree_int8`` each step, carrying
the residual alongside the Adam state. (Unit-level quantization invariants
live in test_grad_compression.py, which needs hypothesis.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.grad_compression import init_error_state

def _engine_setup():
    from repro.core.esrnn import esrnn_init, make_config
    from repro.core.heads import frozen_param_groups
    from repro.data.pipeline import prepare
    from repro.data.synthetic_m4 import generate
    from repro.train.engine import make_step_fn, split_frozen
    from repro.train.optimizer import AdamConfig, adam_init

    d = prepare(generate("quarterly", scale=0.002, seed=1))
    y, cats = jnp.asarray(d.train), jnp.asarray(d.cats)
    cfg = make_config("quarterly")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    frozen = frozen_param_groups(cfg)
    mask = jnp.ones(y.shape, jnp.float32)
    mk = lambda compress: make_step_fn(
        cfg, AdamConfig(lr=1e-3), y, cats, mask, frozen=frozen,
        compress=compress)
    opt = adam_init(split_frozen(params, frozen)[0])
    return params, opt, mk, y.shape[0]


def test_engine_compress_step_trains_and_carries_error_state():
    """Compressed steps train (loss drops), err state is live f32, and the
    per-series HW table is untouched by compression (exact-gradient path)."""
    params, adam0, mk, n = _engine_setup()
    step = mk(compress=True)
    opt = (adam0, init_error_state(
        {k: v for k, v in adam0["mu"].items() if k != "hw"}))
    losses = []
    idx = jnp.arange(16) % n  # fixed batch: losses are directly comparable
    for _ in range(8):
        params, opt, loss = step(params, opt, idx)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    adam_state, err = opt
    err_leaves = jax.tree_util.tree_leaves(err)
    assert all(l.dtype == jnp.float32 for l in err_leaves)
    # after 8 quantized steps the residual must have accumulated something
    assert any(float(jnp.abs(l).max()) > 0 for l in err_leaves)
    assert "hw" not in err  # per-series table never enters the collective


def test_engine_compress_tracks_uncompressed_trajectory():
    """int8 + error feedback stays close to the exact dense trajectory."""
    params0, adam0, mk, n = _engine_setup()
    step_c = mk(compress=True)
    step_d = mk(compress=False)
    pc, oc = params0, (adam0, init_error_state(
        {k: v for k, v in adam0["mu"].items() if k != "hw"}))
    pd, od = params0, adam0
    for k in range(8):
        idx = (jnp.arange(16) + 16 * k) % n
        pc, oc, lc = step_c(pc, oc, idx)
        pd, od, ld = step_d(pd, od, idx)
    np.testing.assert_allclose(float(lc), float(ld), rtol=0.05)


def test_engine_sparse_plus_compress_raises():
    from repro.core.esrnn import make_config
    from repro.train.engine import make_step_fn
    from repro.train.optimizer import AdamConfig

    cfg = make_config("quarterly")
    y = jnp.ones((4, 20))
    with pytest.raises(ValueError, match="dense optimizer"):
        make_step_fn(cfg, AdamConfig(lr=1e-3), y,
                     jnp.zeros((4, cfg.n_categories)),
                     jnp.ones_like(y), frozen=frozenset(),
                     sparse=True, compress=True)


def test_trainer_config_compress_raises_with_sparse_adam():
    from repro.train.trainer import TrainConfig

    cfg = TrainConfig(sparse_adam=True, compress_grads=True)
    assert cfg.compress_grads and cfg.sparse_adam  # construction is fine;
    # the trainer rejects the combination at fit time (engine test above
    # covers the step-level guard)
