"""Error-feedback gradient compression invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.train.grad_compression import (
    compress_tree_int8, init_error_state, int8_compress, int8_decompress,
    topk_compress,
)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), n=st.integers(2, 500))
def test_int8_error_feedback_is_lossless_in_total(seed, n):
    """g + err_in == deq + err_out (the residual carries all the loss)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    err = jnp.asarray(rng.normal(0, 0.1, n), jnp.float32)
    q, scale, new_err = int8_compress(g, err, jax.random.PRNGKey(seed))
    deq = int8_decompress(q, scale)
    np.testing.assert_allclose(np.asarray(g + err), np.asarray(deq + new_err),
                               rtol=1e-5, atol=1e-5)
    assert q.dtype == jnp.int8


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_int8_quantization_error_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, 256), jnp.float32)
    q, scale, err = int8_compress(g, jnp.zeros(256), jax.random.PRNGKey(0))
    assert float(jnp.abs(err).max()) <= float(scale) + 1e-6


def test_topk_sparsity_and_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, 1000), jnp.float32)
    sparse, err = topk_compress(g, jnp.zeros(1000), k_frac=0.1)
    nnz = int(jnp.sum(sparse != 0))
    assert nnz <= 120  # ~10% (ties tolerated)
    np.testing.assert_allclose(np.asarray(sparse + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    # kept entries are the largest
    kept_min = float(jnp.abs(sparse[sparse != 0]).min())
    dropped_max = float(jnp.abs(err[sparse == 0]).max())
    assert kept_min >= dropped_max - 1e-6


def test_error_feedback_accumulates_dropped_signal():
    """A small constant gradient below threshold is eventually transmitted."""
    g = jnp.full(100, 0.01)
    g = g.at[0].set(10.0)  # one big entry hogs top-k
    err = jnp.zeros(100)
    transmitted = jnp.zeros(100)
    for _ in range(30):
        sparse, err = topk_compress(g, err, k_frac=0.02)
        transmitted = transmitted + sparse
    # entry 1 (small) must have been flushed at least once via error feedback
    assert float(transmitted[1]) > 0.0


def test_tree_compression_roundtrip():
    params = {"a": jnp.ones((4, 4)), "b": jnp.full(7, -2.0)}
    errs = init_error_state(params)
    vals, new_errs = compress_tree_int8(
        jax.tree_util.tree_map(lambda x: x * 0.5, params), errs,
        jax.random.PRNGKey(0))
    for v, e, p in zip(jax.tree_util.tree_leaves(vals),
                       jax.tree_util.tree_leaves(new_errs),
                       jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(v + e), np.asarray(p) * 0.5,
                                   rtol=1e-5, atol=1e-5)
