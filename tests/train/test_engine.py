"""Fused superstep engine: trajectory equality, boundaries, resume, compose.

The contract under test: ``scan_steps=K`` changes *when the host syncs*, not
what gets computed -- the fused ``lax.scan`` superstep walks the same loss
trajectory as the per-step loop (same step math in the same order; we assert
atol=1e-6 and observe bit-identity on CPU), eval/checkpoints fire at the
same absolute steps, and a checkpoint taken mid-run resumes onto the same
trajectory from any superstep boundary. The 8-host-device data-parallel and
``use_pallas`` variants run the same assertions through their respective
loss paths (the multi-device one in a subprocess, because XLA locks the host
device count at first init).
"""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.esrnn import make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.engine import next_boundary, segment_steps
from repro.train.trainer import TrainConfig, train_esrnn


@pytest.fixture(scope="module")
def data():
    return prepare(generate("quarterly", scale=0.002, seed=3))


@pytest.fixture(scope="module")
def mcfg():
    return make_config("quarterly", hidden_size=8)


_BASE = dict(batch_size=8, lr=1e-3, seed=5)


def _fit(mcfg, data, n_steps, **kw):
    kw = {**_BASE, "eval_every": 1000, "ckpt_every": 1000, **kw}
    hooks = kw.pop("hooks", None)
    return train_esrnn(mcfg, data, TrainConfig(n_steps=n_steps, **kw),
                       hooks=hooks)


# ---------------------------------------------------------------------------
# segment planner
# ---------------------------------------------------------------------------


def test_segment_steps_land_on_every_boundary():
    segs = list(segment_steps(0, 100, 32, 50, 30))
    ends = np.cumsum([k for _, k in segs])
    assert ends[-1] == 100
    for b in (30, 50, 60, 90, 100):            # every eval/ckpt multiple
        assert b in ends, (b, ends)
    assert all(k <= 32 for _, k in segs)
    # resume from an arbitrary step realigns with the same absolute bounds
    segs_r = list(segment_steps(37, 100, 32, 50, 30))
    assert segs_r[0] == (37, 13)               # first stop: step 50
    ends_r = 37 + np.cumsum([k for _, k in segs_r])
    assert set(ends_r) <= set(ends) | {50}


def test_next_boundary():
    assert next_boundary(0, 100, 50, 30) == 30
    assert next_boundary(30, 100, 50, 30) == 50
    assert next_boundary(99, 100, 50, 30) == 100
    assert next_boundary(0, 10, 0, 0) == 10    # disabled everys -> n_steps


# ---------------------------------------------------------------------------
# trajectory equality: fused vs per-step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scan_steps", [1, 4, 32])
def test_superstep_matches_perstep_trajectory(mcfg, data, scan_steps):
    ref = _fit(mcfg, data, 20)                 # per-step engine
    out = _fit(mcfg, data, 20, scan_steps=scan_steps)
    h_ref = np.asarray(ref["history"]["loss"])
    h = np.asarray(out["history"]["loss"])
    assert h.shape == h_ref.shape == (20,)
    np.testing.assert_allclose(h, h_ref, atol=1e-6)
    for (pa, a), b in zip(
        jax.tree_util.tree_leaves_with_path(ref["params"]),
        jax.tree_util.tree_leaves(out["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=str(pa))


def test_sparse_adam_fused_matches_perstep(mcfg, data):
    """The sparse segment optimizer is engine-invariant too."""
    ref = _fit(mcfg, data, 16, sparse_adam=True)
    out = _fit(mcfg, data, 16, sparse_adam=True, scan_steps=8)
    np.testing.assert_allclose(np.asarray(out["history"]["loss"]),
                               np.asarray(ref["history"]["loss"]), atol=1e-6)


def test_eval_fires_at_same_steps(mcfg, data):
    ref = _fit(mcfg, data, 20, eval_every=5)
    out = _fit(mcfg, data, 20, eval_every=5, scan_steps=4)
    assert [s for s, _ in ref["history"]["val_smape"]] \
        == [s for s, _ in out["history"]["val_smape"]] == [5, 10, 15, 20]
    np.testing.assert_allclose(
        [v for _, v in out["history"]["val_smape"]],
        [v for _, v in ref["history"]["val_smape"]], atol=1e-5)


def test_on_step_hook_granularity(mcfg, data):
    """Per-step: float per step. Fused: one loss array per superstep."""
    per, fused = [], []
    _fit(mcfg, data, 10,
         hooks={"on_step": lambda s, l, p: per.append((s, l))})
    _fit(mcfg, data, 10, scan_steps=4,
         hooks={"on_step": lambda s, l, p: fused.append((s, l))})
    assert [s for s, _ in per] == list(range(10))
    assert all(isinstance(l, float) for _, l in per)
    assert [s for s, _ in fused] == [3, 7, 9]  # superstep boundaries - 1
    assert [np.asarray(l).shape for _, l in fused] == [(4,), (4,), (2,)]
    np.testing.assert_allclose(
        np.concatenate([np.atleast_1d(l) for _, l in fused]),
        np.asarray([l for _, l in per]), atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint -> resume mid-run -> trajectory match
# ---------------------------------------------------------------------------


def test_fused_resume_mid_run_matches(mcfg, data, tmp_path):
    """20 fused steps straight vs 12 + restart + 8: same trajectory/params.

    ckpt_every=6 makes the superstep segments land on 6/12/18 (not scan_steps
    multiples), and the restart resumes from step 12 -- a mid-run superstep
    boundary -- through the stateless schedule.
    """
    kw = dict(scan_steps=4, ckpt_every=6)
    ref = _fit(mcfg, data, 20, **kw)

    d = str(tmp_path / "fused-resume")
    first = _fit(mcfg, data, 12, ckpt_dir=d, **kw)
    assert len(first["history"]["loss"]) == 12
    out = _fit(mcfg, data, 20, ckpt_dir=d, **kw)
    assert out["resumed_from"] == 12
    np.testing.assert_allclose(np.asarray(out["history"]["loss"]),
                               np.asarray(ref["history"]["loss"])[12:],
                               atol=1e-6)
    for (pa, a), b in zip(
        jax.tree_util.tree_leaves_with_path(ref["params"]),
        jax.tree_util.tree_leaves(out["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(pa))


def test_resume_rejects_flipped_sparse_adam(mcfg, data, tmp_path):
    """Dense and sparse Adam states are not interchangeable: flipping
    sparse_adam across a resume must fail with an actionable error."""
    d = str(tmp_path / "sparse-flip")
    _fit(mcfg, data, 8, ckpt_dir=d, ckpt_every=4, sparse_adam=True)
    with pytest.raises(ValueError, match="sparse_adam"):
        _fit(mcfg, data, 16, ckpt_dir=d, ckpt_every=4, sparse_adam=False)


def test_perstep_ckpt_resumes_into_fused_engine(mcfg, data, tmp_path):
    """Engines share schedule + state format: ckpt under one, resume under
    the other, land on the straight-run trajectory."""
    ref = _fit(mcfg, data, 20, scan_steps=4, ckpt_every=10)
    d = str(tmp_path / "cross-engine")
    _fit(mcfg, data, 10, ckpt_dir=d, ckpt_every=10)          # per-step
    out = _fit(mcfg, data, 20, ckpt_dir=d, ckpt_every=10, scan_steps=4)
    assert out["resumed_from"] == 10
    np.testing.assert_allclose(np.asarray(out["history"]["loss"]),
                               np.asarray(ref["history"]["loss"])[10:],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# composition: use_pallas in-process, 8 host devices in a subprocess
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("scan_steps", [4, 32])
def test_superstep_matches_perstep_with_pallas(data, scan_steps):
    cfg_k = make_config("quarterly", hidden_size=8, use_pallas=True)
    ref = _fit(cfg_k, data, 12)
    out = _fit(cfg_k, data, 12, scan_steps=scan_steps)
    np.testing.assert_allclose(np.asarray(out["history"]["loss"]),
                               np.asarray(ref["history"]["loss"]), atol=1e-6)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.core.esrnn import make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn
import jax

data = prepare(generate("quarterly", scale=0.002, seed=3))
mcfg = make_config("quarterly", hidden_size=8)
base = dict(batch_size=8, lr=1e-3, eval_every=1000, ckpt_every=1000, seed=5)
out = {"devices": len(jax.devices())}

ref = train_esrnn(mcfg, data, TrainConfig(n_steps=12, **base))
h_ref = np.asarray(ref["history"]["loss"])
for scan_steps in (1, 4, 32):
    dp = train_esrnn(mcfg, data, TrainConfig(
        n_steps=12, scan_steps=scan_steps, data_parallel=8, **base))
    out[f"dp_scan{scan_steps}_absdiff"] = float(
        np.max(np.abs(np.asarray(dp["history"]["loss"]) - h_ref)))

# fused + data-parallel + pallas kernels, all at once
cfg_k = make_config("quarterly", hidden_size=8, use_pallas=True)
k = train_esrnn(cfg_k, data, TrainConfig(
    n_steps=12, scan_steps=4, data_parallel=8, **base))
out["dp_pallas_scan4_absdiff"] = float(
    np.max(np.abs(np.asarray(k["history"]["loss"]) - h_ref)))

# sparse per-series Adam composes with the series-sharded loss: the
# reference is the single-device sparse per-step run (sparse != dense by
# design, so it gets its own baseline)
ref_sp = train_esrnn(mcfg, data, TrainConfig(
    n_steps=12, sparse_adam=True, **base))
dp_sp = train_esrnn(mcfg, data, TrainConfig(
    n_steps=12, scan_steps=4, data_parallel=8, sparse_adam=True, **base))
out["dp_sparse_scan4_absdiff"] = float(np.max(np.abs(
    np.asarray(dp_sp["history"]["loss"])
    - np.asarray(ref_sp["history"]["loss"]))))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_superstep_matches_perstep_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    # fused scan around the shard_map'd loss vs single-device per-step loop:
    # the documented DP tolerance (float summation order) applies per step
    for key in ("dp_scan1_absdiff", "dp_scan4_absdiff", "dp_scan32_absdiff",
                "dp_pallas_scan4_absdiff", "dp_sparse_scan4_absdiff"):
        assert out[key] <= 1e-6, (key, out)
