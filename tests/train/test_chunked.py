"""Chunked streaming fit: out-of-core HostStateTable vs resident training.

The exactness contract of the out-of-core path: streaming row chunks of the
HW table + sparse-Adam state through the device (``TrainConfig.series_chunk``)
is a pure memory-placement change. On the same chunk-major schedule the
streamed fit must walk the device-resident reference trajectory
(``chunk_resident=True``) bit-for-bit on one backend (gated at <= 1e-6 for
cross-platform slack), resume bit-exactly from its row-sharded checkpoints,
and restore those checkpoints into resident mode and vice versa.
"""

import os

import jax
import numpy as np

from repro.core.esrnn import make_config
from repro.data.pipeline import synthetic_prepared
from repro.train.trainer import TrainConfig, train_esrnn

_MCFG = make_config("quarterly", hidden_size=8)
_N = 19


def _data(n=_N):
    return synthetic_prepared(n, seasonality=_MCFG.seasonality,
                              horizon=_MCFG.output_size, series_length=24)


def _cfg(**over):
    base = dict(batch_size=8, n_steps=24, scan_steps=4, sparse_adam=True,
                series_chunk=16, eval_every=12, ckpt_every=1000, seed=0)
    base.update(over)
    return TrainConfig(**base)


def _assert_trees_close(a, b, atol=1e-6):
    for (pa, x), y in zip(jax.tree_util.tree_flatten_with_path(a)[0],
                          jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   err_msg=str(pa))


def test_stream_matches_resident_reference():
    """Same chunk-major schedule, streamed vs full-table-on-device."""
    data = _data()
    out_s = train_esrnn(_MCFG, data, _cfg())
    out_r = train_esrnn(_MCFG, data, _cfg(chunk_resident=True))
    l_s = np.asarray(out_s["history"]["loss"], np.float64)
    l_r = np.asarray(out_r["history"]["loss"], np.float64)
    assert l_s.shape == l_r.shape == (24,)
    np.testing.assert_allclose(l_s, l_r, atol=1e-6)
    _assert_trees_close(out_s["params"], out_r["params"])
    _assert_trees_close(out_s["opt_state"], out_r["opt_state"])
    # streamed eval decomposes the same mean into chunk-local terms: equal
    # up to float summation order
    (_, vs_s), (_, vs_r) = out_s["history"]["val_smape"][-1], \
        out_r["history"]["val_smape"][-1]
    np.testing.assert_allclose(vs_s, vs_r, rtol=1e-5)
    # the streamed fit hands back a host-resident table, not device arrays
    assert all(isinstance(a, np.ndarray)
               for a in jax.tree_util.tree_leaves(out_s["params"]["hw"]))


def test_stream_resume_bit_exact(tmp_path):
    """12 + restart + 12 == 24 straight, bit-for-bit, across chunk visits."""
    data = _data()
    straight = train_esrnn(_MCFG, data, _cfg())
    d = str(tmp_path / "stream")
    train_esrnn(_MCFG, data, _cfg(n_steps=12, ckpt_dir=d))
    resumed = train_esrnn(_MCFG, data, _cfg(ckpt_dir=d))
    assert resumed["resumed_from"] == 12
    for (pa, a), b in zip(
        jax.tree_util.tree_flatten_with_path(straight["params"])[0],
        jax.tree_util.tree_leaves(resumed["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(pa))


def test_chunked_checkpoint_restores_into_resident(tmp_path):
    """Row-sharded checkpoint files -> resident-mode resume, same answer."""
    data = _data()
    d = str(tmp_path / "chunked")
    train_esrnn(_MCFG, data, _cfg(n_steps=12, ckpt_dir=d))
    step_dir = os.path.join(d, "step_12")
    assert any(".shard_" in f for f in os.listdir(step_dir))  # table sharded
    out = train_esrnn(_MCFG, data, _cfg(chunk_resident=True, ckpt_dir=d))
    assert out["resumed_from"] == 12
    ref = train_esrnn(_MCFG, data, _cfg())
    _assert_trees_close(out["params"], ref["params"])


def test_resident_checkpoint_restores_into_stream(tmp_path):
    """Unsharded (resident-written) checkpoint -> streamed resume."""
    data = _data()
    d = str(tmp_path / "resident")
    train_esrnn(_MCFG, data, _cfg(chunk_resident=True, n_steps=12, ckpt_dir=d))
    step_dir = os.path.join(d, "step_12")
    assert not any(".shard_" in f for f in os.listdir(step_dir))
    out = train_esrnn(_MCFG, data, _cfg(ckpt_dir=d))
    assert out["resumed_from"] == 12
    ref = train_esrnn(_MCFG, data, _cfg())
    _assert_trees_close(out["params"], ref["params"])


def test_chunked_requires_sparse_and_rejects_compress():
    data = _data()
    import pytest

    with pytest.raises(ValueError, match="sparse"):
        train_esrnn(_MCFG, data, _cfg(compress_grads=True, sparse_adam=False))
    # sparse_adam is implied, not required, when unset
    out = train_esrnn(_MCFG, data, _cfg(n_steps=4, sparse_adam=False))
    assert len(out["history"]["loss"]) == 4


def test_estimator_chunked_inference_matches_resident():
    """predict/evaluate stream chunk-by-chunk to the resident answers."""
    from repro.forecast import ESRNNForecaster, get_smoke_spec

    spec = get_smoke_spec("esrnn-quarterly", n_steps=8, batch_size=8,
                          series_chunk=8, sparse_adam=True, scan_steps=4)
    f = ESRNNForecaster(spec).fit(_data(_N))
    assert f.n_series_ == _N and _N > spec.series_chunk

    res = ESRNNForecaster(spec.replace(series_chunk=0))
    res.params_, res.n_series_ = f.params_, f.n_series_
    res.data_, res.cats_ = f.data_, f.cats_

    np.testing.assert_allclose(f.predict(), res.predict(), atol=1e-6)
    ev_c, ev_r = f.evaluate(), res.evaluate()
    for key in ("smape", "mase", "smape_comb", "mase_comb",
                "smape_naive2", "mase_naive2", "owa"):
        np.testing.assert_allclose(ev_c[key], ev_r[key], rtol=1e-5,
                                   err_msg=key)
    bt_c = f.backtest(origins=(20, 24))
    bt_r = res.backtest(origins=(20, 24))
    np.testing.assert_allclose(bt_c["forecasts"], bt_r["forecasts"],
                               atol=1e-6)
    for oc, orr in zip(bt_c["per_origin"], bt_r["per_origin"]):
        np.testing.assert_allclose(oc["smape"], orr["smape"], rtol=1e-5)
        np.testing.assert_allclose(oc["mase"], orr["mase"], rtol=1e-5)
