"""Adam-with-groups optimizer tests, incl. the sparse per-series path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (
    AdamConfig, adam_init, adam_init_sparse, adam_update, adam_update_sparse,
    esrnn_group_fn, global_norm, hw_table_rows,
)


def test_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    cfg = AdamConfig(lr=0.1)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = adam_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_group_lr_applied():
    """The per_series group moves 10x faster on identical gradients."""
    params = {"hw": {"a": jnp.ones(3)}, "rnn": {"w": jnp.ones(3)}}
    opt = adam_init(params)
    cfg = AdamConfig(lr=0.01, group_lr={"per_series": 10.0, "default": 1.0})
    grads = {"hw": {"a": jnp.ones(3)}, "rnn": {"w": jnp.ones(3)}}
    p2, _ = adam_update(grads, opt, params, cfg, group_fn=esrnn_group_fn)
    d_hw = float(jnp.abs(params["hw"]["a"] - p2["hw"]["a"]).mean())
    d_rnn = float(jnp.abs(params["rnn"]["w"] - p2["rnn"]["w"]).mean())
    np.testing.assert_allclose(d_hw / d_rnn, 10.0, rtol=1e-4)


def test_clip_norm_caps_update():
    params = {"w": jnp.zeros(4)}
    opt = adam_init(params)
    cfg = AdamConfig(lr=1.0, clip_norm=1e-6)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = adam_update(huge, opt, params, cfg)
    # clipped grad ~ 1e-6 -> normalized Adam step still bounded by lr
    assert float(jnp.abs(p2["w"]).max()) <= 1.0 + 1e-6


def test_schedules_monotone():
    cfg = AdamConfig(lr=1.0, schedule="cosine", total_steps=100)
    from repro.train.optimizer import _schedule_factor

    f0 = float(_schedule_factor(cfg, jnp.asarray(0)))
    f50 = float(_schedule_factor(cfg, jnp.asarray(50)))
    f100 = float(_schedule_factor(cfg, jnp.asarray(100)))
    assert f0 > f50 > f100 >= cfg.min_lr_frac - 1e-6


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    np.testing.assert_allclose(float(global_norm(t)), 5.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Sparse per-series Adam: segment updates + closed-form moment catch-up
# ---------------------------------------------------------------------------

_N, _B = 12, 4


def _toy_params(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "hw": {"alpha": jax.random.normal(k1, (_N, 3)),
               "seas": jax.random.normal(k2, (_N,))},
        "rnn": {"w": jax.random.normal(k3, (5,))},
    }


def _toy_grads(key, idx=None):
    """Per-row hw grads for ``idx`` (sparse layout) or full-table (dense)."""
    k1, k2, k3 = jax.random.split(key, 3)
    rows = _N if idx is None else len(idx)
    return {
        "hw": {"alpha": jax.random.normal(k1, (rows, 3)),
               "seas": jax.random.normal(k2, (rows,))},
        "rnn": {"w": jax.random.normal(k3, (5,))},
    }


def _scatter(grads_rows, idx):
    """Sparse-layout grads -> the dense zero-padded table the old path used."""
    def put(g):
        return jnp.zeros((_N,) + g.shape[1:], g.dtype).at[idx].set(g)
    return {"hw": jax.tree_util.tree_map(put, grads_rows["hw"]),
            "rnn": grads_rows["rnn"]}


_CFG = AdamConfig(lr=0.05, clip_norm=1.0,
                  group_lr={"per_series": 10.0, "default": 1.0})


def test_sparse_init_adds_row_clock():
    params = _toy_params(jax.random.PRNGKey(0))
    assert hw_table_rows(params) == _N
    state = adam_init_sparse(params)
    assert state["t_hw"].shape == (_N,)
    assert state["t_hw"].dtype == jnp.int32
    # mu/nu/step identical in structure to the dense state
    dense = adam_init(params)
    assert (jax.tree_util.tree_structure(state["mu"])
            == jax.tree_util.tree_structure(dense["mu"]))


def test_sparse_full_batch_identical_to_dense():
    """With every row in every batch the sparse path IS dense Adam."""
    params = _toy_params(jax.random.PRNGKey(1))
    idx = jnp.arange(_N)
    p_d, s_d = dict(params), adam_init(params)
    p_s, s_s = dict(params), adam_init_sparse(params)
    for t in range(5):
        g = _toy_grads(jax.random.PRNGKey(10 + t))
        p_d, s_d = adam_update(g, s_d, p_d, _CFG, group_fn=esrnn_group_fn)
        p_s, s_s = adam_update_sparse(g, s_s, p_s, _CFG, idx=idx,
                                      group_fn=esrnn_group_fn)
        for (path, a), b in zip(
            jax.tree_util.tree_flatten_with_path(p_d)[0],
            jax.tree_util.tree_leaves(p_s),
        ):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-7, err_msg=str(path))


def test_sparse_matches_masked_dense_per_leaf():
    """Random partial batches: sparse == dense math restricted to the batch.

    Reference semantics (the sparse path's contract): Adam moments evolve
    exactly as dense Adam's -- a skipped row's zero gradient decays them by
    b1/b2 per step, which the sparse path replays as one b1^k/b2^k power at
    the next touch -- while *parameter* updates apply only to the batch's
    rows (dense Adam would keep drifting skipped rows along stale momentum).
    The reference below runs the dense update on the zero-padded scattered
    gradient and freezes the untouched rows' params; the final full-table
    touch forces every row's lazy catch-up so moments are comparable
    per-leaf across the whole table.
    """
    params = _toy_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    p_ref, s_ref = dict(params), adam_init(params)
    p_s, s_s = dict(params), adam_init_sparse(params)

    schedule = [jnp.asarray(np.sort(rng.choice(_N, _B, replace=False)))
                for _ in range(9)] + [jnp.arange(_N)]  # final: touch all
    for t, idx in enumerate(schedule):
        g_rows = _toy_grads(jax.random.PRNGKey(100 + t), idx)
        # reference: dense Adam on the scattered grads, untouched rows frozen
        touched = np.zeros(_N, bool)
        touched[np.asarray(idx)] = True
        p_new, s_ref = adam_update(_scatter(g_rows, idx), s_ref, p_ref, _CFG,
                                   group_fn=esrnn_group_fn)
        mask = jnp.asarray(touched)
        p_ref = {
            "hw": jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    mask.reshape((_N,) + (1,) * (new.ndim - 1)), new, old),
                p_new["hw"], p_ref["hw"]),
            "rnn": p_new["rnn"],
        }
        p_s, s_s = adam_update_sparse(g_rows, s_s, p_s, _CFG, idx=idx,
                                      group_fn=esrnn_group_fn)

    for (path, a), b in zip(
        jax.tree_util.tree_flatten_with_path(p_ref)[0],
        jax.tree_util.tree_leaves(p_s),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"params {path}")
    # after the final all-rows touch every lazy row has caught up: the
    # closed-form b1^k/b2^k moments equal the dense path's k iterated decays
    for key in ("mu", "nu"):
        for (path, a), b in zip(
            jax.tree_util.tree_flatten_with_path(s_ref[key])[0],
            jax.tree_util.tree_leaves(s_s[key]),
        ):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, err_msg=f"{key} {path}")
    assert int(s_s["step"]) == int(s_ref["step"]) == len(schedule)


def test_sparse_skipped_rows_hold_still():
    """Rows outside the batch must not move (the whole point of the path)."""
    params = _toy_params(jax.random.PRNGKey(3))
    s = adam_init_sparse(params)
    # seed nonzero momentum everywhere so dense Adam *would* drift them
    idx_all = jnp.arange(_N)
    g = _toy_grads(jax.random.PRNGKey(42))
    params, s = adam_update_sparse(g, s, params, _CFG, idx=idx_all,
                                   group_fn=esrnn_group_fn)
    idx = jnp.asarray([0, 1, 2, 3])
    g_rows = _toy_grads(jax.random.PRNGKey(43), idx)
    p2, s2 = adam_update_sparse(g_rows, s, params, _CFG, idx=idx,
                                group_fn=esrnn_group_fn)
    for leaf_a, leaf_b in zip(jax.tree_util.tree_leaves(params["hw"]),
                              jax.tree_util.tree_leaves(p2["hw"])):
        np.testing.assert_array_equal(np.asarray(leaf_a)[4:],
                                      np.asarray(leaf_b)[4:])
        assert np.abs(np.asarray(leaf_a)[:4] - np.asarray(leaf_b)[:4]).max() > 0
    np.testing.assert_array_equal(np.asarray(s2["t_hw"]),
                                  np.asarray([2, 2, 2, 2] + [1] * (_N - 4)))


def test_sparse_clocks_exact_across_chunk_boundaries():
    """Chunk-local sparse updates == full-table sparse updates, bit-for-bit.

    The out-of-core trainer slices a chunk's rows (params + mu/nu moments +
    t_hw clocks) out of a host table, runs ``adam_update_sparse`` with
    chunk-LOCAL indices, and writes the rows back. Because ``t_hw`` carries
    GLOBAL step numbers and ``step`` is a global scalar, the closed-form
    b1^k/b2^k moment catch-up is identical whether a row's skip interval
    spans steps inside one chunk visit or whole visits of OTHER chunks.
    The schedule below makes rows sit out entire foreign-chunk visits
    (k > 1 catch-up across a chunk boundary) before their next touch.
    """
    params = _toy_params(jax.random.PRNGKey(7))
    p_full, s_full = dict(params), adam_init_sparse(params)

    # host "table": writable numpy rows of the per-series state
    host = lambda tree: jax.tree_util.tree_map(
        lambda a: np.array(a), tree)
    table = {"hw": host(params["hw"]),
             "mu": jax.tree_util.tree_map(np.zeros_like, host(params["hw"])),
             "nu": jax.tree_util.tree_map(np.zeros_like, host(params["hw"])),
             "t_hw": np.zeros(_N, np.int32)}
    shared = params["rnn"]
    mu_sh, nu_sh = s_full["mu"]["rnn"], s_full["nu"]["rnn"]
    step_sc = s_full["step"]

    chunks = [(0, 6), (6, 12)]
    # (chunk, global row idx) visits; e.g. row 0 touched at t=0 and not
    # again until t=4 -- two full steps of chunk 1 in between
    visits = [(0, [0, 2, 4, 5]), (1, [6, 7, 9, 11]), (1, [8, 10, 6, 7]),
              (0, [1, 2, 3, 5]), (0, [0, 4, 1, 3]), (1, [11, 9, 8, 10])]
    for t, (c, gidx) in enumerate(visits):
        g_rows = _toy_grads(jax.random.PRNGKey(300 + t),
                            jnp.asarray(gidx))
        # reference: full-table sparse update with global indices
        p_full, s_full = adam_update_sparse(
            g_rows, s_full, p_full, _CFG, idx=jnp.asarray(gidx),
            group_fn=esrnn_group_fn)
        # chunked: slice the chunk out, update with LOCAL indices, absorb
        lo, hi = chunks[c]
        sl = lambda tree: jax.tree_util.tree_map(
            lambda a: jnp.asarray(a[lo:hi]), tree)
        cp = {"hw": sl(table["hw"]), "rnn": shared}
        cs = {"mu": {"hw": sl(table["mu"]), "rnn": mu_sh},
              "nu": {"hw": sl(table["nu"]), "rnn": nu_sh},
              "step": step_sc, "t_hw": jnp.asarray(table["t_hw"][lo:hi])}
        cp, cs = adam_update_sparse(
            g_rows, cs, cp, _CFG, idx=jnp.asarray(gidx) - lo,
            group_fn=esrnn_group_fn)
        wb = lambda dst, src: jax.tree_util.tree_map(
            lambda d, s: d.__setitem__(slice(lo, hi), np.asarray(s)),
            dst, src)
        wb(table["hw"], cp["hw"])
        wb(table["mu"], cs["mu"]["hw"])
        wb(table["nu"], cs["nu"]["hw"])
        table["t_hw"][lo:hi] = np.asarray(cs["t_hw"])
        shared, mu_sh, nu_sh = cp["rnn"], cs["mu"]["rnn"], cs["nu"]["rnn"]
        step_sc = cs["step"]

    cmp = lambda a, b, msg: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b), err_msg=msg)
    jax.tree_util.tree_map(
        lambda a, b: cmp(a, b, "hw params"), table["hw"], p_full["hw"])
    jax.tree_util.tree_map(
        lambda a, b: cmp(a, b, "mu"), table["mu"], s_full["mu"]["hw"])
    jax.tree_util.tree_map(
        lambda a, b: cmp(a, b, "nu"), table["nu"], s_full["nu"]["hw"])
    cmp(table["t_hw"], s_full["t_hw"], "t_hw clocks")
    jax.tree_util.tree_map(
        lambda a, b: cmp(a, b, "shared"), shared, p_full["rnn"])
    cmp(step_sc, s_full["step"], "global step")


def test_bitexact_determinism():
    params = {"w": jnp.asarray([1.0, 2.0])}
    cfg = AdamConfig(lr=0.01)
    grads = {"w": jnp.asarray([0.5, -0.5])}
    p1, o1 = adam_update(grads, adam_init(params), params, cfg)
    p2, o2 = adam_update(grads, adam_init(params), params, cfg)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
