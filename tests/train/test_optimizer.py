"""Adam-with-groups optimizer tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (
    AdamConfig, adam_init, adam_update, esrnn_group_fn, global_norm,
)


def test_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    cfg = AdamConfig(lr=0.1)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = adam_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_group_lr_applied():
    """The per_series group moves 10x faster on identical gradients."""
    params = {"hw": {"a": jnp.ones(3)}, "rnn": {"w": jnp.ones(3)}}
    opt = adam_init(params)
    cfg = AdamConfig(lr=0.01, group_lr={"per_series": 10.0, "default": 1.0})
    grads = {"hw": {"a": jnp.ones(3)}, "rnn": {"w": jnp.ones(3)}}
    p2, _ = adam_update(grads, opt, params, cfg, group_fn=esrnn_group_fn)
    d_hw = float(jnp.abs(params["hw"]["a"] - p2["hw"]["a"]).mean())
    d_rnn = float(jnp.abs(params["rnn"]["w"] - p2["rnn"]["w"]).mean())
    np.testing.assert_allclose(d_hw / d_rnn, 10.0, rtol=1e-4)


def test_clip_norm_caps_update():
    params = {"w": jnp.zeros(4)}
    opt = adam_init(params)
    cfg = AdamConfig(lr=1.0, clip_norm=1e-6)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = adam_update(huge, opt, params, cfg)
    # clipped grad ~ 1e-6 -> normalized Adam step still bounded by lr
    assert float(jnp.abs(p2["w"]).max()) <= 1.0 + 1e-6


def test_schedules_monotone():
    cfg = AdamConfig(lr=1.0, schedule="cosine", total_steps=100)
    from repro.train.optimizer import _schedule_factor

    f0 = float(_schedule_factor(cfg, jnp.asarray(0)))
    f50 = float(_schedule_factor(cfg, jnp.asarray(50)))
    f100 = float(_schedule_factor(cfg, jnp.asarray(100)))
    assert f0 > f50 > f100 >= cfg.min_lr_frac - 1e-6


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    np.testing.assert_allclose(float(global_norm(t)), 5.0, rtol=1e-6)


def test_bitexact_determinism():
    params = {"w": jnp.asarray([1.0, 2.0])}
    cfg = AdamConfig(lr=0.01)
    grads = {"w": jnp.asarray([0.5, -0.5])}
    p1, o1 = adam_update(grads, adam_init(params), params, cfg)
    p2, o2 = adam_update(grads, adam_init(params), params, cfg)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
