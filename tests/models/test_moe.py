"""MoE dispatch correctness: gather/scatter path vs dense per-expert loop."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.moe import moe_apply, moe_init


def dense_reference(p, cfg, x):
    """Loop over experts densely -- no capacity, no dispatch."""
    b, s, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk_prob:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        fe = (jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])) @ p["w_down"][e]
        w_e = jnp.sum(jnp.where(top_ids == e, top_w, 0.0), axis=-1)
        y = y + fe * w_e[..., None].astype(x.dtype)
    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return y


def test_dropless_dispatch_matches_dense():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, cfg, x, capacity=16)  # dropless at this size
    y_ref = dense_reference(p, cfg, x)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.9  # Switch aux loss lower bound is 1 at balance


def test_shared_experts_path():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_model)), jnp.float32)
    y, _ = moe_apply(p, cfg, x, capacity=8)
    y_ref = dense_reference(p, cfg, x)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_capacity_drop_zeroes_not_corrupts():
    """With capacity 1, dropped tokens lose expert contributions but the
    output stays finite and the kept tokens' results are a subset of the
    dropless output's structure."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (1, 32, cfg.d_model)), jnp.float32)
    y, _ = moe_apply(p, cfg, x, capacity=1)
    assert bool(jnp.isfinite(y).all())


def test_grads_flow_through_dispatch():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = moe_apply(p, cfg, x, capacity=8)
        return jnp.mean(jnp.square(y)) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert bool(jnp.any(g["router"] != 0))
    assert bool(jnp.any(g["w_gate"] != 0))
    assert bool(jnp.any(g["w_down"] != 0))
