"""Mamba2 SSD vs sequential recurrence (hypothesis shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked


def seq_ref(x, dt, a, bb, cc):
    b, t, h, p = x.shape
    g, n = bb.shape[2], bb.shape[3]
    rep = h // g
    s = np.zeros((b, h, p, n))
    ys = np.zeros((b, t, h, p))
    for ti in range(t):
        da = np.exp(np.asarray(dt[:, ti]) * np.asarray(a)[None, :])
        bh = np.repeat(np.asarray(bb[:, ti]), rep, axis=1)
        ch = np.repeat(np.asarray(cc[:, ti]), rep, axis=1)
        upd = np.einsum("bhp,bhn->bhpn",
                        np.asarray(x[:, ti]) * np.asarray(dt[:, ti])[..., None], bh)
        s = s * da[:, :, None, None] + upd
        ys[:, ti] = np.einsum("bhpn,bhn->bhp", s, ch)
    return ys, s


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([8, 16, 32, 64]),
    chunk=st.sampled_from([4, 8, 16]),
    h=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**30),
)
def test_ssd_chunked_equals_sequential(t, chunk, h, seed):
    if t % chunk:
        chunk = t
    rng = np.random.default_rng(seed)
    b, p, g, n = 2, 4, 1, 8
    x = jnp.asarray(rng.normal(0, 1, (b, t, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.2, (b, t, h))), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(1, 0.3, h)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    y, s = ssd_chunked(x, dt, a, bb, cc, chunk=chunk)
    y_ref, s_ref = seq_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s, s_ref, rtol=1e-4, atol=1e-4)


def test_chunk_size_invariance():
    rng = np.random.default_rng(0)
    b, t, h, p, g, n = 1, 64, 2, 4, 1, 8
    x = jnp.asarray(rng.normal(0, 1, (b, t, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.2, (b, t, h))), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(1, 0.3, h)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    outs = [ssd_chunked(x, dt, a, bb, cc, chunk=c)[0] for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)


def test_state_decay_kills_history():
    """Large negative A*dt makes the recurrence memoryless intra-step."""
    b, t, h, p, g, n = 1, 16, 1, 2, 1, 4
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (b, t, h, p)), jnp.float32)
    dt = jnp.full((b, t, h), 50.0)
    a = jnp.asarray([-10.0])
    bb = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(0, 1, (b, t, g, n)), jnp.float32)
    y, _ = ssd_chunked(x, dt, a, bb, cc, chunk=8)
    # each y_t should equal C_t . (dt_t x_t B_t): no cross-time mixing
    t_probe = 3
    cb = float(np.asarray(cc[0, t_probe, 0]) @ np.asarray(bb[0, t_probe, 0]))
    ref = cb * np.asarray(x[0, t_probe, 0]) * 50.0
    np.testing.assert_allclose(np.asarray(y[0, t_probe, 0]), ref, rtol=1e-3)
