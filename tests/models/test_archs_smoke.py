"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeCell, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.train.optimizer import adam_init


def _batch(cfg, b, s, rng, with_labels=True):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_frames, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s, rng)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    cell = ShapeCell("t", "train", s, b, microbatch=None)
    step = make_train_step(model, cell)
    p32 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, params)
    p2, opt2, loss2 = step(p32, adam_init(p32), batch)
    assert bool(jnp.isfinite(loss2))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b_: bool(jnp.any(a != b_)), p32, p2)
    assert any(jax.tree_util.tree_leaves(moved)), f"{arch}: no param moved"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    off = cfg.n_patches if cfg.family == "vlm" else 0
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    batch = _batch(cfg, b, s, rng, with_labels=False)
    batch["tokens"] = toks[:, :s]

    _, caches = model.prefill(params, batch, s + 8 + off)
    dlogits, _ = model.decode(
        params,
        {"tokens": toks[:, s:s + 1],
         "positions": jnp.full((b, 1), s + off, jnp.int32)},
        caches)

    batch_full = dict(batch)
    batch_full["tokens"] = toks
    flogits, _ = model.prefill(params, batch_full, s + 9 + off)
    np.testing.assert_allclose(
        np.asarray(dlogits), np.asarray(flogits), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims_match_assignment(arch):
    """The exact assigned dimensions survive in the full configs."""
    expect = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_moe_extras():
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.top_k, q.head_dim) == (128, 8, 128)
    d = get_config("deepseek-v2-lite-16b")
    assert (d.n_experts, d.top_k, d.n_shared_experts) == (64, 6, 2)
    assert (d.kv_lora_rank, d.use_mla) == (512, True)
    z = get_config("zamba2-2.7b")
    assert (z.ssm_state, z.attn_every) == (64, 6)
    m = get_config("mamba2-1.3b")
    assert m.ssm_state == 128 and m.family == "ssm"
