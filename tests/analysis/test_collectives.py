"""Collective audit: shared HLO-text parsing + invariant evaluation.

The counting layer is exercised on canned HLO text (fast, no mesh); the
invariant layer on seeded good/bad count dictionaries. The end-to-end
8-device compile of the real sharded programs runs in the CI graph-audit
job and tests/distributed/ -- not here.
"""

from repro.analysis.collectives import collective_findings
from repro.analysis.hlo_text import (
    collective_bytes_by_kind, collective_counts, collective_ops, type_bytes,
)

CANNED = """\
HloModule jit_grad, entry_computation_layout=...

%region_0 (a: f32[], b: f32[]) -> f32[] {
  ROOT %add = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[8,40]) -> f32[8,40] {
  %p0 = f32[8,40]{1,0} parameter(0)
  %ar = f32[8,40]{1,0} all-reduce(f32[8,40]{1,0} %p0), to_apply=%region_0
  %ag-start = f32[16,40]{1,0} all-gather-start(f32[8,40]{1,0} %ar), dimensions={0}
  %ag-done = f32[16,40]{1,0} all-gather-done(f32[16,40]{1,0} %ag-start)
  ROOT %out = f32[8,40]{1,0} slice(f32[16,40]{1,0} %ag-done), slice={[0:8], [0:40]}
}
"""


def test_collective_ops_counts_start_not_done():
    ops = collective_ops(CANNED)
    assert [k for k, _ in ops] == ["all-reduce", "all-gather"]
    assert collective_counts(CANNED) == {"all-reduce": 1, "all-gather": 1}


def test_collective_bytes_by_kind():
    by_kind = collective_bytes_by_kind(CANNED)
    assert by_kind["all-reduce"] == 8 * 40 * 4
    assert by_kind["all-gather"] == 16 * 40 * 4


def test_type_bytes_tuples_and_scalars():
    assert type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert type_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert type_bytes("pred[]") == 1
    assert type_bytes("token[]") == 0


def test_healthy_counts_pass():
    counts = {"devices": 8, "predict": {}, "loss_grad": {"all-reduce": 17}}
    findings, metrics = collective_findings(counts)
    assert findings == []
    assert metrics == {"devices": 8, "predict_collectives": 0,
                       "grad_all_reduces": 17, "grad_other_collectives": 0}


def test_collective_in_predict_is_flagged():
    counts = {"devices": 8, "predict": {"all-gather": 2},
              "loss_grad": {"all-reduce": 17}}
    findings, _ = collective_findings(counts)
    assert any("sharded predict" in f.message for f in findings)


def test_non_psum_gradient_collective_is_flagged():
    counts = {"devices": 8, "predict": {},
              "loss_grad": {"all-reduce": 17, "collective-permute": 1}}
    findings, _ = collective_findings(counts)
    assert any("non-psum" in f.message for f in findings)


def test_missing_gradient_all_reduce_is_flagged():
    counts = {"devices": 8, "predict": {}, "loss_grad": {}}
    findings, _ = collective_findings(counts)
    assert any("no all-reduce" in f.message for f in findings)
