"""Gradient-leak lint: mutation-style self-tests on tiny fixtures.

Each deliberately broken step function must be *flagged* (the lint's own
regression suite), and the clean step must pass -- a lint that never fires
or always fires is worse than none.
"""

import jax
import jax.numpy as jnp

from repro.analysis.gradleak import (
    gradient_leak_findings, probe_batch_size,
)

FROZEN = frozenset({"rnn"})
B = 5  # probe batch rows, distinct from every weight dim below


def _params():
    return {
        "rnn": {"w": jnp.ones((4, 3))},
        "head": {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))},
    }


def _loss(p, x):
    h = jnp.tanh(x @ p["rnn"]["w"])
    return jnp.sum((h @ p["head"]["w"] + p["head"]["b"]) ** 2)


def clean_step(params, opt_state, idx):
    """Differentiates the trainable subtree only; frozen passes through."""
    x = jnp.ones((B, 4)) * idx.sum()

    def loss_fn(head):
        return _loss({"rnn": params["rnn"], "head": head}, x)

    g = jax.grad(loss_fn)(params["head"])
    new_head = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg,
                                      params["head"], g)
    return {"rnn": params["rnn"], "head": new_head}, opt_state, idx


def leaky_step(params, opt_state, idx):
    """Differentiates the FULL tree: reservoir weight gradients get built
    and the frozen group is updated -- both checks must fire."""
    x = jnp.ones((B, 4)) * idx.sum()
    g = jax.grad(lambda p: _loss(p, x))(params)
    new = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, params, g)
    return new, opt_state, idx


def test_clean_step_has_no_findings():
    params = _params()
    opt = {"head": jax.tree_util.tree_map(jnp.zeros_like, params["head"])}
    findings, metrics = gradient_leak_findings(
        clean_step, params, opt, jnp.arange(B), FROZEN)
    assert findings == []
    assert metrics["frozen_leaves"] == 1
    assert metrics["passthrough_ok"] == 1
    assert metrics["grad_primitive_hits"] == 0
    assert metrics["eqns_scanned"] > 0


def test_leaky_step_is_flagged():
    params = _params()
    opt = {"head": jax.tree_util.tree_map(jnp.zeros_like, params["head"])}
    findings, metrics = gradient_leak_findings(
        leaky_step, params, opt, jnp.arange(B), FROZEN)
    assert findings, "lint failed to flag a full-tree gradient step"
    messages = " | ".join(f.message for f in findings)
    # the frozen leaf is no longer a structural pass-through...
    assert "passed through" in messages or "unchanged" in messages
    # ...and a gradient primitive produces a frozen-weight-shaped value
    assert metrics["grad_primitive_hits"] >= 1


def test_frozen_moments_in_opt_state_are_flagged():
    params = _params()
    opt = jax.tree_util.tree_map(jnp.zeros_like, params)  # moments for ALL
    findings, _ = gradient_leak_findings(
        clean_step, params, opt, jnp.arange(B), FROZEN)
    assert any("optimizer state carries moments" in f.message
               for f in findings)


def test_probe_batch_size_avoids_frozen_dims():
    params = _params()
    b = probe_batch_size(None, params, candidates=(3, 4, 5), frozen=FROZEN)
    assert b == 5  # 3 and 4 collide with the frozen (4, 3) reservoir
