"""Donation audit: aliasing header parsing + donated-but-copied detection."""

import jax
import jax.numpy as jnp

from repro.analysis.donation import donated_leaf_count, donation_findings
from repro.analysis.hlo_text import input_output_aliases


def _compiled(donate):
    f = jax.jit(lambda s, x: (s + x, jnp.sum(x)),
                donate_argnums=(0,) if donate else ())
    s = jnp.zeros((128,), jnp.float32)
    x = jnp.ones((128,), jnp.float32)
    return f.lower(s, x).compile()


def test_donated_buffer_aliases_in_compiled_module():
    compiled = _compiled(donate=True)
    aliases = input_output_aliases(compiled.as_text())
    assert len(aliases) == 1
    findings, metrics = donation_findings(compiled, 1, what="toy step")
    assert findings == []
    assert metrics == {"aliased_buffers": 1, "expected_aliases": 1}


def test_un_donated_buffer_is_flagged():
    """Seeded violation: drop donate_argnums and the audit must fire."""
    compiled = _compiled(donate=False)
    findings, metrics = donation_findings(compiled, 1, what="toy step")
    assert any("donated-but-copied" in f.message for f in findings)
    assert metrics["aliased_buffers"] == 0


def test_alias_header_parser_on_canned_module():
    header = ('HloModule jit_step, input_output_alias={ {0}: (0, {}, '
              'may-alias), {1,2}: (3, {}) }, entry_computation_layout=...\n'
              'ENTRY %main () -> f32[] {\n}\n')
    assert input_output_aliases(header) == [((0,), 0), ((1, 2), 3)]


def test_alias_header_absent_means_no_aliases():
    assert input_output_aliases("HloModule jit_f\nENTRY %main {\n}\n") == []


def test_duplicate_parameter_alias_is_flagged():
    class Fake:
        def as_text(self):
            return ("HloModule m, input_output_alias={ {0}: (0, {}), "
                    "{1}: (0, {}) }\n")

    findings, _ = donation_findings(Fake(), 2, what="fake")
    assert any("multiple outputs" in f.message for f in findings)


def test_donated_leaf_count_spans_trees():
    params = {"a": jnp.zeros(3), "b": {"c": jnp.zeros(2)}}
    opt = (jnp.zeros(1), jnp.zeros(1))
    assert donated_leaf_count(params, opt) == 4
