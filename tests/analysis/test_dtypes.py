"""Dtype-policy lint: clean programs pass, seeded f64/upcast programs fail."""

import jax
import jax.numpy as jnp

from repro.analysis.dtypes import dtype_findings


def test_clean_f32_program_passes():
    def f(x):
        return jnp.tanh(x @ x.T).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4, 3)))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="float32")
    assert findings == []
    assert metrics["f64_avals"] == 0
    assert metrics["float_upcasts"] == 0
    assert metrics["eqns_scanned"] > 0


def test_f64_promotion_is_flagged():
    """Seeded violation: an x64-enabled program producing float64 values."""
    with jax.experimental.enable_x64():
        def f(x):
            return x.astype(jnp.float64) * 2.0

        jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="float32")
    assert any("f64 promotion" in f.message for f in findings)
    assert metrics["f64_avals"] >= 1
    # the f32 -> f64 convert is also an above-policy upcast
    assert metrics["float_upcasts"] >= 1


def test_upcast_beyond_bf16_policy_is_flagged():
    """Under a bfloat16 policy an f32 convert is the silent-upcast failure."""
    def f(x):
        return x.astype(jnp.float32).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="bfloat16")
    assert any("silent upcast" in f.message for f in findings)
    assert metrics["float_upcasts"] >= 1


def test_downcast_within_policy_passes():
    def f(x):
        return x.astype(jnp.bfloat16).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    findings, _ = dtype_findings(jaxpr, policy_dtype="float32")
    assert findings == []


def test_findings_dedup_by_dtype_pair():
    def f(x):
        a = x.astype(jnp.float32).sum()
        b = (x * 2).astype(jnp.float32).sum()
        return a + b

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="bfloat16")
    assert metrics["float_upcasts"] >= 2
    assert len([f for f in findings if "silent upcast" in f.message]) == 1


# ---------------------------------------------------------------------------
# mixed-precision policy (state_dtype relaxation + accumulation checks)
# ---------------------------------------------------------------------------


def test_state_dtype_allows_declared_accumulation_upcasts():
    """Under bf16 policy + f32 state, the fp32 accumulation points pass."""
    def f(x):
        return x.astype(jnp.float32).sum()  # declared accumulation

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    findings, metrics = dtype_findings(
        jaxpr, policy_dtype="bfloat16", state_dtype="float32")
    assert findings == []
    assert metrics["float_upcasts"] == 0
    assert metrics["state_dtype"] == "float32"


def test_state_dtype_still_flags_f64():
    with jax.experimental.enable_x64():
        def f(x):
            return x.astype(jnp.float64) * 2.0

        jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    findings, _ = dtype_findings(
        jaxpr, policy_dtype="bfloat16", state_dtype="float32")
    assert any("f64 promotion" in f.message for f in findings)


def test_bf16_esrnn_forecast_is_policy_clean():
    """The real bf16 forecast program lints clean under (bf16, f32-state)."""
    import dataclasses as _dc

    import numpy as np

    from repro.core.esrnn import esrnn_forecast_fn, esrnn_init, make_config

    cfg = _dc.replace(make_config("quarterly"), precision="bf16")
    rng = np.random.default_rng(0)
    n, t = 8, 30
    y = jnp.asarray(np.abs(rng.lognormal(2, 0.3, (n, t))) + 0.5, jnp.float32)
    cats = jnp.eye(cfg.n_categories, dtype=jnp.float32)[
        jnp.zeros((n,), jnp.int32)]
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    jaxpr = jax.make_jaxpr(
        lambda p, yy, cc: esrnn_forecast_fn(cfg, p, yy, cc))(params, y, cats)
    findings, _ = dtype_findings(
        jaxpr, policy_dtype="bfloat16", state_dtype="float32")
    assert findings == []


def test_accumulation_findings_clean_on_real_trees():
    from repro.analysis.dtypes import accumulation_findings

    params = {"hw": {"alpha_logit": jnp.zeros((4,), jnp.float32)},
              "rnn": {"wx": jnp.zeros((3, 3), jnp.float32)}}
    opt = {"mu": {"rnn": jnp.zeros((3, 3), jnp.float32)},
           "nu": {"rnn": jnp.zeros((3, 3), jnp.float32)}, "t": 0}
    loss = jax.ShapeDtypeStruct((), jnp.float32)
    findings, metrics = accumulation_findings(params, opt, loss)
    assert findings == []
    assert metrics["loss_dtype"] == "float32"


def test_accumulation_findings_fire_on_seeded_violations():
    from repro.analysis.dtypes import accumulation_findings

    params = {"hw": {"alpha_logit": jnp.zeros((4,), jnp.bfloat16)}}
    opt = {"mu": {"w": jnp.zeros((3,), jnp.bfloat16)},
           "nu": {"w": jnp.zeros((3,), jnp.float32)}}
    loss = jax.ShapeDtypeStruct((), jnp.bfloat16)
    findings, metrics = accumulation_findings(params, opt, loss)
    msgs = " ".join(f.message for f in findings)
    assert "HW table" in msgs
    assert "Adam moments" in msgs
    assert "loss reduction" in msgs
    assert metrics["hw_table_dtypes_bad"] == ["bfloat16"]
