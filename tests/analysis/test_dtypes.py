"""Dtype-policy lint: clean programs pass, seeded f64/upcast programs fail."""

import jax
import jax.numpy as jnp

from repro.analysis.dtypes import dtype_findings


def test_clean_f32_program_passes():
    def f(x):
        return jnp.tanh(x @ x.T).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4, 3)))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="float32")
    assert findings == []
    assert metrics["f64_avals"] == 0
    assert metrics["float_upcasts"] == 0
    assert metrics["eqns_scanned"] > 0


def test_f64_promotion_is_flagged():
    """Seeded violation: an x64-enabled program producing float64 values."""
    with jax.experimental.enable_x64():
        def f(x):
            return x.astype(jnp.float64) * 2.0

        jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="float32")
    assert any("f64 promotion" in f.message for f in findings)
    assert metrics["f64_avals"] >= 1
    # the f32 -> f64 convert is also an above-policy upcast
    assert metrics["float_upcasts"] >= 1


def test_upcast_beyond_bf16_policy_is_flagged():
    """Under a bfloat16 policy an f32 convert is the silent-upcast failure."""
    def f(x):
        return x.astype(jnp.float32).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="bfloat16")
    assert any("silent upcast" in f.message for f in findings)
    assert metrics["float_upcasts"] >= 1


def test_downcast_within_policy_passes():
    def f(x):
        return x.astype(jnp.bfloat16).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    findings, _ = dtype_findings(jaxpr, policy_dtype="float32")
    assert findings == []


def test_findings_dedup_by_dtype_pair():
    def f(x):
        a = x.astype(jnp.float32).sum()
        b = (x * 2).astype(jnp.float32).sum()
        return a + b

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    findings, metrics = dtype_findings(jaxpr, policy_dtype="bfloat16")
    assert metrics["float_upcasts"] >= 2
    assert len([f for f in findings if "silent upcast" in f.message]) == 1
