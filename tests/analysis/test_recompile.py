"""Recompile sentinel: the fc[:n] partial-fill bug class stays dead.

PR 6 shipped a dispatcher that sliced the *device* forecast array per
request (``fc[:n]``): every distinct partial fill ``n`` compiled a fresh
slice executable, an unbounded compile family invisible to the bucket-grid
counters. These tests (a) reproduce the bug class directly and show the
sentinel catches it, and (b) pin the fixed serving path to its declared
``len(length_buckets) x len(batch_buckets)`` budget using ground-truth XLA
compile counts, not dispatcher intent.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import _probe_model
from repro.analysis.recompile import (
    CompileBudgetExceeded, CompileCounter, check_compile_budget,
)
from repro.forecast.serving import BucketDispatcher, synthetic_request_stream
from repro.forecast.spec import get_smoke_spec


def test_device_slice_per_n_is_an_unbounded_compile_family(compile_sentinel):
    """The PR-6 bug class: slicing a device array per distinct ``n``
    compiles one executable per ``n``; the host-side ``np.asarray(fc)[:n]``
    form compiles nothing."""
    fc = jnp.arange(64.0)
    fills = (3, 5, 7, 11, 13)

    before = compile_sentinel.count
    for n in fills:
        _ = fc[:n]  # device slice: distinct shape -> distinct executable
    device_compiles = compile_sentinel.count - before
    assert device_compiles >= len(fills)

    host = np.asarray(fc)
    before = compile_sentinel.count
    for n in fills:
        _ = host[:n]  # host slice: zero XLA involvement
    assert compile_sentinel.count - before == 0


def test_expect_raises_on_budget_overrun(compile_sentinel):
    # a shape no other test slices, so the process-wide jit cache is cold
    fc = jnp.arange(49.0) + 1.0
    with pytest.raises(CompileBudgetExceeded):
        with compile_sentinel.expect(budget=1, what="partial-fill slices"):
            for n in (3, 5, 7):
                _ = fc[:n]


def test_expect_passes_within_budget(compile_sentinel):
    with compile_sentinel.expect(budget=8, what="nothing"):
        pass  # no compiles at all


def test_serving_stays_within_declared_grid_budget():
    """The fixed dispatcher: ragged lengths and partial fills across two
    identical waves, yet ``xla_compiles`` (ground truth) never exceeds the
    bucket grid and the warm second wave compiles nothing."""
    cfg, params, _, _ = _probe_model(get_smoke_spec("esn-quarterly"))
    disp = BucketDispatcher(cfg, params, length_buckets=(32, 64),
                            batch_buckets=(1, 8))
    assert disp.compile_budget == 4
    assert disp.stats.compile_budget == 4

    for wave in range(2):
        before = disp.stats.xla_compiles
        reqs = synthetic_request_stream(cfg, 16, n_known=15, seed=0,
                                        len_range=(20, 60))
        out = disp.forecast_batch(reqs)
        assert len(out) == len(reqs)
        wave_compiles = disp.stats.xla_compiles - before
        if wave == 0:
            assert wave_compiles <= disp.compile_budget
        else:
            assert wave_compiles == 0  # warm grid: every request a cache hit
    check_compile_budget(disp.stats)  # returns, does not raise


def test_check_compile_budget_raises_on_overrun():
    class Stats:
        xla_compiles = 9
        compile_budget = 4
        compiles = 4
        cache_hits = 5

    with pytest.raises(CompileBudgetExceeded):
        check_compile_budget(Stats())


def test_check_compile_budget_requires_a_budget():
    class Stats:
        xla_compiles = 0
        compile_budget = None

    with pytest.raises(ValueError):
        check_compile_budget(Stats())
