"""The `analyze` CLI + run_audit report plumbing.

The fast ``predict`` entry (one trace, no fits, no serving waves) keeps
this a tier-1 test; the full fit/serve/collectives audit runs in the CI
graph-audit job and scripts/ci.sh.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import run_audit
from repro.forecast.spec import get_smoke_spec

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def test_run_audit_predict_entry_is_clean():
    report = run_audit(get_smoke_spec("esn-quarterly"), entries=("predict",))
    assert report.ok
    d = report.to_dict()
    assert d["ok"] is True
    assert d["violations_total"] == 0
    (sec,) = d["sections"]
    assert sec["name"] == "predict"
    assert sec["metrics"]["dtype"]["eqns_scanned"] > 0
    json.loads(report.to_json())  # round-trips


def test_run_audit_rejects_unknown_entry():
    with pytest.raises(ValueError):
        run_audit(get_smoke_spec("esn-quarterly"), entries=("fit", "nope"))


def test_analyze_cli_writes_report_and_exits_zero(tmp_path):
    out = tmp_path / "audit.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.forecast", "analyze",
         "--smoke", "--set", "head=esn", "--entries", "predict",
         "--json-out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert proc.returncode == 0, proc.stderr
    report = json.loads(out.read_text())
    assert report["ok"] is True
    names = [s["name"] for s in report["sections"]]
    assert names == ["predict"]
