"""Pallas fused LSTM cell vs oracle: shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import lstm_cell_ref


def _setup(b, i, h, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(0, 0.2, s), dtype)
    return (mk(i, 4 * h), mk(h, 4 * h), mk(4 * h), mk(b, i), mk(b, h), mk(b, h))


@pytest.mark.parametrize("b", [1, 13, 128, 300])
@pytest.mark.parametrize("i,h", [(30, 50), (4, 8), (128, 128), (20, 40)])
def test_lstm_cell_shapes(b, i, h):
    wx, wh, bb, x, hh, cc = _setup(b, i, h, seed=b + i + h)
    h1, c1 = lstm_cell_ref(wx, wh, bb, x, hh, cc)
    h2, c2 = ops.lstm_cell(wx, wh, bb, x, hh, cc)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.03)])
def test_lstm_cell_dtypes(dtype, tol):
    wx, wh, bb, x, hh, cc = _setup(9, 12, 16, seed=1, dtype=dtype)
    h1, c1 = lstm_cell_ref(
        *(t.astype(jnp.float32) for t in (wx, wh, bb, x, hh, cc)))
    h2, c2 = ops.lstm_cell(wx, wh, bb, x, hh, cc)
    assert h2.dtype == dtype
    np.testing.assert_allclose(h2.astype(jnp.float32), h1, rtol=tol, atol=tol)


def test_drnn_use_pallas_matches():
    """Full dilated stack with the kernel behind lstm_cell."""
    import jax
    from repro.core.drnn import drnn_apply, drnn_init

    dil = ((1, 2), (4, 8))
    params = drnn_init(jax.random.PRNGKey(0), 6, 40, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 6))
    o1, c1 = drnn_apply(params, x, dilations=dil, use_pallas=False)
    o2, c2 = drnn_apply(params, x, dilations=dil, use_pallas=True)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)
