"""Pallas fused LSTM cell vs oracle: shape/dtype sweep + gradients.

The cell carries a custom_vjp: the forward rule re-runs the fused kernel
with the gate activations as an extra output, the backward rule is a single
fused kernel producing every cotangent -- (dwx, dwh, db, dx, dh, dc) --
with the weight/bias grads accumulated across batch-grid steps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import lstm_cell_ref


def _setup(b, i, h, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(0, 0.2, s), dtype)
    return (mk(i, 4 * h), mk(h, 4 * h), mk(4 * h), mk(b, i), mk(b, h), mk(b, h))


@pytest.mark.parametrize("b", [1, 13, 128, 300])
@pytest.mark.parametrize("i,h", [(30, 50), (4, 8), (128, 128), (20, 40)])
def test_lstm_cell_shapes(b, i, h):
    wx, wh, bb, x, hh, cc = _setup(b, i, h, seed=b + i + h)
    h1, c1 = lstm_cell_ref(wx, wh, bb, x, hh, cc)
    h2, c2 = ops.lstm_cell(wx, wh, bb, x, hh, cc)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.03)])
def test_lstm_cell_dtypes(dtype, tol):
    wx, wh, bb, x, hh, cc = _setup(9, 12, 16, seed=1, dtype=dtype)
    h1, c1 = lstm_cell_ref(
        *(t.astype(jnp.float32) for t in (wx, wh, bb, x, hh, cc)))
    h2, c2 = ops.lstm_cell(wx, wh, bb, x, hh, cc)
    assert h2.dtype == dtype
    np.testing.assert_allclose(h2.astype(jnp.float32), h1, rtol=tol, atol=tol)


def test_drnn_use_pallas_matches():
    """Full dilated stack with the kernel behind lstm_cell."""
    from repro.core.drnn import drnn_apply, drnn_init

    dil = ((1, 2), (4, 8))
    params = drnn_init(jax.random.PRNGKey(0), 6, 40, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 6))
    o1, c1 = drnn_apply(params, x, dilations=dil, use_pallas=False)
    o2, c2 = drnn_apply(params, x, dilations=dil, use_pallas=True)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients (custom_vjp fused backward kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,i,h", [(7, 30, 50), (256, 128, 128), (13, 4, 8)])
def test_lstm_cell_grads_match_reference(b, i, h):
    """Every cotangent (dwx, dwh, db, dx, dh, dc) vs jax.grad of the oracle.

    Covers batch-grid accumulation (b=256 -> two BLOCK_B tiles) and the
    gate-block padding strips (i/h not lane-aligned)."""
    args = _setup(b, i, h, seed=b + 2 * i + h)
    rng = np.random.default_rng(b + 1)
    w1 = jnp.asarray(rng.normal(0, 1, (b, h)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 1, (b, h)), jnp.float32)

    def proj(cell_fn, *a):
        hn, cn = cell_fn(*a)
        return jnp.sum(hn * w1) + jnp.sum(cn * w2)

    g_ker = jax.grad(lambda *a: proj(ops.lstm_cell, *a),
                     argnums=tuple(range(6)))(*args)
    g_ref = jax.grad(lambda *a: proj(lstm_cell_ref, *a),
                     argnums=tuple(range(6)))(*args)
    names = ("dwx", "dwh", "db", "dx", "dh", "dc")
    for name, gk, gr in zip(names, g_ker, g_ref):
        scale = max(1.0, float(jnp.max(jnp.abs(gr))))
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=1e-5 * scale, err_msg=name)


def test_drnn_grad_use_pallas_matches():
    """Gradient through the full dilated stack (kernel cell inside scan)."""
    from repro.core.drnn import drnn_apply, drnn_init

    dil = ((1, 2), (2, 4))
    params = drnn_init(jax.random.PRNGKey(0), 6, 16, dil)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 9, 6))

    def proj(p, use_pallas):
        out, c_sq = drnn_apply(p, x, dilations=dil, use_pallas=use_pallas)
        return jnp.sum(jnp.tanh(out)) + c_sq

    g1 = jax.grad(lambda p: proj(p, False))(params)
    g2 = jax.grad(lambda p: proj(p, True))(params)
    for a, b_ in zip(jax.tree_util.tree_leaves(g1),
                     jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)
