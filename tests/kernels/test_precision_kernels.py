"""Mixed-precision contracts of the Pallas kernels.

The bf16 policy's kernel half: ``hw_scan`` must keep its recurrence state in
the *param* dtype (fp32) even when y streams in bf16, the fused LSTM cell
must match the pure bf16 cell (both accumulate gate dots in fp32 on the MXU),
and ``block_b_for`` must widen the batch tile for 2-byte streams.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.holt_winters import hw_init_params, hw_smooth
from repro.kernels import lstm_cell as _lstm
from repro.kernels import ops
from repro.kernels.ref import lstm_cell_ref


def _hw_setup(n, t, m, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(np.abs(rng.lognormal(2, 0.5, (n, t))) + 0.5, jnp.float32)
    p = hw_init_params(n, m, dtype=jnp.float32)
    p = dataclasses.replace(
        p,
        alpha_logit=jnp.asarray(rng.normal(0, 1, n), jnp.float32),
        gamma_logit=jnp.asarray(rng.normal(0, 1, n), jnp.float32),
        init_seas_logit=jnp.asarray(rng.normal(0, 0.2, (n, m)), jnp.float32),
    )
    return y, p


def test_block_b_for_widens_on_bf16():
    assert _lstm.block_b_for(jnp.float32) == _lstm.BLOCK_B
    assert _lstm.block_b_for(jnp.bfloat16) == 2 * _lstm.BLOCK_B
    assert _lstm.block_b_for(jnp.float16) == 2 * _lstm.BLOCK_B


@pytest.mark.parametrize("m", [1, 4])
def test_hw_scan_bf16_stream_fp32_state(m):
    """bf16 y against fp32 HW params: state stays fp32, values track fp32.

    The tolerance is the bf16 *input rounding* (y is quantized once on the
    way in), not accumulation drift -- the recurrence itself runs fp32.
    """
    y, p = _hw_setup(n=12, t=41, m=m, seed=m)
    lv32, ss32 = ops.hw_scan(y, p, seasonality=m)
    lv16, ss16 = ops.hw_scan(y.astype(jnp.bfloat16), p, seasonality=m)
    assert lv16.dtype == jnp.float32
    assert ss16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(lv16), np.asarray(lv32),
                               rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(ss16), np.asarray(ss32),
                               rtol=2e-2, atol=1e-2)


def test_hw_scan_bf16_matches_pure_hw_smooth():
    """Kernel vs pure-jnp path under the same bf16-y / fp32-params split."""
    y, p = _hw_setup(n=9, t=30, m=4, seed=7)
    y16 = y.astype(jnp.bfloat16)
    lv_k, ss_k = ops.hw_scan(y16, p, seasonality=4)
    lv_p, ss_p = hw_smooth(y16, p, seasonality=4, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lv_k), np.asarray(lv_p.astype(jnp.float32)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ss_k), np.asarray(ss_p.astype(jnp.float32)),
                               rtol=1e-4, atol=1e-4)


def _cell_setup(b, i, h, seed, dtype):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(0, 0.2, s), dtype)
    return (mk(i, 4 * h), mk(h, 4 * h), mk(4 * h), mk(b, i), mk(b, h), mk(b, h))


@pytest.mark.parametrize("b,i,h", [(9, 12, 16), (300, 30, 40)])
def test_lstm_cell_bf16_forward_matches_pure(b, i, h):
    """Fused kernel vs the pure bf16 cell (core.drnn path), not the fp32
    oracle: both sides quantize identically, so tolerances are tight."""
    from repro.core import drnn

    args = _cell_setup(b, i, h, seed=b + i, dtype=jnp.bfloat16)
    wx, wh, bb, x, hh, cc = args
    h_k, c_k = ops.lstm_cell(wx, wh, bb, x, hh, cc)
    h_p, c_p = drnn.lstm_cell({"wx": wx, "wh": wh, "b": bb}, x, hh, cc,
                              use_pallas=False)
    assert h_k.dtype == jnp.bfloat16 and c_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(h_k, np.float32),
                               np.asarray(h_p, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(c_k, np.float32),
                               np.asarray(c_p, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_lstm_cell_bf16_grads_track_fp32_reference():
    """bf16 kernel cotangents vs fp32 oracle grads: bf16-rounding-level
    agreement proves the backward dots accumulate wide despite emitting
    stream-dtype tensors."""
    b, i, h = 13, 30, 40
    args16 = _cell_setup(b, i, h, seed=3, dtype=jnp.bfloat16)
    args32 = tuple(a.astype(jnp.float32) for a in args16)
    rng = np.random.default_rng(4)
    w1 = jnp.asarray(rng.normal(0, 1, (b, h)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 1, (b, h)), jnp.float32)

    def proj(cell_fn, *a):
        hn, cn = cell_fn(*a)
        return (jnp.sum(hn.astype(jnp.float32) * w1)
                + jnp.sum(cn.astype(jnp.float32) * w2))

    g16 = jax.grad(lambda *a: proj(ops.lstm_cell, *a),
                   argnums=tuple(range(6)))(*args16)
    g32 = jax.grad(lambda *a: proj(lstm_cell_ref, *a),
                   argnums=tuple(range(6)))(*args32)
    names = ("dwx", "dwh", "db", "dx", "dh", "dc")
    for name, gk, gr in zip(names, g16, g32):
        scale = max(1.0, float(jnp.max(jnp.abs(gr))))
        np.testing.assert_allclose(np.asarray(gk, np.float32), np.asarray(gr),
                                   atol=0.03 * scale, err_msg=name)
