"""kernels/ops.py interpret-mode dispatch coverage.

``ESRNNConfig(use_pallas=True)`` routes the HW scan and the LSTM cell
through the Pallas kernels; off-TPU those run in interpret mode
(``kernels.ops._interpret()``), so the full kernel wiring -- padding to
hardware-aligned shapes, gate-block padding, constrained-space transforms,
stripping -- is exercised in CI without a TPU. The dispatch must be
numerically equivalent to the pure-jax path: same recurrence, same numbers
(float32 interpret mode vs XLA fusion; atol documented on each assert).

Both directions: the kernels carry custom_vjp rules (time-reversed adjoint
scan for hw_scan, fused gate-gradient kernel for lstm_cell), so
``jax.grad(esrnn_loss)`` with ``use_pallas=True`` must match the pure-jax
gradients on every param-tree leaf, and a full ``fit`` trajectory through
the public estimator must track the reference path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import (
    esrnn_forecast, esrnn_init, esrnn_loss, esrnn_loss_fn, make_config,
)
from repro.kernels import ops


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(7)
    n, t = 8, 40
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.4, (n, t))) + 1, jnp.float32)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    return y, cats


def _cfg(use_pallas):
    return make_config("quarterly", hidden_size=8, use_pallas=use_pallas)


def _max_leaf_diff(tree_a, tree_b):
    return float(max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: jnp.max(jnp.abs(a - b)), tree_a, tree_b))))


def test_interpret_mode_is_selected_off_tpu():
    if jax.default_backend() != "tpu":
        assert ops._interpret()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_esrnn_loss_runs_under_both_dispatches(batch, use_pallas):
    y, cats = batch
    cfg = _cfg(use_pallas)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    loss = esrnn_loss(cfg, params, y, cats)
    assert np.isfinite(float(loss))


def test_esrnn_loss_pallas_matches_pure_jax(batch):
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    ref = esrnn_loss(cfg_ref, params, y, cats)
    ker = esrnn_loss(cfg_k, params, y, cats)
    # same float32 recurrence, different fusion order: 1e-5 covers it
    np.testing.assert_allclose(float(ker), float(ref), rtol=1e-5, atol=1e-6)


def test_esrnn_forecast_pallas_matches_pure_jax(batch):
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    ref = esrnn_forecast(cfg_ref, params, y, cats)
    ker = esrnn_forecast(cfg_k, params, y, cats)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients: the kernel path must train
# ---------------------------------------------------------------------------


def test_esrnn_loss_grad_pallas_matches_pure_jax(batch):
    """jax.grad(esrnn_loss) equivalence on every param-tree leaf."""
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    l_ref, g_ref = jax.value_and_grad(
        lambda p: esrnn_loss(cfg_ref, p, y, cats))(params)
    l_ker, g_ker = jax.value_and_grad(
        lambda p: esrnn_loss(cfg_k, p, y, cats))(params)
    np.testing.assert_allclose(float(l_ker), float(l_ref), rtol=1e-5, atol=1e-6)
    assert _max_leaf_diff(g_ker, g_ref) <= 1e-5
    # gradients reach both param groups (not silently zero anywhere)
    assert float(jnp.max(jnp.abs(g_ker["hw"].alpha_logit))) > 0
    assert float(jnp.max(jnp.abs(g_ker["rnn"][0][0]["wx"]))) > 0


def test_esrnn_loss_grad_pallas_matches_with_mask(batch):
    """Same, under a variable-length observation mask."""
    y, cats = batch
    n, t = y.shape
    rng = np.random.default_rng(3)
    mask = np.ones((n, t), np.float32)
    for i in range(n):
        mask[i, : rng.integers(0, t // 3)] = 0.0   # ragged left-padding
    mask = jnp.asarray(mask)
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(1), cfg_ref, n)
    g_ref = jax.grad(lambda p: esrnn_loss(cfg_ref, p, y, cats, mask))(params)
    g_ker = jax.grad(lambda p: esrnn_loss(cfg_k, p, y, cats, mask))(params)
    assert _max_leaf_diff(g_ker, g_ref) <= 1e-5


def test_esrnn_loss_grad_wrt_inputs_matches(batch):
    """Cotangents to y itself (not just params) agree across dispatches."""
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    gy_ref = jax.grad(lambda yy: esrnn_loss_fn(cfg_ref, params, yy, cats))(y)
    gy_ker = jax.grad(lambda yy: esrnn_loss_fn(cfg_k, params, yy, cats))(y)
    np.testing.assert_allclose(np.asarray(gy_ker), np.asarray(gy_ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_fit_trajectory_pallas_matches_reference():
    """12-step smoke fit through the public estimator, kernels vs pure jax.

    Acceptance criterion of the trainable-kernel path: identical batch
    schedule + optimizer, gradients equal to float noise, so the loss
    trajectories and fitted forecasts must track (atol 1e-5 mirrors the
    sharded-vs-single-device fit bound in tests/distributed).
    """
    from repro.forecast import ESRNNForecaster, get_smoke_spec

    spec = get_smoke_spec("esrnn-quarterly", data_seed=5, n_steps=12,
                          batch_size=8, data_scale=0.0005)
    f_ref = ESRNNForecaster(spec).fit()
    f_ker = ESRNNForecaster(spec.replace(use_pallas=True)).fit()
    assert f_ker.spec.use_pallas and f_ker.config.use_pallas
    h_ref = np.asarray(f_ref.history_["loss"])
    h_ker = np.asarray(f_ker.history_["loss"])
    assert len(h_ref) == 12
    np.testing.assert_allclose(h_ker, h_ref, atol=1e-5)
    np.testing.assert_allclose(f_ker.predict(), f_ref.predict(),
                               rtol=1e-4, atol=1e-5)
