"""kernels/ops.py interpret-mode dispatch coverage.

``ESRNNConfig(use_pallas=True)`` routes the HW scan and the LSTM cell
through the Pallas kernels; off-TPU those run in interpret mode
(``kernels.ops._interpret()``), so the full kernel wiring -- padding to
hardware-aligned shapes, gate-block padding, constrained-space transforms,
stripping -- is exercised in CI without a TPU. The dispatch must be
numerically equivalent to the pure-jax path: same recurrence, same numbers
(float32 interpret mode vs XLA fusion; atol documented on each assert).

Forward equivalence only: ``pl.pallas_call`` has no JVP rule, so the kernel
path does not differentiate (training keeps ``use_pallas=False``; the
kernels serve the forward/serving path on TPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esrnn import esrnn_forecast, esrnn_init, esrnn_loss, make_config
from repro.kernels import ops


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(7)
    n, t = 8, 40
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.4, (n, t))) + 1, jnp.float32)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    return y, cats


def _cfg(use_pallas):
    return make_config("quarterly", hidden_size=8, use_pallas=use_pallas)


def test_interpret_mode_is_selected_off_tpu():
    if jax.default_backend() != "tpu":
        assert ops._interpret()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_esrnn_loss_runs_under_both_dispatches(batch, use_pallas):
    y, cats = batch
    cfg = _cfg(use_pallas)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, y.shape[0])
    loss = esrnn_loss(cfg, params, y, cats)
    assert np.isfinite(float(loss))


def test_esrnn_loss_pallas_matches_pure_jax(batch):
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    ref = esrnn_loss(cfg_ref, params, y, cats)
    ker = esrnn_loss(cfg_k, params, y, cats)
    # same float32 recurrence, different fusion order: 1e-5 covers it
    np.testing.assert_allclose(float(ker), float(ref), rtol=1e-5, atol=1e-6)


def test_esrnn_forecast_pallas_matches_pure_jax(batch):
    y, cats = batch
    cfg_ref, cfg_k = _cfg(False), _cfg(True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg_ref, y.shape[0])
    ref = esrnn_forecast(cfg_ref, params, y, cats)
    ker = esrnn_forecast(cfg_k, params, y, cats)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
