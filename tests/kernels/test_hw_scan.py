"""Pallas hw_scan kernel vs pure-jnp oracle: shape/dtype sweep."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.holt_winters import hw_init_params
from repro.kernels import ops
from repro.kernels.ref import hw_scan_ref


def _setup(n, t, m, seed, dtype):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(np.abs(rng.lognormal(2, 0.5, (n, t))) + 0.5, dtype)
    p = hw_init_params(n, m, dtype=dtype)
    p = dataclasses.replace(
        p,
        alpha_logit=jnp.asarray(rng.normal(0, 1, n), dtype),
        gamma_logit=jnp.asarray(rng.normal(0, 1, n), dtype),
        init_seas_logit=jnp.asarray(rng.normal(0, 0.2, (n, m)), dtype),
    )
    return y, p


@pytest.mark.parametrize("n", [1, 5, 128, 200])
@pytest.mark.parametrize("t", [8, 73])
@pytest.mark.parametrize("m", [1, 4, 12])
def test_hw_scan_shapes(n, t, m):
    y, p = _setup(n, t, m, seed=n * 1000 + t + m, dtype=jnp.float32)
    lv, ss = ops.hw_scan(y, p, seasonality=m)
    c = p.constrained()
    seas0 = c["init_seas"] if m > 1 else jnp.ones((n, m), y.dtype)
    gamma = c["gamma"] if m > 1 else jnp.zeros_like(c["gamma"])
    lv_ref, ss_ref = hw_scan_ref(y, c["alpha"], gamma, seas0)
    assert lv.shape == (n, t) and ss.shape == (n, t + m)
    np.testing.assert_allclose(lv, lv_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ss, ss_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.05)])
def test_hw_scan_dtypes(dtype, rtol):
    y, p = _setup(37, 40, 4, seed=0, dtype=jnp.float32)
    lv32, ss32 = ops.hw_scan(y, p, seasonality=4)
    yd = y.astype(dtype)
    pd = dataclasses.replace(
        p, alpha_logit=p.alpha_logit.astype(dtype),
        gamma_logit=p.gamma_logit.astype(dtype),
        init_seas_logit=p.init_seas_logit.astype(dtype))
    lv, ss = ops.hw_scan(yd, pd, seasonality=4)
    assert lv.dtype == dtype
    np.testing.assert_allclose(lv.astype(jnp.float32), lv32, rtol=rtol, atol=rtol)


def test_matches_hw_smooth_use_pallas_flag():
    from repro.core.holt_winters import hw_smooth

    y, p = _setup(9, 30, 4, seed=5, dtype=jnp.float32)
    lv1, ss1 = hw_smooth(y, p, seasonality=4, use_pallas=False)
    lv2, ss2 = hw_smooth(y, p, seasonality=4, use_pallas=True)
    np.testing.assert_allclose(lv1, lv2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ss1, ss2, rtol=1e-5, atol=1e-5)
