"""Pallas hw_scan kernel vs pure-jnp oracle: shape/dtype sweep + gradients.

The kernel carries a custom_vjp whose backward is the time-reversed adjoint
recurrence (kernels/hw_scan.py). Gradient coverage here: analytic-vs-autodiff
equivalence against the pure-jnp oracle, finite-difference spot checks on the
raw kernel cotangents, pad-lane gradient isolation, and the CPU
``_vmem_scratch`` fallback exercised for real in interpret mode.
"""

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.holt_winters import hw_init_params
from repro.kernels import hw_scan as hw_scan_mod
from repro.kernels import ops
from repro.kernels.ref import hw_scan_ref


def _setup(n, t, m, seed, dtype):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(np.abs(rng.lognormal(2, 0.5, (n, t))) + 0.5, dtype)
    p = hw_init_params(n, m, dtype=dtype)
    p = dataclasses.replace(
        p,
        alpha_logit=jnp.asarray(rng.normal(0, 1, n), dtype),
        gamma_logit=jnp.asarray(rng.normal(0, 1, n), dtype),
        init_seas_logit=jnp.asarray(rng.normal(0, 0.2, (n, m)), dtype),
    )
    return y, p


@pytest.mark.parametrize("n", [1, 5, 128, 200])
@pytest.mark.parametrize("t", [8, 73])
@pytest.mark.parametrize("m", [1, 4, 12])
def test_hw_scan_shapes(n, t, m):
    y, p = _setup(n, t, m, seed=n * 1000 + t + m, dtype=jnp.float32)
    lv, ss = ops.hw_scan(y, p, seasonality=m)
    c = p.constrained()
    seas0 = c["init_seas"] if m > 1 else jnp.ones((n, m), y.dtype)
    gamma = c["gamma"] if m > 1 else jnp.zeros_like(c["gamma"])
    lv_ref, ss_ref = hw_scan_ref(y, c["alpha"], gamma, seas0)
    assert lv.shape == (n, t) and ss.shape == (n, t + m)
    np.testing.assert_allclose(lv, lv_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ss, ss_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.05)])
def test_hw_scan_dtypes(dtype, rtol):
    y, p = _setup(37, 40, 4, seed=0, dtype=jnp.float32)
    lv32, ss32 = ops.hw_scan(y, p, seasonality=4)
    yd = y.astype(dtype)
    pd = dataclasses.replace(
        p, alpha_logit=p.alpha_logit.astype(dtype),
        gamma_logit=p.gamma_logit.astype(dtype),
        init_seas_logit=p.init_seas_logit.astype(dtype))
    lv, ss = ops.hw_scan(yd, pd, seasonality=4)
    assert lv.dtype == dtype
    np.testing.assert_allclose(lv.astype(jnp.float32), lv32, rtol=rtol, atol=rtol)


def test_matches_hw_smooth_use_pallas_flag():
    from repro.core.holt_winters import hw_smooth

    y, p = _setup(9, 30, 4, seed=5, dtype=jnp.float32)
    lv1, ss1 = hw_smooth(y, p, seasonality=4, use_pallas=False)
    lv2, ss2 = hw_smooth(y, p, seasonality=4, use_pallas=True)
    np.testing.assert_allclose(lv1, lv2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ss1, ss2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients (custom_vjp backward kernel)
# ---------------------------------------------------------------------------


def _weighted_sum(n, t, m, seed):
    """A fixed random linear functional of (levels, seas) -> scalar."""
    rng = np.random.default_rng(seed)
    wl = jnp.asarray(rng.normal(0, 1, (n, t)), jnp.float32)
    ws = jnp.asarray(rng.normal(0, 1, (n, t + m)), jnp.float32)
    return wl, ws


@pytest.mark.parametrize("n,t,m", [(5, 23, 4), (128, 10, 1), (40, 8, 12)])
def test_hw_scan_grad_matches_autodiff_reference(n, t, m):
    """Analytic backward kernel == jax.grad through the pure-jnp scan.

    Covers padding (n=5, 40), the non-seasonal m=1 path, and T < m. Grads
    are taken in the unconstrained HWParams space through ops.hw_scan, so
    the sigmoid/exp transforms and pad/strip plumbing are differentiated
    alongside the kernel.
    """
    y, p = _setup(n, t, m, seed=n + t + m, dtype=jnp.float32)
    wl, ws = _weighted_sum(n, t, m, seed=99)

    def proj_kernel(p, y):
        lv, ss = ops.hw_scan(y, p, seasonality=m)
        return jnp.sum(lv * wl) + jnp.sum(ss * ws)

    def proj_ref(p, y):
        c = p.constrained()
        seas0 = c["init_seas"] if m > 1 else jnp.ones((n, m), y.dtype)
        gamma = c["gamma"] if m > 1 else jnp.zeros_like(c["gamma"])
        lv, ss = hw_scan_ref(y, c["alpha"], gamma, seas0)
        return jnp.sum(lv * wl) + jnp.sum(ss * ws)

    gk_p, gk_y = jax.grad(proj_kernel, argnums=(0, 1))(p, y)
    gr_p, gr_y = jax.grad(proj_ref, argnums=(0, 1))(p, y)
    scale = max(1.0, float(jnp.max(jnp.abs(gr_y))))
    np.testing.assert_allclose(gk_y, gr_y, atol=1e-4 * scale)
    for leaf_k, leaf_r in zip(jax.tree_util.tree_leaves(gk_p),
                              jax.tree_util.tree_leaves(gr_p)):
        s = max(1.0, float(jnp.max(jnp.abs(leaf_r))))
        np.testing.assert_allclose(leaf_k, leaf_r, atol=1e-4 * s)


def test_hw_scan_cotangents_finite_difference():
    """Central-difference spot checks on raw hw_scan_tm cotangents."""
    rng = np.random.default_rng(11)
    n, t, m = 128, 12, 4
    y = jnp.asarray(np.abs(rng.lognormal(0.5, 0.3, (n, t))) + 0.5, jnp.float32)
    alpha = jnp.asarray(rng.uniform(0.3, 0.7, n), jnp.float32)
    gamma = jnp.asarray(rng.uniform(0.3, 0.7, n), jnp.float32)
    s0 = jnp.asarray(np.exp(rng.normal(0, 0.1, (m, n))), jnp.float32)
    wl, ws = _weighted_sum(n, t, m, seed=12)

    def f(y, alpha, gamma, s0):
        lv, ss = hw_scan_mod.hw_scan_tm(y.T, alpha, gamma, s0,
                                        interpret=True)
        return jnp.sum(lv.T * wl) + jnp.sum(ss.T * ws)

    grads = jax.grad(f, argnums=(0, 1, 2, 3))(y, alpha, gamma, s0)
    f64 = lambda *a: float(f(*a))
    eps = 1e-3
    # a few fixed coordinates in each input
    checks = [
        (0, y, [(0, 0), (3, 7), (100, t - 1)]),
        (1, alpha, [(5,), (77,)]),
        (2, gamma, [(9,), (50,)]),
        (3, s0, [(0, 4), (m - 1, 64)]),
    ]
    args = [y, alpha, gamma, s0]
    for argnum, arr, coords in checks:
        for coord in coords:
            delta = np.zeros(arr.shape, np.float32)
            delta[coord] = eps
            hi = list(args); hi[argnum] = arr + delta
            lo = list(args); lo[argnum] = arr - delta
            fd = (f64(*hi) - f64(*lo)) / (2 * eps)
            an = float(grads[argnum][coord])
            assert abs(fd - an) <= 2e-2 * max(1.0, abs(fd)), (
                f"argnum {argnum} coord {coord}: fd={fd} analytic={an}")


def test_pad_lane_grads_are_isolated():
    """Padded (N=120 -> 128) grads == unpadded (N=128) grads row-for-row.

    The recurrence is per-series independent, so lane padding must be
    invisible to gradients: any phantom cotangent scattered from a
    duplicated pad lane back into the last real lane would break this.
    """
    y_full, p_full = _setup(128, 20, 4, seed=2, dtype=jnp.float32)
    n_sub = 120
    p_sub = dataclasses.replace(
        p_full,
        alpha_logit=p_full.alpha_logit[:n_sub],
        gamma_logit=p_full.gamma_logit[:n_sub],
        init_seas_logit=p_full.init_seas_logit[:n_sub],
    )
    y_sub = y_full[:n_sub]

    def proj(p, y):
        lv, ss = ops.hw_scan(y, p, seasonality=4)
        return jnp.sum(jnp.log1p(jnp.square(lv))) + jnp.sum(jnp.sqrt(ss))

    g_full_p, g_full_y = jax.grad(proj, argnums=(0, 1))(p_full, y_full)
    g_sub_p, g_sub_y = jax.grad(proj, argnums=(0, 1))(p_sub, y_sub)
    np.testing.assert_array_equal(np.asarray(g_sub_y),
                                  np.asarray(g_full_y)[:n_sub])
    np.testing.assert_array_equal(np.asarray(g_sub_p.alpha_logit),
                                  np.asarray(g_full_p.alpha_logit)[:n_sub])
    np.testing.assert_array_equal(np.asarray(g_sub_p.gamma_logit),
                                  np.asarray(g_full_p.gamma_logit)[:n_sub])
    np.testing.assert_array_equal(np.asarray(g_sub_p.init_seas_logit),
                                  np.asarray(g_full_p.init_seas_logit)[:n_sub])


# ---------------------------------------------------------------------------
# _vmem_scratch CPU fallback
# ---------------------------------------------------------------------------


def test_vmem_scratch_fallback_is_constructible():
    """The no-pltpu fallback must build a real scratch allocation.

    Regression: it used to call ``pl.MemorySpace.ANY(shape, dtype)``, which
    is an enum member and not callable (TypeError hidden behind
    ``type: ignore`` + ``pragma: no cover``).
    """
    from jax.experimental import pallas as pl

    ref = pl.MemoryRef((4, 128), jnp.dtype(jnp.float32), pl.MemorySpace.ANY)
    assert ref.memory_space == pl.MemorySpace.ANY
    with pytest.raises(TypeError):
        pl.MemorySpace.ANY((4, 128), jnp.float32)  # the old broken call


def test_vmem_scratch_fallback_runs_in_interpret_mode(monkeypatch):
    """Force the except path and run the kernel end-to-end on it."""
    import jax.experimental.pallas as pl_pkg

    # make `from jax.experimental.pallas import tpu` fail inside
    # _vmem_scratch: drop the already-bound attribute and poison sys.modules
    monkeypatch.delattr(pl_pkg, "tpu", raising=False)
    monkeypatch.setitem(sys.modules, "jax.experimental.pallas.tpu", None)
    with pytest.raises(ImportError):
        from jax.experimental.pallas import tpu  # noqa: F401

    fallback = hw_scan_mod._vmem_scratch((4, 128), jnp.float32)
    from jax.experimental import pallas as pl

    assert isinstance(fallback, pl.MemoryRef)
    assert fallback.memory_space == pl.MemorySpace.ANY

    # odd T so the jit cache cannot reuse a trace built with pltpu.VMEM
    y, p = _setup(130, 31, 4, seed=8, dtype=jnp.float32)
    hw_scan_mod.hw_scan_tm.clear_cache()
    try:
        lv, ss = ops.hw_scan(y, p, seasonality=4)
        c = p.constrained()
        lv_ref, ss_ref = hw_scan_ref(y, c["alpha"], c["gamma"], c["init_seas"])
        np.testing.assert_allclose(lv, lv_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(ss, ss_ref, rtol=1e-5, atol=1e-5)
    finally:
        hw_scan_mod.hw_scan_tm.clear_cache()
