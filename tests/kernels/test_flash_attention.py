"""Pallas flash attention vs oracle: causal/GQA/decode/cross sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import attention_ref


def _qkv(b, hq, hkv, tq, tk, d, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, hq, tq, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, tk, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, tk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (16, 1)])
@pytest.mark.parametrize("tq,tk", [(64, 64), (64, 128), (1, 96), (33, 96)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(hq, hkv, tq, tk, causal):
    q, k, v = _qkv(2, hq, hkv, tq, tk, 32, seed=hq * tq + tk + causal)
    ref = attention_ref(q, k, v, causal=causal)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d", [16, 64, 128])
def test_head_dims(d):
    q, k, v = _qkv(1, 4, 2, 64, 64, d, seed=d)
    ref = attention_ref(q, k, v, causal=True)
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_bf16():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=0, dtype=jnp.bfloat16)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True)
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=0.05, atol=0.05)


def test_non_divisible_tk_snaps_block():
    """Tk=96 with requested block 64 -> snapped to a divisor (48/32/...)."""
    q, k, v = _qkv(1, 2, 2, 16, 96, 32, seed=3)
    ref = attention_ref(q, k, v, causal=True)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_chunked_attention_matches_kernel():
    """The pure-JAX chunked path (model default) == kernel == oracle."""
    from repro.models.attention import chunked_attention

    q, k, v = _qkv(2, 8, 4, 128, 128, 32, seed=9)
    ref = attention_ref(q, k, v, causal=True)
    chunked = chunked_attention(q, k, v, causal=True, scale=32 ** -0.5, q_chunk=32)
    kern = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(chunked, ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(kern, ref, rtol=2e-5, atol=2e-5)
