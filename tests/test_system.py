"""End-to-end behaviour tests for the paper's system.

The paper's claims, scaled to CPU test budgets:
1. the vectorized ES-RNN trains (loss falls) and beats seasonal-naive,
2. vectorized batching is faster than the per-series loop (Table 5's
   mechanism),
3. the framework trains an LM arch end-to-end with falling loss.
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L
from repro.core.esrnn import (
    esrnn_forecast, esrnn_init, esrnn_loss, esrnn_loss_fn,
    esrnn_loss_loop_reference, gather_series, make_config,
)
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn


@pytest.fixture(scope="module")
def trained():
    data = prepare(generate("quarterly", scale=0.004, seed=42))
    cfg = make_config("quarterly")
    out = train_esrnn(cfg, data, TrainConfig(
        batch_size=32, n_steps=60, lr=4e-3, eval_every=30, ckpt_dir=None))
    return cfg, data, out


def test_loss_decreases(trained):
    _, _, out = trained
    losses = out["history"]["loss"]
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_beats_seasonal_naive_on_validation(trained):
    cfg, data, out = trained
    m, o = data.seasonality, data.horizon
    fc = esrnn_forecast(cfg, out["params"], jnp.asarray(data.train),
                        jnp.asarray(data.cats))
    model_smape = float(L.smape(fc, jnp.asarray(data.val_target)))
    reps = -(-o // m)
    snaive = np.tile(data.train[:, -m:], (1, reps))[:, :o]
    naive_smape = float(L.smape(jnp.asarray(snaive), jnp.asarray(data.val_target)))
    assert model_smape < naive_smape, (model_smape, naive_smape)


def test_vectorized_program_is_batch_invariant():
    """Table 5's mechanism, asserted structurally (wall-clock on a shared
    single-core CI host is flaky; the timing variant below is opt-in).

    The vectorized loss traces to the SAME program regardless of how many
    series are batched -- one dispatch, one compile, work grows only inside
    ops. The per-series loop reference traces to a program that grows
    linearly in N (one jitted call per series): exactly the dispatch/compile
    overhead the paper's vectorization removes.
    """
    cfg = make_config("quarterly", hidden_size=8)
    rng = np.random.default_rng(0)

    def trace_eqns(fn, n):
        params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
        y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, 72))) + 1,
                        jnp.float32)
        c = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
        return len(jax.make_jaxpr(lambda p: fn(p, y, c))(params).eqns)

    vec = lambda p, y, c: esrnn_loss_fn(cfg, p, y, c)
    assert trace_eqns(vec, 4) == trace_eqns(vec, 8) == trace_eqns(vec, 16)

    loop = lambda p, y, c: esrnn_loss_loop_reference(cfg, p, y, c)
    e4, e8 = trace_eqns(loop, 4), trace_eqns(loop, 8)
    # each extra series adds at least one more dispatched call to the program
    assert e8 - e4 >= 4, (e4, e8)


@pytest.mark.slow
@pytest.mark.skipif(os.environ.get("ESRNN_TIMING") != "1",
                    reason="wall-clock speedup assert is flaky on shared "
                           "single-core hosts; opt in with ESRNN_TIMING=1")
def test_vectorized_faster_than_loop(trained):
    """Table 5's mechanism at test scale: batched >= 3x faster than looped."""
    cfg, data, out = trained
    n = min(24, data.n_series)
    params = gather_series(out["params"], slice(0, n))
    y = jnp.asarray(data.train[:n])
    c = jnp.asarray(data.cats[:n])

    esrnn_loss(cfg, params, y, c).block_until_ready()  # warm
    t0 = time.perf_counter()
    for _ in range(3):
        esrnn_loss(cfg, params, y, c).block_until_ready()
    t_vec = (time.perf_counter() - t0) / 3

    esrnn_loss_loop_reference(cfg, params, y, c)  # warm the per-series jit
    t0 = time.perf_counter()
    esrnn_loss_loop_reference(cfg, params, y, c)
    t_loop = time.perf_counter() - t0

    assert t_loop / t_vec > 3.0, (t_loop, t_vec)


def test_lm_training_loss_decreases():
    from repro.launch.train import train

    out = train("granite-3-2b", smoke=True, steps=14, batch=4, seq=64,
                lr=1e-3, microbatch=2)
    losses = out["losses"]
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
