"""Roofline cost-model tests: loop-aware jaxpr FLOPs + HLO walker."""

import jax
import jax.numpy as jnp


from repro.roofline.analysis import RooflineTerms, model_flops
from repro.roofline.hlo_walk import _type_bytes, analyze_hlo
from repro.roofline.jaxpr_cost import flops_of


def test_matmul_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    assert flops_of(f, a, b) == 2 * 64 * 128 * 32


def test_scan_multiplies_trip_count():
    def body(h, w):
        return h @ w, None

    def f(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 16, 16), jnp.float32)
    got = flops_of(f, h, ws)
    assert got >= 10 * 2 * 16 * 16 * 16
    assert got < 11 * 2 * 16 * 16 * 16  # only elementwise slack


def test_grad_includes_backward_flops():
    def f(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    fwd = flops_of(f, w, x)
    both = flops_of(jax.grad(f), w, x)
    assert both > 2 * fwd  # bwd of a matmul is 2 matmuls


def test_remat_recompute_counted():
    def layer(h, w):
        return jnp.tanh(h @ w)

    def f_plain(h, w):
        return jnp.sum(layer(h, w))

    f_remat = lambda h, w: jnp.sum(
        jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)(h, w))
    h = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    g_plain = flops_of(jax.grad(f_plain, argnums=1), h, w)
    g_remat = flops_of(jax.grad(f_remat, argnums=1), h, w)
    assert g_remat > g_plain  # recompute shows up -- the useful-flops signal


def test_type_bytes():
    assert _type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _type_bytes("bf16[8]") == 16
    assert _type_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert _type_bytes("pred[]") == 1


def test_hlo_walker_trip_counts():
    """8-step scanned matmul: walker bytes scale ~8x a single step."""
    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws1 = jax.ShapeDtypeStruct((1, 128, 128), jnp.float32)
    ws8 = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    b1 = analyze_hlo(jax.jit(f).lower(h, ws1).compile().as_text())
    b8 = analyze_hlo(jax.jit(f).lower(h, ws8).compile().as_text())
    ratio = b8["bytes_per_device"] / max(b1["bytes_per_device"], 1)
    assert 3.0 < ratio < 12.0


def test_roofline_terms_math():
    t = RooflineTerms(
        chips=256, flops_global=256 * 197e12, bytes_global=256 * 819e9,
        collective_global=0.0, collective_by_kind={},
        per_device_peak_memory=None, argument_bytes=None, temp_bytes=None,
        output_bytes=None)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert t.dominant in ("compute", "memory")
    assert model_flops(int(1e9), 1000) == 6e12


# ---------------------------------------------------------------------------
# jaxpr-level byte accounting (backend-independent precision yardstick)
# ---------------------------------------------------------------------------


def test_jaxpr_bytes_matmul_exact():
    from repro.roofline.jaxpr_cost import bytes_of, jaxpr_bytes_by_dtype

    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    # one dot eqn: in (8*16 + 16*4) + out (8*4) floats, 4 bytes each
    assert bytes_of(jnp.dot, a, b) == 4 * (8 * 16 + 16 * 4 + 8 * 4)
    by_dt = jaxpr_bytes_by_dtype(jax.make_jaxpr(jnp.dot)(a, b))
    assert set(by_dt) == {"float32"}


def test_jaxpr_bytes_scan_scales_with_trip_count():
    from repro.roofline.jaxpr_cost import bytes_of

    def body_scan(steps):
        def f(x):
            def step(c, _):
                return jnp.tanh(c), None
            return jax.lax.scan(step, x, None, length=steps)[0]
        return f

    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    b5 = bytes_of(body_scan(5), x)
    b10 = bytes_of(body_scan(10), x)
    # the tanh body dominates; doubling the trip count ~doubles the bytes
    assert b10 > 1.8 * b5


def test_jaxpr_bytes_halve_under_bf16():
    """The property the BENCH roofline ratio rests on: the same program in
    a 2-byte stream dtype accounts ~half the aval bytes."""
    from repro.roofline.jaxpr_cost import bytes_of

    def f(x):
        return jnp.tanh(x * 2.0 + 1.0)

    b32 = bytes_of(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    b16 = bytes_of(f, jax.ShapeDtypeStruct((256, 256), jnp.bfloat16))
    assert abs(b16 / b32 - 0.5) < 0.05


def test_jaxpr_bytes_by_dtype_splits_mixed_program():
    from repro.roofline.jaxpr_cost import jaxpr_bytes_by_dtype

    def f(x16, w32):
        # bf16 stream into an f32-emitting dot: both dtypes show up
        return jnp.dot(x16, w32.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)

    jaxpr = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((32, 64), jnp.bfloat16),
        jax.ShapeDtypeStruct((64, 16), jnp.float32),
    )
    by_dt = jaxpr_bytes_by_dtype(jaxpr)
    assert by_dt.get("bfloat16", 0) > 0
    assert by_dt.get("float32", 0) > 0
