"""Sharded end-to-end inference: predict / quantiles / evaluate / backtest.

Mirror of ``test_series_dp.py`` for the inference layer: the 8-device
checks run in a subprocess with forced host devices (XLA locks the device
count at first jax init); the in-process tests cover the spec/padding/
degenerate-mesh behaviour on the default backend.

Tolerances: forecasts are per-row device-local math (no collectives), so
sharded == single-device bit-for-bit in practice; asserted at rtol 1e-6.
Metrics go through ``psum(sum)/psum(count)`` -- exact global masked means,
equal to the single-device metric up to float32 summation order (<= 1e-6
relative; asserted absolutely at 1e-5 on sMAPE values ~ a few units, with
observed diffs ~1e-7).
"""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.forecast import ESRNNForecaster, get_smoke_spec
from repro.sharding import series


@pytest.fixture(scope="module")
def fitted():
    f = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3))
    f.fit(n_steps=4)
    return f


def test_mesh_on_available_devices_matches_plain(fitted):
    """Whatever the default backend offers (1 device in the plain tier-1
    run, 8 under the CI sharded-smoke job), the mesh path must agree."""
    f = fitted
    mesh = series.make_series_mesh(len(jax.devices()))
    np.testing.assert_allclose(f.predict(mesh=mesh), f.predict(), rtol=1e-6)
    e1, e8 = f.evaluate(), f.evaluate(mesh=mesh)
    assert abs(e1["smape"] - e8["smape"]) <= 1e-5
    assert abs(e1["mase"] - e8["mase"]) <= 1e-5
    b1, b8 = f.backtest(), f.backtest(mesh=mesh)
    assert abs(b1["smape"] - b8["smape"]) <= 1e-5
    np.testing.assert_allclose(b8["forecasts"], b1["forecasts"], rtol=1e-6)


def test_row_padding_strips_exactly(fitted):
    """N=19 on any mesh: rows pad to the device multiple and strip back."""
    f = fitted
    mesh = series.make_series_mesh(len(jax.devices()))
    p = f.predict(mesh=mesh)
    assert p.shape == (f.n_series_, f.horizon)
    q = f.predict_quantiles(mesh=mesh)
    assert all(v.shape == (f.n_series_, f.horizon) for v in q.values())


def test_backtest_requires_origins_with_custom_y(fitted):
    with pytest.raises(ValueError, match="origins"):
        fitted.backtest(y=fitted.data_.train)


def test_backtest_masks_horizon_past_series_end(fitted):
    """An origin H-1 steps from the end scores only the observed points."""
    f = fitted
    t_full = f.data_.val_input.shape[1] + f.data_.test_target.shape[1]
    bt = f.backtest(origins=(t_full - 1,))
    assert np.isfinite(bt["smape"])
    # only 1 of H target steps exists; the metrics still average something
    assert bt["per_origin"][0]["origin"] == t_full - 1


def test_backtest_default_origins_are_val_and_test(fitted):
    f = fitted
    bt = f.backtest()
    train_len = f.data_.train.shape[1]
    assert bt["origins"] == [train_len, train_len + f.data_.horizon]
    # the second origin scores the same window evaluate(split="test") does
    ev = f.evaluate(split="test")
    assert abs(bt["per_origin"][1]["smape"] - ev["smape"]) <= 1e-4
    # and the first origin's forecast IS predict-from-train
    np.testing.assert_allclose(
        bt["forecasts"][:, 0], f.predict(f.data_.train, f.data_.cats),
        rtol=1e-6)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.forecast import (
    BucketDispatcher, ESRNNForecaster, get_smoke_spec,
    synthetic_request_stream,
)
from repro.sharding.series import make_series_mesh

out = {"devices": len(jax.devices())}
mesh = make_series_mesh(8)


def run_variant(tag, spec):
    f = ESRNNForecaster(spec).fit()
    out[tag + "_n"] = int(f.n_series_)  # 19: exercises the pad/strip path

    p1, p8 = f.predict(), f.predict(mesh=mesh)
    out[tag + "_predict_reldiff"] = float(
        np.max(np.abs(p1 - p8) / np.abs(p1)))

    q1 = f.predict_quantiles(taus=(0.1, 0.9))
    q8 = f.predict_quantiles(taus=(0.1, 0.9), mesh=mesh)
    out[tag + "_quantile_reldiff"] = float(max(
        np.max(np.abs(q1[t] - q8[t]) / np.abs(q1[t])) for t in q1))

    e1, e8 = f.evaluate(), f.evaluate(mesh=mesh)
    out[tag + "_eval_absdiff"] = float(max(
        abs(e1[k] - e8[k]) for k in ("smape", "mase", "owa")))

    b1, b8 = f.backtest(), f.backtest(mesh=mesh)
    out[tag + "_backtest_absdiff"] = float(max(
        abs(b1["smape"] - b8["smape"]), abs(b1["mase"] - b8["mase"])))
    out[tag + "_backtest_fc_reldiff"] = float(
        np.max(np.abs(b1["forecasts"] - b8["forecasts"])
               / np.abs(b1["forecasts"])))
    return f


f = run_variant("plain", get_smoke_spec("esrnn-quarterly", data_seed=3,
                                        n_steps=6))
run_variant("pallas", get_smoke_spec("esrnn-quarterly", data_seed=3,
                                     n_steps=6, use_pallas=True))
# ragged variant: variable_length left-padding -> unequal per-shard valid
# counts in training AND ragged histories at inference time
run_variant("ragged", get_smoke_spec("esrnn-quarterly", data_seed=7,
                                     n_steps=6, variable_length=True,
                                     min_length=60))

# spec.data_parallel alone (no explicit mesh) routes inference sharded
fdp = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3,
                                     n_steps=6, data_parallel=8)).fit()
p_dp = fdp.predict()            # resolves its own 8-device mesh
f_ref = ESRNNForecaster(get_smoke_spec("esrnn-quarterly", data_seed=3,
                                       n_steps=6)).fit()
out["dp_spec_predict_reldiff"] = float(
    np.max(np.abs(p_dp - f_ref.predict()) / np.abs(p_dp)))

# sharded serving off a DP-fitted (device-sharded) table: host snapshot,
# numpy per-request gather, shard_map forecast
srv1 = BucketDispatcher(fdp.config, fdp.params_,
                             length_buckets=(32, 64),
                             batch_buckets=(1, 4, 16))
srv8 = BucketDispatcher(fdp.config, fdp.params_,
                             length_buckets=(32, 64),
                             batch_buckets=(1, 4, 16), mesh=mesh)
out["serve_table_is_host_numpy"] = all(
    isinstance(a, np.ndarray)
    for a in jax.tree_util.tree_leaves(srv8._host_table.hw))
reqs = synthetic_request_stream(fdp.config, 23, n_known=fdp.n_series_,
                                seed=0)
o1 = srv1.forecast_batch(reqs)
o8 = srv8.forecast_batch(reqs)
out["serve_reldiff"] = float(max(
    np.max(np.abs(a - b) / np.abs(a)) for a, b in zip(o1, o8)))
compiles_w1 = srv8.stats.compiles
srv8.forecast_batch(reqs)       # wave 2: every bucket shape already built
out["serve_wave2_new_compiles"] = int(srv8.stats.compiles - compiles_w1)
out["serve_cache_hits"] = int(srv8.stats.cache_hits)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_inference_matches_single_device_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    # the plain/pallas variants have N=19: pad/strip path exercised
    assert out["plain_n"] % 8 != 0, "want the pad/strip path exercised"
    for tag in ("plain", "pallas", "ragged"):
        assert out[f"{tag}_predict_reldiff"] <= 1e-6, (tag, out)
        assert out[f"{tag}_quantile_reldiff"] <= 1e-6, (tag, out)
        assert out[f"{tag}_eval_absdiff"] <= 1e-6, (tag, out)
        assert out[f"{tag}_backtest_absdiff"] <= 1e-6, (tag, out)
        assert out[f"{tag}_backtest_fc_reldiff"] <= 1e-6, (tag, out)
    # spec.data_parallel routes inference sharded without an explicit mesh
    assert out["dp_spec_predict_reldiff"] <= 1e-6, out
    # serving: host-resident table (regression: per-request primer/known-row
    # resolution must never gather the sharded device table) + equivalence
    assert out["serve_table_is_host_numpy"], out
    assert out["serve_reldiff"] <= 1e-6, out
    assert out["serve_wave2_new_compiles"] == 0, out
    assert out["serve_cache_hits"] > 0, out
