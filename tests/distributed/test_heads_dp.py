"""Sharded fit/predict parity per pluggable head (lstm / esn / ssm).

The head registry's sharding story is structural: every head keeps its
trained weights in replicated top-level groups and its per-series state in
``"hw"`` only, so the series-DP param specs, the exact psum'd masked-mean
loss, and the sharded inference path are head-agnostic by construction.
This test forces 8 host devices in a subprocess (XLA locks the device
count at first init) and asserts, for each head, that

* an 8-way ``data_parallel`` fit reproduces the single-device fit
  (final-loss and forecast parity <= 1e-6), and
* sharded predict off one fitted table == single-device predict,
* the esn reservoir stays bit-frozen under the sharded fit too.
"""

import json
import subprocess
import sys

import pytest

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.forecast import ESRNNForecaster, get_smoke_spec
from repro.sharding.series import make_series_mesh

out = {"devices": len(jax.devices())}
mesh = make_series_mesh(8)

for head in ("lstm", "esn", "ssm"):
    name = {"lstm": "esrnn"}.get(head, head) + "-quarterly"
    spec = get_smoke_spec(name, data_seed=3, n_steps=6)

    f1 = ESRNNForecaster(spec)
    data = f1.make_data()
    f1.init_params(data.n_series)
    f8 = ESRNNForecaster(spec.replace(data_parallel=8))
    f8.init_params(data.n_series)
    rnn_init = (jax.tree_util.tree_map(np.asarray, f8.params_["rnn"])
                if head == "esn" else None)
    f1.fit(data)
    f8.fit(data)

    out[head + "_loss_absdiff"] = float(abs(
        f1.history_["loss"][-1] - f8.history_["loss"][-1]))
    p1 = f1.predict()
    p8dp = f8.predict()             # resolves its own 8-device mesh
    out[head + "_fit_predict_reldiff"] = float(
        np.max(np.abs(p1 - p8dp) / np.abs(p1)))
    # sharded predict off the single-device table
    p1m = f1.predict(mesh=mesh)
    out[head + "_predict_reldiff"] = float(
        np.max(np.abs(p1 - p1m) / np.abs(p1)))
    e1, e8 = f1.evaluate(), f8.evaluate(mesh=mesh)
    out[head + "_owa_absdiff"] = float(abs(e1["owa"] - e8["owa"]))

    if head == "esn":
        out["esn_reservoir_frozen_sharded"] = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(rnn_init),
                            jax.tree_util.tree_leaves(f8.params_["rnn"])))

print(json.dumps(out))
"""


@pytest.mark.slow
def test_every_head_fit_and_predict_parity_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    for head in ("lstm", "esn", "ssm"):
        assert out[f"{head}_loss_absdiff"] <= 1e-6, (head, out)
        assert out[f"{head}_fit_predict_reldiff"] <= 1e-6, (head, out)
        assert out[f"{head}_predict_reldiff"] <= 1e-6, (head, out)
        assert out[f"{head}_owa_absdiff"] <= 1e-5, (head, out)
    assert out["esn_reservoir_frozen_sharded"], out
