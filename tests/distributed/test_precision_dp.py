"""bf16 policy under series-DP sharding: 8 forced host devices.

The mixed-precision path must be *sharding-transparent*: the policy casts
happen inside the per-shard compute, the psum'd masked-mean loss and the
per-series HW table stay fp32 on every shard. Unlike the fp32 path (1e-6
parity in test_heads_dp.py), bf16 parity is bounded by quantization, not
layout: GSPMD partitioning changes which f32 intermediates get rounded to
bf16, so sharded-vs-single differences sit at the bf16 ulp scale (~1e-4
after a short fit) -- the tolerances here pin that budget so a real
divergence (sharded math changing, state dropping to bf16) still fails.
Also checks the sharded predict roofline probe emits finite numbers and
the bf16/fp32 byte ratio survives partitioning.
"""

import json
import subprocess
import sys

import pytest

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.forecast import ESRNNForecaster, get_smoke_spec
from repro.sharding.series import make_series_mesh

out = {"devices": len(jax.devices())}
mesh = make_series_mesh(8)

spec = get_smoke_spec("esrnn-quarterly", data_seed=3, n_steps=6)
spec16 = spec.replace(precision="bf16")

f1 = ESRNNForecaster(spec16)
data = f1.make_data()
f1.init_params(data.n_series)
f8 = ESRNNForecaster(spec16.replace(data_parallel=8))
f8.init_params(data.n_series)
f1.fit(data)
f8.fit(data)

out["loss_absdiff"] = float(abs(
    f1.history_["loss"][-1] - f8.history_["loss"][-1]))
p1 = f1.predict()
p8 = f8.predict()
out["fit_predict_reldiff"] = float(np.max(np.abs(p1 - p8) / np.abs(p1)))
p1m = f1.predict(mesh=mesh)
out["predict_reldiff"] = float(np.max(np.abs(p1 - p1m) / np.abs(p1)))
e1, e8 = f1.evaluate(), f8.evaluate(mesh=mesh)
out["owa_absdiff"] = float(abs(e1["owa"] - e8["owa"]))
out["hw_f32"] = all(
    str(l.dtype) == "float32"
    for l in jax.tree_util.tree_leaves(f8.params_["hw"]))

# sharded predict roofline probe: finite terms + the bf16 byte saving
# survives GSPMD partitioning
from repro.core.esrnn import make_config
from repro.roofline.esrnn import predict_roofline
import dataclasses

cfg32 = make_config("quarterly")
r32 = predict_roofline(cfg32, mesh=mesh)
r16 = predict_roofline(dataclasses.replace(cfg32, precision="bf16"), mesh=mesh)
out["sharded_predict_bytes_finite"] = bool(
    np.isfinite(r32.jaxpr_bytes) and np.isfinite(r16.jaxpr_bytes)
    and r32.jaxpr_bytes > 0)
out["sharded_predict_bytes_ratio"] = float(r16.jaxpr_bytes / r32.jaxpr_bytes)

print(json.dumps(out))
"""


@pytest.mark.slow
def test_bf16_sharded_fit_predict_parity_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["loss_absdiff"] <= 1e-6, out
    # bf16-ulp budget (see module docstring), not the fp32 paths' 1e-6
    assert out["fit_predict_reldiff"] <= 1e-3, out
    assert out["predict_reldiff"] <= 1e-3, out
    assert out["owa_absdiff"] <= 1e-3, out
    assert out["hw_f32"], out
    assert out["sharded_predict_bytes_finite"], out
    # the policy's byte saving must survive partitioning (<= 0.65 gate is
    # enforced on the fit program in CI; predict is typically lower still)
    assert out["sharded_predict_bytes_ratio"] <= 0.75, out
