"""Distributed tests: sharding specs + an 8-virtual-device mini dry-run.

The multi-device test runs in a subprocess because XLA locks the host device
count at first jax init (the main test process must keep seeing 1 device).
"""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.sharding import specs


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs.set_mesh(mesh)
    axes = {"dp": "data", "tp": "model"}

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    class K:
        def __init__(self, key):
            self.key = key

    # every named dim divides 1, so no divisibility fallbacks here
    assert specs.param_spec((K("embed"),), Leaf((100, 64)), axes) == P("model", "data")
    assert specs.param_spec((K("layers"), K("attn"), K("wq")), Leaf((4, 64, 128)), axes) \
        == P(None, "data", "model")
    assert specs.param_spec((K("layers"), K("attn"), K("wo")), Leaf((4, 128, 64)), axes) \
        == P(None, "model", "data")
    assert specs.param_spec((K("layers"), K("moe"), K("w_gate")), Leaf((4, 8, 64, 32)), axes) \
        == P(None, "model", "data", None)
    assert specs.param_spec((K("layers"), K("ssm"), K("w_in")), Leaf((4, 64, 200)), axes) \
        == P(None, "data", None)
    assert specs.param_spec((K("final_norm"), K("scale")), Leaf((64,)), axes) == P(None)


def test_divisibility_guard():
    from jax.sharding import PartitionSpec as P

    specs._MESH = None  # no mesh -> sizes default 1 -> everything "divides"

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    class K:
        def __init__(self, key):
            self.key = key

    spec = specs.param_spec((K("embed"),), Leaf((100, 64)),
                            {"dp": "data", "tp": "model"})
    assert spec == P("model", "data")


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import numpy as np

from repro.configs import ShapeCell, get_smoke_config
from repro.launch import steps as S
from repro.models.model import build_model
from repro.roofline import analysis
from repro.roofline.jaxpr_cost import jaxpr_flops
from repro.sharding import specs
from repro.sharding.ctx import activation_sharding

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("yi-6b")
cell = ShapeCell("t", "train", 32, 8, microbatch=4)
model = build_model(cfg)
specs.set_mesh(mesh)
axes = specs.axes_for(mesh)
batch_abs = S.batch_template(cfg, cell)
batch_sh = specs.batch_shardings(mesh, batch_abs, cell.global_batch)
with mesh, activation_sharding(mesh, dp=axes["dp"], tp=axes["tp"]):
    params_abs = S.abstract_params(model, master_fp32=True)
    params_sh = specs.param_shardings(mesh, params_abs)
    opt_abs = S.abstract_opt_state(params_abs)
    opt_sh = {"mu": params_sh, "nu": params_sh, "step": NamedSharding(mesh, P())}
    fn = S.make_train_step(model, cell)
    jitted = jax.jit(fn, in_shardings=(params_sh, opt_sh, batch_sh),
                     out_shardings=(params_sh, opt_sh, NamedSharding(mesh, P())))
    traced = jitted.trace(params_abs, opt_abs, batch_abs)
    flops = jaxpr_flops(traced.jaxpr)
    compiled = traced.lower().compile()
    terms = analysis.analyze(compiled, 8, flops_global=flops)

    # actually RUN the sharded step on the 8 virtual devices
    rng = np.random.default_rng(0)
    params = jax.tree_util.tree_map(
        lambda l, s: jax.device_put(jnp.zeros(l.shape, l.dtype) + 0.01, s),
        params_abs, params_sh)
    params = jax.tree_util.tree_map(
        lambda x: x if x.ndim else x, params)
    # proper init instead of zeros for stability
    p0 = model.init(jax.random.PRNGKey(0))
    p0 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, p0)
    params = jax.tree_util.tree_map(jax.device_put, p0, params_sh)
    from repro.train.optimizer import adam_init
    opt = jax.tree_util.tree_map(jax.device_put, adam_init(params),
                                 {"mu": params_sh, "nu": params_sh,
                                  "step": NamedSharding(mesh, P())})
    batch = {
        "tokens": jax.device_put(
            jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
            batch_sh["tokens"]),
        "labels": jax.device_put(
            jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
            batch_sh["labels"]),
    }
    p2, o2, loss = jitted(params, opt, batch)
    print(json.dumps({
        "devices": len(jax.devices()),
        "loss": float(loss),
        "flops": terms.flops_global,
        "collective": terms.collective_global,
        "dominant": terms.dominant,
    }))
"""


@pytest.mark.slow
def test_mini_dryrun_and_real_step_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560, env=None, cwd=None)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert np.isfinite(out["loss"]) and out["loss"] > 0
    assert out["flops"] > 0
    assert out["collective"] > 0  # sharded training must communicate
