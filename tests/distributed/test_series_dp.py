"""Series-data-parallel ES-RNN: sharded vs single-device equivalence.

The multi-device checks run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` because XLA locks the
host device count at first jax init (the main test process must keep seeing
one device). Spec/guard tests run in-process on the 1-device mesh.

Tolerances (documented, asserted below): the shard_map path evaluates the
same math with per-shard partial sums psum-reduced into a single global
masked mean (exact even for unequal per-shard mask counts), so results
differ from the single-device batch mean only by float32 summation order --
|loss_dp - loss| <= 1e-6 per evaluation, and <= 5e-7 * step accumulated
drift over an Adam trajectory (we assert atol=1e-5 over 12 smoke steps,
~400x headroom on what we observe, ~2e-8).
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.core.esrnn import esrnn_init, make_config
from repro.sharding import series


def test_param_specs_shard_hw_only():
    cfg = make_config("quarterly", hidden_size=8, attention=True)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n_series=4)
    specs = series.esrnn_param_specs(params)
    hw_specs = jax.tree_util.tree_leaves(
        specs["hw"], is_leaf=lambda x: isinstance(x, P))
    assert hw_specs and all(s == P("series") for s in hw_specs)
    for group in ("rnn", "head", "attn"):
        leaves = jax.tree_util.tree_leaves(
            specs[group], is_leaf=lambda x: isinstance(x, P))
        assert leaves and all(s == P() for s in leaves), group


def test_param_shardings_match_tree():
    cfg = make_config("quarterly", hidden_size=8)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n_series=4)
    mesh = series.make_series_mesh(1)
    sh = series.esrnn_param_shardings(mesh, params)
    assert sh["hw"].alpha_logit.spec == P("series")
    assert sh["head"]["dense_w"].spec == P()
    # structure mirrors params exactly (same keys, incl. optional leaves)
    jax.tree_util.tree_map(lambda a, b: None, params, sh,
                           is_leaf=lambda x: x is None)


def test_divisibility_guard_raises():
    mesh = series.make_series_mesh(1)
    assert series.check_series_divisible(5, mesh) == 1
    with pytest.raises(ValueError, match="does not divide"):
        # fake a 8-wide mesh requirement via a simple stand-in object
        class FakeDevices:
            size = 8

        class FakeMesh:
            devices = FakeDevices()
            axis_names = ("series",)

        series.check_series_divisible(12, FakeMesh())


def test_make_series_mesh_rejects_unavailable_devices():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="are available"):
        series.make_series_mesh(n + 1)


def test_masked_mean_exact_on_available_devices():
    """psum(sum)/psum(count) masked-mean semantics on the default backend.

    With one device this degenerates to the single-device mean; under the
    CI sharded-smoke job (8 forced host devices) the shards carry unequal
    valid-target counts and the equality is the real exactness check (the
    8-device-from-1-process variant lives in the subprocess test below).
    """
    from repro.core.esrnn import esrnn_loss

    d = len(jax.devices())
    mesh = series.make_series_mesh(d)
    cfg = make_config("quarterly", hidden_size=8)
    rng = np.random.default_rng(1)
    n, t = 2 * d, 60
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, t))).astype(np.float32) + 1)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    mask = np.ones((n, t), np.float32)
    for i in range(n):
        mask[i, : rng.integers(0, t // 3)] = 0.0  # ragged -> unequal shards
    mask = jnp.asarray(mask)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    l_single = esrnn_loss(cfg, params, y, cats, mask)
    l_dp = series.esrnn_loss_dp(cfg, params, y, cats, mask, mesh=mesh)
    assert abs(float(l_single) - float(l_dp)) <= 1e-6


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np

from repro.core.esrnn import esrnn_init, esrnn_loss, make_config
from repro.forecast import ESRNNForecaster, get_smoke_spec
from repro.sharding.series import esrnn_loss_dp, make_series_mesh

out = {"devices": len(jax.devices())}
mesh = make_series_mesh(8)

# -- direct loss + grad equivalence on random series ------------------------
cfg = make_config("quarterly", hidden_size=8)
rng = np.random.default_rng(0)
n = 16
y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, 72))).astype(np.float32) + 1)
cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
l_single = esrnn_loss(cfg, params, y, cats)
l_dp = esrnn_loss_dp(cfg, params, y, cats, mesh=mesh)
out["loss_absdiff"] = float(abs(l_single - l_dp))

g_single = jax.grad(lambda p: esrnn_loss(cfg, p, y, cats))(params)
g_dp = jax.grad(lambda p: esrnn_loss_dp(cfg, p, y, cats, mesh=mesh))(params)
out["grad_absdiff"] = float(max(
    jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: jnp.max(jnp.abs(a - b)), g_single, g_dp))))

# the backward pass must all-reduce the replicated shared-weight grads
hlo = (jax.jit(jax.grad(lambda p: esrnn_loss_dp(cfg, p, y, cats, mesh=mesh)))
       .lower(params).compile().as_text())
out["grad_has_all_reduce"] = "all-reduce" in hlo

# -- exact global masked mean under unequal per-shard mask counts -----------
# ragged left-padding: every series (and so every 2-series shard) has a
# different valid-target count; psum(sum)/psum(count) must still equal the
# single-device masked mean (the old per-shard-mean pmean did not)
mask = np.ones((n, 72), np.float32)
for i in range(n):
    mask[i, : rng.integers(0, 30)] = 0.0
mask = jnp.asarray(mask)
counts = [float(mask[s : s + 2].sum()) for s in range(0, n, 2)]
out["shard_mask_counts_unequal"] = len(set(counts)) > 1
l_single_m = esrnn_loss(cfg, params, y, cats, mask)
l_dp_m = esrnn_loss_dp(cfg, params, y, cats, mask, mesh=mesh)
out["masked_loss_absdiff"] = float(abs(l_single_m - l_dp_m))
g_single_m = jax.grad(lambda p: esrnn_loss(cfg, p, y, cats, mask))(params)
g_dp_m = jax.grad(
    lambda p: esrnn_loss_dp(cfg, p, y, cats, mask, mesh=mesh))(params)
out["masked_grad_absdiff"] = float(max(
    jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: jnp.max(jnp.abs(a - b)), g_single_m, g_dp_m))))

# -- Pallas kernel path composes with shard_map -----------------------------
cfg_k = make_config("quarterly", hidden_size=8, use_pallas=True)
l_dp_k = esrnn_loss_dp(cfg_k, params, y, cats, mask, mesh=mesh)
out["pallas_dp_loss_absdiff"] = float(abs(l_single_m - l_dp_k))
g_dp_k = jax.grad(
    lambda p: esrnn_loss_dp(cfg_k, p, y, cats, mask, mesh=mesh))(params)
out["pallas_dp_grad_absdiff"] = float(max(
    jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: jnp.max(jnp.abs(a - b)), g_single_m, g_dp_k))))

# -- fit equivalence through the public estimator ---------------------------
spec = get_smoke_spec("esrnn-quarterly", data_seed=3, n_steps=12)
f_single = ESRNNForecaster(spec).fit()
f_dp = ESRNNForecaster(spec.replace(data_parallel=8)).fit()
h1 = np.asarray(f_single.history_["loss"])
h2 = np.asarray(f_dp.history_["loss"])
out["n_steps"] = len(h1)
out["fit_loss_absdiff"] = float(np.max(np.abs(h1 - h2)))
p1, p2 = f_single.predict(), f_dp.predict()
out["predict_reldiff"] = float(np.max(np.abs(p1 - p2) / np.abs(p1)))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_fit_matches_single_device_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    # single loss/grad evaluation: float-summation-order noise only
    assert out["loss_absdiff"] <= 1e-6, out
    assert out["grad_absdiff"] <= 1e-6, out
    # shared-weight grads are psum'd across the series axis
    assert out["grad_has_all_reduce"], "dp grad compiled without a collective"
    # exact global masked mean: unequal per-shard valid counts still match
    # the single-device masked mean (psum(sum)/psum(count) semantics)
    assert out["shard_mask_counts_unequal"], "test data failed to be ragged"
    assert out["masked_loss_absdiff"] <= 1e-6, out
    assert out["masked_grad_absdiff"] <= 1e-6, out
    # the trainable Pallas kernel path composes with shard_map
    assert out["pallas_dp_loss_absdiff"] <= 1e-6, out
    assert out["pallas_dp_grad_absdiff"] <= 1e-6, out
    # full smoke fit through ESRNNForecaster: documented atol=1e-5 over the
    # 12-step Adam trajectory (observed ~2e-8); forecasts track to 1e-4 rel
    assert out["n_steps"] == 12
    assert out["fit_loss_absdiff"] <= 1e-5, out
    assert out["predict_reldiff"] <= 1e-4, out
