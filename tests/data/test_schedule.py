"""Stateless batch schedule: the cached permutation + materialized array.

Separate from test_pipeline.py on purpose: that module gates on
``pytest.importorskip("hypothesis")``, and these contracts -- which the
fused engine's resume correctness rides on -- must run even where
hypothesis is not installed (CI does not install it).
"""

import numpy as np

from repro.data.pipeline import batch_indices, batch_schedule, epoch_permutation


def test_epoch_permutation_cached_and_bit_identical():
    """The cache returns the exact draw the stateless contract promises."""
    a = epoch_permutation(37, 4, 9)
    b = epoch_permutation(37, 4, 9)
    assert a is b                       # cache hit: same frozen array
    assert not a.flags.writeable
    rng = np.random.default_rng(np.random.SeedSequence([9, 4]))
    np.testing.assert_array_equal(a, rng.permutation(37))
    # batch_indices slices the cache but hands out private writable copies
    epoch0 = epoch_permutation(37, 0, 9)   # step 0 -> epoch 0
    out = batch_indices(37, 8, 0, seed=9)
    np.testing.assert_array_equal(out, epoch0[:8])
    out[0] = -1                        # must not poison the cache
    np.testing.assert_array_equal(batch_indices(37, 8, 0, seed=9), epoch0[:8])


def test_batch_indices_covers_epoch_and_wraps():
    """An epoch's batches tile the permutation; the tail wraps to its head."""
    n, bs = 13, 5                      # steps_per_epoch=3, last step wraps
    perm = epoch_permutation(n, 0, 3)
    batches = [batch_indices(n, bs, s, seed=3) for s in range(3)]
    np.testing.assert_array_equal(np.concatenate(batches)[:n], perm)
    np.testing.assert_array_equal(batches[-1][-2:], perm[:2])  # static shape


def test_batch_schedule_matches_batch_indices():
    """(steps, batch) array == the per-step calls, incl. wrap + resume."""
    n, bs = 13, 5
    full = batch_schedule(n, bs, 0, 9, seed=7)
    assert full.shape == (9, bs)
    for s in range(9):
        np.testing.assert_array_equal(full[s],
                                      batch_indices(n, bs, s, seed=7))
    # stateless in start_step: a resumed slice is the same global schedule
    resumed = batch_schedule(n, bs, 4, 5, seed=7)
    np.testing.assert_array_equal(resumed, full[4:])
    assert batch_schedule(n, bs, 3, 0, seed=7).shape == (0, bs)


def test_perm_cache_bounded_by_bytes_with_lru_eviction():
    """The byte bound evicts least-recently-used permutations, and an entry
    larger than the whole budget is handed out uncached."""
    from repro.data.pipeline import _BoundedPermCache

    cache = _BoundedPermCache(max_bytes=200)
    draw = lambda n: (lambda: np.arange(n))        # int64: 8 bytes/row
    a = cache.get_or_draw(("a",), draw(10))        # 80 bytes
    cache.get_or_draw(("b",), draw(10))            # 160
    assert cache.get_or_draw(("a",), draw(10)) is a    # hit bumps "a"
    cache.get_or_draw(("c",), draw(10))            # 240 -> evicts LRU "b"
    assert cache.nbytes <= 200
    assert cache.get_or_draw(("a",), draw(10)) is a    # survived
    b2 = cache.get_or_draw(("b",), draw(10))       # redrawn after eviction
    assert not b2.flags.writeable
    big = cache.get_or_draw(("big",), draw(100))   # 800 bytes > budget
    assert not big.flags.writeable
    assert ("big",) not in cache._entries          # returned uncached
    assert cache.nbytes <= 200


def test_chunk_schedule_covers_every_row_each_epoch():
    """Chunk-pure batches whose union is exactly [0, N) per epoch."""
    from repro.data.pipeline import (
        chunk_batch_schedule, chunk_layout, chunk_visit_plan)

    n, chunk, bs = 19, 8, 4
    per_chunk, spe = chunk_layout(n, chunk, bs)
    assert [(lo, hi) for lo, hi, _, _ in per_chunk] == [(0, 8), (8, 16),
                                                        (16, 19)]
    assert [bs_c for _, _, bs_c, _ in per_chunk] == [4, 4, 3]  # ragged tail
    assert spe == 2 + 2 + 1
    visits = list(chunk_visit_plan(n, chunk, bs, 0, spe, seed=5))
    assert sorted(v.chunk_id for v in visits) == [0, 1, 2]  # each once
    seen = set()
    for v in visits:
        sched = chunk_batch_schedule(v.hi - v.lo, v.batch_size, v.epoch,
                                     v.chunk_id, v.start_k, v.n_steps,
                                     seed=5)
        assert sched.shape == (v.n_steps, v.batch_size)
        assert sched.min() >= 0 and sched.max() < v.hi - v.lo  # chunk-local
        seen.update((v.lo + sched).ravel().tolist())
    assert seen == set(range(n))


def test_chunk_visit_plan_stateless_resume():
    """Re-entering the plan at any global step replays the same schedule,
    including a mid-visit entry (start_k > 0)."""
    from repro.data.pipeline import chunk_visit_plan

    n, chunk, bs, total = 19, 8, 4, 12

    def expand(vs):
        return [(v.epoch, v.chunk_id, v.lo, v.hi, v.batch_size,
                 v.start_k + i, v.step + i)
                for v in vs for i in range(v.n_steps)]

    full = expand(chunk_visit_plan(n, chunk, bs, 0, total, seed=3))
    assert [t[-1] for t in full] == list(range(total))  # every global step
    for start in (3, 7, 11):
        resumed = expand(chunk_visit_plan(n, chunk, bs, start, total, seed=3))
        assert resumed == full[start:], start
    start = next(s for s, t in enumerate(full) if t[5] > 0)  # mid-visit step
    mid = list(chunk_visit_plan(n, chunk, bs, start, total, seed=3))[0]
    assert mid.start_k > 0                      # landed inside a visit
