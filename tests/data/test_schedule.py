"""Stateless batch schedule: the cached permutation + materialized array.

Separate from test_pipeline.py on purpose: that module gates on
``pytest.importorskip("hypothesis")``, and these contracts -- which the
fused engine's resume correctness rides on -- must run even where
hypothesis is not installed (CI does not install it).
"""

import numpy as np

from repro.data.pipeline import batch_indices, batch_schedule, epoch_permutation


def test_epoch_permutation_cached_and_bit_identical():
    """The cache returns the exact draw the stateless contract promises."""
    a = epoch_permutation(37, 4, 9)
    b = epoch_permutation(37, 4, 9)
    assert a is b                       # cache hit: same frozen array
    assert not a.flags.writeable
    rng = np.random.default_rng(np.random.SeedSequence([9, 4]))
    np.testing.assert_array_equal(a, rng.permutation(37))
    # batch_indices slices the cache but hands out private writable copies
    epoch0 = epoch_permutation(37, 0, 9)   # step 0 -> epoch 0
    out = batch_indices(37, 8, 0, seed=9)
    np.testing.assert_array_equal(out, epoch0[:8])
    out[0] = -1                        # must not poison the cache
    np.testing.assert_array_equal(batch_indices(37, 8, 0, seed=9), epoch0[:8])


def test_batch_indices_covers_epoch_and_wraps():
    """An epoch's batches tile the permutation; the tail wraps to its head."""
    n, bs = 13, 5                      # steps_per_epoch=3, last step wraps
    perm = epoch_permutation(n, 0, 3)
    batches = [batch_indices(n, bs, s, seed=3) for s in range(3)]
    np.testing.assert_array_equal(np.concatenate(batches)[:n], perm)
    np.testing.assert_array_equal(batches[-1][-2:], perm[:2])  # static shape


def test_batch_schedule_matches_batch_indices():
    """(steps, batch) array == the per-step calls, incl. wrap + resume."""
    n, bs = 13, 5
    full = batch_schedule(n, bs, 0, 9, seed=7)
    assert full.shape == (9, bs)
    for s in range(9):
        np.testing.assert_array_equal(full[s],
                                      batch_indices(n, bs, s, seed=7))
    # stateless in start_step: a resumed slice is the same global schedule
    resumed = batch_schedule(n, bs, 4, 5, seed=7)
    np.testing.assert_array_equal(resumed, full[4:])
    assert batch_schedule(n, bs, 3, 0, seed=7).shape == (0, bs)
