"""Data pipeline tests: Eq.7/8 splits, equalization, stateless batching."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import MIN_LENGTH, batch_indices, iterate_batches, prepare
from repro.data.synthetic_m4 import TABLE2_COUNTS, TABLE3_LEN_STATS, generate


@pytest.fixture(scope="module")
def ds():
    return generate("quarterly", scale=0.003, seed=11)


def test_split_boundaries_eq8(ds):
    """train | val | test tile the series tail exactly (Eq. 7/8)."""
    data = prepare(ds)
    o = data.horizon
    c = MIN_LENGTH["quarterly"]
    assert data.train.shape[1] == c
    assert data.val_target.shape[1] == o
    assert data.test_target.shape[1] == o
    # reconstruct: for every kept series the tail must match source
    kept = 0
    for y in ds.series:
        if len(y) < c + 2 * o:
            continue
        tail = y[-(c + 2 * o):]
        row = kept
        np.testing.assert_array_equal(data.train[row], tail[:c])
        np.testing.assert_array_equal(data.val_target[row], tail[c:c + o])
        np.testing.assert_array_equal(data.test_target[row], tail[c + o:])
        np.testing.assert_array_equal(
            data.val_input[row], tail[:c + o])
        kept += 1
    assert kept == data.n_series


def test_short_series_disregarded(ds):
    """Section 5.2: series below the threshold are dropped."""
    data = prepare(ds)
    need = MIN_LENGTH["quarterly"] + 2 * ds.horizon
    expected = sum(1 for y in ds.series if len(y) >= need)
    assert data.n_series == expected


def test_variable_length_masks(ds):
    data = prepare(ds, variable_length=True)
    assert data.mask.shape == data.train.shape
    assert set(np.unique(data.mask)).issubset({0.0, 1.0})
    # masked rows are left-padded: zeros only at the start
    for row in data.mask:
        nz = np.nonzero(row)[0]
        assert (np.diff(nz) == 1).all()
        assert row[-1] == 1.0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 200), bs=st.integers(1, 64), seed=st.integers(0, 999))
def test_batch_indices_deterministic_and_covering(n, bs, seed):
    bs = min(bs, n)
    steps = -(-n // bs)
    a = [batch_indices(n, bs, s, seed=seed) for s in range(steps)]
    b = [batch_indices(n, bs, s, seed=seed) for s in range(steps)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)  # restart safety
    seen = set(np.concatenate(a).tolist())
    assert seen == set(range(n))  # an epoch covers every series


def test_resume_mid_epoch(ds):
    data = prepare(ds)
    full = list(iterate_batches(data, 8, 10, seed=3))
    resumed = list(iterate_batches(data, 8, 10, seed=3, start_step=4))
    assert len(resumed) == 6
    for (s1, i1, _, _), (s2, i2, _, _) in zip(full[4:], resumed):
        assert s1 == s2
        np.testing.assert_array_equal(i1, i2)


def test_synthetic_matches_table_stats():
    """Generator tracks Table 2 category mix and Table 3 length stats."""
    ds = generate("monthly", scale=0.01, seed=0)
    counts = TABLE2_COUNTS["monthly"]
    frac = np.bincount(ds.categories, minlength=6) / ds.n_series
    expect = np.asarray(counts) / sum(counts)
    assert np.abs(frac - expect).max() < 0.05
    lens = np.asarray([len(s) for s in ds.series])
    mean, std, lo, hi = TABLE3_LEN_STATS["monthly"]
    assert lens.min() >= lo and lens.max() <= hi
    assert abs(lens.mean() - mean) / mean < 0.35
    for y in ds.series[:50]:
        assert (y > 0).all()
