#!/usr/bin/env bash
# Runtime-hygiene launcher: pin the process environment before python/jax
# start, then exec the wrapped command.
#
#   bash scripts/run_env.sh python -m benchmarks.run --fast
#   bash scripts/run_env.sh python -m repro.launch.forecast fit --spec esrnn-quarterly
#
# What it pins (and why):
#   * tcmalloc via LD_PRELOAD when present -- glibc malloc fragments badly
#     under XLA's large transient host allocations; tcmalloc is the
#     standard fix on TPU VMs. Silently skipped when no candidate exists.
#   * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD -- silence the >1GB alloc
#     warnings that large per-series tables trigger.
#   * XLA_FLAGS --xla_force_host_platform_device_count -- deterministic
#     host-device count for the series-mesh sharded paths (set
#     ESRNN_HOST_DEVICES=1 for single-device runs; only appended when the
#     flag is not already pinned by the caller).
#   * JAX_DEFAULT_DTYPE_BITS=32 / JAX_ENABLE_X64=0 -- keep weak types at
#     32 bits so a stray python float can never promote a bf16/f32 program
#     to f64 (the dtype lint would fail the run; this stops it compiling).
#   * TF_CPP_MIN_LOG_LEVEL -- drop libtpu/XLA info-spam from benchmark logs.
set -euo pipefail

# --- allocator ------------------------------------------------------------
if [ -z "${LD_PRELOAD:-}" ]; then
  for so in \
      /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
      /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
      /usr/lib/libtcmalloc.so.4; do
    if [ -e "$so" ]; then
      export LD_PRELOAD="$so"
      break
    fi
  done
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# --- logging --------------------------------------------------------------
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-2}"

# --- dtypes ---------------------------------------------------------------
export JAX_ENABLE_X64=0
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# --- device topology ------------------------------------------------------
# pin the host-platform device count unless the caller already did
case "${XLA_FLAGS:-}" in
  *xla_force_host_platform_device_count*) ;;
  *)
    export XLA_FLAGS="--xla_force_host_platform_device_count=${ESRNN_HOST_DEVICES:-8}${XLA_FLAGS:+ $XLA_FLAGS}"
    ;;
esac

exec "$@"
