#!/usr/bin/env python
"""Million-series chunked fit + predict smoke (CI: million-series-smoke job).

The out-of-core claim, end to end on CPU: a synthetic 1M-series dataset
(short T -- the point is row count, not sequence length) fits through
``ForecastSpec.series_chunk`` with the per-series HW table + sparse-Adam
moments host-resident, crosses several chunk visits, streams the final
validation eval and the full (N, H) predict chunk by chunk, and the whole
process stays under a wall-clock and peak-RSS budget. A resident fit at
this N would put the full table + moments + data on device and is exactly
what this path exists to avoid.

Also gates exactness at small N: the streamed fit's loss trajectory must
match the device-resident reference on the same chunk-major schedule
(``chunk_resident=True``) to <= 1e-6 (bit-exact in practice on one backend).

Usage (from the repo root):
    PYTHONPATH=src python scripts/million_series_smoke.py
    PYTHONPATH=src python scripts/million_series_smoke.py --n 200000  # quick
"""

import argparse
import dataclasses
import resource
import sys
import time

import numpy as np


def rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--chunk", type=int, default=65_536)
    ap.add_argument("--batch", type=int, default=8_192)
    ap.add_argument("--steps", type=int, default=32,
                    help="default crosses 4 chunk visits at chunk/batch=8")
    ap.add_argument("--budget-s", type=float, default=900.0,
                    help="wall-clock budget for fit+predict")
    ap.add_argument("--budget-rss-mb", type=float, default=4096.0,
                    help="peak host RSS budget for the whole process")
    ap.add_argument("--skip-exactness", action="store_true")
    args = ap.parse_args()

    from repro.data.pipeline import synthetic_prepared
    from repro.forecast import ESRNNForecaster, get_spec

    spec = get_spec(
        "esrnn-quarterly", hidden_size=8, batch_size=args.batch,
        n_steps=args.steps, series_chunk=args.chunk, sparse_adam=True,
        scan_steps=8, eval_every=10**9, ckpt_every=10**9, smoke=True)

    t0 = time.perf_counter()
    data = synthetic_prepared(args.n, seasonality=spec.model.seasonality,
                              horizon=spec.horizon, series_length=24)
    t_data = time.perf_counter() - t0
    print(f"data: N={args.n} T={data.train.shape[1]}+2x{data.horizon} "
          f"built in {t_data:.1f}s (rss {rss_mb():.0f} MB)")

    t0 = time.perf_counter()
    f = ESRNNForecaster(spec).fit(data)
    t_fit = time.perf_counter() - t0
    losses = np.asarray(f.history_["loss"], np.float64)
    assert len(losses) == args.steps and np.isfinite(losses).all(), losses
    val = f.history_["val_smape"]
    assert val and np.isfinite(val[-1][1]), val
    print(f"fit: {args.steps} steps (chunk={args.chunk}, batch={args.batch}) "
          f"in {t_fit:.1f}s, final loss {losses[-1]:.4f}, "
          f"streamed val sMAPE {val[-1][1]:.2f} (rss {rss_mb():.0f} MB)")

    t0 = time.perf_counter()
    fc = f.predict()
    t_pred = time.perf_counter() - t0
    assert fc.shape == (args.n, spec.horizon), fc.shape
    assert np.isfinite(fc).all()
    print(f"predict: streamed {args.n} x {spec.horizon} forecasts "
          f"in {t_pred:.1f}s (rss {rss_mb():.0f} MB)")

    if not args.skip_exactness:
        from repro.core.esrnn import make_config
        from repro.train.trainer import TrainConfig, train_esrnn

        mcfg = make_config("quarterly", hidden_size=8)
        small = synthetic_prepared(512, seasonality=mcfg.seasonality,
                                   horizon=mcfg.output_size, series_length=24)
        scfg = TrainConfig(batch_size=64, n_steps=24, scan_steps=4,
                           sparse_adam=True, series_chunk=128,
                           eval_every=10**9, ckpt_every=10**9)
        l_stream = np.asarray(
            train_esrnn(mcfg, small, scfg)["history"]["loss"], np.float64)
        l_ref = np.asarray(train_esrnn(
            mcfg, small, dataclasses.replace(scfg, chunk_resident=True)
        )["history"]["loss"], np.float64)
        absdiff = float(np.max(np.abs(l_stream - l_ref)))
        print(f"exactness: streamed-vs-resident loss absdiff {absdiff:.2e} "
              f"over {scfg.n_steps} steps at N=512")
        assert absdiff <= 1e-6, absdiff

    wall = t_fit + t_pred
    peak = rss_mb()
    print(f"budgets: fit+predict {wall:.1f}s (<= {args.budget_s:.0f}s), "
          f"peak rss {peak:.0f} MB (<= {args.budget_rss_mb:.0f} MB)")
    assert wall <= args.budget_s, (wall, args.budget_s)
    assert peak <= args.budget_rss_mb, (peak, args.budget_rss_mb)
    print("million-series smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
