#!/usr/bin/env bash
# Tier-1 gate + forecast-surface smoke. Run from anywhere:
#   bash scripts/ci.sh
# Also the entry point of .github/workflows/ci.yml. No --deselect list:
# everything collected must pass; the one wall-clock-dependent test gates
# itself behind the `slow` marker + ESRNN_TIMING=1 (see tests/test_system.py).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== ruff lint (if installed) =="
if command -v ruff > /dev/null 2>&1; then
    ruff check .
else
    echo "ruff not installed locally; CI's lint job enforces it"
fi

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== spec registry smoke (every head family listed) =="
python -m repro.launch.forecast specs
python -m repro.launch.forecast specs --json > /dev/null

echo "== forecast fit smoke (20 steps) =="
python -m repro.launch.forecast fit --spec esrnn-quarterly --smoke --steps 20

echo "== fused-superstep fit smoke (scan_steps=8, sparse per-series adam) =="
python -m repro.launch.forecast fit --spec esrnn-quarterly --smoke --steps 20 \
    --set scan_steps=8 --set sparse_adam=true

echo "== chunked out-of-core fit smoke (host HW table, series_chunk=24) =="
python -m repro.launch.forecast fit --spec esrnn-quarterly --smoke --steps 20 \
    --set series_chunk=24 --set scan_steps=4

echo "== pluggable-head fit smokes (esn frozen reservoir, ssm scan) =="
python -m repro.launch.forecast fit --spec esn-quarterly --smoke --steps 20 \
    --set sparse_adam=true
python -m repro.launch.forecast fit --spec ssm-quarterly --smoke --steps 20

echo "== forecast serve smoke (continuous batching) =="
python -m repro.launch.forecast serve --smoke --steps 3 --requests 16

echo "== observe/forecast round-trip smoke (online state ingestion) =="
python - <<'EOF' | python -m repro.launch.forecast observe --smoke --steps 3 --seed-histories
import json
for t in range(12):
    print(json.dumps({"op": "observe", "series_id": 0, "y": 100.0 + t}))
print(json.dumps({"op": "forecast", "series_id": 0}))
print(json.dumps({"op": "stats"}))
EOF

echo "== rolling-origin backtest smoke =="
python -m repro.launch.forecast backtest --smoke --steps 3 --origins 60,72,80

echo "== graph-audit smoke (jaxpr/HLO invariant lints, zero violations) =="
python -m repro.launch.forecast analyze --smoke --set head=esn \
    --entries fit,predict,serve

echo "CI OK"
