#!/usr/bin/env bash
# Tier-1 gate + forecast-surface smoke. Run from anywhere:
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
# The --deselect list is the known pre-existing jax-version drift, identical
# at the seed commit (see .claude/skills/verify/SKILL.md): 3 sharding tests
# hitting the removed jax.sharding.AxisType, the LM launcher behind the same
# drift, and a wall-clock speedup assert that is flaky on single-core hosts.
python -m pytest -x -q \
  --deselect tests/distributed/test_sharding.py::test_param_spec_rules \
  --deselect tests/distributed/test_sharding.py::test_divisibility_guard \
  --deselect tests/distributed/test_sharding.py::test_mini_dryrun_and_real_step_on_8_devices \
  --deselect tests/test_system.py::test_lm_training_loss_decreases \
  --deselect tests/test_system.py::test_vectorized_faster_than_loop

echo "== forecast fit smoke (20 steps) =="
python -m repro.launch.forecast fit --spec esrnn-quarterly --smoke --steps 20

echo "== forecast serve smoke =="
python -m repro.launch.forecast serve --smoke --steps 3 --requests 16

echo "CI OK"
