"""Mamba2 block via SSD (state-space duality), Dao & Gu 2024 [arXiv:2405.21060].

Chunked algorithm: within a chunk the token mixing is a masked quadratic
(attention-like) einsum; across chunks a first-order recurrence carries the
(H, P, N) state. That recurrence is *structurally the Holt-Winters level
update* (h_t = a_t * h_{t-1} + b_t) -- the same series-on-lanes/time-in-VMEM
schedule as kernels/hw_scan.py applies (DESIGN.md section 5).

Decode is the O(1) recurrent step on a persistent (B, H, P, N) state plus a
(B, K-1, conv_dim) causal-conv tail cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init, rms_norm


class SSMCache(NamedTuple):
    state: jax.Array      # (B, H, P, N)
    conv: jax.Array       # (B, K-1, conv_dim) last inputs, time-major


def ssm_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_nheads
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (g*n), C (g*n), dt (h)]
        "w_in": dense_init(ks[0], d, 2 * di + 2 * g * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    di = cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di : 2 * di]
    bb = zxbcdt[..., 2 * di : 2 * di + g * n]
    cc = zxbcdt[..., 2 * di + g * n : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, x, bb, cc, dt


def _causal_conv(u, w, b, *, tail: Optional[jax.Array] = None):
    """Depthwise causal conv1d. u: (B, T, C); w: (K, C). Returns same shape
    plus the new (K-1)-tail for caches."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)             # (B, T+K-1, C)
    out = sum(up[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_tail = up[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(out + b), new_tail


def _segsum(a):
    """Lower-triangular segment sums: out[i, j] = sum_{j < l <= i} a[l].

    a: (..., Q). Returns (..., Q, Q) with -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j<l<=i}
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, bb, cc, *, chunk: int):
    """SSD forward. x: (B,T,H,P); dt: (B,T,H); a: (H,) negative;
    bb, cc: (B,T,G,N). Returns y: (B,T,H,P) and final state (B,H,P,N)."""
    b, t, h, p = x.shape
    g, n = bb.shape[2], bb.shape[3]
    q = min(chunk, t)
    nc = t // q
    assert nc * q == t, "T must be a multiple of the SSD chunk"
    rep = h // g

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = bb.reshape(b, nc, q, g, n)
    cc_ = cc.reshape(b, nc, q, g, n)

    # decay math stays fp32 (exp of cumsums); the *large* einsum operands
    # and outputs run in the input dtype -- on a bf16 pod this halves the
    # dominant memory-roofline traffic AND keeps every gradient tensor bf16
    # (Perf hillclimb 2, iteration 1: fp32 intermediates forced f32 grads
    # through the whole backward).
    cdt = x.dtype
    da = dtc * a[None, None, None, :]                   # (B,NC,Q,H) negative
    cum = jnp.cumsum(da, axis=2)

    # intra-chunk (diagonal) term
    # exp/segsum in fp32, then the (Q, Q) product chain in compute dtype
    # (iteration 2: the three (B,NC,H,Q,Q) L-chain tensors were still f32)
    l_mat = jnp.exp(_segsum(jnp.moveaxis(da, 3, 2))).astype(cdt)  # (B,NC,H,Q,Q)
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc_, bc)      # (B,NC,G,Q,Q)
    cb = jnp.repeat(cb, rep, axis=2)                    # (B,NC,H,Q,Q)
    scores = cb * l_mat * jnp.moveaxis(dtc, 3, 2).astype(cdt)[:, :, :, None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # chunk-final states: sum_k exp(cum_end - cum_k) * dt_k * B_k x_k
    decay = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,NC,Q,H)
    xw = xc * (dtc * decay).astype(cdt)[..., None]      # (B,NC,Q,H,P)
    bh = jnp.repeat(bc, rep, axis=3)                    # (B,NC,Q,H,N) -- G->H
    states = jnp.einsum("bcqhn,bcqhp->bchpn", bh.astype(cdt), xw).astype(jnp.float32)

    # inter-chunk recurrence over NC: S_c = exp(sum da_c) * S_{c-1} + states_c
    # (carried in fp32: it is the long recurrence)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,NC,H)

    def step(s_prev, inp):
        dec, st = inp
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev                            # emit state *entering* chunk

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    s_final, s_in = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)                     # (B,NC,H,P,N)

    # inter-chunk contribution: C_t . (decay-to-t * S_in)
    in_decay = jnp.exp(cum)                             # (B,NC,Q,H)
    ch = jnp.repeat(cc_, rep, axis=3)                   # (B,NC,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", ch.astype(cdt),
                       s_in.astype(cdt)) * in_decay.astype(cdt)[..., None]

    y = (y_diag + y_off).reshape(b, t, h, p)
    return y, s_final


def ssm_apply(p, cfg: ArchConfig, u, *, cache: Optional[SSMCache] = None):
    """u: (B, T, d). Train/prefill (cache None -> chunked SSD) or decode
    (cache set, T == 1 recurrent step). Returns (out, new_cache)."""
    b, t, d = u.shape
    di, h, pp = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = u @ p["w_in"]
    z, x, bb, cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([x, bb, cc], axis=-1)
    a = -jnp.exp(p["a_log"])                             # (H,)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)

    if cache is None:
        conv_out, tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        x_, bb_, cc_ = (conv_out[..., :di],
                        conv_out[..., di : di + g * n],
                        conv_out[..., di + g * n :])
        xh = x_.reshape(b, t, h, pp)
        bbr = bb_.reshape(b, t, g, n)
        ccr = cc_.reshape(b, t, g, n)
        dtr = dt_act
        # pad T to a chunk multiple: dt == 0 on padding makes the recurrence
        # a no-op (decay exp(0) = 1, update 0), so the final state is exact.
        q = min(cfg.ssm_chunk, t)
        pad = (-t) % q
        if pad:
            padt = lambda z: jnp.pad(z, ((0, 0), (0, pad)) + ((0, 0),) * (z.ndim - 2))
            xh, bbr, ccr, dtr = padt(xh), padt(bbr), padt(ccr), padt(dtr)
        y, s_final = ssd_chunked(xh, dtr, a, bbr, ccr, chunk=q)
        y = y[:, :t]
        new_cache = SSMCache(state=s_final, conv=tail) if tail is not None else None
    else:
        # decode: conv over cached tail + this step
        conv_out, tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], tail=cache.conv)
        x_, bb_, cc_ = (conv_out[..., :di],
                        conv_out[..., di : di + g * n],
                        conv_out[..., di + g * n :])
        xh = x_.reshape(b, t, h, pp)[:, -1]              # (B,H,P)
        bt = bb_.reshape(b, t, g, n)[:, -1]              # (B,G,N)
        ct = cc_.reshape(b, t, g, n)[:, -1]
        dt1 = dt_act[:, -1]                              # (B,H)
        da = jnp.exp(dt1 * a[None, :])                   # (B,H)
        rep = h // g
        bh = jnp.repeat(bt, rep, axis=1)                 # (B,H,N)
        ch = jnp.repeat(ct, rep, axis=1)
        upd = jnp.einsum("bhp,bhn->bhpn", xh * dt1[..., None], bh.astype(jnp.float32))
        state = cache.state * da[:, :, None, None] + upd
        yt = jnp.einsum("bhpn,bhn->bhp", state, ch.astype(jnp.float32))
        y = yt[:, None].reshape(b, 1, h, pp)
        new_cache = SSMCache(state=state, conv=tail)

    # D skip on the post-conv SSM input (compute dtype)
    y = y.astype(u.dtype) + (p["d_skip"].astype(u.dtype)[None, None, :, None]
                             * x_.reshape(b, t, h, pp).astype(u.dtype))
    y = y.reshape(b, t, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    return y @ p["w_out"], new_cache


def make_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> SSMCache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )
