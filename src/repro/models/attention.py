"""Attention variants: GQA (+bias/partial-RoPE/QK-norm), MLA, cross-attn.

Memory-safe by construction:
* training/prefill attention is *chunked* over query blocks (``lax.scan``) so
  the full (Tq, Tk) score matrix never materializes -- required to compile
  the 32k prefill cells within HBM;
* GQA never materializes head-repeated K/V -- scores are computed grouped
  ``(B, Hkv, group, Tq, Tk)`` via einsum;
* decode attends over the preallocated cache with an explicit position mask.

An optional Pallas flash-attention kernel (kernels/flash_attention.py)
replaces the chunked path when ``use_pallas`` is set.

KV caches: (B, S_max, Hkv, hd) bf16, written with dynamic_update_slice.
Prefill builds the cache by writing computed K/V into the zero-initialized
buffer; decode appends one step. Cache sequence axis is the one sharded on
the model axis when head counts don't divide it (sharding/specs.py).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, dense_init, rms_norm

DEFAULT_Q_CHUNK = 512


class KVCache(NamedTuple):
    k: jax.Array       # (B, S_max, Hkv, hd)
    v: jax.Array
    length: jax.Array  # () int32 current fill


def _grouped(q, hkv):
    b, hq, tq, hd = q.shape
    return q.reshape(b, hkv, hq // hkv, tq, hd)


def _attn_block(qg, k, v, q_start, offset, causal, scale, extra_mask=None):
    """qg: (B, Hkv, G, BQ, hd); k/v: (B, Hkv, Tk, hd)."""
    tk = k.shape[2]
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, k).astype(jnp.float32) * scale
    if causal:
        q_ids = q_start + jnp.arange(qg.shape[3])[:, None] + offset
        k_ids = jnp.arange(tk)[None, :]
        s = jnp.where(k_ids <= q_ids, s, -jnp.inf)
    if extra_mask is not None:  # (B, BQ, Tk) validity
        s = jnp.where(extra_mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,bktd->bkgqd", p.astype(v.dtype), v)


def chunked_attention(q, k, v, *, causal: bool, scale: float,
                      q_chunk: int = DEFAULT_Q_CHUNK, use_pallas: bool = False):
    """softmax(q k^T) v without materializing (Tq, Tk) or repeated KV.

    q: (B, Hq, Tq, hd); k, v: (B, Hkv, Tk, hd). End-aligned causal offset.
    """
    if use_pallas:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.flash_attention(q, k, v, causal=causal)

    b, hq, tq, hd = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = jnp.asarray(scale, jnp.float32)
    offset = tk - tq
    qg = _grouped(q, hkv)

    if tq <= q_chunk:
        out = _attn_block(qg, k, v, 0, offset, causal, scale)
        return out.reshape(b, hq, tq, dv)

    n_chunks = tq // q_chunk
    rem = tq - n_chunks * q_chunk
    g = hq // hkv
    q_main = qg[:, :, :, : n_chunks * q_chunk].reshape(b, hkv, g, n_chunks, q_chunk, hd)
    q_main = jnp.moveaxis(q_main, 3, 0)   # (n_chunks, B, Hkv, G, BQ, hd)

    def body(_, qc_i):
        qc, i = qc_i
        return None, _attn_block(qc, k, v, i * q_chunk, offset, causal, scale)

    _, outs = jax.lax.scan(body, None, (q_main, jnp.arange(n_chunks)))
    outs = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, n_chunks * q_chunk, dv)
    if rem:
        tail = _attn_block(qg[:, :, :, n_chunks * q_chunk :], k, v,
                           n_chunks * q_chunk, offset, causal, scale)
        outs = jnp.concatenate([outs, tail], axis=3)
    return outs.reshape(b, hq, tq, dv)


def cached_attention(q, k, v, positions, scale):
    """Decode-step attention over a preallocated cache buffer.

    q: (B, Hq, S, hd) at absolute ``positions``; k/v: (B, Hkv, S_max, hd).
    Key slot j is valid iff j <= query position (slots are written at their
    absolute position, so unwritten future slots are masked out).
    """
    b, hq, s, hd = q.shape
    hkv, smax = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    qg = _grouped(q, hkv)
    logits = jnp.einsum("bkgqd,bktd->bkgqt", qg, k).astype(jnp.float32) * scale
    pos = jnp.broadcast_to(jnp.asarray(positions), (b, s))
    mask = jnp.arange(smax)[None, None, :] <= pos[:, :, None]   # (B, S, Smax)
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", probs.astype(v.dtype), v)
    return out.reshape(b, hq, s, dv)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def make_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def gqa_apply(
    p,
    cfg: ArchConfig,
    x,
    positions,
    *,
    cache: Optional[KVCache] = None,
    cache_max_len: Optional[int] = None,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """x: (B, S, d).

    Modes: train (no cache args); prefill (``cache_max_len`` set: attention
    over the fresh K/V, returns a cache buffer of that length); decode
    (``cache`` set: append S positions, attend over the buffer).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    scale = cfg.attention_multiplier if cfg.attention_multiplier is not None else hd ** -0.5
    qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))

    new_cache = None
    if cache is not None:  # decode/append
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        new_cache = KVCache(kc, vc, cache.length + s)
        out = cached_attention(qh, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                               positions, scale)
    else:
        out = chunked_attention(qh, kh, vh, causal=True, scale=scale,
                                use_pallas=use_pallas)
        if cache_max_len is not None:  # prefill: publish the cache buffer
            pad = cache_max_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = KVCache(kc, vc, jnp.asarray(s, jnp.int32))
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, hq * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S_max, kv_lora_rank)
    k_rope: jax.Array  # (B, S_max, qk_rope_dim)
    length: jax.Array


def mla_init(key, cfg: ArchConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], d, h * (dn + dr), dtype),
        "w_dkv": dense_init(ks[1], d, r + dr, dtype),        # latent + shared k_rope
        "kv_norm": jnp.ones((r,), dtype),
        "w_uk": dense_init(ks[2], r, h * dn, dtype),
        "w_uv": dense_init(ks[3], r, h * dv, dtype),
        "wo": dense_init(ks[4], h * dv, d, dtype),
    }


def make_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        jnp.zeros((), jnp.int32),
    )


def mla_apply(p, cfg: ArchConfig, x, positions, *,
              cache: Optional[MLACache] = None,
              cache_max_len: Optional[int] = None,
              use_pallas: bool = False,
              absorbed_decode: bool = True):
    b, s, d = x.shape
    h = cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q = (x @ p["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = x @ p["w_dkv"]
    c_kv, k_rope = ckv[..., :r], ckv[..., r:]
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)[:, :, 0]

    new_cache = None
    scale = (dn + dr) ** -0.5

    if cache is not None:  # decode
        c_all = jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), cache.length, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), cache.length, axis=1)
        new_cache = MLACache(c_all, kr_all, cache.length + s)
        if absorbed_decode:
            out = _mla_absorbed(p, cfg, q_nope, q_rope, c_all, kr_all, positions, scale)
            return out @ p["wo"], new_cache
        tk = c_all.shape[1]
        k_nope = (c_all @ p["w_uk"]).reshape(b, tk, h, dn)
        v = (c_all @ p["w_uv"]).reshape(b, tk, h, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (b, tk, h, dr))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (qfull, k, v))
        out = cached_attention(qh, kh, vh, positions, scale)
    else:  # train / prefill
        k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
        v = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (qfull, k, v))
        out = chunked_attention(qh, kh, vh, causal=True, scale=scale,
                                use_pallas=use_pallas)
        if cache_max_len is not None:
            pad = cache_max_len - s
            new_cache = MLACache(
                jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
                jnp.asarray(s, jnp.int32),
            )
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, h * dv)
    return out @ p["wo"], new_cache


def _mla_absorbed(p, cfg, q_nope, q_rope, c_all, kr_all, positions, scale):
    """Matrix-absorbed MLA decode (beyond-paper serving optimization).

    Attention runs in the rank-r latent space: q_lat = q_nope @ W_uk^T per
    head; scores = q_lat . c_kv + q_rope . k_rope. Avoids materializing
    per-head K/V of length S_max (O(S*h*(dn+dv)) -> O(S*(r+dr)) bytes).
    """
    b, s, h, dn = q_nope.shape
    r = cfg.kv_lora_rank
    dv = cfg.v_head_dim
    smax = c_all.shape[1]
    w_uk = p["w_uk"].reshape(r, h, dn)
    w_uv = p["w_uv"].reshape(r, h, dv)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    logits = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_all)
        + jnp.einsum("bshd,btd->bhst", q_rope, kr_all)
    ).astype(jnp.float32) * scale
    pos = jnp.broadcast_to(jnp.asarray(positions), (b, s))
    mask = jnp.arange(smax)[None, None, :] <= pos[:, :, None]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs.astype(c_all.dtype), c_all)
    out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv)
    return out.reshape(b, s, h * dv)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_init(key, cfg: ArchConfig, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, h * hd, dtype),
        "wv": dense_init(ks[2], d, h * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
        "bq": jnp.zeros((h * hd,), dtype),
        "bv": jnp.zeros((h * hd,), dtype),
        "bo": jnp.zeros((d,), dtype),
    }


def cross_attn_apply(p, cfg: ArchConfig, x, memory, *, use_pallas: bool = False):
    """x: (B, S, d) queries; memory: (B, M, d) encoder states."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"] + p["bq"]).reshape(b, s, h, hd)
    k = (memory @ p["wk"]).reshape(b, -1, h, hd)
    v = (memory @ p["wv"] + p["bv"]).reshape(b, -1, h, hd)
    qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    out = chunked_attention(qh, kh, vh, causal=False, scale=hd ** -0.5,
                            use_pallas=use_pallas)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, h * hd)
    return out @ p["wo"] + p["bo"]
