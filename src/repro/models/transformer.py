"""Decoder-only transformer assembly (dense / MoE / VLM families).

Layer-stacked params + ``lax.scan`` over layers (keeps HLO size O(1) in
depth) with ``jax.checkpoint`` remat per layer for training. DeepSeek-style
``first_dense_layers`` are held out of the scan as prefix layers.

Three entry points per model: ``loss`` (teacher-forced CE), ``prefill``
(build KV caches + last-position logits), ``decode`` (single-token step).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as MOE
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_norm,
    cross_entropy_loss,
    embed_init,
    embed_lookup,
    norm_init,
    swiglu_init,
    swiglu_apply,
)
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ArchConfig, *, moe_layer: bool, d_ff: int, dtype):
    k_attn, k_mlp = jax.random.split(key)
    attn = (A.mla_init if cfg.use_mla else A.gqa_init)(k_attn, cfg, dtype)
    p = {
        "attn_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn,
        "mlp_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if moe_layer:
        p["moe"] = MOE.moe_init(k_mlp, cfg, dtype)
    else:
        p["mlp"] = swiglu_init(k_mlp, cfg.d_model, d_ff, dtype)
    return p


def lm_init(cfg: ArchConfig, key, dtype=None):
    dtype = dtype or cfg.jdtype
    n_prefix = cfg.first_dense_layers if cfg.family == "moe" else 0
    n_scan = cfg.n_layers - n_prefix
    keys = jax.random.split(key, cfg.n_layers + 3)

    prefix = [
        _layer_init(keys[i], cfg, moe_layer=False,
                    d_ff=(cfg.first_dense_d_ff or cfg.d_ff), dtype=dtype)
        for i in range(n_prefix)
    ]
    stacked = [
        _layer_init(keys[n_prefix + i], cfg,
                    moe_layer=(cfg.family == "moe"), d_ff=cfg.d_ff, dtype=dtype)
        for i in range(n_scan)
    ]
    layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)

    params = {
        "embed": embed_init(keys[-3], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if prefix:
        params["prefix_layers"] = prefix
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[-2], cfg.vocab_size, cfg.d_model, dtype).T
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _block(cfg: ArchConfig, p, h, positions, *, moe_layer: bool,
           cache=None, cache_max_len=None, use_pallas=False):
    """Pre-norm residual block. Returns (h, new_cache, aux_loss)."""
    attn_fn = A.mla_apply if cfg.use_mla else A.gqa_apply
    a_out, new_cache = attn_fn(
        p["attn"], cfg, apply_norm(h, p["attn_norm"], cfg.norm), positions,
        cache=cache, cache_max_len=cache_max_len, use_pallas=use_pallas,
    )
    h = h + cfg.residual_multiplier * a_out
    x = apply_norm(h, p["mlp_norm"], cfg.norm)
    if moe_layer:
        m_out, aux = MOE.moe_apply(p["moe"], cfg, x)
    else:
        m_out, aux = swiglu_apply(p["mlp"], x), jnp.zeros((), jnp.float32)
    h = h + cfg.residual_multiplier * m_out
    return h, new_cache, aux


def _scan_layers(cfg: ArchConfig, params, h, positions, *, caches=None,
                 cache_max_len=None, remat=False, use_pallas=False):
    """Scan the stacked layers. caches: stacked (L, ...) pytree or None.
    Returns (h, new_caches, aux_sum)."""
    moe_layer = cfg.family == "moe"

    def one_layer(h, layer_in):
        lp, lc = layer_in
        h, nc, aux = _block(cfg, lp, h, positions, moe_layer=moe_layer,
                            cache=lc, cache_max_len=cache_max_len,
                            use_pallas=use_pallas)
        return h, (nc, aux)

    if remat:
        policy = (jax.checkpoint_policies.save_only_these_names("moe_out")
                  if moe_layer else jax.checkpoint_policies.nothing_saveable)
        one_layer = jax.checkpoint(one_layer, policy=policy)

    h, (new_caches, auxs) = jax.lax.scan(
        one_layer, h, (params["layers"], caches))
    return h, new_caches, jnp.sum(auxs)


def _embed_h(cfg, params, tokens):
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h = h * cfg.embedding_multiplier
    return constrain(h, "dp", None, None)


def _logits(cfg, params, h):
    h = apply_norm(h, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = logits / cfg.logits_scaling
    return constrain(logits, "dp", None, "tp")


def _run_prefix(cfg, params, h, positions, *, caches=None, cache_max_len=None,
                use_pallas=False):
    """DeepSeek first-dense layers (held out of the scan)."""
    new_caches = []
    if "prefix_layers" not in params:
        return h, None
    for i, lp in enumerate(params["prefix_layers"]):
        lc = None if caches is None else jax.tree_util.tree_map(lambda c: c[i], caches)
        h, nc, _ = _block(cfg, lp, h, positions, moe_layer=False,
                          cache=lc, cache_max_len=cache_max_len,
                          use_pallas=use_pallas)
        new_caches.append(nc)
    if new_caches[0] is None:
        return h, None
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_caches)
    return h, stacked


# ---------------------------------------------------------------------------
# entry points (dense / moe; vlm adds the patch prefix)
# ---------------------------------------------------------------------------


def lm_loss(cfg: ArchConfig, params, batch, *, use_pallas=False):
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("loss_mask")
    b, s = tokens.shape
    h = _embed_h(cfg, params, tokens)
    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.jdtype)      # (B, P, d)
        h = jnp.concatenate([img, h], axis=1)
        pad = jnp.zeros((b, img.shape[1]), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        img_mask = jnp.zeros((b, img.shape[1]), jnp.float32)
        tok_mask = mask if mask is not None else jnp.ones((b, s), jnp.float32)
        mask = jnp.concatenate([img_mask, tok_mask], axis=1)
    positions = jnp.arange(h.shape[1])[None, :]
    h, _ = _run_prefix(cfg, params, h, positions, use_pallas=use_pallas)
    h, _, aux = _scan_layers(cfg, params, h, positions, remat=cfg.remat,
                             use_pallas=use_pallas)
    logits = _logits(cfg, params, h)
    ce = cross_entropy_loss(logits, labels, mask)
    return ce + 0.01 * aux


def lm_make_caches(cfg: ArchConfig, batch_size: int, max_len: int, dtype):
    make = (A.make_mla_cache if cfg.use_mla else A.make_kv_cache)
    one = make(cfg, batch_size, max_len, dtype)
    n_prefix = cfg.first_dense_layers if cfg.family == "moe" else 0
    n_scan = cfg.n_layers - n_prefix
    caches = {"layers": jax.tree_util.tree_map(
        lambda c: jnp.zeros((n_scan,) + c.shape, c.dtype), one)}
    if n_prefix:
        caches["prefix"] = jax.tree_util.tree_map(
            lambda c: jnp.zeros((n_prefix,) + c.shape, c.dtype), one)
    return caches


def lm_prefill(cfg: ArchConfig, params, batch, *, max_len: int, use_pallas=False):
    """Returns (last-token logits, caches)."""
    tokens = batch["tokens"]
    h = _embed_h(cfg, params, tokens)
    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.jdtype)
        h = jnp.concatenate([img, h], axis=1)
    positions = jnp.arange(h.shape[1])[None, :]
    h, pre_caches = _run_prefix(cfg, params, h, positions,
                                cache_max_len=max_len, use_pallas=use_pallas)
    h, new_caches, _ = _scan_layers(cfg, params, h, positions,
                                    cache_max_len=max_len, use_pallas=use_pallas)
    logits = _logits(cfg, params, h[:, -1:, :])
    out = {"layers": new_caches}
    if pre_caches is not None:
        out["prefix"] = pre_caches
    return logits, out


def lm_decode(cfg: ArchConfig, params, batch, caches, *, use_pallas=False):
    """One-token step. batch: tokens (B, 1), positions (B, 1) absolute."""
    tokens, positions = batch["tokens"], batch["positions"]
    h = _embed_h(cfg, params, tokens)
    pre_caches = caches.get("prefix")
    h, new_pre = _run_prefix(cfg, params, h, positions, caches=pre_caches,
                             use_pallas=use_pallas)
    h, new_caches, _ = _scan_layers(cfg, params, h, positions,
                                    caches=caches["layers"],
                                    use_pallas=use_pallas)
    logits = _logits(cfg, params, h)
    out = {"layers": new_caches}
    if new_pre is not None:
        out["prefix"] = new_pre
    return logits, out
