"""Unified model API: build_model(config) -> Model with init/loss/prefill/decode.

The train_step (optimizer + grad accumulation) lives in launch/steps.py and
is family-agnostic: it only needs ``loss`` and the batch pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm_lm as SL
from repro.models import transformer as TF
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., jax.Array]            # (params, batch) -> scalar
    prefill: Callable[..., Any]               # (params, batch, max_len) -> (logits, caches)
    decode: Callable[..., Any]                # (params, batch, caches) -> (logits, caches)
    make_caches: Callable[..., Any]           # (batch, max_len, dtype) -> caches


def build_model(cfg: ArchConfig, *, use_pallas: bool = False) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: TF.lm_init(cfg, key),
            loss=lambda p, b: TF.lm_loss(cfg, p, b, use_pallas=use_pallas),
            prefill=lambda p, b, max_len: TF.lm_prefill(cfg, p, b, max_len=max_len,
                                                        use_pallas=use_pallas),
            decode=lambda p, b, c: TF.lm_decode(cfg, p, b, c, use_pallas=use_pallas),
            make_caches=lambda bs, ml, dt: TF.lm_make_caches(cfg, bs, ml, dt),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: SL.ssm_lm_init(cfg, key),
            loss=lambda p, b: SL.ssm_lm_loss(cfg, p, b),
            prefill=lambda p, b, max_len: SL.ssm_lm_prefill(cfg, p, b, max_len=max_len),
            decode=lambda p, b, c: SL.ssm_lm_decode(cfg, p, b, c),
            make_caches=lambda bs, ml, dt: SL.ssm_lm_make_caches(cfg, bs, ml, dt),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: HY.hybrid_init(cfg, key),
            loss=lambda p, b: HY.hybrid_loss(cfg, p, b, use_pallas=use_pallas),
            prefill=lambda p, b, max_len: HY.hybrid_prefill(cfg, p, b, max_len=max_len,
                                                            use_pallas=use_pallas),
            decode=lambda p, b, c: HY.hybrid_decode(cfg, p, b, c, use_pallas=use_pallas),
            make_caches=lambda bs, ml, dt: HY.hybrid_make_caches(cfg, bs, ml, dt),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: ED.encdec_init(cfg, key),
            loss=lambda p, b: ED.encdec_loss(cfg, p, b, use_pallas=use_pallas),
            prefill=lambda p, b, max_len: ED.encdec_prefill(cfg, p, b, max_len=max_len,
                                                            use_pallas=use_pallas),
            decode=lambda p, b, c: ED.encdec_decode(cfg, p, b, c, use_pallas=use_pallas),
            make_caches=lambda bs, ml, dt: ED.encdec_make_caches(cfg, bs, ml, dt),
        )
    raise ValueError(f"unknown family {fam}")
