"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Per the task spec the modality frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model) -- the two strided
conv1d layers of Whisper are not modeled. Positions are sinusoidal for both
stacks (Whisper uses learned decoder positions capped at 448; the assigned
decode shapes go to 32k, so we substitute sinusoidal -- noted in DESIGN.md).

LayerNorm + GELU MLP + MHA (n_kv_heads == n_heads), pre-norm residuals,
decoder has self-attn (causal, cached) + cross-attn over encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_norm, cross_entropy_loss, embed_init, embed_lookup,
    gelu_mlp_apply, gelu_mlp_init, norm_init,
)
from repro.sharding.ctx import constrain


def _sinusoid(positions, d):
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "attn": A.gqa_init(k1, cfg, dtype),
        "mlp_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "attn": A.gqa_init(k1, cfg, dtype),
        "cross_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "cross": A.cross_attn_init(k2, cfg, dtype),
        "mlp_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def encdec_init(cfg: ArchConfig, key, dtype=None):
    dtype = dtype or cfg.jdtype
    n_enc = cfg.n_enc_layers or cfg.n_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 4)
    enc = [_enc_layer_init(keys[i], cfg, dtype) for i in range(n_enc)]
    dec = [_dec_layer_init(keys[n_enc + i], cfg, dtype) for i in range(cfg.n_layers)]
    return {
        "enc_layers": jax.tree_util.tree_map(lambda *x: jnp.stack(x), *enc),
        "enc_norm": norm_init(cfg.d_model, "layernorm", dtype),
        "embed": embed_init(keys[-3], cfg.vocab_size, cfg.d_model, dtype),
        "dec_layers": jax.tree_util.tree_map(lambda *x: jnp.stack(x), *dec),
        "dec_norm": norm_init(cfg.d_model, "layernorm", dtype),
    }


def encode(cfg: ArchConfig, params, frames, *, use_pallas=False, remat=False):
    """frames: (B, M, d) precomputed embeddings (conv stub)."""
    h = frames.astype(cfg.jdtype)
    h = h + _sinusoid(jnp.arange(h.shape[1])[None, :], cfg.d_model).astype(h.dtype)
    h = constrain(h, "dp", None, None)

    def one(h, lp):
        x = apply_norm(h, lp["attn_norm"], "layernorm")
        # bidirectional self-attention
        b, s, _ = x.shape
        q = (x @ lp["attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        k = (x @ lp["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = (x @ lp["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        o = A.chunked_attention(qh, kh, vh, causal=False, scale=cfg.hd ** -0.5,
                                use_pallas=use_pallas)
        o = jnp.swapaxes(o, 1, 2).reshape(b, s, -1) @ lp["attn"]["wo"]
        h = h + o
        h = h + gelu_mlp_apply(lp["mlp"], apply_norm(h, lp["mlp_norm"], "layernorm"))
        return h, None

    if remat:
        one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(one, h, params["enc_layers"])
    return apply_norm(h, params["enc_norm"], "layernorm")


def _dec_block(cfg, lp, h, memory, positions, *, cache=None, cache_max_len=None,
               use_pallas=False):
    a_out, nc = A.gqa_apply(lp["attn"], cfg,
                            apply_norm(h, lp["attn_norm"], "layernorm"),
                            positions, cache=cache, cache_max_len=cache_max_len,
                            use_pallas=use_pallas)
    h = h + a_out
    h = h + A.cross_attn_apply(lp["cross"], cfg,
                               apply_norm(h, lp["cross_norm"], "layernorm"),
                               memory, use_pallas=use_pallas)
    h = h + gelu_mlp_apply(lp["mlp"], apply_norm(h, lp["mlp_norm"], "layernorm"))
    return h, nc


def decode_stack(cfg, params, tokens, memory, positions, *, caches=None,
                 cache_max_len=None, use_pallas=False, remat=False):
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h = h + _sinusoid(positions, cfg.d_model).astype(h.dtype)
    h = constrain(h, "dp", None, None)

    def one(h, xs):
        lp, lc = xs
        h, nc = _dec_block(cfg, lp, h, memory, positions, cache=lc,
                           cache_max_len=cache_max_len, use_pallas=use_pallas)
        return h, nc

    if remat:
        one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    h, new_caches = jax.lax.scan(one, h, (params["dec_layers"], caches))
    h = apply_norm(h, params["dec_norm"], "layernorm")
    return h, new_caches


def encdec_loss(cfg: ArchConfig, params, batch, *, use_pallas=False, **_):
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    memory = encode(cfg, params, frames, use_pallas=use_pallas, remat=cfg.remat)
    positions = jnp.arange(tokens.shape[1])[None, :]
    h, _ = decode_stack(cfg, params, tokens, memory, positions,
                        use_pallas=use_pallas, remat=cfg.remat)
    logits = constrain(h @ params["embed"].T, "dp", None, "tp")  # tied head
    return cross_entropy_loss(logits, labels, batch.get("loss_mask"))


def encdec_make_caches(cfg: ArchConfig, batch_size: int, max_len: int, dtype):
    one = A.make_kv_cache(cfg, batch_size, max_len, dtype)
    return {
        "self": jax.tree_util.tree_map(
            lambda c: jnp.zeros((cfg.n_layers,) + c.shape, c.dtype), one),
        "memory": jnp.zeros((batch_size, cfg.n_frames, cfg.d_model), dtype),
    }


def encdec_prefill(cfg: ArchConfig, params, batch, *, max_len: int,
                   use_pallas=False, **_):
    frames, tokens = batch["frames"], batch["tokens"]
    memory = encode(cfg, params, frames, use_pallas=use_pallas)
    positions = jnp.arange(tokens.shape[1])[None, :]
    h, caches = decode_stack(cfg, params, tokens, memory, positions,
                             cache_max_len=max_len, use_pallas=use_pallas)
    logits = constrain(h[:, -1:, :] @ params["embed"].T, "dp", None, "tp")
    return logits, {"self": caches, "memory": memory}


def encdec_decode(cfg: ArchConfig, params, batch, caches, *, use_pallas=False, **_):
    tokens, positions = batch["tokens"], batch["positions"]
    h, new_caches = decode_stack(cfg, params, tokens, caches["memory"], positions,
                                 caches=caches["self"], use_pallas=use_pallas)
    logits = constrain(h @ params["embed"].T, "dp", None, "tp")
    return logits, {"self": new_caches, "memory": caches["memory"]}
