"""Shared functional building blocks for the LM architecture stack.

No flax: params are nested dicts of jnp arrays; every module is an
``init(key, ...) -> params`` plus a pure ``apply`` function. All matmuls cast
to the config compute dtype (bf16) with fp32 master params handled by the
caller (train substrate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, params, kind: str):
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    return rms_norm(x, params["scale"])


def norm_init(d, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# -- rotary ------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, *, theta: float = 10000.0, fraction: float = 1.0):
    """Rotary embedding on the leading ``fraction`` of head dims.

    x: (..., S, H, D); positions: broadcastable to (..., S). Non-interleaved
    (half-split) convention, fp32 rotation.
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                          # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# -- MLPs ----------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["w_in"] + p["b_in"], approximate=True) @ p["w_out"] + p["b_out"]


# -- embeddings -----------------------------------------------------------------


def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def cross_entropy_loss(logits, labels, mask=None):
    """Token CE with fp32 logsumexp; logits (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
