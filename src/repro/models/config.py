"""Architecture config schema covering all assigned families."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | encdec | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False            # per-head RMSNorm on q/k (qwen3)
    rope_fraction: float = 1.0       # chatglm3 "2d rope": 0.5
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    # scalar multipliers (granite)
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    attention_multiplier: Optional[float] = None  # None -> 1/sqrt(head_dim)
    logits_scaling: float = 1.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0      # deepseek-v2: layer 0 keeps a dense FFN
    first_dense_d_ff: int = 0        # ... with its own (larger) dense d_ff
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    # --- hybrid (zamba2) ---
    attn_every: int = 0              # shared attn block after every k ssm layers
    # --- encdec (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500             # encoder positions (conv frontend stub)
    # --- vlm (internvl2) ---
    n_patches: int = 0               # image patch positions (ViT stub)
    # --- compute ---
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
            hd = self.hd
            if self.use_mla:
                attn = (
                    d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            per_layer += attn
        if self.family == "encdec":
            per_layer += per_layer  # cross attention ~ same size as self-attn
        if self.family in ("dense", "vlm", "encdec"):
            ff_mult = 2 if self.family == "encdec" else 3  # gelu vs swiglu
            per_layer += ff_mult * d * self.d_ff
        if self.family == "moe":
            per_layer += 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            per_layer += d * self.n_experts  # router
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            ssm = d * 2 * di + d * 2 * self.ssm_ngroups * self.ssm_state
            ssm += d * self.ssm_nheads + di * d  # dt proj + out proj
            per_layer = ssm if self.family == "ssm" else per_layer
            if self.family == "hybrid":
                per_layer = ssm  # per-ssm-layer; shared block counted below
        total_layers = self.n_layers + (self.n_enc_layers or 0)
        n += per_layer * total_layers
        if self.family == "hybrid" and self.attn_every:
            hd_full = self.d_model // self.n_heads
            shared = (
                2 * d * d  # concat proj
                + d * hd_full * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * hd_full * d
                + 3 * d * self.d_ff
            )
            n += shared  # weights shared across applications
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        n = self.param_count()
        n -= 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts) * self.n_layers
        dense_ff = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        return int(n + dense_ff * self.n_layers)
