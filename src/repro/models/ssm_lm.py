"""Mamba2 (attention-free) language model: embed -> scanned SSD blocks -> head."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm as S
from repro.models.config import ArchConfig
from repro.models.layers import cross_entropy_loss, embed_init, embed_lookup, norm_init, apply_norm
from repro.sharding.ctx import constrain


def ssm_lm_init(cfg: ArchConfig, key, dtype=None):
    dtype = dtype or cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [
        {"norm": norm_init(cfg.d_model, cfg.norm, dtype),
         "ssm": S.ssm_init(keys[i], cfg, dtype)}
        for i in range(cfg.n_layers)
    ]
    layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": embed_init(keys[-3], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "lm_head": embed_init(keys[-2], cfg.vocab_size, cfg.d_model, dtype).T,
    }


def _scan_blocks(cfg, params, h, *, caches=None, remat=False):
    def one(h, xs):
        lp, lc = xs
        out, nc = S.ssm_apply(lp["ssm"], cfg, apply_norm(h, lp["norm"], cfg.norm),
                              cache=lc)
        return h + out, nc

    if remat:
        one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(one, h, (params["layers"], caches))


def ssm_lm_loss(cfg: ArchConfig, params, batch, **_):
    tokens, labels = batch["tokens"], batch["labels"]
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h = constrain(h, "dp", None, None)
    h, _ = _scan_blocks(cfg, params, h, remat=cfg.remat)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = constrain(h @ params["lm_head"], "dp", None, "tp")
    return cross_entropy_loss(logits, labels, batch.get("loss_mask"))


def ssm_lm_make_caches(cfg: ArchConfig, batch_size: int, max_len: int, dtype):
    one = S.make_ssm_cache(cfg, batch_size, dtype)
    return jax.tree_util.tree_map(
        lambda c: jnp.zeros((cfg.n_layers,) + c.shape, c.dtype), one)


def ssm_lm_prefill(cfg: ArchConfig, params, batch, *, max_len: int, **_):
    """SSM 'prefill' = run the sequence chunked, keep final recurrent states.

    (cache=None routes ssm_apply through the SSD path, which returns the
    final (B, H, P, N) state + conv tail -- exactly the decode cache.)"""
    tokens = batch["tokens"]
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h = constrain(h, "dp", None, None)
    h, new_caches = _scan_blocks(cfg, params, h, caches=None)
    h = apply_norm(h[:, -1:, :], params["final_norm"], cfg.norm)
    logits = constrain(h @ params["lm_head"], "dp", None, "tp")
    return logits, new_caches


def ssm_lm_decode(cfg: ArchConfig, params, batch, caches, **_):
    tokens = batch["tokens"]
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h, new_caches = _scan_blocks(cfg, params, h, caches=caches)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = constrain(h @ params["lm_head"], "dp", None, "tp")
    return logits, new_caches
