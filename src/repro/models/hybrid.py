"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

Structure (simplified from Zamba2 [arXiv:2411.15242], noted in DESIGN.md):
``n_layers`` Mamba2 blocks in G groups of ``attn_every``; after each group
the shared transformer block (same weights every application, Zamba's
parameter-sharing trick) runs on ``proj(concat(h, e0))`` where e0 is the
initial embedding (Zamba's global skip). Each application has its own KV
cache (weights shared, activations not).

Mamba params are stacked (G, K, ...): the outer group loop is a short python
unroll (G ~ 9), the inner K layers scan -- keeps HLO compact while letting
each shared-block application index its own cache slot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import ssm as S
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_norm, cross_entropy_loss, dense_init, embed_init, embed_lookup,
    norm_init, swiglu_init, swiglu_apply,
)
from repro.sharding.ctx import constrain


def hybrid_init(cfg: ArchConfig, key, dtype=None):
    dtype = dtype or cfg.jdtype
    g = cfg.n_layers // cfg.attn_every
    k = cfg.attn_every
    keys = jax.random.split(key, cfg.n_layers + 6)
    blocks = [
        {"norm": norm_init(cfg.d_model, cfg.norm, dtype),
         "ssm": S.ssm_init(keys[i], cfg, dtype)}
        for i in range(cfg.n_layers)
    ]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    # (L, ...) -> (G, K, ...)
    stacked = jax.tree_util.tree_map(
        lambda x: x.reshape((g, k) + x.shape[1:]), stacked)
    shared = {
        "w_concat": dense_init(keys[-6], 2 * cfg.d_model, cfg.d_model, dtype),
        "attn_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": A.gqa_init(keys[-5], cfg, dtype),
        "mlp_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": swiglu_init(keys[-4], cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": embed_init(keys[-3], cfg.vocab_size, cfg.d_model, dtype),
        "mamba": stacked,
        "shared": shared,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "lm_head": embed_init(keys[-2], cfg.vocab_size, cfg.d_model, dtype).T,
    }


def _mamba_group(cfg, gp, h, *, caches=None, remat=False):
    def one(h, xs):
        lp, lc = xs
        out, nc = S.ssm_apply(lp["ssm"], cfg, apply_norm(h, lp["norm"], cfg.norm),
                              cache=lc)
        return h + out, nc

    if remat:
        one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(one, h, (gp, caches))


def _shared_block(cfg, sp, h, e0, positions, *, cache=None, cache_max_len=None,
                  use_pallas=False, remat=False):
    def body(h):
        x = jnp.concatenate([h, e0], axis=-1) @ sp["w_concat"]
        a_out, nc = A.gqa_apply(
            sp["attn"], cfg, apply_norm(x, sp["attn_norm"], cfg.norm), positions,
            cache=cache, cache_max_len=cache_max_len, use_pallas=use_pallas)
        h = h + a_out
        h = h + swiglu_apply(sp["mlp"], apply_norm(h, sp["mlp_norm"], cfg.norm))
        return h, nc

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    return body(h)


def _forward(cfg, params, tokens, positions, *, mamba_caches=None,
             attn_caches=None, cache_max_len=None, use_pallas=False,
             remat=False):
    g = cfg.n_layers // cfg.attn_every
    h = embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    h = constrain(h, "dp", None, None)
    e0 = h
    new_mamba, new_attn = [], []
    for gi in range(g):
        gp = jax.tree_util.tree_map(lambda x: x[gi], params["mamba"])
        mc = None if mamba_caches is None else jax.tree_util.tree_map(
            lambda c: c[gi], mamba_caches)
        h, nmc = _mamba_group(cfg, gp, h, caches=mc, remat=remat)
        ac = None if attn_caches is None else jax.tree_util.tree_map(
            lambda c: c[gi], attn_caches)
        h, nac = _shared_block(cfg, params["shared"], h, e0, positions,
                               cache=ac, cache_max_len=cache_max_len,
                               use_pallas=use_pallas, remat=remat)
        new_mamba.append(nmc)
        new_attn.append(nac)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    stack = lambda xs: (None if xs[0] is None
                        else jax.tree_util.tree_map(lambda *y: jnp.stack(y), *xs))
    return h, stack(new_mamba), stack(new_attn)


def hybrid_loss(cfg: ArchConfig, params, batch, *, use_pallas=False, **_):
    tokens, labels = batch["tokens"], batch["labels"]
    positions = jnp.arange(tokens.shape[1])[None, :]
    h, _, _ = _forward(cfg, params, tokens, positions, use_pallas=use_pallas,
                       remat=cfg.remat)
    logits = constrain(h @ params["lm_head"], "dp", None, "tp")
    return cross_entropy_loss(logits, labels, batch.get("loss_mask"))


def hybrid_make_caches(cfg: ArchConfig, batch_size: int, max_len: int, dtype):
    g = cfg.n_layers // cfg.attn_every
    k = cfg.attn_every
    ssm_one = S.make_ssm_cache(cfg, batch_size, dtype)
    mamba = jax.tree_util.tree_map(
        lambda c: jnp.zeros((g, k) + c.shape, c.dtype), ssm_one)
    kv_one = A.make_kv_cache(cfg, batch_size, max_len, dtype)
    attn = jax.tree_util.tree_map(
        lambda c: jnp.zeros((g,) + c.shape, c.dtype), kv_one)
    return {"mamba": mamba, "attn": attn}


def hybrid_prefill(cfg: ArchConfig, params, batch, *, max_len: int,
                   use_pallas=False, **_):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])[None, :]
    h, nm, na = _forward(cfg, params, tokens, positions,
                         cache_max_len=max_len, use_pallas=use_pallas)
    logits = constrain(h[:, -1:, :] @ params["lm_head"], "dp", None, "tp")
    return logits, {"mamba": nm, "attn": na}


def hybrid_decode(cfg: ArchConfig, params, batch, caches, *, use_pallas=False, **_):
    tokens, positions = batch["tokens"], batch["positions"]
    h, nm, na = _forward(cfg, params, tokens, positions,
                         mamba_caches=caches["mamba"],
                         attn_caches=caches["attn"], use_pallas=use_pallas)
    logits = constrain(h @ params["lm_head"], "dp", None, "tp")
    return logits, {"mamba": nm, "attn": na}
