"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

TPU-native expert parallelism: experts are sharded on the ``model`` mesh axis
(EP), tokens on ``data``; GSPMD materializes the all-to-alls at the
data<->expert boundary. Dispatch avoids the classic GShard ``(G,S,E,C)``
one-hot tensor (O(S*E*C) memory) by computing *positions within expert
buffers* via a cumsum and using scatter/gather:

  router -> top-k ids/weights -> position = cumsum(one-hot) - 1
  buffer (G, E, C, d) <- scatter tokens     (drop if position >= capacity)
  expert FFN on (G, E, C, d)                (batched einsum over E)
  out <- gather back, combine with router weights

Shared experts (DeepSeek-V2) run densely on every token.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init
from repro.sharding.ctx import constrain


def moe_init(key, cfg: ArchConfig, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # fp32 router
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, fs, dtype),
            "w_up": dense_init(k2, d, fs, dtype),
            "w_down": dense_init(k3, fs, d, dtype),
        }
    return p


def _capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(c, cfg.top_k, 4)
    return min(c, tokens_per_group)


def moe_apply(p, cfg: ArchConfig, x, *, capacity: Optional[int] = None):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss (scalar).

    The batch dim is the dispatch group (per-device groups under GSPMD).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity or _capacity(s, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)                  # (B, S, K)
    if cfg.norm_topk_prob:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    one_hot = jax.nn.one_hot(top_ids, e, dtype=jnp.float32)   # (B, S, K, E)
    fe = jnp.mean(one_hot.sum(2), axis=(0, 1))                # fraction routed
    aux = e * jnp.sum(me * fe) / k

    # position of each (token, k) within its expert's buffer, per group
    flat_assign = one_hot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat_assign, axis=1) - flat_assign       # count before me
    pos = jnp.sum(pos * flat_assign, axis=-1).reshape(b, s, k)  # (B, S, K)
    keep = (pos < c)
    pos_c = jnp.minimum(pos, c - 1).astype(jnp.int32)

    # scatter tokens into (B, E, C, d)
    xk = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d)).reshape(b, s * k, d)
    ids_f = top_ids.reshape(b, s * k)
    pos_f = pos_c.reshape(b, s * k)
    keep_f = keep.reshape(b, s * k)
    xk = jnp.where(keep_f[..., None], xk, 0.0)

    def scatter_group(xg, ig, pg):
        buf = jnp.zeros((e, c, d), xg.dtype)
        return buf.at[ig, pg].add(xg)

    # EP sharding (hillclimb iterations 1-2, EXPERIMENTS.md section Perf):
    # scatter into a *group-sharded, full-E* buffer -- indices and updates
    # are dp-local, so the scatter emits no collectives -- then slice to the
    # (groups on dp) x (experts on tp) 2D layout (a free reshard on the
    # (data, model) mesh: every (group, expert) pair has one owner).
    from repro.sharding import specs as _specs
    ep = _specs._PARAM_MODE != "decode"
    # decode (1 token/seq): tiny buffers -- GSPMD's replicated schedule with
    # f-sharded experts measured best; constraints only help the EP regime.
    maybe = (lambda t, *dims: constrain(t, *dims)) if ep else (lambda t, *dims: t)
    xk = maybe(xk, "dp", None, None)
    buf = jax.vmap(scatter_group)(xk, ids_f, pos_f)           # (B, E, C, d)
    buf = maybe(buf, "dp", "tp", None, None)

    # expert FFN (SwiGLU), batched over E
    gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    up = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    act = jax.nn.silu(gate) * up
    out_buf = jnp.einsum("becf,efd->becd", act, p["w_down"])  # (B, E, C, d)
    out_buf = maybe(out_buf, "dp", "tp", None, None)

    # gather back + weighted combine (single-gather formulation measured
    # best of three combine variants -- see EXPERIMENTS.md section Perf)
    def gather_group(ob, ig, pg):
        return ob[ig, pg]                                     # (S*K, d)

    ytok = jax.vmap(gather_group)(out_buf, ids_f, pos_f)      # (B, S*K, d)
    ytok = maybe(ytok, "dp", None, None)
    wk = (top_w.reshape(b, s * k) * keep_f).astype(ytok.dtype)
    y = (ytok * wk[..., None]).reshape(b, s, k, d).sum(axis=2)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    # saved under selective remat: the backward reuses the dispatched result
    # instead of re-running the dispatch collectives (hillclimb iteration 4)
    y = jax.ad_checkpoint.checkpoint_name(y, "moe_out")
    return y, aux
