"""ESRNNForecaster: estimator-style entry point for the hybrid ES-RNN.

One object, five verbs -- the whole paper workflow behind a stable surface:

    f = ESRNNForecaster("esrnn-quarterly")          # or a ForecastSpec
    f.fit(data)                                     # joint two-group training
    yhat = f.predict()                              # (N, H) point forecast
    bands = f.predict_quantiles(taus=(0.1, 0.5, 0.9))
    scores = f.evaluate(split="test")               # sMAPE/MASE/OWA vs
                                                    # Comb / Naive2
    f.save(path);  g = ESRNNForecaster.load(path)   # shared Checkpointer

The estimator wraps the pure ``esrnn_init/esrnn_loss/esrnn_forecast``
functions from ``repro.core.esrnn`` -- it holds state (spec, params, data),
the math stays functional and jitted.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import losses as L
from repro.core.comb import comb_forecast, naive2_forecast
from repro.core.esrnn import (
    esrnn_forecast, esrnn_init, esrnn_loss, esrnn_loss_and_grad, gather_series,
)
from repro.core.holt_winters import hw_smooth
from repro.data.pipeline import PreparedData, prepare
from repro.data.synthetic_m4 import M4Dataset, generate
from repro.forecast.spec import ForecastSpec, get_spec
from repro.train.trainer import train_from_spec

_META_FILE = "forecaster.json"


class NotFittedError(RuntimeError):
    pass


class ESRNNForecaster:
    """Scikit-style estimator over the vectorized ES-RNN."""

    def __init__(self, spec: Union[str, ForecastSpec] = "esrnn-quarterly",
                 **overrides):
        if isinstance(spec, str):
            spec = get_spec(spec, **overrides)
        elif overrides:
            spec = spec.replace(**overrides)
        self.spec = spec
        self.params_: Optional[Dict] = None
        self.history_: Optional[Dict] = None
        self.n_series_: Optional[int] = None
        self.data_: Optional[PreparedData] = None
        self.cats_: Optional[np.ndarray] = None   # fitted one-hots, persisted

    # -- config shortcuts ----------------------------------------------------

    @property
    def config(self):
        return self.spec.model

    @property
    def horizon(self) -> int:
        return self.spec.horizon

    def _check_fitted(self):
        if self.params_ is None:
            raise NotFittedError(
                "this ESRNNForecaster has no params; call fit(), "
                "init_params(), or load() first")

    # -- data ----------------------------------------------------------------

    def make_data(self) -> PreparedData:
        """Spec-driven synthetic M4 slice (Tables 2/3 profile, section 5)."""
        spec = self.spec
        ds = generate(spec.frequency, scale=spec.data_scale, seed=spec.data_seed)
        return prepare(ds, min_length=spec.min_length,
                       variable_length=spec.variable_length)

    def _coerce_data(self, data) -> PreparedData:
        if data is None:
            return self.make_data()
        if isinstance(data, M4Dataset):
            return prepare(data, min_length=self.spec.min_length,
                           variable_length=self.spec.variable_length)
        if isinstance(data, PreparedData):
            return data
        raise TypeError(f"cannot fit on {type(data).__name__}; "
                        "pass PreparedData, M4Dataset, or None")

    # -- fit -----------------------------------------------------------------

    def init_params(self, n_series: int, seed: Optional[int] = None):
        """Primer initialization without training (cold-start serving)."""
        seed = self.spec.seed if seed is None else seed
        self.params_ = esrnn_init(jax.random.PRNGKey(seed), self.config, n_series)
        self.n_series_ = n_series
        return self.params_

    def fit(self, data=None, *, ckpt_dir: Optional[str] = None,
            n_steps: Optional[int] = None, hooks=None,
            mesh=None) -> "ESRNNForecaster":
        """Joint two-group training (spec's rnn_lr / hw_lr); returns self.

        ``mesh``: optional 1-D series mesh for multi-device data-parallel
        training (see ``repro.sharding.series.make_series_mesh``); without
        one, ``spec.data_parallel > 1`` builds a mesh over that many local
        devices. Fitted params are identical in structure either way, so
        predict/evaluate/save/serve are unchanged.

        ``spec.scan_steps > 1`` trains through the fused superstep engine
        (K steps per donated ``lax.scan`` dispatch, host sync at superstep
        boundaries) -- same loss trajectory, fewer dispatches; composes
        with ``mesh``/``data_parallel`` and ``use_pallas``. When ``hooks``
        contains ``on_step`` it then fires once per superstep with the
        segment's loss array. ``spec.sparse_adam`` switches the per-series
        table to the sparse segment optimizer.
        """
        pdata = self._coerce_data(data)
        out = train_from_spec(self.spec, pdata, ckpt_dir=ckpt_dir,
                              n_steps=n_steps, params=self.params_, hooks=hooks,
                              mesh=mesh)
        self.params_ = out["params"]
        self.history_ = out["history"]
        self.n_series_ = pdata.n_series
        self.data_ = pdata
        self.cats_ = np.asarray(pdata.cats, np.float32)
        return self

    # -- predict -------------------------------------------------------------

    def _resolve_inputs(self, y, cats, series_idx):
        self._check_fitted()
        if y is None:
            if self.data_ is None:
                raise NotFittedError("predict() without y requires fit(data)")
            y = self.data_.train
        y = jnp.asarray(y, self.config.jdtype)
        if cats is None and self.cats_ is not None:
            # fitted categories: the rows of y are (a subset of) the fitted
            # series, so reuse their one-hots rather than zeroing the feature
            # (survives save/load -- cats_ is persisted in forecaster.json)
            if series_idx is not None:
                cats = self.cats_[np.asarray(series_idx)]
            elif y.shape[0] == self.cats_.shape[0]:
                cats = self.cats_
        if cats is None:
            cats = jnp.zeros((y.shape[0], self.config.n_categories))
        cats = jnp.asarray(cats, self.config.jdtype)
        params = self.params_
        if series_idx is not None:
            params = gather_series(params, np.asarray(series_idx))
        n_hw = params["hw"].alpha_logit.shape[0]
        if y.shape[0] != n_hw:
            raise ValueError(
                f"y has {y.shape[0]} series but the fitted per-series table "
                f"has {n_hw}; pass series_idx to select rows")
        return params, y, cats

    def predict(self, y=None, cats=None, *,
                series_idx: Optional[Sequence[int]] = None) -> np.ndarray:
        """Point forecast (N, H) from the end of each series (Eq. 5).

        With no arguments, forecasts the fitted training series. ``y`` may be
        any history for the fitted series (e.g. train+val to forecast the test
        window); ``series_idx`` selects per-series HW rows when y is a subset.
        """
        params, y, cats = self._resolve_inputs(y, cats, series_idx)
        return np.asarray(esrnn_forecast(self.config, params, y, cats))

    def predict_quantiles(
        self, y=None, cats=None, *, taus: Tuple[float, ...] = (0.1, 0.5, 0.9),
        series_idx: Optional[Sequence[int]] = None,
    ) -> Dict[float, np.ndarray]:
        """Quantile bands around the point forecast.

        The model is trained on a single pinball quantile (spec ``tau``), so
        its output is one quantile path. Bands are derived from the fitted
        Holt-Winters in-sample residuals: the multiplicative model says
        y_t = l_t * s_t * eps_t, so per-series log-residual spread sigma gives
        q_tau(h) = yhat * exp(z_tau * sigma * sqrt(h)) -- a random-walk
        widening in log-space (beyond-paper convenience; tau=0.5 returns the
        point forecast exactly).
        """
        params, y, cats = self._resolve_inputs(y, cats, series_idx)
        point = esrnn_forecast(self.config, params, y, cats)      # (N, H)
        levels, seas = hw_smooth(
            y, params["hw"], seasonality=self.config.seasonality,
            seasonality2=self.config.seasonality2,
            use_pallas=self.config.use_pallas)
        t_len = y.shape[1]
        fitted = levels * seas[:, :t_len]
        log_resid = jnp.log(jnp.maximum(y, 1e-8)) - jnp.log(
            jnp.maximum(fitted, 1e-8))
        sigma = jnp.std(log_resid, axis=1, keepdims=True)          # (N, 1)
        steps = jnp.sqrt(jnp.arange(1, self.horizon + 1))[None, :]  # (1, H)
        out = {}
        for tau in taus:
            z = jax.scipy.special.ndtri(jnp.asarray(tau, jnp.float32))
            out[tau] = np.asarray(point * jnp.exp(z * sigma * steps))
        return out

    # -- loss (golden-equivalence surface + benchmarks) ----------------------

    def loss(self, y, cats) -> jax.Array:
        """Training loss through the estimator (same jitted fn the fit uses)."""
        self._check_fitted()
        return esrnn_loss(self.config, self.params_,
                          jnp.asarray(y), jnp.asarray(cats))

    def loss_and_grad(self, y, cats):
        self._check_fitted()
        return esrnn_loss_and_grad(self.config, self.params_,
                                   jnp.asarray(y), jnp.asarray(cats))

    # -- evaluate ------------------------------------------------------------

    def evaluate(self, data: Optional[PreparedData] = None,
                 split: str = "test") -> Dict[str, float]:
        """M4-style scores: sMAPE/MASE/OWA vs the Comb and Naive2 benchmarks.

        ``split="test"`` forecasts from train+val and scores on the test
        window (Eq. 7); ``split="val"`` forecasts from train and scores on
        the validation window.
        """
        self._check_fitted()
        data = data if data is not None else self.data_
        if data is None:
            raise NotFittedError("evaluate() needs PreparedData (fit or pass)")
        if split == "test":
            insample, target = data.val_input, data.test_target
        elif split == "val":
            insample, target = data.train, data.val_target
        else:
            raise ValueError(f"split must be 'val' or 'test', got {split!r}")
        m, h = data.seasonality, min(self.horizon, target.shape[1])
        target_j = jnp.asarray(target[:, :h])
        insample_j = jnp.asarray(insample)

        fc = self.predict(insample, data.cats)[:, :h]
        fc_comb = np.asarray(comb_forecast(insample, h, m), np.float32)
        fc_n2 = np.asarray(naive2_forecast(insample, h, m), np.float32)

        def score(f):
            f = jnp.asarray(f)
            return (float(L.smape(f, target_j)),
                    float(L.mase(f, target_j, insample_j, m)))

        s_es, m_es = score(fc)
        s_cb, m_cb = score(fc_comb)
        s_n2, m_n2 = score(fc_n2)
        return {
            "split": split,
            "smape": s_es, "mase": m_es,
            "owa": float(L.owa(s_es, m_es, s_n2, m_n2)),
            "smape_comb": s_cb, "mase_comb": m_cb,
            "owa_comb": float(L.owa(s_cb, m_cb, s_n2, m_n2)),
            "smape_naive2": s_n2, "mase_naive2": m_n2,
        }

    # -- persistence (shared Checkpointer) -----------------------------------

    def save(self, directory: str) -> str:
        """Persist spec + params atomically via the shared Checkpointer.

        Params live under ``<directory>/params/`` so a saved estimator can
        share a directory with trainer checkpoints (``fit(ckpt_dir=...)``
        writes ``step_<n>/`` trees of (params, opt_state) at the top level;
        colliding with those would corrupt crash-resume).
        """
        self._check_fitted()
        ckpt = Checkpointer(os.path.join(directory, "params"), keep=self.spec.keep)
        step = len(self.history_["loss"]) if self.history_ else 0
        ckpt.save(step, self.params_)
        meta = {
            "spec": self.spec.to_dict(),
            "n_series": int(self.n_series_),
            "step": step,
            "cats": self.cats_.tolist() if self.cats_ is not None else None,
        }
        tmp = os.path.join(directory, _META_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=2)
        os.replace(tmp, os.path.join(directory, _META_FILE))
        return directory

    @classmethod
    def load(cls, directory: str) -> "ESRNNForecaster":
        with open(os.path.join(directory, _META_FILE)) as f:
            meta = json.load(f)
        spec = ForecastSpec.from_dict(meta["spec"])
        f = cls(spec)
        template = esrnn_init(
            jax.random.PRNGKey(spec.seed), spec.model, meta["n_series"])
        _, f.params_ = Checkpointer(
            os.path.join(directory, "params")).restore(template, step=meta["step"])
        f.n_series_ = meta["n_series"]
        if meta.get("cats") is not None:
            f.cats_ = np.asarray(meta["cats"], np.float32)
        return f
