"""ESRNNForecaster: estimator-style entry point for the hybrid ES-RNN.

One object, six verbs -- the whole paper workflow behind a stable surface:

    f = ESRNNForecaster("esrnn-quarterly")          # or a ForecastSpec
    f.fit(data)                                     # joint two-group training
    yhat = f.predict()                              # (N, H) point forecast
    bands = f.predict_quantiles(taus=(0.1, 0.5, 0.9))
    scores = f.evaluate(split="test")               # sMAPE/MASE/OWA vs
                                                    # Comb / Naive2
    bt = f.backtest(origins=(72, 80))               # rolling-origin scores,
                                                    # one forward pass
    f.save(path);  g = ESRNNForecaster.load(path)   # shared Checkpointer
    srv = f.serve()                                 # continuous-batching
                                                    # online server

Every inference verb accepts ``mesh=`` (or inherits ``spec.data_parallel``)
to run series-sharded across devices with exact psum'd metrics; rows are
padded to the device multiple and stripped, so any N works.

The estimator wraps the pure ``esrnn_init/esrnn_loss/esrnn_forecast*``
functions from ``repro.core.esrnn`` (all backed by the single
``repro.core.forward`` state-space pass) -- it holds state (spec, params,
data), the math stays functional and jitted.
"""

from __future__ import annotations

import json
import logging
import os
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import losses as L
from repro.core.comb import comb_forecast, naive2_forecast
from repro.core.esrnn import (
    esrnn_forecast, esrnn_forecast_at, esrnn_init, esrnn_loss,
    esrnn_loss_and_grad, esrnn_predict_stats, gather_series,
)
from repro.data.pipeline import PreparedData, chunk_bounds, prepare
from repro.data.synthetic_m4 import M4Dataset, generate
from repro.forecast.spec import ForecastSpec, get_spec
from repro.train.trainer import train_from_spec

log = logging.getLogger("repro.forecast")

_META_FILE = "forecaster.json"


class NotFittedError(RuntimeError):
    pass


def _pad_rows(a, pad: int):
    """Repeat the last row ``pad`` times (sharded-inference row padding)."""
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)], axis=0)


class ESRNNForecaster:
    """Scikit-style estimator over the vectorized ES-RNN."""

    def __init__(self, spec: Union[str, ForecastSpec] = "esrnn-quarterly",
                 **overrides):
        if isinstance(spec, str):
            spec = get_spec(spec, **overrides)
        elif overrides:
            spec = spec.replace(**overrides)
        self.spec = spec
        self.params_: Optional[Dict] = None
        self.history_: Optional[Dict] = None
        self.n_series_: Optional[int] = None
        self.data_: Optional[PreparedData] = None
        self.cats_: Optional[np.ndarray] = None   # fitted one-hots, persisted

    # -- config shortcuts ----------------------------------------------------

    @property
    def config(self):
        return self.spec.model

    @property
    def horizon(self) -> int:
        return self.spec.horizon

    def _check_fitted(self):
        if self.params_ is None:
            raise NotFittedError(
                "this ESRNNForecaster has no params; call fit(), "
                "init_params(), or load() first")

    # -- data ----------------------------------------------------------------

    def make_data(self) -> PreparedData:
        """Spec-driven synthetic M4 slice (Tables 2/3 profile, section 5)."""
        spec = self.spec
        ds = generate(spec.frequency, scale=spec.data_scale, seed=spec.data_seed)
        return prepare(ds, min_length=spec.min_length,
                       variable_length=spec.variable_length)

    def _coerce_data(self, data) -> PreparedData:
        if data is None:
            return self.make_data()
        if isinstance(data, M4Dataset):
            return prepare(data, min_length=self.spec.min_length,
                           variable_length=self.spec.variable_length)
        if isinstance(data, PreparedData):
            return data
        raise TypeError(f"cannot fit on {type(data).__name__}; "
                        "pass PreparedData, M4Dataset, or None")

    # -- fit -----------------------------------------------------------------

    def init_params(self, n_series: int, seed: Optional[int] = None):
        """Primer initialization without training (cold-start serving)."""
        seed = self.spec.seed if seed is None else seed
        self.params_ = esrnn_init(jax.random.PRNGKey(seed), self.config, n_series)
        self.n_series_ = n_series
        return self.params_

    def fit(self, data=None, *, ckpt_dir: Optional[str] = None,
            n_steps: Optional[int] = None, hooks=None,
            mesh=None) -> "ESRNNForecaster":
        """Joint two-group training (spec's rnn_lr / hw_lr); returns self.

        ``mesh``: optional 1-D series mesh for multi-device data-parallel
        training (see ``repro.sharding.series.make_series_mesh``); without
        one, ``spec.data_parallel > 1`` builds a mesh over that many local
        devices. Fitted params are identical in structure either way, so
        predict/evaluate/save/serve are unchanged.

        ``spec.scan_steps > 1`` trains through the fused superstep engine
        (K steps per donated ``lax.scan`` dispatch, host sync at superstep
        boundaries) -- same loss trajectory, fewer dispatches; composes
        with ``mesh``/``data_parallel`` and ``use_pallas``. When ``hooks``
        contains ``on_step`` it then fires once per superstep with the
        segment's loss array. ``spec.sparse_adam`` switches the per-series
        table to the sparse segment optimizer.
        """
        pdata = self._coerce_data(data)
        out = train_from_spec(self.spec, pdata, ckpt_dir=ckpt_dir,
                              n_steps=n_steps, params=self.params_, hooks=hooks,
                              mesh=mesh)
        self.params_ = out["params"]
        self.history_ = out["history"]
        self.n_series_ = pdata.n_series
        self.data_ = pdata
        self.cats_ = np.asarray(pdata.cats, np.float32)
        return self

    # -- predict -------------------------------------------------------------

    def _resolve_inputs(self, y, cats, series_idx, *, host: bool = False):
        """Resolve (params, y, cats). ``host=True`` keeps everything in host
        numpy (the chunked-streaming verbs slice rows out before any device
        transfer, so an out-of-core table never lands on device whole)."""
        xp = np if host else jnp
        self._check_fitted()
        if y is None:
            if self.data_ is None:
                raise NotFittedError("predict() without y requires fit(data)")
            y = self.data_.train
        y = xp.asarray(y, self.config.jdtype)
        if cats is None and self.cats_ is not None:
            # fitted categories: the rows of y are (a subset of) the fitted
            # series, so reuse their one-hots rather than zeroing the feature
            # (survives save/load -- cats_ is persisted in forecaster.json)
            if series_idx is not None:
                cats = self.cats_[np.asarray(series_idx)]
            elif y.shape[0] == self.cats_.shape[0]:
                cats = self.cats_
        if cats is None:
            cats = xp.zeros((y.shape[0], self.config.n_categories))
        cats = xp.asarray(cats, self.config.jdtype)
        params = self.params_
        if series_idx is not None:
            params = gather_series(params, np.asarray(series_idx))
        n_hw = params["hw"].alpha_logit.shape[0]
        if y.shape[0] != n_hw:
            raise ValueError(
                f"y has {y.shape[0]} series but the fitted per-series table "
                f"has {n_hw}; pass series_idx to select rows")
        return params, y, cats

    # -- sharded-inference plumbing ------------------------------------------

    def _resolve_mesh(self, mesh):
        """Explicit mesh, else one built from ``spec.data_parallel`` (> 1).

        Mirrors ``fit``'s resolution rule so an estimator fitted with
        ``data_parallel=8`` serves predict/evaluate/backtest sharded the
        same way without re-plumbing a mesh through every call. A 1-device
        mesh degenerates to the single-device path (identical math, no
        shard_map hop).
        """
        if mesh is None and self.spec.data_parallel > 1:
            from repro.sharding.series import make_series_mesh

            try:
                mesh = make_series_mesh(self.spec.data_parallel)
            except ValueError:
                # an estimator fitted data-parallel elsewhere must still
                # predict on a smaller host: inference is semantically
                # identical on any device count, so degrade to single-device
                # (training keeps raising -- its mesh is an explicit ask)
                log.warning(
                    "spec.data_parallel=%d exceeds the %d available "
                    "device(s); inference runs single-device",
                    self.spec.data_parallel, len(jax.devices()))
                mesh = None
        if mesh is not None and mesh.devices.size == 1:
            mesh = None
        return mesh

    def _shard_rows(self, params, arrays, mesh):
        """Pad rows (params hw + batch arrays) up to the mesh multiple.

        Inference batches are whatever the caller has -- unlike training
        batches they need not divide the device count -- so the rows are
        padded by repeating the last one (``pad`` returned for stripping /
        masking the metrics).
        """
        n = arrays[0].shape[0]
        pad = (-n) % mesh.devices.size
        if pad:
            params = {
                k: (jax.tree_util.tree_map(lambda a: _pad_rows(a, pad), v)
                    if k == "hw" else v)
                for k, v in params.items()}
            arrays = tuple(_pad_rows(jnp.asarray(a), pad) for a in arrays)
        return params, arrays, pad

    def _chunk_ranges(self, n: int):
        """[lo, hi) series chunks when the spec streams, else None."""
        c = self.spec.series_chunk
        if c and c > 0 and n > c:
            return chunk_bounds(n, c)
        return None

    def _forecast_chunk(self, params, y, cats, mesh):
        """One chunk's forecast: host slices in, (rows, H) numpy out.

        Composes chunk streaming (outer loop) with the series mesh (inner
        shard): the chunk's rows are padded to the device multiple and the
        pad stripped, exactly like resident sharded inference.
        """
        rows = y.shape[0]
        p_c = {k: (jax.tree_util.tree_map(jnp.asarray, v) if k == "hw" else v)
               for k, v in params.items()}
        y = jnp.asarray(y)
        cats = jnp.asarray(cats)
        if mesh is None:
            return np.asarray(esrnn_forecast(self.config, p_c, y, cats))
        from repro.sharding.series import esrnn_forecast_dp

        p_c, (y, cats), _pad = self._shard_rows(p_c, (y, cats), mesh)
        return np.asarray(
            esrnn_forecast_dp(self.config, p_c, y, cats, mesh=mesh))[:rows]

    def predict(self, y=None, cats=None, *,
                series_idx: Optional[Sequence[int]] = None,
                mesh=None) -> np.ndarray:
        """Point forecast (N, H) from the end of each series (Eq. 5).

        With no arguments, forecasts the fitted training series. ``y`` may be
        any history for the fitted series (e.g. train+val to forecast the test
        window); ``series_idx`` selects per-series HW rows when y is a subset.

        ``mesh``: optional 1-D series mesh for sharded inference (defaults
        to one over ``spec.data_parallel`` devices when that is > 1): each
        device forecasts its own HW-table rows under ``shard_map``; rows
        are padded to the device multiple and stripped, so any N works.

        ``spec.series_chunk > 0`` streams the forecast: rows move to device
        one ``series_chunk``-sized shard at a time (params table included --
        after a chunked fit its leaves are host numpy and never land on
        device whole), each shard running through the same jitted forecast
        (and the same mesh, when sharded).
        """
        mesh = self._resolve_mesh(mesh)
        n_in = (self.n_series_ if y is None else np.shape(y)[0])
        if series_idx is None and self._chunk_ranges(n_in or 0):
            params, y, cats = self._resolve_inputs(y, cats, None, host=True)
            out = np.empty((y.shape[0], self.horizon), np.float32)
            shared = {k: v for k, v in params.items() if k != "hw"}
            for lo, hi in self._chunk_ranges(y.shape[0]):
                p_c = {"hw": jax.tree_util.tree_map(
                    lambda a: a[lo:hi], params["hw"]), **shared}
                out[lo:hi] = self._forecast_chunk(
                    p_c, y[lo:hi], cats[lo:hi], mesh)
            return out
        params, y, cats = self._resolve_inputs(y, cats, series_idx)
        if mesh is None:
            return np.asarray(esrnn_forecast(self.config, params, y, cats))
        from repro.sharding.series import esrnn_forecast_dp

        n = y.shape[0]
        params, (y, cats), _pad = self._shard_rows(params, (y, cats), mesh)
        fc = esrnn_forecast_dp(self.config, params, y, cats, mesh=mesh)
        return np.asarray(fc)[:n]

    def predict_quantiles(
        self, y=None, cats=None, *, taus: Tuple[float, ...] = (0.1, 0.5, 0.9),
        series_idx: Optional[Sequence[int]] = None, mesh=None,
    ) -> Dict[float, np.ndarray]:
        """Quantile bands around the point forecast.

        The model is trained on a single pinball quantile (spec ``tau``), so
        its output is one quantile path. Bands are derived from the fitted
        Holt-Winters in-sample residuals: the multiplicative model says
        y_t = l_t * s_t * eps_t, so per-series log-residual spread sigma gives
        q_tau(h) = yhat * exp(z_tau * sigma * sqrt(h)) -- a random-walk
        widening in log-space (beyond-paper convenience; tau=0.5 returns the
        point forecast exactly). Point and sigma come off ONE forward-core
        pass (``esrnn_predict_stats``); ``mesh`` shards it like ``predict``.
        """
        params, y, cats = self._resolve_inputs(y, cats, series_idx)
        mesh = self._resolve_mesh(mesh)
        n = y.shape[0]
        if mesh is None:
            point, sigma = esrnn_predict_stats(self.config, params, y, cats)
        else:
            from repro.sharding.series import esrnn_predict_stats_dp

            params, (y, cats), _pad = self._shard_rows(params, (y, cats), mesh)
            point, sigma = esrnn_predict_stats_dp(
                self.config, params, y, cats, mesh=mesh)
            point, sigma = point[:n], sigma[:n]
        steps = jnp.sqrt(jnp.arange(1, self.horizon + 1))[None, :]  # (1, H)
        out = {}
        for tau in taus:
            z = jax.scipy.special.ndtri(jnp.asarray(tau, jnp.float32))
            out[tau] = np.asarray(point * jnp.exp(z * sigma * steps))
        return out

    # -- loss (golden-equivalence surface + benchmarks) ----------------------

    def loss(self, y, cats) -> jax.Array:
        """Training loss through the estimator (same jitted fn the fit uses)."""
        self._check_fitted()
        return esrnn_loss(self.config, self.params_,
                          jnp.asarray(y), jnp.asarray(cats))

    def loss_and_grad(self, y, cats):
        self._check_fitted()
        return esrnn_loss_and_grad(self.config, self.params_,
                                   jnp.asarray(y), jnp.asarray(cats))

    # -- evaluate ------------------------------------------------------------

    def evaluate(self, data: Optional[PreparedData] = None,
                 split: str = "test", *, mesh=None) -> Dict[str, float]:
        """M4-style scores: sMAPE/MASE/OWA vs the Comb and Naive2 benchmarks.

        ``split="test"`` forecasts from train+val and scores on the test
        window (Eq. 7); ``split="val"`` forecasts from train and scores on
        the validation window.

        ``mesh`` (or ``spec.data_parallel > 1``) shards the model's
        forecast + scoring over the series axis: each device scores its own
        rows and the metric sums/counts are psum'd once -- the exact global
        masked mean, so padded rows (N not a device multiple) contribute
        nothing and the scores match single-device to float summation
        order. The Comb/Naive2 baselines are cheap numpy and stay on host.
        """
        self._check_fitted()
        data = data if data is not None else self.data_
        if data is None:
            raise NotFittedError("evaluate() needs PreparedData (fit or pass)")
        if split == "test":
            insample, target = data.val_input, data.test_target
        elif split == "val":
            insample, target = data.train, data.val_target
        else:
            raise ValueError(f"split must be 'val' or 'test', got {split!r}")
        m, h = data.seasonality, min(self.horizon, target.shape[1])
        mesh = self._resolve_mesh(mesh)
        if self._chunk_ranges(insample.shape[0]):
            return self._evaluate_chunked(
                data, insample, target, m, h, split, mesh)
        target_j = jnp.asarray(target[:, :h])
        insample_j = jnp.asarray(insample)

        if mesh is None:
            fc = self.predict(insample, data.cats)[:, :h]
            s_es = float(L.smape(jnp.asarray(fc), target_j))
            m_es = float(L.mase(jnp.asarray(fc), target_j, insample_j, m))
        else:
            from repro.sharding.series import esrnn_eval_dp

            n = insample.shape[0]
            params = self.params_
            if params["hw"].alpha_logit.shape[0] != n:
                raise ValueError(
                    f"evaluate data has {n} series but the fitted table has "
                    f"{params['hw'].alpha_logit.shape[0]}")
            params, arrays, pad = self._shard_rows(
                params,
                (jnp.asarray(insample, self.config.jdtype),
                 jnp.asarray(data.cats, self.config.jdtype),
                 target_j, insample_j),
                mesh)
            y_p, cats_p, target_p, ins_p = arrays
            # padded rows score 0 into both numerator and denominator
            rmask_p = jnp.asarray(
                np.concatenate([np.ones(n), np.zeros(pad)]).astype(np.float32))
            scores = esrnn_eval_dp(
                self.config, params, y_p, cats_p, target_p, ins_p,
                seasonality=m, mesh=mesh, row_mask=rmask_p)
            s_es, m_es = float(scores["smape"]), float(scores["mase"])

        fc_comb = np.asarray(comb_forecast(insample, h, m), np.float32)
        fc_n2 = np.asarray(naive2_forecast(insample, h, m), np.float32)

        def score(f):
            f = jnp.asarray(f)
            return (float(L.smape(f, target_j)),
                    float(L.mase(f, target_j, insample_j, m)))

        s_cb, m_cb = score(fc_comb)
        s_n2, m_n2 = score(fc_n2)
        return {
            "split": split,
            "smape": s_es, "mase": m_es,
            "owa": float(L.owa(s_es, m_es, s_n2, m_n2)),
            "smape_comb": s_cb, "mase_comb": m_cb,
            "owa_comb": float(L.owa(s_cb, m_cb, s_n2, m_n2)),
            "smape_naive2": s_n2, "mase_naive2": m_n2,
        }

    def _evaluate_chunked(self, data, insample, target, m, h, split, mesh):
        """Streamed scores: model + baselines chunk by chunk, exact terms.

        Identical math to the resident path -- sMAPE/MASE are global
        sums-over-counts and every per-series scale is row-local, so
        accumulating each chunk's ``smape_terms``/``mase_terms`` and
        dividing once reproduces the full-batch masked means. Nothing
        N-sized ever lands on device.
        """
        params, y, cats = self._resolve_inputs(
            insample, data.cats, None, host=True)
        shared = {k: v for k, v in params.items() if k != "hw"}
        tgt = np.asarray(target[:, :h], np.float32)
        acc = {k: np.zeros(4, np.float64) for k in ("esrnn", "comb", "naive2")}

        def add(name, fc, tgt_c, ins_c):
            fc_j, tgt_j = jnp.asarray(fc), jnp.asarray(tgt_c)
            s0, s1 = L.smape_terms(fc_j, tgt_j)
            m0, m1 = L.mase_terms(fc_j, tgt_j, jnp.asarray(ins_c), m)
            acc[name] += np.array(
                [float(s0), float(s1), float(m0), float(m1)])

        for lo, hi in self._chunk_ranges(y.shape[0]):
            p_c = {"hw": jax.tree_util.tree_map(
                lambda a: a[lo:hi], params["hw"]), **shared}
            fc = self._forecast_chunk(p_c, y[lo:hi], cats[lo:hi], mesh)[:, :h]
            ins_c = np.asarray(y[lo:hi])
            add("esrnn", fc, tgt[lo:hi], ins_c)
            add("comb", np.asarray(comb_forecast(ins_c, h, m), np.float32),
                tgt[lo:hi], ins_c)
            add("naive2", np.asarray(naive2_forecast(ins_c, h, m), np.float32),
                tgt[lo:hi], ins_c)

        def score(name):
            s, sc, mm, mc = acc[name]
            return 200.0 * s / max(sc, 1.0), mm / max(mc, 1.0)

        s_es, m_es = score("esrnn")
        s_cb, m_cb = score("comb")
        s_n2, m_n2 = score("naive2")
        return {
            "split": split,
            "smape": s_es, "mase": m_es,
            "owa": float(L.owa(s_es, m_es, s_n2, m_n2)),
            "smape_comb": s_cb, "mase_comb": m_cb,
            "owa_comb": float(L.owa(s_cb, m_cb, s_n2, m_n2)),
            "smape_naive2": s_n2, "mase_naive2": m_n2,
        }

    # -- rolling-origin backtest ---------------------------------------------

    def backtest(self, data: Optional[PreparedData] = None, *,
                 origins: Optional[Sequence[int]] = None,
                 y=None, cats=None, mesh=None) -> Dict:
        """Rolling-origin backtest: forecast at several origins, no refit.

        For each origin ``o`` (an observation count), the model forecasts as
        if only ``y[:, :o]`` had been observed and is scored on the next
        ``H`` actuals. All origins are read off ONE forward pass of the
        unified state-space core (``esrnn_forecast_at``): the causal HW
        recurrence means the states at position ``o-1`` ARE the re-primed
        truncated-history states, so K origins cost one dispatch, not K
        re-runs (Hewamalage et al.'s rolling-origin protocol made cheap).

        Defaults: the full fitted history (train+val+test) with origins at
        the end of train and the end of val -- i.e. the validation and test
        windows of ``evaluate``, produced by one call. ``origins`` may be
        any increasing observation counts in ``[input_size, T]``; horizons
        that run past the series end are masked out of the metrics (an
        origin with no scorable targets at all reports NaN).

        ``mesh`` (or ``spec.data_parallel > 1``) shards rows like
        ``predict``; metric sums/counts are psum'd for the exact global
        masked mean. Returns per-origin and overall sMAPE/MASE plus the
        (N, K, H) forecasts.
        """
        self._check_fitted()
        if y is None:
            data = data if data is not None else self.data_
            if data is None:
                raise NotFittedError(
                    "backtest() needs PreparedData (fit or pass data=)")
            y = np.concatenate([data.val_input, data.test_target], axis=1)
            cats = data.cats if cats is None else cats
            if origins is None:
                train_len = data.train.shape[1]
                origins = (train_len, train_len + data.horizon)
        elif origins is None:
            raise ValueError("backtest(y=...) needs explicit origins")
        chunked = bool(self._chunk_ranges(np.shape(y)[0]))
        params, y, cats = self._resolve_inputs(y, cats, None, host=chunked)
        m = max(self.config.seasonality, 1)
        h = self.horizon
        n, t_len = y.shape
        origins = tuple(int(o) for o in origins)

        # per-origin scoring windows + validity masks (numpy, host-side)
        y_np = np.asarray(y)
        target = np.zeros((n, len(origins), h), np.float32)
        tmask = np.zeros((n, len(origins), h), np.float32)
        for k, o in enumerate(origins):
            avail = max(0, min(h, t_len - o))
            target[:, k, :avail] = y_np[:, o:o + avail]
            tmask[:, k, :avail] = 1.0

        mesh = self._resolve_mesh(mesh)
        if chunked:
            # stream chunks through the one-pass multi-origin forecast; the
            # per-origin metric terms are exact sums, so they accumulate
            shared = {k: v for k, v in params.items() if k != "hw"}
            fc = np.empty((n, len(origins), h), np.float32)
            tacc = np.zeros((4, len(origins)), np.float64)
            for lo, hi in self._chunk_ranges(n):
                rows = hi - lo
                p_c = {"hw": jax.tree_util.tree_map(
                    lambda a: jnp.asarray(a[lo:hi]), params["hw"]), **shared}
                y_c, c_c = jnp.asarray(y[lo:hi]), jnp.asarray(cats[lo:hi])
                if mesh is None:
                    fc_c = esrnn_forecast_at(
                        self.config, p_c, y_c, c_c, origins)
                    terms_c = L.rolling_metric_terms(
                        fc_c, jnp.asarray(target[lo:hi]),
                        jnp.asarray(tmask[lo:hi]), y_c, origins, m)
                else:
                    from repro.sharding.series import esrnn_backtest_dp

                    p_p, arrays, pad = self._shard_rows(
                        p_c, (y_c, c_c, jnp.asarray(target[lo:hi])), mesh)
                    y_p, c_p, t_p = arrays
                    tm_p = jnp.asarray(np.concatenate(
                        [tmask[lo:hi],
                         np.zeros((pad,) + tmask.shape[1:], np.float32)]))
                    fc_p, terms_c = esrnn_backtest_dp(
                        self.config, p_p, y_p, c_p, origins, t_p, tm_p,
                        seasonality=m, mesh=mesh)
                    fc_c = np.asarray(fc_p)[:rows]
                fc[lo:hi] = np.asarray(fc_c)
                tacc += np.stack(
                    [np.asarray(t, np.float64) for t in terms_c])
            terms = tuple(tacc)
        elif mesh is None:
            fc = esrnn_forecast_at(self.config, params, y, cats, origins)
            terms = L.rolling_metric_terms(
                fc, jnp.asarray(target), jnp.asarray(tmask), y, origins, m)
            fc = np.asarray(fc)
        else:
            from repro.sharding.series import esrnn_backtest_dp

            params_p, arrays, pad = self._shard_rows(
                params, (y, cats, jnp.asarray(target)), mesh)
            y_p, cats_p, target_p = arrays
            # padded rows are fully masked out of the metric sums/counts
            tmask_p = jnp.asarray(np.concatenate(
                [tmask, np.zeros((pad,) + tmask.shape[1:], np.float32)]))
            fc_p, terms = esrnn_backtest_dp(
                self.config, params_p, y_p, cats_p, origins, target_p,
                tmask_p, seasonality=m, mesh=mesh)
            fc = np.asarray(fc_p)[:n]

        s_sum, s_cnt, m_sum, m_cnt = (np.asarray(t, np.float64) for t in terms)

        def ratio(num, cnt):
            # an origin with no scorable targets (e.g. origin == T) is
            # unscored: NaN, not a perfect-looking 0.0
            return float(num / cnt) if cnt > 0 else float("nan")

        per_origin = [
            {"origin": o,
             "smape": ratio(200.0 * s_sum[k], s_cnt[k]),
             "mase": ratio(m_sum[k], m_cnt[k])}
            for k, o in enumerate(origins)]
        return {
            "origins": list(origins),
            "horizon": h,
            "per_origin": per_origin,
            "smape": ratio(200.0 * s_sum.sum(), s_cnt.sum()),
            "mase": ratio(m_sum.sum(), m_cnt.sum()),
            "forecasts": fc,
        }

    # -- serving -------------------------------------------------------------

    def serve(self, *, server_config=None,
              length_buckets: Tuple[int, ...] = (32, 64, 128, 256),
              batch_buckets: Tuple[int, ...] = (1, 4, 16, 64),
              mesh=None, seed_histories: bool = False):
        """Continuous-batching online server over the fitted params.

        Returns an (unstarted) :class:`repro.forecast.server.ForecastServer`
        -- ``start()`` it for threaded serving or drive ``step()``/``drain()``
        synchronously. ``seed_histories=True`` pre-registers every fitted
        series' training history in the online store (masked left-padding
        stripped), so ``observe``/history-less forecasts work for known ids
        from the first request instead of only after their first write.
        Inherits ``spec.data_parallel`` sharding like the other verbs.
        """
        self._check_fitted()
        from repro.forecast.server import ForecastServer

        srv = ForecastServer(
            self.config, self.params_, server_config=server_config,
            length_buckets=length_buckets, batch_buckets=batch_buckets,
            mesh=self._resolve_mesh(mesh))
        if seed_histories:
            if self.data_ is None:
                raise NotFittedError(
                    "serve(seed_histories=True) needs fitted data; call "
                    "fit(data) first")
            y = np.asarray(self.data_.train, np.float32)
            mask = np.asarray(self.data_.mask, np.float32)
            for sid in range(y.shape[0]):
                real = y[sid][mask[sid] > 0]
                srv.store.seed(
                    sid, real, row=srv.dispatcher.resolve_row(sid),
                    category=int(np.argmax(self.cats_[sid]))
                    if self.cats_ is not None else None)
        return srv

    # -- persistence (shared Checkpointer) -----------------------------------

    def save(self, directory: str) -> str:
        """Persist spec + params atomically via the shared Checkpointer.

        Params live under ``<directory>/params/`` so a saved estimator can
        share a directory with trainer checkpoints (``fit(ckpt_dir=...)``
        writes ``step_<n>/`` trees of (params, opt_state) at the top level;
        colliding with those would corrupt crash-resume).
        """
        self._check_fitted()
        ckpt = Checkpointer(os.path.join(directory, "params"), keep=self.spec.keep)
        step = len(self.history_["loss"]) if self.history_ else 0
        ckpt.save(step, self.params_)
        meta = {
            "spec": self.spec.to_dict(),
            "n_series": int(self.n_series_),
            "step": step,
            "cats": self.cats_.tolist() if self.cats_ is not None else None,
        }
        tmp = os.path.join(directory, _META_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=2)
        os.replace(tmp, os.path.join(directory, _META_FILE))
        return directory

    @classmethod
    def load(cls, directory: str) -> "ESRNNForecaster":
        with open(os.path.join(directory, _META_FILE)) as f:
            meta = json.load(f)
        spec = ForecastSpec.from_dict(meta["spec"])
        f = cls(spec)
        template = esrnn_init(
            jax.random.PRNGKey(spec.seed), spec.model, meta["n_series"])
        _, f.params_ = Checkpointer(
            os.path.join(directory, "params")).restore(template, step=meta["step"])
        f.n_series_ = meta["n_series"]
        if meta.get("cats") is not None:
            f.cats_ = np.asarray(meta["cats"], np.float32)
        return f
