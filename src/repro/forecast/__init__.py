"""Unified forecasting API: spec registry, estimator, batched + online serving.

    from repro.forecast import ESRNNForecaster, get_spec

    f = ESRNNForecaster("esrnn-quarterly").fit()
    f.predict(); f.evaluate(); f.backtest(); f.save("/tmp/fq")

CLI: ``python -m repro.launch.forecast {fit|predict|eval|backtest|serve}``.

Submodules are imported lazily (PEP 562) so that ``repro.train.trainer`` can
import :mod:`repro.forecast.spec` without a cycle through the estimator.
"""

from __future__ import annotations

from repro.forecast.spec import ForecastSpec, get_smoke_spec, get_spec, list_specs

__all__ = [
    "ForecastSpec", "get_spec", "get_smoke_spec", "list_specs",
    "ESRNNForecaster", "NotFittedError",
    "BucketDispatcher", "BatchedForecastServer", "ForecastRequest",
    "ServeStats", "synthetic_request_stream",
    "ForecastServer", "ServerConfig", "ObserveWrite",
]

_LAZY = {
    "ESRNNForecaster": "repro.forecast.estimator",
    "NotFittedError": "repro.forecast.estimator",
    "BucketDispatcher": "repro.forecast.serving",
    "BatchedForecastServer": "repro.forecast.serving",
    "ForecastRequest": "repro.forecast.serving",
    "ServeStats": "repro.forecast.serving",
    "synthetic_request_stream": "repro.forecast.serving",
    "ForecastServer": "repro.forecast.server",
    "ServerConfig": "repro.forecast.server",
    "ObserveWrite": "repro.forecast.server",
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
