"""Continuous-batching forecast server with online HW state ingestion.

The production serving front end for fitted ES-RNN models:

* :class:`~repro.forecast.server.engine.ForecastServer` -- bounded request
  queue, deadline-driven dynamic bucket fill, batched dispatch through the
  shared jit-cached bucket kernels, ``observe`` write ingestion, and the
  idle fine-tune hook.
* :class:`~repro.forecast.server.state.OnlineStateStore` -- host-side
  rolled Holt-Winters state per tracked series (the ``hw_step`` recurrence
  applied observation-by-observation).
* :class:`~repro.forecast.server.finetune.IdleFineTuner` -- sparse-Adam
  bursts on recently observed series during queue idle gaps.

The synchronous batch-at-a-time surface is
:meth:`repro.forecast.serving.BucketDispatcher.forecast_batch` (the legacy
``BatchedForecastServer`` wrapper is deprecated).
"""

from repro.forecast.server.engine import (
    ForecastFuture, ForecastServer, QueueFull, ServerConfig,
)
from repro.forecast.server.state import ObserveWrite, OnlineStateStore

__all__ = [
    "ForecastFuture",
    "ForecastServer",
    "ObserveWrite",
    "OnlineStateStore",
    "QueueFull",
    "ServerConfig",
]
