"""Online Holt-Winters state ingestion: the server's resident state table.

The creative unlock of serving ES-RNN (vs a generic NN forecaster) is that
the per-series half of the model is a *one-step recurrence*: level and
seasonality evolve by :func:`repro.core.forward.hw_step` -- the exact body
of the training-time ``hw_smooth`` scan -- so the server can ingest a new
observation and roll that series' state forward in place, O(1) per write,
no refit and no re-pass over history. Forecasts issued afterwards condition
on the extended history, so they stay fresh under heavy write traffic.

:class:`OnlineStateStore` keeps, per tracked series id:

* the **history tail** (most recent ``history_cap`` observations, float32)
  -- what the batched forecast pass actually consumes,
* the **rolled HW state** ``(level, s_ring, s2_ring)`` after the *full*
  observed history -- exact even once the tail is truncated, because the
  recurrence is applied observation-by-observation as writes arrive
  (``tests/forecast/test_server.py`` asserts it against a from-scratch
  ``hw_smooth`` pass over the extended history, per frequency, including
  the dual-seasonality ring),
* the category and the resolved row in the extended HW table (fitted row
  for known ids, the cold-start primer row otherwise).

All arithmetic is host-side numpy float32 mirroring the f32 device scan
(same expression order -- ``hw_step`` is shared, not re-derived), so the
hot write path never touches a device. Writes are absorbed in batches
(:meth:`absorb`): the scheduler drains the whole write queue in one pass
before a forecast dispatch, and series with a single pending write -- the
common case -- roll in one vectorized ``hw_step`` across the batch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.esrnn import ESRNNConfig
from repro.core.forward import hw_step


@dataclasses.dataclass
class ObserveWrite:
    """One queued observation: series ``series_id`` gained value ``y``."""

    series_id: int
    y: float
    category: Optional[int] = None   # sticky: None keeps the known category


def _sigmoid32(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float32)
    return (1.0 / (1.0 + np.exp(-x, dtype=np.float32))).astype(np.float32)


@dataclasses.dataclass
class SeriesState:
    """Rolled Holt-Winters state + history tail for one tracked series."""

    series_id: int
    row: int                      # row in the extended HW table
    category: int
    # constrained per-series smoothing parameters (f32, cached at prime time)
    alpha: np.float32
    gamma: np.float32
    gamma2: Optional[np.float32]
    init_s_ring: np.ndarray       # (m,) constrained initial ring
    init_s2_ring: np.ndarray      # (m2,)
    # rolled state; level is None until the first observation arrives
    level: Optional[np.float32] = None
    s_ring: np.ndarray = None     # type: ignore[assignment]
    s2_ring: np.ndarray = None    # type: ignore[assignment]
    t: int = 0                    # total observations absorbed (full history)
    history: List[float] = dataclasses.field(default_factory=list)
    truncated: bool = False       # tail dropped observations beyond the cap
    last_write: int = -1          # store write counter at last observation

    def __post_init__(self):
        if self.s_ring is None:
            self.s_ring = self.init_s_ring.copy()
        if self.s2_ring is None:
            self.s2_ring = self.init_s2_ring.copy()

    def history_array(self) -> np.ndarray:
        return np.asarray(self.history, np.float32)

    def future_seasonal(self, m: int) -> np.ndarray:
        """Combined future factors s_T .. s_{T+m-1} (both rings, tiled).

        Mirrors the ``future`` construction of ``hw_smooth``: the shorter
        second ring tiles up to the primary period, and the product is what
        de-seasonalization uses -- directly comparable to
        ``hw_smooth(y_full)[1][:, T:]``.
        """
        m2 = len(self.s2_ring)
        reps = (m + m2 - 1) // m2
        return (self.s_ring[:m]
                * np.tile(self.s2_ring, reps)[:m]).astype(np.float32)


class OnlineStateStore:
    """Host-side table of rolled HW states, keyed by series id.

    ``row_params`` returns the current host HW-table snapshot (the
    dispatcher's extended fitted-plus-primer table); it is re-read on
    :meth:`refresh` after an idle fine-tune changes the table underneath.
    """

    def __init__(
        self,
        config: ESRNNConfig,
        table: Callable[[], object],
        n_known: int,
        *,
        history_cap: int,
    ):
        self.config = config
        self._table = table
        self.n_known = n_known
        self.history_cap = int(history_cap)
        self._states: Dict[int, SeriesState] = {}
        self._seasonal = config.seasonality > 1
        self._dual = config.seasonality2 > 1
        self._writes = 0   # monotone write counter (recency ordering)

    # -- introspection -------------------------------------------------------

    def __contains__(self, series_id: int) -> bool:
        return series_id in self._states

    def __len__(self) -> int:
        return len(self._states)

    def get(self, series_id: int) -> Optional[SeriesState]:
        return self._states.get(series_id)

    def history(self, series_id: int) -> Optional[np.ndarray]:
        st = self._states.get(series_id)
        return st.history_array() if st is not None else None

    def recently_observed(
        self, *, rows_below: Optional[int] = None, min_history: int = 0,
    ) -> List[SeriesState]:
        """Tracked series, most recently written first (fine-tune candidates).

        ``rows_below`` keeps only series with a fitted table row below it
        (cold-start primer series have no row of their own to fine-tune);
        ``min_history`` drops series whose stored tail is too short to form
        a training window.
        """
        states = [
            st for st in self._states.values()
            if (rows_below is None or st.row < rows_below)
            and len(st.history) >= min_history]
        return sorted(states, key=lambda st: st.last_write, reverse=True)

    # -- registration --------------------------------------------------------

    def _constrained_row(self, row: int):
        hw = self._table()
        alpha = _sigmoid32(hw.alpha_logit[row])
        gamma = _sigmoid32(hw.gamma_logit[row])
        if self._seasonal:
            s_ring = np.exp(np.asarray(hw.init_seas_logit[row], np.float32))
        else:
            s_ring = np.ones(
                max(self.config.seasonality, 1), np.float32)
        if self._dual:
            gamma2 = _sigmoid32(hw.gamma2_logit[row])
            s2_ring = np.exp(
                np.asarray(hw.init_seas_logit2[row], np.float32))
        else:
            gamma2 = None
            s2_ring = np.ones(1, np.float32)
        return alpha, gamma, gamma2, s_ring.astype(np.float32), s2_ring.astype(np.float32)

    def ensure(self, series_id: int, *, row: int,
               category: Optional[int] = None) -> SeriesState:
        """Get-or-create the state for ``series_id`` (resolved table ``row``)."""
        st = self._states.get(series_id)
        if st is None:
            alpha, gamma, gamma2, s_ring, s2_ring = self._constrained_row(row)
            st = SeriesState(
                series_id=series_id, row=row, category=category or 0,
                alpha=alpha, gamma=gamma, gamma2=gamma2,
                init_s_ring=s_ring, init_s2_ring=s2_ring)
            self._states[series_id] = st
        if category is not None:
            st.category = category
        return st

    # -- the write path ------------------------------------------------------

    def _roll_one(self, st: SeriesState, y: float) -> None:
        """Apply one observation to a state (the scalar hw_step path)."""
        y32 = np.float32(y)
        if st.level is None:
            # primer estimate, exactly as hw_smooth: the first observation
            # de-seasonalized by the initial ring heads the recurrence
            st.level = np.float32(y32 / (st.s_ring[0] * st.s2_ring[0]))
        l_t, s_new, s2_new = hw_step(
            y32, st.level, st.s_ring[0], st.s2_ring[0],
            st.alpha, st.gamma, st.gamma2,
            seasonal=self._seasonal, dual=self._dual)
        st.level = np.float32(l_t)
        st.s_ring = np.roll(st.s_ring, -1)
        st.s_ring[-1] = s_new
        st.s2_ring = np.roll(st.s2_ring, -1)
        st.s2_ring[-1] = s2_new
        self._note_obs(st, y32)

    def _note_obs(self, st: SeriesState, y32: np.float32) -> None:
        st.t += 1
        st.history.append(float(y32))
        if len(st.history) > self.history_cap:
            del st.history[:len(st.history) - self.history_cap]
            st.truncated = True
        self._writes += 1
        st.last_write = self._writes

    def absorb(self, writes: Sequence[ObserveWrite],
               resolve_row: Callable[[Optional[int]], int]) -> int:
        """Absorb a batch of writes; returns the number applied.

        Series with exactly ONE pending write and an already-primed state --
        the steady-state shape of a live write stream -- roll together in a
        single vectorized ``hw_step`` over the write batch; everything else
        (first-ever observations, multi-write bursts, which must apply in
        order) takes the scalar path. Both paths are the same f32
        expression, so the split is invisible in the numbers.
        """
        if not writes:
            return 0
        by_sid: Dict[int, List[ObserveWrite]] = {}
        for w in writes:
            self.ensure(int(w.series_id), row=resolve_row(w.series_id),
                        category=w.category)
            by_sid.setdefault(int(w.series_id), []).append(w)

        fast = [sid for sid, ws in by_sid.items()
                if len(ws) == 1 and self._states[sid].level is not None]
        if len(fast) > 1:
            sts = [self._states[s] for s in fast]
            y = np.asarray([by_sid[s][0].y for s in fast], np.float32)
            lvl = np.asarray([st.level for st in sts], np.float32)
            s_t = np.asarray([st.s_ring[0] for st in sts], np.float32)
            s2_t = np.asarray([st.s2_ring[0] for st in sts], np.float32)
            alpha = np.asarray([st.alpha for st in sts], np.float32)
            gamma = np.asarray([st.gamma for st in sts], np.float32)
            gamma2 = (np.asarray([st.gamma2 for st in sts], np.float32)
                      if self._dual else None)
            l_t, s_new, s2_new = hw_step(
                y, lvl, s_t, s2_t, alpha, gamma, gamma2,
                seasonal=self._seasonal, dual=self._dual)
            s2_new = np.broadcast_to(np.asarray(s2_new, np.float32), l_t.shape)
            for i, st in enumerate(sts):
                st.level = np.float32(l_t[i])
                st.s_ring = np.roll(st.s_ring, -1)
                st.s_ring[-1] = np.float32(s_new[i])
                st.s2_ring = np.roll(st.s2_ring, -1)
                st.s2_ring[-1] = np.float32(s2_new[i])
                self._note_obs(st, np.float32(y[i]))
            slow = [s for s in by_sid if s not in set(fast)]
        else:
            slow = list(by_sid)

        for sid in slow:
            st = self._states[sid]
            for w in by_sid[sid]:
                self._roll_one(st, w.y)
        return sum(len(ws) for ws in by_sid.values())

    # -- seeding + fine-tune refresh -----------------------------------------

    def seed(self, series_id: int, history: Iterable[float], *, row: int,
             category: Optional[int] = None) -> SeriesState:
        """Register a series with an existing history (warm start).

        The history is rolled through the same recurrence one observation at
        a time, so a seeded series is indistinguishable from one built up by
        ``observe`` calls.
        """
        st = self.ensure(series_id, row=row, category=category)
        for y in np.asarray(history, np.float32):
            self._roll_one(st, y)
        return st

    def refresh(self, rows: Optional[Sequence[int]] = None) -> int:
        """Re-prime states after the HW table changed under them.

        The idle fine-tune updates per-series smoothing parameters in the
        fitted table; a state rolled under the OLD parameters no longer
        matches a fresh pass under the new ones, so affected series re-pull
        their constrained row and replay their stored history tail. (Post-
        refresh the invariant is "state == pass over the *stored* history"
        -- for a truncated tail the pre-truncation prefix is gone, which is
        exactly what the batched forecast conditions on anyway.)
        """
        rows_set = None if rows is None else set(int(r) for r in rows)
        n = 0
        for st in self._states.values():
            if rows_set is not None and st.row not in rows_set:
                continue
            alpha, gamma, gamma2, s_ring, s2_ring = self._constrained_row(st.row)
            st.alpha, st.gamma, st.gamma2 = alpha, gamma, gamma2
            st.init_s_ring, st.init_s2_ring = s_ring, s2_ring
            st.level = None
            st.s_ring = s_ring.copy()
            st.s2_ring = s2_ring.copy()
            history, st.history, st.t = st.history, [], 0
            writes_before, last_write = self._writes, st.last_write
            for y in history:
                self._roll_one(st, y)
            # the replay is not new traffic: keep the write clock and this
            # series' recency rank exactly where they were
            self._writes, st.last_write = writes_before, last_write
            n += 1
        return n
