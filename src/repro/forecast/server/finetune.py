"""Idle-triggered incremental fine-tune: a few sparse-Adam steps on live series.

The serving loop has natural gaps -- the request queue drains, the deadline
timer has nothing to flush -- and the online store knows exactly which
series have received new observations since the fit. This module spends
those gaps productively: it assembles a small batch from the most recently
observed *known* series (cold-start primer series have no fitted row to
tune), runs a handful of training steps through the same loss and sparse
per-series Adam the offline trainer uses
(:func:`repro.train.engine.make_online_step_fn` +
``adam_update_sparse``), and hands the updated params back to the
dispatcher. Only the touched HW rows and the shared RNN move; the rest of
the per-series table is untouched by construction of the sparse update.

Discipline notes:

* The fine-tune batch is padded to a fixed ``window`` (left-pad history +
  mask, the section-8.1 convention); the jitted step compiles once per
  distinct batch fill (at most ``batch`` shapes, and in steady state the
  fill saturates at ``batch`` so bursts are cache hits).
* The Adam state (``adam_init_sparse``) persists across bursts -- moments
  warm up over the serving session instead of restarting cold each idle
  gap, and the ``t_hw`` row clocks give per-row moment catch-up exactly
  as in offline sparse training.
* After a burst the caller must propagate the new table:
  ``dispatcher.set_params`` (host snapshot rebuild) and
  ``store.refresh(rows)`` (re-roll the affected series' online state under
  the new smoothing parameters). :meth:`IdleFineTuner.run` returns the
  touched rows so the server can do exactly that.
"""

from __future__ import annotations

import logging
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esrnn import ESRNNConfig
from repro.core.heads import frozen_param_groups
from repro.train.engine import make_online_step_fn, split_frozen
from repro.train.optimizer import AdamConfig, adam_init_sparse

log = logging.getLogger("repro.forecast.server")


class IdleFineTuner:
    """Sparse-Adam burst trainer over the online store's freshest series.

    ``steps`` training steps per :meth:`run` call, batching up to ``batch``
    recently-observed known series on a fixed ``window`` (the largest
    serving length bucket by default). ``lr`` drives the shared RNN;
    ``hw_lr_ratio`` scales the per-series group relative to it (the
    ``group_lr['per_series']`` multiplier), mirroring the offline trainer's
    two-group schedule.
    """

    def __init__(
        self,
        config: ESRNNConfig,
        params,
        *,
        steps: int = 2,
        batch: int = 32,
        window: int = 64,
        lr: float = 1e-4,
        hw_lr_ratio: float = 10.0,
        min_history: Optional[int] = None,
    ):
        self.config = config
        self.steps = int(steps)
        self.batch = int(batch)
        self.window = int(window)
        # a training window must cover at least one full input+output span
        floor = config.input_size + config.output_size
        self.min_history = int(min_history if min_history is not None
                               else min(floor, self.window))
        self.cfg_adam = AdamConfig(
            lr=lr, group_lr={"per_series": hw_lr_ratio},
            schedule="constant")
        # head-declared frozen groups (e.g. the esn reservoir) stay fixed
        # online exactly as offline: no gradients, no Adam moments
        frozen = frozen_param_groups(config)
        self.opt_state = adam_init_sparse(split_frozen(params, frozen)[0])
        self._step = jax.jit(
            make_online_step_fn(config, self.cfg_adam, frozen=frozen))
        self.last_loss: Optional[float] = None

    # -- batch assembly ------------------------------------------------------

    def build_batch(
        self, store, n_known: int,
    ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
        """(y, cats, mask, rows) over the freshest eligible series, or None.

        Histories are clipped to the most recent ``window`` observations and
        left-padded (first value, mask 0) to the fixed window, so the jitted
        step sees one shape forever.
        """
        states = store.recently_observed(
            rows_below=n_known, min_history=self.min_history)[:self.batch]
        if not states:
            return None
        b = len(states)
        y = np.empty((b, self.window), np.float32)
        mask = np.zeros((b, self.window), np.float32)
        cats = np.zeros((b, self.config.n_categories), np.float32)
        rows = np.empty((b,), np.int32)
        for i, st in enumerate(states):
            h = st.history_array()[-self.window:]
            y[i, :self.window - len(h)] = h[0]
            y[i, self.window - len(h):] = h
            mask[i, self.window - len(h):] = 1.0
            if 0 <= st.category < self.config.n_categories:
                cats[i, st.category] = 1.0
            rows[i] = st.row
        return y, cats, mask, rows

    # -- the burst -----------------------------------------------------------

    def run(self, store, params, n_known: int):
        """One idle burst: returns ``(params, touched_rows)``.

        ``touched_rows`` is empty when no eligible series exist (params are
        returned unchanged); otherwise the caller owns propagating the new
        params to the dispatcher snapshot and refreshing the store rows.
        """
        built = self.build_batch(store, n_known)
        if built is None:
            return params, []
        y, cats, mask, rows = built
        yj, cj, mj, rj = (jnp.asarray(y), jnp.asarray(cats),
                          jnp.asarray(mask), jnp.asarray(rows))
        loss = None
        for _ in range(self.steps):
            params, self.opt_state, loss = self._step(
                params, self.opt_state, yj, cj, mj, rj)
        self.last_loss = float(loss)
        log.debug("idle fine-tune: %d series x %d steps, loss %.5f",
                  len(rows), self.steps, self.last_loss)
        return params, [int(r) for r in rows]
