"""The continuous-batching forecast server: queue -> bucket fill -> dispatch.

``BucketDispatcher.forecast_batch`` serves whatever batch the caller
assembled; under live traffic nobody assembles batches -- requests trickle
in one at a time, and serving them one at a time wastes the entire point of
the GPU implementation (a batch-1 forecast costs nearly the same wall time
as a batch-64 one through the same jitted kernel). :class:`ForecastServer`
closes that gap with the standard continuous-batching loop:

* **bounded request queue** -- ``submit`` enqueues a request and returns a
  :class:`ForecastFuture` immediately; when the queue is full the submitter
  blocks (backpressure), so an overloaded server degrades by queueing
  delay, not by unbounded memory growth.
* **dynamic bucket fill with a max-wait deadline** -- the scheduler groups
  pending requests by length bucket and dispatches a group as soon as it
  can fill a full batch, or when its oldest request has waited
  ``max_wait_ms`` (the knob trades p50 latency against batch occupancy;
  ``max_wait_ms=0`` degenerates to dispatch-immediately).
* **batched dispatch** through the shared
  :class:`~repro.forecast.serving.BucketDispatcher` -- the exact
  ``esrnn_forecast``/``esrnn_forecast_dp`` jit-cached bucket kernels the
  synchronous wrapper uses; the continuous front end adds no new numerics.
* **online state ingestion** -- ``observe`` enqueues
  :class:`~repro.forecast.server.state.ObserveWrite` records; the scheduler
  absorbs the whole write queue in one batched pass *before* every
  dispatch, so forecasts read their own writes (a forecast submitted after
  an ``observe`` ack always conditions on the new observation) while the
  write path never stalls a forecast on per-observation work.
* **idle fine-tune hook** -- when the queue fully drains after activity,
  an optional :class:`~repro.forecast.server.finetune.IdleFineTuner` burst
  runs a few sparse-Adam steps on the most recently observed known series,
  then the dispatcher snapshot and the store re-sync to the updated table.

The scheduler is single-threaded (one dispatching thread, or the caller's
thread via :meth:`step`/:meth:`drain` for deterministic tests), which keeps
``ServeStats`` single-writer and the store free of fine-grained locking:
the only lock is the queue's own condition variable.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.esrnn import ESRNNConfig
from repro.forecast.serving import (
    BucketDispatcher, ForecastRequest, ServeStats,
)
from repro.forecast.server.state import ObserveWrite, OnlineStateStore


class QueueFull(RuntimeError):
    """The bounded request queue stayed full past the submit timeout."""


@dataclasses.dataclass
class ServerConfig:
    """Continuous-batching knobs (the serving analogue of a TrainConfig)."""

    max_queue: int = 1024          # bounded request queue (backpressure)
    max_wait_ms: float = 5.0       # deadline: oldest request's max hold time
    max_batch: Optional[int] = None   # per-dispatch cap (None: largest bucket)
    history_cap: Optional[int] = None  # online store tail (None: largest
                                       # length bucket -- what forecasts use)
    compile_budget: Optional[int] = None  # declared XLA-compile bound for
                                       # the recompile sentinel (None:
                                       # length x batch bucket-grid size)
    # idle fine-tune hook (0 steps = off)
    finetune_steps: int = 0
    finetune_batch: int = 32
    finetune_lr: float = 1e-4
    finetune_hw_lr_ratio: float = 10.0
    finetune_min_history: Optional[int] = None


class ForecastFuture:
    """Handle for one submitted request: blocks on :meth:`result`."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value: np.ndarray) -> None:
        self._value = value
        self._event.set()

    def set_exception(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError("forecast not ready within timeout")
        if self._error is not None:
            raise self._error
        return self._value


@dataclasses.dataclass
class _Pending:
    request: ForecastRequest
    future: ForecastFuture
    arrival: float               # perf_counter at submit


class ForecastServer:
    """Continuous-batching serving front end over the shared dispatcher.

    Use either threaded (``start()`` / ``submit`` / ``observe`` / ``stop()``)
    or synchronously (``submit``+``step(force=True)`` or the
    ``forecast_batch`` compatibility call) -- the scheduler pass is the same
    code path, so tests drive it deterministically without threads.
    """

    def __init__(
        self,
        config: ESRNNConfig,
        params,
        *,
        server_config: Optional[ServerConfig] = None,
        length_buckets: Tuple[int, ...] = (32, 64, 128, 256),
        batch_buckets: Tuple[int, ...] = (1, 4, 16, 64),
        mesh=None,
    ):
        self.config = config
        self.server_config = server_config or ServerConfig()
        sc = self.server_config
        self.stats = ServeStats()
        self.dispatcher = BucketDispatcher(
            config, params, length_buckets=length_buckets,
            batch_buckets=batch_buckets, max_batch=sc.max_batch,
            mesh=mesh, stats=self.stats,
            compile_budget=sc.compile_budget)
        cap = (sc.history_cap if sc.history_cap is not None
               else self.dispatcher.length_buckets[-1])
        self.store = OnlineStateStore(
            config, lambda: self.dispatcher._hw_table,
            self.dispatcher.n_known, history_cap=cap)
        self.tuner = None
        if sc.finetune_steps > 0:
            from repro.forecast.server.finetune import IdleFineTuner

            self.tuner = IdleFineTuner(
                config, params, steps=sc.finetune_steps,
                batch=sc.finetune_batch,
                window=self.dispatcher.length_buckets[-1],
                lr=sc.finetune_lr, hw_lr_ratio=sc.finetune_hw_lr_ratio,
                min_history=sc.finetune_min_history)

        self._cond = threading.Condition()
        self._pending: List[_Pending] = []
        self._writes: List[ObserveWrite] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._active_since_tune = False

    # -- client surface ------------------------------------------------------

    def submit(self, request: ForecastRequest,
               timeout: Optional[float] = None) -> ForecastFuture:
        """Enqueue a request; returns its future immediately.

        Blocks (backpressure) while the bounded queue is full; raises
        :class:`QueueFull` if it stays full past ``timeout``.
        """
        fut = ForecastFuture()
        entry = _Pending(request, fut, time.perf_counter())
        with self._cond:
            deadline = None if timeout is None else time.monotonic() + timeout
            while len(self._pending) >= self.server_config.max_queue:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise QueueFull(
                        f"request queue held {len(self._pending)} >= "
                        f"max_queue={self.server_config.max_queue} past "
                        f"the submit timeout")
                if not self._cond.wait(timeout=remaining):
                    raise QueueFull(
                        f"request queue held {len(self._pending)} >= "
                        f"max_queue={self.server_config.max_queue} past "
                        f"the submit timeout")
            self._pending.append(entry)
            self.stats.note_queue_depth(len(self._pending))
            self._cond.notify_all()
        return fut

    def observe(self, series_id: int, y: float,
                category: Optional[int] = None) -> None:
        """Ingest one new observation for ``series_id`` (async, batched).

        Returns immediately; the write is absorbed into the online HW state
        by the scheduler before the next dispatch, so any forecast submitted
        after this call conditions on the new value (read-your-writes).
        """
        with self._cond:
            self._writes.append(ObserveWrite(int(series_id), float(y),
                                             category))
            self._cond.notify_all()

    def forecast_batch(
        self, requests: Sequence[ForecastRequest]
    ) -> List[np.ndarray]:
        """Compatibility verb: submit all, force-drain, return in order."""
        futs = [self.submit(r) for r in requests]
        if self._thread is None:
            self.drain()
        return [f.result() for f in futs]

    def check_compile_budget(self) -> int:
        """Assert true XLA compiles stayed within the declared bucket budget.

        Raises :class:`repro.analysis.CompileBudgetExceeded` when serving
        compiled more executables than the bucket grid allows (the PR-6
        ``fc[:n]`` bug class); returns the compile count otherwise. Ops
        runbooks call this after a soak; the graph auditor calls the same
        check in CI.
        """
        from repro.analysis.recompile import check_compile_budget

        return check_compile_budget(self.stats)

    # -- scheduler -----------------------------------------------------------

    def _absorb_writes(self) -> int:
        with self._cond:
            writes, self._writes = self._writes, []
        if not writes:
            return 0
        n = self.store.absorb(writes, self.dispatcher.resolve_row)
        self.stats.observes += n
        self.stats.write_batches += 1
        self._active_since_tune = True
        return n

    def _resolve_history(self, entry: _Pending) -> Optional[np.ndarray]:
        """Request history: explicit ``y``, else the online store's tail."""
        r = entry.request
        if r.y is not None:
            return np.asarray(r.y, np.float32)
        hist = (None if r.series_id is None
                else self.store.history(r.series_id))
        if hist is None or len(hist) == 0:
            entry.future.set_exception(ValueError(
                f"request for series {r.series_id} has no history: pass y "
                f"explicitly or observe() the series first"))
            return None
        return hist

    def step(self, force: bool = False) -> Tuple[int, Optional[float]]:
        """One scheduler pass: absorb writes, dispatch due bucket groups.

        Returns ``(completed, next_deadline)`` -- the number of requests
        answered and the ``perf_counter`` time at which the oldest remaining
        request hits its ``max_wait_ms`` deadline (None when the queue is
        empty). ``force`` dispatches everything regardless of fill/deadline
        (the drain / synchronous path).
        """
        self._absorb_writes()

        with self._cond:
            pending, self._pending = self._pending, []
        if not pending:
            self._maybe_finetune()
            return 0, None

        # group by length bucket, resolving online histories after the write
        # absorption above (read-your-writes ordering)
        groups: Dict[int, List[Tuple[_Pending, np.ndarray]]] = {}
        for entry in pending:
            hist = self._resolve_history(entry)
            if hist is None:
                continue
            b = self.dispatcher.pick_length_bucket(len(hist))
            groups.setdefault(b, []).append((entry, hist))

        now = time.perf_counter()
        max_wait_s = self.server_config.max_wait_ms / 1e3
        max_batch = self.dispatcher.max_batch
        completed = 0
        leftover: List[_Pending] = []
        for bucket in sorted(groups):
            entries = groups[bucket]
            due = (force or len(entries) >= max_batch
                   or now - min(e.arrival for e, _ in entries) >= max_wait_s)
            if not due:
                leftover.extend(e for e, _ in entries)
                continue
            t0 = time.perf_counter()
            for lo in range(0, len(entries), max_batch):
                chunk = entries[lo:lo + max_batch]
                reqs = [dataclasses.replace(e.request, y=h)
                        for e, h in chunk]
                try:
                    fc = self.dispatcher.run_bucket(reqs, bucket)
                except Exception as err:     # the batch fails, not the server
                    for e, _ in chunk:
                        e.future.set_exception(err)
                    continue
                done_t = time.perf_counter()
                for j, (e, _) in enumerate(chunk):
                    e.future.set_result(fc[j])
                    self.stats.record_latency(done_t - e.arrival)
                completed += len(chunk)
            self.stats.total_s += time.perf_counter() - t0
        self.stats.requests += completed
        if completed:
            self._active_since_tune = True

        with self._cond:
            # leftover groups go back in arrival order, ahead of anything
            # submitted during the dispatch
            leftover.sort(key=lambda e: e.arrival)
            self._pending = leftover + self._pending
            self.stats.note_queue_depth(len(self._pending))
            if completed:
                self._cond.notify_all()   # wake blocked submitters
            next_deadline = (min(e.arrival for e in self._pending)
                             + max_wait_s if self._pending else None)
            empty = not self._pending and not self._writes
        if empty:
            self._maybe_finetune()
        return completed, next_deadline

    def drain(self) -> int:
        """Force-dispatch until the queue and write backlog are empty."""
        total = 0
        while True:
            with self._cond:
                if not self._pending and not self._writes:
                    return total
            done, _ = self.step(force=True)
            total += done

    def _maybe_finetune(self) -> None:
        """Idle hook: one fine-tune burst per drained busy period."""
        if self.tuner is None or not self._active_since_tune:
            return
        self._active_since_tune = False
        params, rows = self.tuner.run(
            self.store, self.dispatcher.params, self.dispatcher.n_known)
        if rows:
            self.dispatcher.set_params(params)
            self.store.refresh(rows)
            self.stats.finetunes += 1

    # -- background thread ---------------------------------------------------

    def start(self) -> "ForecastServer":
        """Run the scheduler on a background thread (idempotent)."""
        if self._thread is not None:
            return self
        self._stop = False
        self._thread = threading.Thread(
            target=self._serve_loop, name="forecast-server", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread, optionally force-draining first."""
        thread = self._thread
        if thread is None:
            return
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        thread.join()
        self._thread = None
        if drain:
            self.drain()

    def __enter__(self) -> "ForecastServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                if self._stop:
                    return
                if not self._pending and not self._writes:
                    self._cond.wait(timeout=0.05)
                    if self._stop:
                        return
            _, next_deadline = self.step()
            if next_deadline is not None:
                # queue holds requests not yet due: sleep to the deadline
                # unless new arrivals top a batch up first
                delay = next_deadline - time.perf_counter()
                if delay > 0:
                    with self._cond:
                        if not self._stop:
                            self._cond.wait(timeout=delay)
