"""Batched forecast serving: pad-to-bucket request batching + jit-cache reuse.

Mirrors the prefill/decode structure of ``repro.launch.serve``, adapted to
forecasting: the "prefill" is the HW-smooth + dilated-LSTM pass over the
request's history, the "decode" is the seasonal de-normalization of the H
output steps. Requests arrive with ragged history lengths and ragged batch
sizes; XLA recompiles per shape, so a naive server would compile once per
distinct (batch, length) -- fatal under heavy traffic. Instead:

* **length buckets**: each request's history is snapped to the smallest
  bucket >= its length (left-padded with its first value, exactly the
  section-8.1 variable-length convention of ``data.pipeline``); longer
  histories keep their most recent ``max(bucket)`` observations,
* **batch buckets**: each group is padded up to the smallest batch bucket by
  repeating the last row (extra rows dropped on return),

so the jit cache holds at most ``len(length_buckets) * len(batch_buckets)``
entries and every subsequent request is a cache hit. ``ServeStats`` reports
the hit/compile split to prove the reuse.

Per-series HW parameters are looked up by ``series_id`` for series seen at
fit time; unknown series fall back to a primer row (alpha = gamma = 0.5,
flat seasonality -- the paper's section-3.3 initialization), which is the
cold-start behaviour of a real forecast service.

Sharding interaction: the fitted table may arrive sharded across a series
mesh (a ``data_parallel`` fit). Request rows are arbitrary (any mix of
known ids and cold-start primers), so resolving them directly against the
*device* table would gather the whole sharded table through the mesh on
every request. Instead the server snapshots the extended table (fitted rows
+ primer row) to **host memory once** at construction; per-request
resolution is then a numpy row gather, and only the gathered ``(B, ...)``
rows ever move to devices -- row-sharded over the serving ``mesh`` when one
is passed, which runs the forecast itself under ``shard_map``
(``esrnn_forecast_dp``) with the batch padded to the device multiple.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esrnn import ESRNNConfig, esrnn_forecast, esrnn_init


@dataclasses.dataclass
class ForecastRequest:
    """One series to forecast: raw history + category + optional identity."""

    y: np.ndarray                    # (T,) strictly positive history
    category: int = 0
    series_id: Optional[int] = None  # row in the fitted per-series table


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    compiles: int = 0
    cache_hits: int = 0
    padded_series: int = 0           # batch-padding rows added (wasted lanes)
    total_s: float = 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.total_s if self.total_s else 0.0


def _pick_bucket(value: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if value <= b:
            return b
    return buckets[-1]


class BatchedForecastServer:
    """Serve h-step forecasts for ragged request streams on a fixed jit cache."""

    def __init__(
        self,
        config: ESRNNConfig,
        params,
        *,
        length_buckets: Tuple[int, ...] = (32, 64, 128, 256),
        batch_buckets: Tuple[int, ...] = (1, 4, 16, 64),
        max_batch: Optional[int] = None,
        mesh=None,
    ):
        self.config = config
        self.params = params
        self.mesh = mesh if mesh is not None and mesh.devices.size > 1 else None
        min_len = config.input_size + max(config.seasonality, 1)
        self.length_buckets = tuple(sorted(max(b, min_len) for b in length_buckets))
        if self.mesh is not None:
            # sharded serving: snap the buckets up to the device multiple at
            # construction so every padded chunk still lands ON a bucket --
            # max_batch and the jit-cache bound keep their documented
            # meaning (a post-hoc pad in the hot path would exceed both)
            d = self.mesh.devices.size
            batch_buckets = {b + (-b) % d for b in batch_buckets}
        self.batch_buckets = tuple(sorted(batch_buckets))
        # a chunk must always fit the largest batch bucket
        self.max_batch = min(max_batch or self.batch_buckets[-1],
                             self.batch_buckets[-1])
        self.n_known = params["hw"].alpha_logit.shape[0]
        # per-series table extended by one primer row for cold-start series
        # (section 3.3 initialization); row n_known == "unknown series".
        # Snapshotted to HOST numpy once: the fitted table may be sharded
        # across a series mesh, and per-request row resolution (arbitrary
        # known/primer mixes) against the device table would re-gather the
        # whole sharded table per request. The numpy gather keeps the hot
        # path device-free; only the gathered (B, ...) rows go to devices.
        primer = esrnn_init(jax.random.PRNGKey(0), config, 1)
        self._hw_table = jax.tree_util.tree_map(
            lambda a, b: np.concatenate(
                [np.asarray(a), np.asarray(b)], axis=0),
            params["hw"], primer["hw"])
        self.stats = ServeStats()
        self._seen_shapes = set()
        if self.mesh is None:
            # esrnn_forecast is already jitted (cfg static); XLA caches per
            # (B, L) shape -- the bucket discipline keeps that cache small.
            self._forecast = partial(esrnn_forecast, self.config)
        else:
            from repro.sharding.series import esrnn_forecast_dp

            # sharded serving: per-series rows device-local under shard_map
            # (jit of the shard_map caches per shape exactly the same way)
            self._forecast = jax.jit(partial(
                esrnn_forecast_dp, self.config, mesh=self.mesh))

    # -- shaping -------------------------------------------------------------

    def _shape_history(self, y: np.ndarray, bucket: int) -> np.ndarray:
        y = np.asarray(y, np.float32)
        if len(y) >= bucket:
            return y[-bucket:]
        pad = np.full(bucket - len(y), y[0], np.float32)
        return np.concatenate([pad, y])

    def _hw_rows(self, requests: Sequence[ForecastRequest]):
        """Per-request HW rows: fitted rows for known ids, primer otherwise.

        One vectorized gather from the extended table (fitted rows + primer
        row) -- no per-request device ops on the serving hot path.
        """
        idx = np.asarray([
            r.series_id
            if r.series_id is not None and 0 <= r.series_id < self.n_known
            else self.n_known
            for r in requests])
        # numpy gather from the host snapshot: no device op, and in
        # particular no cross-device gather of a mesh-sharded fitted table
        return jax.tree_util.tree_map(lambda a: a[idx], self._hw_table)

    # -- serving -------------------------------------------------------------

    def _run_bucket(self, requests: List[ForecastRequest], bucket: int):
        """Forecast one length-bucket group, padded to a batch bucket."""
        n = len(requests)
        # with a mesh, the buckets were snapped to the device multiple at
        # construction, so bb always divides the mesh evenly
        bb = _pick_bucket(n, self.batch_buckets)
        padded = requests + [requests[-1]] * (bb - n)
        self.stats.padded_series += bb - n

        y = np.stack([self._shape_history(r.y, bucket) for r in padded])
        cats = np.zeros((bb, self.config.n_categories), np.float32)
        for row, r in enumerate(padded):
            # out-of-range category -> all-zero one-hot (cold start, like an
            # unknown series_id); never let one bad request fail the batch
            if 0 <= r.category < self.config.n_categories:
                cats[row, r.category] = 1.0

        hw = self._hw_rows(padded)
        params = dict(self.params, hw=hw)

        shape = (bb, bucket)
        if shape in self._seen_shapes:
            self.stats.cache_hits += 1
        else:
            self._seen_shapes.add(shape)
            self.stats.compiles += 1
        fc = self._forecast(params, jnp.asarray(y), jnp.asarray(cats))
        self.stats.batches += 1
        return np.asarray(fc[:n])

    def forecast_batch(
        self, requests: Sequence[ForecastRequest]
    ) -> List[np.ndarray]:
        """Serve a batch of ragged requests; returns (H,) per request, in order."""
        t0 = time.perf_counter()
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(
                _pick_bucket(len(r.y), self.length_buckets), []).append(i)

        out: List[Optional[np.ndarray]] = [None] * len(requests)
        for bucket, idxs in sorted(groups.items()):
            for lo in range(0, len(idxs), self.max_batch):
                chunk = idxs[lo:lo + self.max_batch]
                fc = self._run_bucket([requests[i] for i in chunk], bucket)
                for j, i in enumerate(chunk):
                    out[i] = fc[j]
        self.stats.requests += len(requests)
        self.stats.total_s += time.perf_counter() - t0
        return out  # type: ignore[return-value]


def synthetic_request_stream(
    config: ESRNNConfig, n_requests: int, *, n_known: int = 0, seed: int = 0,
    len_range: Tuple[int, int] = (20, 200),
) -> List[ForecastRequest]:
    """Ragged request stream for smoke/benchmark runs (lognormal level walks)."""
    rng = np.random.default_rng(seed)
    m = max(config.seasonality, 1)
    reqs = []
    for i in range(n_requests):
        t = int(rng.integers(*len_range))
        drift = rng.normal(0, 0.002, t).cumsum()
        seas = np.tile(np.exp(rng.normal(0, 0.08, m)), t // m + 1)[:t]
        y = np.exp(np.log(rng.uniform(50, 500)) + drift) * seas
        y = np.maximum(y * np.exp(rng.normal(0, 0.03, t)), 1e-3)
        sid = int(rng.integers(0, n_known)) if n_known and rng.random() < 0.5 else None
        reqs.append(ForecastRequest(
            y=y.astype(np.float32),
            category=int(rng.integers(0, config.n_categories)),
            series_id=sid,
        ))
    return reqs
