"""Bucketed forecast dispatch: pad-to-bucket batching + jit-cache reuse.

Mirrors the prefill/decode structure of ``repro.launch.serve``, adapted to
forecasting: the "prefill" is the HW-smooth + dilated-LSTM pass over the
request's history, the "decode" is the seasonal de-normalization of the H
output steps. Requests arrive with ragged history lengths and ragged batch
sizes; XLA recompiles per shape, so a naive server would compile once per
distinct (batch, length) -- fatal under heavy traffic. Instead:

* **length buckets**: each request's history is snapped to the smallest
  bucket >= its length (left-padded with its first value, exactly the
  section-8.1 variable-length convention of ``data.pipeline``); longer
  histories keep their most recent ``max(bucket)`` observations, counted
  in ``ServeStats.truncated_series`` (the forecast then conditions on the
  truncated tail -- a real, visible serving decision, not a silent clamp),
* **batch buckets**: each group is padded up to the smallest batch bucket by
  repeating the last row (extra rows dropped on return),

so the jit cache holds at most ``len(length_buckets) * len(batch_buckets)``
entries and every subsequent request is a cache hit. ``ServeStats`` reports
the hit/compile split to prove the reuse, plus per-request latency
percentiles and queue gauges for the continuous-batching front end.

The module splits serving into two layers:

* :class:`BucketDispatcher` -- the shared kernel-dispatch core: history
  shaping, per-request HW-row resolution against a host-side table
  snapshot, bucket-padded batched dispatch through
  ``esrnn_forecast``/``esrnn_forecast_dp``. Both servers drive it.
* :class:`BatchedForecastServer` -- **deprecated** thin wrapper over the
  dispatcher's synchronous batch surface. The production front end is
  :class:`repro.forecast.server.ForecastServer`, the continuous-batching
  request loop with online ``observe`` state ingestion; scripted/batch
  workloads call :meth:`BucketDispatcher.forecast_batch` directly.

Per-series HW parameters are looked up by ``series_id`` for series seen at
fit time; unknown series fall back to a primer row (alpha = gamma = 0.5,
flat seasonality -- the paper's section-3.3 initialization), which is the
cold-start behaviour of a real forecast service.

Sharding interaction: the fitted table may arrive sharded across a series
mesh (a ``data_parallel`` fit). Request rows are arbitrary (any mix of
known ids and cold-start primers), so resolving them directly against the
*device* table would gather the whole sharded table through the mesh on
every request. Instead the dispatcher snapshots the extended table (fitted
rows + primer row) to **host memory once** at construction; per-request
resolution is then a numpy row gather, and only the gathered ``(B, ...)``
rows ever move to devices -- row-sharded over the serving ``mesh`` when one
is passed, which runs the forecast itself under ``shard_map``
(``esrnn_forecast_dp``) with the batch padded to the device multiple.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import time
import warnings
from functools import partial
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.recompile import CompileCounter
from repro.core.esrnn import ESRNNConfig, esrnn_forecast, esrnn_init

log = logging.getLogger("repro.forecast.serving")

# latency samples kept for the percentile estimate (FIFO window; sustained
# runs see the *recent* distribution, not a forever-average)
_LATENCY_WINDOW = 65536


@dataclasses.dataclass
class ForecastRequest:
    """One series to forecast: raw history + category + optional identity.

    ``y=None`` is allowed when ``series_id`` is set and the serving layer
    tracks that series' history online (the continuous server's ``observe``
    verb); the dispatcher itself requires a resolved history.
    """

    y: Optional[np.ndarray] = None   # (T,) strictly positive history
    category: int = 0
    series_id: Optional[int] = None  # row in the fitted per-series table


@dataclasses.dataclass
class ServeStats:
    """Serving counters + latency/queue telemetry.

    Counter fields are plain ints (single-writer: the dispatching thread);
    ``latencies_s`` is a bounded FIFO window over per-request latencies
    (submit -> result for the continuous server, batch wall-time per
    request for the synchronous wrapper).
    """

    requests: int = 0
    batches: int = 0
    compiles: int = 0                # bucket-grid shapes the dispatcher
                                     # intended to compile
    xla_compiles: int = 0            # backend compiles XLA actually ran
                                     # while a dispatch was armed (ground
                                     # truth; catches compiles the bucket
                                     # accounting cannot see)
    compile_budget: Optional[int] = None  # declared bound: len(length
                                     # buckets) x len(batch buckets)
    cache_hits: int = 0
    padded_series: int = 0           # batch-padding rows added (wasted lanes)
    truncated_series: int = 0        # histories longer than the largest
                                     # length bucket (served on the tail)
    observes: int = 0                # online observations absorbed
    write_batches: int = 0           # batched write-absorption passes
    finetunes: int = 0               # idle incremental fine-tune runs
    queue_depth: int = 0             # gauge: pending requests at last pass
    queue_peak: int = 0              # high-water mark of the request queue
    total_s: float = 0.0
    latencies_s: Deque[float] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=_LATENCY_WINDOW),
        repr=False)

    @property
    def requests_per_s(self) -> float:
        # guard: a zero-elapsed window (no timed work yet, or a clock with
        # coarse resolution on a trivial batch) reports 0, not a ZeroDivision
        return self.requests / self.total_s if self.total_s > 0 else 0.0

    def record_latency(self, seconds: float) -> None:
        self.latencies_s.append(seconds)

    def note_queue_depth(self, depth: int) -> None:
        self.queue_depth = depth
        self.queue_peak = max(self.queue_peak, depth)

    def reset(self) -> None:
        """Zero every counter and drop the latency window.

        Benchmarks call this after the jit-cache warm-up pass so that
        compile-time latencies never pollute the measured distribution (the
        jit cache itself survives -- only the telemetry resets).
        """
        self.requests = self.batches = self.compiles = self.cache_hits = 0
        self.xla_compiles = 0        # compile_budget survives: it is a
                                     # declaration, not a counter
        self.padded_series = self.truncated_series = 0
        self.observes = self.write_batches = self.finetunes = 0
        self.queue_depth = self.queue_peak = 0
        self.total_s = 0.0
        self.latencies_s.clear()

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p95/p99 of the recorded request latencies, in milliseconds.

        NaN (not 0.0) when nothing has been recorded -- an empty window must
        not read as a perfect latency.
        """
        if not self.latencies_s:
            nan = float("nan")
            return {"p50_ms": nan, "p95_ms": nan, "p99_ms": nan}
        lat_ms = np.asarray(self.latencies_s, np.float64) * 1e3
        p50, p95, p99 = np.percentile(lat_ms, [50.0, 95.0, 99.0])
        return {"p50_ms": float(p50), "p95_ms": float(p95),
                "p99_ms": float(p99)}


def _pick_bucket(value: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= value; the largest bucket when value exceeds all.

    The overflow case means *truncation* for length bucketing (only the most
    recent ``buckets[-1]`` observations are served) -- callers that route
    histories through this must count it (``ServeStats.truncated_series``)
    so the clamp is visible in telemetry rather than silent.
    """
    for b in buckets:
        if value <= b:
            return b
    return buckets[-1]


class BucketDispatcher:
    """The shared serving core: shape, resolve, and dispatch one bucket.

    Owns the jit-cache discipline (length x batch bucket grid), the
    host-side HW-table snapshot, and the sharded/single-device forecast
    callable. Both the synchronous :class:`BatchedForecastServer` and the
    continuous-batching ``repro.forecast.server.ForecastServer`` drive it;
    neither re-implements any batching math.
    """

    def __init__(
        self,
        config: ESRNNConfig,
        params,
        *,
        length_buckets: Tuple[int, ...] = (32, 64, 128, 256),
        batch_buckets: Tuple[int, ...] = (1, 4, 16, 64),
        max_batch: Optional[int] = None,
        mesh=None,
        stats: Optional[ServeStats] = None,
        compile_budget: Optional[int] = None,
    ):
        self.config = config
        self.mesh = mesh if mesh is not None and mesh.devices.size > 1 else None
        min_len = config.input_size + max(config.seasonality, 1)
        self.length_buckets = tuple(sorted(max(b, min_len) for b in length_buckets))
        if self.mesh is not None:
            # sharded serving: snap the buckets up to the device multiple at
            # construction so every padded chunk still lands ON a bucket --
            # max_batch and the jit-cache bound keep their documented
            # meaning (a post-hoc pad in the hot path would exceed both)
            d = self.mesh.devices.size
            batch_buckets = {b + (-b) % d for b in batch_buckets}
        self.batch_buckets = tuple(sorted(batch_buckets))
        # a chunk must always fit the largest batch bucket
        self.max_batch = min(max_batch or self.batch_buckets[-1],
                             self.batch_buckets[-1])
        self.stats = stats if stats is not None else ServeStats()
        # the declared jit-cache bound the recompile sentinel audits against;
        # ServeStats.xla_compiles counts what XLA actually did while armed
        self.compile_budget = (
            compile_budget if compile_budget is not None
            else len(self.length_buckets) * len(self.batch_buckets))
        self.stats.compile_budget = self.compile_budget
        self._xla_counter = CompileCounter(stats=self.stats)
        self._seen_shapes = set()
        self._warned_truncation = False
        self.set_params(params)
        if self.mesh is None:
            # esrnn_forecast is already jitted (cfg static); XLA caches per
            # (B, L) shape -- the bucket discipline keeps that cache small.
            self._forecast = partial(esrnn_forecast, self.config)
        else:
            from repro.sharding.series import esrnn_forecast_dp

            # sharded serving: per-series rows device-local under shard_map
            # (jit of the shard_map caches per shape exactly the same way)
            self._forecast = jax.jit(partial(
                esrnn_forecast_dp, self.config, mesh=self.mesh))

    # -- params / host table -------------------------------------------------

    def set_params(self, params) -> None:
        """(Re)install params and rebuild the host-side HW-table snapshot.

        Called at construction and again whenever the serving params change
        in place (the idle fine-tune hook) -- the snapshot must never go
        stale relative to the table the batched forecast closes over.
        """
        from repro.train.host_table import HostStateTable

        self.params = params
        self.n_known = params["hw"].alpha_logit.shape[0]
        # per-series table extended by one primer row for cold-start series
        # (section 3.3 initialization); row n_known == "unknown series".
        # Host-side by construction: the fitted table may be sharded across
        # a series mesh, and per-request row resolution (arbitrary
        # known/primer mixes) against the device table would re-gather the
        # whole sharded table per request. The snapshot is a HostStateTable
        # + primer *view* (``ExtendedHWView``) rather than a concatenated
        # second copy -- zero-copy when the fitted leaves are already host
        # numpy (a chunked fit / chunked checkpoint), one D2H otherwise;
        # only the gathered (B, ...) rows ever go to devices.
        primer = esrnn_init(jax.random.PRNGKey(0), self.config, 1)
        self._host_table = HostStateTable.from_hw(params["hw"])
        self._hw_table = self._host_table.extended(primer["hw"])

    # -- shaping -------------------------------------------------------------

    def pick_length_bucket(self, n_obs: int) -> int:
        """Length bucket for a history of ``n_obs``, counting truncation."""
        b = _pick_bucket(n_obs, self.length_buckets)
        if n_obs > self.length_buckets[-1]:
            self.stats.truncated_series += 1
            if not self._warned_truncation:
                self._warned_truncation = True
                log.warning(
                    "history of %d observations exceeds the largest length "
                    "bucket (%d); serving on the most recent %d (counted in "
                    "ServeStats.truncated_series; further truncations are "
                    "counted silently)", n_obs, b, b)
        return b

    def shape_history(self, y: np.ndarray, bucket: int) -> np.ndarray:
        y = np.asarray(y, np.float32)
        if len(y) >= bucket:
            return y[-bucket:]
        pad = np.full(bucket - len(y), y[0], np.float32)
        return np.concatenate([pad, y])

    def resolve_row(self, series_id: Optional[int]) -> int:
        """Extended-table row for a request: fitted row or the primer row."""
        if series_id is not None and 0 <= series_id < self.n_known:
            return int(series_id)
        return self.n_known

    def hw_rows(self, requests: Sequence[ForecastRequest]):
        """Per-request HW rows: fitted rows for known ids, primer otherwise.

        One vectorized gather from the extended table (fitted rows + primer
        row) -- no per-request device ops on the serving hot path.
        """
        idx = np.asarray([self.resolve_row(r.series_id) for r in requests])
        # numpy gather through the host view: no device op, and in
        # particular no cross-device gather of a mesh-sharded fitted table
        return self._hw_table.rows(idx)

    # -- dispatch ------------------------------------------------------------

    def run_bucket(self, requests: List[ForecastRequest], bucket: int):
        """Forecast one length-bucket group, padded to a batch bucket.

        Every request must carry a resolved history (``y`` not None) -- the
        online-store resolution happens upstream in the continuous server.
        """
        n = len(requests)
        # with a mesh, the buckets were snapped to the device multiple at
        # construction, so bb always divides the mesh evenly
        bb = _pick_bucket(n, self.batch_buckets)
        padded = requests + [requests[-1]] * (bb - n)
        self.stats.padded_series += bb - n

        y = np.stack([self.shape_history(r.y, bucket) for r in padded])
        cats = np.zeros((bb, self.config.n_categories), np.float32)
        for row, r in enumerate(padded):
            # out-of-range category -> all-zero one-hot (cold start, like an
            # unknown series_id); never let one bad request fail the batch
            if 0 <= r.category < self.config.n_categories:
                cats[row, r.category] = 1.0

        hw = self.hw_rows(padded)
        params = dict(self.params, hw=hw)

        shape = (bb, bucket)
        if shape in self._seen_shapes:
            self.stats.cache_hits += 1
        else:
            self._seen_shapes.add(shape)
            self.stats.compiles += 1
        # armed sentinel: every backend compile XLA runs inside this block
        # lands in ServeStats.xla_compiles, including ones the bucket
        # accounting above cannot see (the fc[:n] slice family was exactly
        # such an invisible compile per distinct partial fill)
        with self._xla_counter:
            fc = self._forecast(params, jnp.asarray(y), jnp.asarray(cats))
            self.stats.batches += 1
            # strip the batch padding on the HOST copy: fc[:n] on the device
            # array is a jitted slice op that XLA compiles once per distinct
            # partial fill n -- an unbounded compile family (~tens of ms
            # each) on the latency path. Transferring padded rows is cheap.
            out = np.asarray(fc)[:n]
        return out

    def forecast_batch(
        self, requests: Sequence[ForecastRequest]
    ) -> List[np.ndarray]:
        """Serve a batch of ragged requests synchronously, in order.

        The scripted/batch entry point: group by length bucket, chunk by
        ``max_batch``, dispatch each chunk through :meth:`run_bucket`,
        return one (H,) forecast per request. Blocks until the whole batch
        is back; per-request latency is the batch wall-time amortized over
        the batch (the continuous server records real arrival times).
        """
        t0 = time.perf_counter()
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            if r.y is None:
                raise ValueError(
                    "ForecastRequest.y is required for batch serving; "
                    "history-less series_id requests need the online "
                    "ForecastServer (repro.forecast.server)")
            groups.setdefault(
                self.pick_length_bucket(len(r.y)), []).append(i)

        out: List[Optional[np.ndarray]] = [None] * len(requests)
        for bucket, idxs in sorted(groups.items()):
            for lo in range(0, len(idxs), self.max_batch):
                chunk = idxs[lo:lo + self.max_batch]
                fc = self.run_bucket([requests[i] for i in chunk], bucket)
                for j, i in enumerate(chunk):
                    out[i] = fc[j]
        dt = time.perf_counter() - t0
        self.stats.requests += len(requests)
        self.stats.total_s += dt
        if requests:
            # batch wall-time attributed to each request: this surface has no
            # per-request arrival times (the continuous server does)
            per_req = dt / len(requests)
            for _ in requests:
                self.stats.record_latency(per_req)
        return out  # type: ignore[return-value]


class BatchedForecastServer:
    """Deprecated synchronous wrapper -- use the dispatcher or ForecastServer.

    Kept one release for callers of the historical surface: constructing one
    emits a :class:`DeprecationWarning` and every call delegates to a
    :class:`BucketDispatcher` (batch workloads call its
    :meth:`~BucketDispatcher.forecast_batch` directly; request loops want
    :class:`repro.forecast.server.ForecastServer`).
    """

    def __init__(
        self,
        config: ESRNNConfig,
        params,
        *,
        length_buckets: Tuple[int, ...] = (32, 64, 128, 256),
        batch_buckets: Tuple[int, ...] = (1, 4, 16, 64),
        max_batch: Optional[int] = None,
        mesh=None,
    ):
        warnings.warn(
            "BatchedForecastServer is deprecated: use "
            "repro.forecast.server.ForecastServer for request serving, or "
            "BucketDispatcher.forecast_batch for synchronous batch "
            "workloads", DeprecationWarning, stacklevel=2)
        self._dispatch = BucketDispatcher(
            config, params, length_buckets=length_buckets,
            batch_buckets=batch_buckets, max_batch=max_batch, mesh=mesh)

    # the dispatcher owns the state; expose the historical surface
    @property
    def config(self):
        return self._dispatch.config

    @property
    def params(self):
        return self._dispatch.params

    @property
    def mesh(self):
        return self._dispatch.mesh

    @property
    def stats(self) -> ServeStats:
        return self._dispatch.stats

    @property
    def length_buckets(self):
        return self._dispatch.length_buckets

    @property
    def batch_buckets(self):
        return self._dispatch.batch_buckets

    @property
    def max_batch(self):
        return self._dispatch.max_batch

    @property
    def compile_budget(self):
        return self._dispatch.compile_budget

    @property
    def n_known(self):
        return self._dispatch.n_known

    @property
    def _hw_table(self):
        return self._dispatch._hw_table

    def _hw_rows(self, requests):
        return self._dispatch.hw_rows(requests)

    def _shape_history(self, y, bucket):
        return self._dispatch.shape_history(y, bucket)

    def forecast_batch(
        self, requests: Sequence[ForecastRequest]
    ) -> List[np.ndarray]:
        return self._dispatch.forecast_batch(requests)


def synthetic_request_stream(
    config: ESRNNConfig, n_requests: int, *, n_known: int = 0, seed: int = 0,
    len_range: Tuple[int, int] = (20, 200),
) -> List[ForecastRequest]:
    """Ragged request stream for smoke/benchmark runs (lognormal level walks).

    Deterministic in ``seed``: the same (config, n_requests, n_known, seed,
    len_range) produces bit-identical histories, categories and series-id
    assignments -- benchmark baselines and continuous-batching runs replay
    the exact same offered load.
    """
    rng = np.random.default_rng(seed)
    m = max(config.seasonality, 1)
    reqs = []
    for i in range(n_requests):
        t = int(rng.integers(*len_range))
        drift = rng.normal(0, 0.002, t).cumsum()
        seas = np.tile(np.exp(rng.normal(0, 0.08, m)), t // m + 1)[:t]
        y = np.exp(np.log(rng.uniform(50, 500)) + drift) * seas
        y = np.maximum(y * np.exp(rng.normal(0, 0.03, t)), 1e-3)
        sid = int(rng.integers(0, n_known)) if n_known and rng.random() < 0.5 else None
        reqs.append(ForecastRequest(
            y=y.astype(np.float32),
            category=int(rng.integers(0, config.n_categories)),
            series_id=sid,
        ))
    return reqs
