"""ForecastSpec: the single registry behind the unified forecasting API.

Mirrors the arch-string pattern of ``repro.configs.get_config`` for the
paper's own model: one name resolves the full recipe -- model hyperparameters
(subsuming ``core.esrnn.PRESETS``), data preparation, and the two-group
training setup (per-series Holt-Winters vs shared-RNN learning rates are
first-class fields, Smyl's joint-training arrangement).

    spec = get_spec("esrnn-quarterly", n_steps=500, hidden_size=64)
    smoke = get_smoke_spec("esrnn-quarterly")

Override kwargs are routed by field name: ``ESRNNConfig`` fields go into the
nested model config, everything else into the spec itself.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.esrnn import ESRNNConfig, make_config
from repro.core.heads import available_heads, get_head

_MODEL_FIELDS = {f.name for f in dataclasses.fields(ESRNNConfig)} - {"name"}


@dataclasses.dataclass(frozen=True)
class ForecastSpec:
    """Everything needed to fit / predict / eval / serve one forecaster."""

    name: str                        # registry name, e.g. "esrnn-quarterly"
    model: ESRNNConfig

    # -- data preparation (paper section 5) --------------------------------
    data_scale: float = 0.01         # fraction of the Table-2 series counts
    data_seed: int = 0
    min_length: Optional[int] = None # None -> pipeline.MIN_LENGTH[frequency]
    variable_length: bool = False    # section 8.1 left-pad + mask path

    # -- joint two-group training (paper section 3.2) ----------------------
    batch_size: int = 256
    n_steps: int = 300
    rnn_lr: float = 1e-3             # shared RNN / head / attention weights
    hw_lr: float = 1e-2              # per-series Holt-Winters parameters
                                     # (Smyl: ~10x the shared-weight lr)
    clip_norm: Optional[float] = 20.0
    seed: int = 0
    eval_every: int = 50
    ckpt_every: int = 50
    keep: int = 3
    smoke: bool = False
    scan_steps: int = 1              # steps fused per donated lax.scan
                                     # superstep (1 = per-step dispatch);
                                     # eval/ckpt/hooks fire at superstep
                                     # boundaries, same absolute steps
    sparse_adam: bool = False        # segment per-series Adam: touch only
                                     # the batch's HW rows, closed-form
                                     # moment catch-up for skipped rows

    # -- multi-device scaling ----------------------------------------------
    data_parallel: int = 0           # devices to shard the series axis over
                                     # (0/1 = single device; must divide
                                     # batch_size; CPU needs XLA_FLAGS=
                                     # --xla_force_host_platform_device_count)
    series_chunk: int = 0            # > 0: out-of-core fit/predict -- the
                                     # per-series HW table + sparse-Adam
                                     # state live in host memory and stream
                                     # through the device series_chunk rows
                                     # at a time (implies sparse_adam; chunk
                                     # = outer loop, data_parallel mesh =
                                     # inner shard; 0 = fully resident)

    @property
    def frequency(self) -> str:
        return self.model.name

    @property
    def horizon(self) -> int:
        return self.model.output_size

    @property
    def use_pallas(self) -> bool:
        """Whether fit/predict route through the Pallas kernels.

        A model-config field surfaced on the spec: override it like any
        other (``get_spec("esrnn-quarterly", use_pallas=True)``, estimator
        kwargs, or ``forecast fit --set use_pallas=true``) and
        ``train_from_spec`` trains through the kernels end-to-end -- the
        hw_scan/lstm_cell custom_vjp backward kernels make the path
        differentiable, and it composes with ``data_parallel``.
        """
        return self.model.use_pallas

    def replace(self, **overrides) -> "ForecastSpec":
        """Override by field name; model-config fields route into ``model``.

        Unknown names raise (naming every valid spec and model field) rather
        than being silently dropped -- a typo like ``hiden_size=64`` must
        fail loudly, not train a default-width model.
        """
        model_kw = {k: v for k, v in overrides.items() if k in _MODEL_FIELDS}
        spec_kw = {k: v for k, v in overrides.items() if k not in _MODEL_FIELDS}
        spec_fields = {f.name for f in dataclasses.fields(ForecastSpec)}
        unknown = [k for k in spec_kw if k not in spec_fields]
        if unknown:
            raise TypeError(
                f"unknown ForecastSpec override(s): {sorted(unknown)}; "
                f"valid spec fields: {sorted(spec_fields - {'model'})}; "
                f"valid model fields: {sorted(_MODEL_FIELDS)}")
        if "head" in model_kw:
            get_head(model_kw["head"])  # unknown head names fail here, loudly
        spec = self
        if model_kw:
            if isinstance(model_kw.get("dilations"), list):
                model_kw["dilations"] = tuple(tuple(d) for d in model_kw["dilations"])
            spec = dataclasses.replace(
                spec, model=dataclasses.replace(spec.model, **model_kw))
        if spec_kw:
            spec = dataclasses.replace(spec, **spec_kw)
        return spec

    # -- serialization (estimator save/load) --------------------------------

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["model"]["dilations"] = [list(g) for g in self.model.dilations]
        return d

    @staticmethod
    def from_dict(d: Dict) -> "ForecastSpec":
        model_kw = dict(d["model"])
        model_kw["dilations"] = tuple(tuple(g) for g in model_kw["dilations"])
        spec_kw = {k: v for k, v in d.items() if k != "model"}
        return ForecastSpec(model=ESRNNConfig(**model_kw), **spec_kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# Frequency -> spec-level defaults beyond the shared dataclass defaults.
_FREQ_SPECS: Dict[str, Dict] = {
    "yearly": dict(),
    "quarterly": dict(),
    "monthly": dict(),
    "hourly": dict(batch_size=64, data_scale=0.05),
}

# Registry prefix -> head registry name. ``esrnn-`` (and the launcher-facing
# ``m4-`` alias, and a bare frequency) is the paper's lstm head; every other
# head in ``repro.core.heads`` gets its own ``<head>-<freq>`` family.
_PREFIX_HEADS: Dict[str, str] = {"esrnn": "lstm", "m4": "lstm"}

# Per-frequency smoke shrinkage: tiny model + tiny run, same code paths.
_SMOKE_OVERRIDES = dict(
    data_scale=0.002, batch_size=16, n_steps=20, eval_every=10,
    ckpt_every=10, hidden_size=8, smoke=True,
)


def _canonical_name(head: str, freq: str) -> str:
    return f"{'esrnn' if head == 'lstm' else head}-{freq}"


def list_specs() -> List[str]:
    """Every registry name: ``esrnn-<freq>`` plus ``<head>-<freq>`` per head."""
    names = [f"esrnn-{freq}" for freq in _FREQ_SPECS]
    for head in available_heads():
        if head == "lstm":
            continue
        names.extend(f"{head}-{freq}" for freq in _FREQ_SPECS)
    return names


def get_spec(name: str, **overrides) -> ForecastSpec:
    """Resolve a registry name (+ optional overrides) into a ForecastSpec.

    Accepts ``esrnn-<freq>`` / ``m4-<freq>`` / a bare frequency (the paper's
    lstm head), or ``<head>-<freq>`` for any other registered head
    (``esn-quarterly``, ``ssm-monthly``, ...). The head is also a model
    field, so ``get_spec("esrnn-quarterly", head="esn")`` and the CLI's
    ``--set head=esn`` resolve to the same spec as ``esn-quarterly``.
    """
    head = "lstm"
    freq = name
    prefix, dash, rest = name.partition("-")
    if dash and rest in _FREQ_SPECS:
        if prefix in _PREFIX_HEADS:
            head, freq = _PREFIX_HEADS[prefix], rest
        elif prefix in available_heads():
            head, freq = prefix, rest
    if freq not in _FREQ_SPECS:
        raise KeyError(
            f"unknown forecast spec {name!r}; available: {list_specs()}")
    if "head" in overrides:      # --set head=... canonicalizes the name too
        head = overrides["head"]
        get_head(head)
    spec = ForecastSpec(
        name=_canonical_name(head, freq),
        model=make_config(freq, head=head), **_FREQ_SPECS[freq])
    return spec.replace(**overrides) if overrides else spec


def get_smoke_spec(name: str, **overrides) -> ForecastSpec:
    """Smoke variant: same pipeline end-to-end, seconds on CPU."""
    return get_spec(name).replace(**{**_SMOKE_OVERRIDES, **overrides})
