"""Activation-sharding context: lets model code place logical constraints
("dp", "tp", None) without knowing the mesh, and no-op outside pjit.

The launcher installs a context mapping logical axes to mesh axes
(dp -> ("pod", "data") on the multi-pod mesh); smoke tests on one device run
with no context and every ``constrain`` is the identity.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

Dim = Union[None, str, Tuple[str, ...]]


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, dp, tp):
    """dp/tp: mesh axis name or tuple of names for the logical axes."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = {"mesh": mesh, "dp": dp, "tp": tp}
    try:
        yield
    finally:
        _state.ctx = prev


def current():
    return getattr(_state, "ctx", None)


def logical_to_spec(dims: Sequence[Dim]) -> Optional[P]:
    ctx = current()
    if ctx is None:
        return None
    out = []
    for d in dims:
        if d is None:
            out.append(None)
        elif isinstance(d, tuple):
            axes = []
            for name in d:
                ax = ctx.get(name, name)
                if ax is None:
                    continue
                axes.extend(ax if isinstance(ax, tuple) else (ax,))
            out.append(tuple(axes) if axes else None)
        else:
            ax = ctx.get(d, d)
            out.append(ax)
    return P(*out)


def constrain(x, *dims: Dim):
    """with_sharding_constraint with logical dims; identity w/o context."""
    ctx = current()
    if ctx is None:
        return x
    spec = logical_to_spec(dims)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], spec)
    )
