"""PartitionSpec rules: parameters, batches, optimizer state, caches.

Strategy (DESIGN.md section 6):
* TP on ``model``: attention q/o heads, FFN hidden, vocab, MoE experts (EP),
  MLA latent, zamba shared-block internals.
* FSDP on ``data`` (x ``pod``): the non-TP dim of every large matrix.
* DP: batch dims on ``data`` (x ``pod``).
* Sequence sharding: decode KV caches shard the sequence axis on ``model``
  (GQA kv-head counts {2,4,8} don't divide 16); MLA caches shard the latent
  dim; SSM state caches shard heads.
* ES-RNN per-series params: sharded on ``data`` -- gradients are device-local
  (the paper's technique as a distribution property).

Rules are name+context based over pytree paths; stacked layer dims (leading
L or (G, K)) get None prepended automatically.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axes_for(mesh: Mesh):
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return {"dp": dp if len(dp) > 1 else dp[0], "tp": "model"}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        k = getattr(e, "key", None)
        if k is None:
            k = getattr(e, "name", None)
        if k is None:
            idx = getattr(e, "idx", None)
            k = f"[{idx}]" if idx is not None else str(e)
        out.append(str(k))
    return tuple(out)


# weight-name classes (trailing-2D rules)
_OUT_TP = {"wq", "wk", "wv", "w_gate", "w_up", "w_in"}      # (d_in, out): out on tp
_IN_TP = {"wo", "w_down", "w_out"}                          # (in, d_out): in on tp
_EMBED = {"embed"}
_HEAD = {"lm_head"}


def param_spec(path, leaf, axes) -> P:
    names = _path_names(path)
    name = names[-1]
    ndim = len(leaf.shape)
    dp, tp = axes["dp"], axes["tp"]
    in_ssm = "ssm" in names or name in ("conv_w", "conv_b", "a_log", "dt_bias",
                                        "d_skip", "out_norm")
    # expert-stacked weights: trailing (E, a, b); shared experts are plain
    # dense mats. (Leading layer-stack dims get None prepended below.)
    in_moe = ("moe" in names and "shared" not in names
              and name in ("w_gate", "w_up", "w_down") and ndim >= 3)

    def base() -> Tuple:
        if name in _EMBED:
            return (tp, dp)
        if name in _HEAD:
            return (dp, tp)
        if in_moe:  # (E, a, b) expert-stacked
            if _PARAM_MODE == "decode":
                return (None, dp, tp) if name in ("w_gate", "w_up") else (None, tp, dp)
            return (tp, dp, None)
        if name == "router":
            return (dp, None)
        if in_ssm:
            if name == "w_in":
                return (dp, None)      # mixed z/x/B/C/dt out dim: keep whole
            if name == "w_out":
                return (None, dp)
            if name == "conv_w":
                return (None, None)
            return tuple([None] * ndim)
        if name == "w_dkv":             # MLA latent down-proj (small)
            return (dp, None)
        if name in ("w_uk", "w_uv"):    # MLA up-proj: heads on tp
            return (None, tp)
        if name == "w_concat":          # zamba concat proj
            return (dp, tp)
        if name in _OUT_TP:
            return (dp, tp)
        if name in _IN_TP:
            return (tp, dp)
        return tuple([None] * ndim)

    spec = base()
    # prepend None for stacked layer dims
    if len(spec) < ndim:
        spec = tuple([None] * (ndim - len(spec))) + spec
    elif len(spec) > ndim:
        spec = spec[-ndim:]
    # divisibility guard: drop axes that don't divide the dim
    mesh_sizes = _mesh_axis_sizes()
    fixed = []
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        size = int(np.prod([mesh_sizes.get(a, 1) for a in (ax if isinstance(ax, tuple) else (ax,))]))
        fixed.append(ax if size and dim % size == 0 else None)
    return P(*fixed)


_MESH: Optional[Mesh] = None
_PARAM_MODE = "train"


def set_mesh(mesh: Mesh):
    global _MESH
    _MESH = mesh


def set_param_mode(mode: str):
    """"train"/"prefill": experts sharded on model (EP -- best for large
    token counts). "decode": experts replicated, FFN hidden sharded (1-token
    steps would otherwise gather expert weights every layer)."""
    global _PARAM_MODE
    _PARAM_MODE = mode


def _mesh_axis_sizes():
    if _MESH is None:
        return {}
    return dict(zip(_MESH.axis_names, _MESH.devices.shape))


def param_shardings(mesh: Mesh, params_abs) -> Any:
    """Pytree of NamedSharding matching ``params_abs`` (abstract pytree)."""
    set_mesh(mesh)
    axes = axes_for(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, axes)),
        params_abs,
    )


# -- batches / caches --------------------------------------------------------


def dp_dim(mesh: Mesh, batch: int):
    """dp axis tuple if it divides the batch, else None (tiny-batch decode)."""
    axes = axes_for(mesh)
    dp = axes["dp"]
    names = dp if isinstance(dp, tuple) else (dp,)
    size = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[n] for n in names]))
    return dp if batch % size == 0 else None


def batch_spec(mesh: Mesh, leaf_ndim: int, batch: int) -> P:
    dims = [dp_dim(mesh, batch)] + [None] * (leaf_ndim - 1)
    return P(*dims)


def cache_spec(mesh: Mesh, path, leaf, batch: int) -> P:
    """Cache sharding by leaf shape heuristics (see module docstring)."""
    names = _path_names(path)
    name = names[-1] if names else ""
    dpd = dp_dim(mesh, batch)
    tp = "model"
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get("model", 1)
    shape = leaf.shape
    nd = len(shape)

    if nd == 0:  # length scalars
        return P()
    if nd == 1:  # stacked length (L,)
        return P(None)

    # identify batch dim: first dim equal to batch after any leading stack dims
    spec = [None] * nd
    b_idx = None
    for i, d in enumerate(shape):
        if d == batch:
            b_idx = i
            break
    if b_idx is not None and dpd is not None:
        spec[b_idx] = dpd

    if name in ("k", "v") and nd >= 4:            # (..., B, S, Hkv, hd)
        s_idx, h_idx = nd - 3, nd - 2
        if shape[h_idx] % tp_size == 0:
            spec[h_idx] = tp
        elif shape[s_idx] % tp_size == 0:
            spec[s_idx] = tp
    elif name in ("c_kv", "k_rope") and nd >= 3:  # (..., B, S, r)
        # shard the *sequence*: absorbed-MLA decode then only all-reduces
        # per-step softmax stats + the tiny (B,1,H,r) context partial sums
        # (hillclimb: latent-dim sharding all-reduced full (B,H,S) logits)
        if shape[-2] % tp_size == 0:
            spec[-2] = tp
        elif shape[-1] % tp_size == 0:
            spec[-1] = tp
    elif name == "state" and nd >= 4:             # (..., B, H, P, N)
        h_idx = nd - 3
        if shape[h_idx] % tp_size == 0:
            spec[h_idx] = tp
    elif name == "conv" and nd >= 3:              # (..., B, K-1, conv_dim)
        if shape[-1] % tp_size == 0:
            spec[-1] = tp
    elif name == "memory" and nd == 3:            # (B, M, d) encoder states
        pass
    return P(*spec)


def cache_shardings(mesh: Mesh, caches_abs, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(mesh, path, leaf, batch)),
        caches_abs,
    )


def batch_shardings(mesh: Mesh, batch_abs, batch: int):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, len(leaf.shape), batch)),
        batch_abs,
    )
