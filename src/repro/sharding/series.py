"""Series-axis data parallelism for the ES-RNN (Mesh/NamedSharding/shard_map).

The paper's contribution is vectorizing the per-series Holt-Winters
parameters so one device trains all series at once; the next scaling axis is
sharding that series dimension across devices. The per-series HW parameter
table ``params["hw"]`` (all leaves ``(N, ...)``) shards trivially along a
1-D ``series`` mesh axis -- each device owns its rows and their gradients
stay device-local -- while the shared RNN/head/attention weights are
replicated and their gradients all-reduced (the transpose of replication
under ``shard_map`` autodiff is exactly the psum the data-parallel update
needs).

Built on the current JAX API only: :func:`jax.make_mesh`,
:class:`jax.sharding.NamedSharding`, and
:func:`jax.experimental.shard_map.shard_map`. The removed
``jax.sharding.AxisType`` is deliberately not referenced anywhere.

Runs on CPU hosts via forced host devices, which is how CI exercises it:

    XLA_FLAGS=--xla_force_host_platform_device_count=8

The loss is a pure traceable function, so the fused training engine
(``repro.train.engine``) can wrap it in ``jax.lax.scan``: one donated
superstep scans K training steps, each evaluating this ``shard_map``-wrapped
loss and its transpose-inserted collectives -- K steps' worth of
all-reduces dispatch as one XLA computation, which is exactly where
multi-device training stops being dispatch-bound.

Semantics of :func:`esrnn_loss_dp`: the loss core is evaluated per-shard in
its decomposed form (``esrnn_loss_terms_fn``: masked pin-ball sum, valid
count, penalty sum) and reduced exactly -- ``psum(masked_sum) /
psum(valid_count)`` plus a pmean of the equal-shaped penalty terms. This is
the *global* masked mean: with ``variable_length`` masks whose valid-target
counts differ across shards it still matches the single-device masked mean
to float-summation order (the old per-shard-mean ``pmean`` only agreed for
equalized masks).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import jax.numpy as jnp

from repro.core import losses as L
from repro.core.esrnn import (
    ESRNNConfig, esrnn_forecast_at_fn, esrnn_forecast_fn, esrnn_loss_terms_fn,
    esrnn_predict_stats_fn,
)

SERIES_AXIS = "series"


def make_series_mesh(
    n_devices: Optional[int] = None,
    *,
    axis_name: str = SERIES_AXIS,
    devices: Optional[Sequence] = None,
) -> Mesh:
    """1-D mesh over the first ``n_devices`` local devices (default: all).

    On a CPU host, more than one device requires forcing host devices
    *before* jax initializes:  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1 or n > len(devs):
        raise ValueError(
            f"requested {n} devices but {len(devs)} are available; on CPU "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=<n> "
            "before the first jax call")
    return jax.make_mesh((n,), (axis_name,), devices=devs[:n])


def esrnn_param_specs(params, *, axis_name: str = SERIES_AXIS):
    """PartitionSpec pytree for an ES-RNN params tree.

    The ``hw`` subtree (per-series table, leading N axis) shards on the
    series axis; every other group (rnn / head / attn) is replicated.
    """
    def group_specs(name, subtree):
        sharded = name == "hw"
        return jax.tree_util.tree_map(
            lambda leaf: P(axis_name) if sharded else P(), subtree)

    return {k: group_specs(k, v) for k, v in params.items()}


def esrnn_param_shardings(mesh: Mesh, params, *, axis_name: str = SERIES_AXIS):
    """NamedSharding pytree matching ``params`` (hw sharded, rest replicated)."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        esrnn_param_specs(params, axis_name=axis_name),
        is_leaf=lambda x: isinstance(x, P),
    )


def check_series_divisible(n: int, mesh: Mesh) -> int:
    """The shard_map path needs the batch to divide the mesh evenly."""
    d = mesh.devices.size
    if n % d:
        raise ValueError(
            f"series batch of {n} does not divide the {d}-device "
            f"'{'/'.join(mesh.axis_names)}' mesh; pick a batch size that is "
            f"a multiple of {d}")
    return d


def esrnn_loss_dp(
    cfg: ESRNNConfig,
    params,
    y,
    cats,
    mask=None,
    *,
    mesh: Mesh,
    axis_name: str = SERIES_AXIS,
):
    """Data-parallel ES-RNN training loss: shard_map over the series axis.

    Exact global masked mean: each shard contributes its masked pin-ball
    *sum* and *valid count* (``esrnn_loss_terms_fn``); both are psum'd and
    divided once, so unequal per-shard mask counts (``variable_length``
    data) still reproduce the single-device masked mean. The section-8.4
    penalties reduce over equal-shaped per-shard tensors, so their pmean is
    already the global mean.

    Differentiable: taking ``jax.grad`` through this function yields
    device-local gradients for the per-series HW rows and psum'd (all-reduced)
    gradients for the replicated RNN/head weights -- shard_map's transpose
    rule inserts the collective, so the trainer needs no manual psum. This
    composes with ``cfg.use_pallas``: the kernels' custom_vjp backward runs
    per-shard inside the shard_map.

    ``params`` is the *batch* params tree (hw rows already gathered for the
    batch); ``y``/``cats``/``mask`` lead with the same series axis, whose
    size the mesh must divide evenly (see :func:`check_series_divisible`).
    """
    check_series_divisible(y.shape[0], mesh)
    pspecs = esrnn_param_specs(params, axis_name=axis_name)
    rows = (y, cats) if mask is None else (y, cats, mask)

    def local_loss(p, *r):
        pin_sum, pin_cnt, penalties = esrnn_loss_terms_fn(cfg, p, *r)
        pin_sum = jax.lax.psum(pin_sum, axis_name)
        pin_cnt = jax.lax.psum(pin_cnt, axis_name)
        return (pin_sum / jnp.maximum(pin_cnt, 1.0)
                + jax.lax.pmean(penalties, axis_name))

    # pallas_call has no shard_map replication rule; the loss is explicitly
    # reduced to a replicated scalar above, so skipping the static rep check
    # on the kernel path is sound (the default path keeps it).
    return shard_map(
        local_loss, mesh=mesh,
        in_specs=(pspecs,) + (P(axis_name),) * len(rows), out_specs=P(),
        check_rep=not cfg.use_pallas,
    )(params, *rows)


# ---------------------------------------------------------------------------
# Sharded inference: forecast / quantile stats / eval / backtest
# ---------------------------------------------------------------------------


def _shard_rows(cfg, local_fn, params, rows, *, mesh, axis_name, out_specs):
    """shard_map a per-shard row function over the series axis.

    ``params`` shard like training (hw rows device-local, shared weights
    replicated); every array in ``rows`` leads with the series axis. The
    static replication check is skipped only on the kernel path, exactly as
    in :func:`esrnn_loss_dp` (pallas_call has no replication rule).
    """
    check_series_divisible(rows[0].shape[0], mesh)
    pspecs = esrnn_param_specs(params, axis_name=axis_name)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspecs,) + (P(axis_name),) * len(rows),
        out_specs=out_specs,
        check_rep=not cfg.use_pallas,
    )(params, *rows)


def esrnn_forecast_dp(
    cfg: ESRNNConfig, params, y, cats, *,
    mesh: Mesh, axis_name: str = SERIES_AXIS,
):
    """Data-parallel h-step forecast: shard_map over the series axis.

    Each device forecasts its own rows from its device-local HW table slice
    and the replicated RNN/head weights -- the per-series structure the
    paper vectorized shards embarrassingly, so there are no collectives at
    all in the forward program. Returns (N, H), sharded on the series axis.
    """
    def local_fc(p, yy, cc):
        return esrnn_forecast_fn(cfg, p, yy, cc)

    return _shard_rows(cfg, local_fc, params, (y, cats), mesh=mesh,
                       axis_name=axis_name, out_specs=P(axis_name))


def esrnn_predict_stats_dp(
    cfg: ESRNNConfig, params, y, cats, *,
    mesh: Mesh, axis_name: str = SERIES_AXIS,
):
    """Sharded ``(forecast, quantile sigma)`` -- the predict_quantiles path.

    Both outputs are per-series rows off the same device-local forward
    states, so they shard with the batch like :func:`esrnn_forecast_dp`.
    """
    def local_stats(p, yy, cc):
        return esrnn_predict_stats_fn(cfg, p, yy, cc)

    return _shard_rows(cfg, local_stats, params, (y, cats), mesh=mesh,
                       axis_name=axis_name,
                       out_specs=(P(axis_name), P(axis_name)))


def esrnn_eval_dp(
    cfg: ESRNNConfig, params, y, cats, target, insample, *,
    seasonality: int, mesh: Mesh, row_mask=None,
    axis_name: str = SERIES_AXIS,
):
    """Sharded sMAPE/MASE of the model forecast as *exact* global means.

    Each shard forecasts its rows from ``y`` and contributes its masked
    metric sums and valid counts (``losses.smape_terms``/``mase_terms``);
    both are psum'd and divided once -- the PR-3 ``psum(sum)/psum(count)``
    pattern, so rows padded up to the mesh multiple (``row_mask`` 0) and
    ragged horizons cannot skew the mean. Returns replicated scalars
    ``{"smape": ..., "mase": ...}`` identical to the single-device metrics
    up to float summation order.

    ``target`` (N, h) is the scoring window, ``insample`` (N, T_in) the
    history for the MASE seasonal-naive scale; ``row_mask`` (N,) is 1 for
    real rows, 0 for padding rows.
    """
    h = target.shape[1]
    rows = ((y, cats, target, insample) if row_mask is None
            else (y, cats, target, insample, row_mask))

    def local_eval(p, yy, cc, tt, ins, *rm):
        fc = esrnn_forecast_fn(cfg, p, yy, cc)[:, :h]
        mask = None if not rm else rm[0][:, None]
        s_sum, s_cnt = L.smape_terms(fc, tt, mask=mask)
        m_sum, m_cnt = L.mase_terms(fc, tt, ins, seasonality, mask=mask)
        s_sum, s_cnt, m_sum, m_cnt = (
            jax.lax.psum(v, axis_name) for v in (s_sum, s_cnt, m_sum, m_cnt))
        return {"smape": 200.0 * s_sum / jnp.maximum(s_cnt, 1.0),
                "mase": m_sum / jnp.maximum(m_cnt, 1.0)}

    return _shard_rows(cfg, local_eval, params, rows, mesh=mesh,
                       axis_name=axis_name,
                       out_specs={"smape": P(), "mase": P()})


def esrnn_backtest_dp(
    cfg: ESRNNConfig, params, y, cats, origins, target, tmask, *,
    seasonality: int, mesh: Mesh, axis_name: str = SERIES_AXIS,
):
    """Sharded rolling-origin forecasts + metric *terms* in one dispatch.

    ``target``/``tmask`` are (N, K, H): per-origin scoring windows and
    their validity masks (0 where the window runs past the series end or
    the row is padding). Returns ``(fc, (s_sum, s_cnt, m_sum, m_cnt))``:
    the (N, K, H) forecasts sharded on the series axis, and the replicated
    (K,) metric terms already psum'd across shards -- the caller divides
    once per origin (and once overall), so sharded backtest metrics match
    single-device to float summation order. One forward pass serves both.
    """
    origins = tuple(int(o) for o in origins)

    def local_bt(p, yy, cc, tt, tm):
        fc = esrnn_forecast_at_fn(cfg, p, yy, cc, origins)
        terms = L.rolling_metric_terms(fc, tt, tm, yy, origins, seasonality)
        return fc, tuple(jax.lax.psum(t, axis_name) for t in terms)

    return _shard_rows(cfg, local_bt, params, (y, cats, target, tmask),
                       mesh=mesh, axis_name=axis_name,
                       out_specs=(P(axis_name), (P(), P(), P(), P())))
