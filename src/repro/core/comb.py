"""The M4 ``Comb`` benchmark (paper section 6) + Naive baselines.

Comb = the arithmetic mean of Simple, Holt, and Damped exponential smoothing
forecasts -- "a tough-to-beat benchmark, with a Rank of 19 in the M4
competition" (Makridakis et al. 2018). As in M4, seasonal series are
deseasonalized by classical multiplicative decomposition (ratio to centered
moving average), forecast, and re-seasonalized.

Everything is vectorized across series (grid-search parameter fitting
included) -- the same batching idea the paper applies to ES-RNN.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def _ses_sse(y, alpha):
    """One-step in-sample SSE of simple ES, vectorized over (N, grid)."""
    n, t = y.shape
    g = alpha.shape[0]
    l = np.broadcast_to(y[:, 0][:, None], (n, g)).copy()
    sse = np.zeros((n, g))
    for i in range(1, t):
        err = y[:, i][:, None] - l
        sse += err**2
        l = l + alpha[None, :] * err
    return sse, l


def ses_forecast(y: np.ndarray, horizon: int) -> np.ndarray:
    alphas = np.linspace(0.05, 0.95, 10)
    sse, levels = _ses_sse(y, alphas)
    best = np.argmin(sse, axis=1)
    l = levels[np.arange(y.shape[0]), best]
    return np.repeat(l[:, None], horizon, axis=1)


def _holt_fit(y, alphas, betas, phi=1.0):
    """Damped Holt, vectorized over series x (alpha, beta) grid."""
    n, t = y.shape
    ga, gb = len(alphas), len(betas)
    a = alphas[None, :, None]
    b = betas[None, None, :]
    l = np.broadcast_to(y[:, 0][:, None, None], (n, ga, gb)).copy()
    tr = np.broadcast_to((y[:, 1] - y[:, 0])[:, None, None], (n, ga, gb)).copy()
    sse = np.zeros((n, ga, gb))
    for i in range(1, t):
        pred = l + phi * tr
        err = y[:, i][:, None, None] - pred
        sse += err**2
        l_new = pred + a * err
        tr = phi * tr + a * b * err
        l = l_new
    return sse, l, tr


def holt_forecast(y: np.ndarray, horizon: int, phi: float = 1.0) -> np.ndarray:
    alphas = np.linspace(0.1, 0.9, 6)
    betas = np.linspace(0.05, 0.5, 4)
    sse, l, tr = _holt_fit(y, alphas, betas, phi)
    n = y.shape[0]
    flat = sse.reshape(n, -1).argmin(axis=1)
    ia, ib = np.unravel_index(flat, sse.shape[1:])
    l_b = l[np.arange(n), ia, ib]
    t_b = tr[np.arange(n), ia, ib]
    if phi == 1.0:
        steps = np.arange(1, horizon + 1)
    else:
        steps = np.cumsum(phi ** np.arange(1, horizon + 1))
    return l_b[:, None] + t_b[:, None] * steps[None, :]


def classical_seasonal_factors(y: np.ndarray, m: int) -> np.ndarray:
    """Multiplicative ratio-to-moving-average decomposition. y: (N, T)."""
    n, t = y.shape
    if m <= 1 or t < 2 * m:
        return np.ones((n, m))
    k = m
    kernel = np.ones(k) / k
    # centered MA (even periods: average of two offset MAs)
    ma = np.apply_along_axis(lambda r: np.convolve(r, kernel, "valid"), 1, y)
    if m % 2 == 0:
        ma = 0.5 * (ma[:, :-1] + ma[:, 1:])
        offset = m // 2
    else:
        offset = (m - 1) // 2
    ratios = y[:, offset : offset + ma.shape[1]] / np.maximum(ma, 1e-8)
    factors = np.ones((n, m))
    for ph in range(m):
        idx = (np.arange(ratios.shape[1]) + offset) % m == ph
        if idx.any():
            factors[:, ph] = np.median(ratios[:, idx], axis=1)
    factors /= factors.mean(axis=1, keepdims=True)
    return factors


def deseasonalize(y: np.ndarray, m: int) -> Tuple[np.ndarray, np.ndarray]:
    factors = classical_seasonal_factors(y, m)
    t = y.shape[1]
    tiled = np.tile(factors, (1, t // m + 1))[:, :t]
    return y / np.maximum(tiled, 1e-8), factors


def reseasonalize(fc: np.ndarray, factors: np.ndarray, t_start: int) -> np.ndarray:
    h = fc.shape[1]
    m = factors.shape[1]
    idx = (t_start + np.arange(h)) % m
    return fc * factors[:, idx]


def comb_forecast(y: np.ndarray, horizon: int, seasonality: int) -> np.ndarray:
    """The M4 benchmark: mean(SES, Holt, Damped) on deseasonalized data."""
    y = np.asarray(y, np.float64)
    ydes, factors = deseasonalize(y, seasonality)
    f1 = ses_forecast(ydes, horizon)
    f2 = holt_forecast(ydes, horizon, phi=1.0)
    f3 = holt_forecast(ydes, horizon, phi=0.9)
    fc = (f1 + f2 + f3) / 3.0
    if seasonality > 1:
        fc = reseasonalize(fc, factors, y.shape[1])
    return np.maximum(fc, 1e-8)


def naive_forecast(y: np.ndarray, horizon: int) -> np.ndarray:
    return np.repeat(y[:, -1:], horizon, axis=1)


def seasonal_naive_forecast(y: np.ndarray, horizon: int, m: int) -> np.ndarray:
    if m <= 1:
        return naive_forecast(y, horizon)
    reps = -(-horizon // m)
    return np.tile(y[:, -m:], (1, reps))[:, :horizon]


def naive2_forecast(y: np.ndarray, horizon: int, m: int) -> np.ndarray:
    """Naive on deseasonalized data (the M4 OWA denominator)."""
    ydes, factors = deseasonalize(np.asarray(y, np.float64), m)
    fc = naive_forecast(ydes, horizon)
    if m > 1:
        fc = reseasonalize(fc, factors, y.shape[1])
    return fc
