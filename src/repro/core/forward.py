"""The unified ES-RNN state-space forward core.

One pure pass computes everything the model ever derives from a batch of
series -- Holt-Winters levels/seasonality, the normalized input windows
(Eq. 6), and the RNN head outputs at every valid window position -- and
returns it as an :class:`ESRNNStates` pytree. Both consumers read from that
single state:

* the training loss (``repro.core.esrnn.esrnn_loss_terms_fn``) scores the
  RNN outputs against the normalized target windows via :func:`loss_terms`,
* the forecast (``repro.core.esrnn.esrnn_forecast``) de-normalizes the
  *last* position's output via :func:`forecast_from_states` -- and, because
  the whole recurrence is causal, :func:`forecast_at_origins` reads off the
  forecast from *any* earlier origin of the same pass (rolling-origin
  backtesting without re-running the model per origin).

Before this module the smoothing / window / future-seasonal-index logic
lived twice (once in the loss, once in the forecast); now there is exactly
one implementation, and it dispatches through the existing
``kernels/ops.py`` pure-jax/Pallas paths (``cfg.use_pallas``).

Causality contract (what makes :func:`forecast_at_origins` sound): every
quantity at time/position ``t`` depends only on observations ``y[:, :t+1]``
-- the HW scan writes ``levels[:, t]`` and ``seas[:, t+k]`` (k <= m) from
``y[:, :t+1]``, the input windows end at ``t``, and the dilated LSTM (and
the causally-masked attention variant) only looks backwards. A forecast
read off at origin ``o`` therefore equals the forecast of the truncated
history ``y[:, :o]`` (asserted to float precision in
``tests/core/test_forward.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import heads as H
from repro.core import losses as L
from repro.core.holt_winters import hw_smooth, hw_step

__all__ = [
    "ESRNNStates", "esrnn_states", "smooth", "hw_step", "window_positions",
    "future_seasonal_idx", "input_windows", "target_windows", "features",
    "rnn_head", "loss_terms", "forecast_from_states", "quantile_sigma",
    "forecast_at_origins",
]

# ``hw_step`` is re-exported here as part of the forward core's public
# surface: it is the exact body of the :func:`smooth` scan (extracted, not
# duplicated), and the forecast server's online ``observe`` path applies it
# on host to roll a series' (level, seasonal-ring) state forward per new
# observation -- one step of the same recurrence :func:`esrnn_states` runs
# over the whole history, so the rolled state matches a from-scratch pass.


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ESRNNStates:
    """Everything one forward pass derives from a batch ``y`` (N, T).

    levels: (N, T)    HW level l_t after observing y_t
    seas:   (N, T+m)  multiplicative seasonality; [:, T:] are future factors
    pos:    (P,)      valid window positions t = W-1 .. T-1
    x_in:   (N, P, W) normalized/de-seasonalized/log input windows (Eq. 6)
    yhat_n: (N, P, H) RNN head outputs (normalized log-space predictions)
    c_sq:   ()        mean squared LSTM cell state (section-8.4 penalty term)
    """

    levels: jax.Array
    seas: jax.Array
    pos: jax.Array
    x_in: jax.Array
    yhat_n: jax.Array
    c_sq: jax.Array


# ---------------------------------------------------------------------------
# The single smoothing / window / seasonal-extension implementation
# ---------------------------------------------------------------------------


def smooth(cfg, params, y):
    """HW smoothing with the config's dispatch (pure jax or Pallas kernels).

    Under the ``bf16`` precision policy the observation stream is cast to the
    compute dtype before smoothing -- that is what halves the y tiles the HW
    recurrence reads -- while the recurrence itself, the per-series HW table,
    and the returned levels/seasonality stay in the state dtype (fp32): the
    smoothing parameters are fp32 and every step promotes, so the
    accumulated state never rounds through bf16.
    """
    cdt = cfg.compute_dtype
    if y.dtype != cdt:
        y = y.astype(cdt)
    return hw_smooth(
        y,
        params["hw"],
        seasonality=cfg.seasonality,
        seasonality2=cfg.seasonality2,
        use_pallas=cfg.use_pallas,
    )


def window_positions(cfg, t_len: int):
    """Valid window positions t = W-1 .. T-1 (input window fully observed)."""
    return jnp.arange(cfg.input_size - 1, t_len)


def future_seasonal_idx(out_idx, t_len: int, m: int):
    """Seasonality indices for targets t+1..t+H, cyclically clamped.

    ``seas`` from :func:`smooth` has ``t_len + m`` valid entries when the
    series has ``t_len`` observations; indices beyond that wrap into the
    last smoothed season. This single helper is the seasonal-extension rule
    for the loss targets, the end-of-series forecast, AND every backtest
    origin (where ``t_len`` is the origin's observation count), so the
    paths cannot drift apart.
    """
    return jnp.where(
        out_idx < t_len + m,
        out_idx,
        t_len + jnp.mod(out_idx - t_len, m),
    )


def input_windows(cfg, y, levels, seas):
    """Normalized + de-seasonalized + log input windows (Eq. 6).

    Returns feats (N, P, W) and the position vector (P,). Every returned
    position has a fully-observed input window (positions start at W-1), so
    no input-side mask is needed; target-side validity is handled by
    :func:`target_windows`.
    """
    w = cfg.input_size
    _, t_len = y.shape
    pos = window_positions(cfg, t_len)                         # (P,)
    in_idx = pos[:, None] + jnp.arange(-w + 1, 1)[None, :]     # (P, W)
    y_in = y[:, in_idx]                                        # (N, P, W)
    s_in = seas[:, in_idx]
    lvl = levels[:, pos]                                       # (N, P)
    x_in = jnp.log(jnp.maximum(y_in / (lvl[:, :, None] * s_in), 1e-8))
    return x_in, pos


def target_windows(cfg, y, levels, seas, pos):
    """Normalized output windows + the position-validity mask.

    Output windows need y up to t+H, so the last H positions have no
    (complete) target; ``out_mask`` (N, P, H) in {0,1} marks real targets.
    Clamped (out-of-range) entries are masked out of the loss.
    """
    n, t_len = y.shape
    h = cfg.output_size
    out_idx = pos[:, None] + jnp.arange(1, h + 1)[None, :]     # (P, H)
    out_valid = out_idx < t_len                                # (P, H)
    out_idx_c = jnp.minimum(out_idx, t_len - 1)
    lvl = levels[:, pos]                                       # (N, P)
    y_out = y[:, out_idx_c]                                    # (N, P, H)
    m = max(cfg.seasonality, 1)
    s_out = seas[:, future_seasonal_idx(out_idx, t_len, m)]
    y_out_n = jnp.log(jnp.maximum(y_out / (lvl[:, :, None] * s_out), 1e-8))
    out_mask = out_valid[None, :, :].astype(y.dtype) * jnp.ones((n, 1, 1), y.dtype)
    return y_out_n, out_mask


def features(x_in, cats):
    """Input windows + broadcast one-hot category features (N, P, W + C)."""
    n, p, _ = x_in.shape
    cat_feat = jnp.broadcast_to(cats[:, None, :], (n, p, cats.shape[-1]))
    return jnp.concatenate([x_in, cat_feat.astype(x_in.dtype)], axis=-1)


def rnn_head(cfg, params, feats):
    """Dilated residual LSTM -> (attention) -> tanh dense -> linear head.

    Kept as the public name of the paper's head; the implementation lives
    in :mod:`repro.core.heads` as the ``lstm`` entry of the head registry
    (same math, bit-for-bit -- the goldens assert it).
    """
    return H.lstm_head_apply(cfg, params, feats)


# ---------------------------------------------------------------------------
# The one forward pass
# ---------------------------------------------------------------------------


def esrnn_states(cfg, params, y, cats) -> ESRNNStates:
    """Run the full state-space forward pass once: smoothing, windows, head.

    This is the shared core of the loss and every forecast/backtest path.
    ``y`` (N, T) strictly positive, ``cats`` (N, C) one-hot. The network
    that maps windowed features to normalized predictions is pluggable:
    ``cfg.head`` selects it from the :mod:`repro.core.heads` registry
    (``lstm`` -- the paper's dilated LSTM, ``esn``, ``ssm``, or anything
    registered since). Every head must be causal along the position axis,
    which is what keeps :func:`forecast_at_origins` sound.
    """
    levels, seas = smooth(cfg, params, y)
    x_in, pos = input_windows(cfg, y, levels, seas)
    feats = features(x_in, cats)
    # The head computes in the policy's dtype (bf16 halves every activation
    # and weight tile it streams); its readout re-emits yhat_n in fp32 so the
    # pinball reduction and the Eq.-5 exp stay full precision.
    cdt = cfg.compute_dtype
    if feats.dtype != cdt:
        feats = feats.astype(cdt)
    yhat_n, c_sq = H.get_head(cfg.head).apply(cfg, params, feats)
    return ESRNNStates(levels=levels, seas=seas, pos=pos, x_in=x_in,
                       yhat_n=yhat_n, c_sq=c_sq)


# ---------------------------------------------------------------------------
# Consumers: loss terms, forecasts, rolling origins
# ---------------------------------------------------------------------------


def loss_terms(cfg, states: ESRNNStates, y, mask=None):
    """Decomposed training-loss terms ``(pinball_sum, valid_count, penalties)``.

    The target windows are scored against the precomputed RNN outputs;
    ``mask`` (N, T) excludes window positions whose input overlaps the
    left-padding of variable-length series. The decomposition exists for
    exact distributed reduction (psum the first two, divide once globally).
    """
    y_out_n, out_mask = target_windows(cfg, y, states.levels, states.seas,
                                       states.pos)
    if mask is not None:
        valid_in = mask[:, states.pos - cfg.input_size + 1]    # (N, P)
        out_mask = out_mask * valid_in[:, :, None]
    pin_sum, pin_cnt = L.pinball_terms(states.yhat_n, y_out_n, tau=cfg.tau,
                                       mask=out_mask)
    penalties = (L.level_variability_penalty(states.levels, cfg.level_penalty)
                 + L.cstate_penalty(states.c_sq, cfg.cstate_penalty))
    return pin_sum, pin_cnt, penalties


def forecast_from_states(cfg, states: ESRNNStates, t_len: int):
    """h-step forecast from the end of the series: (N, H), de-normalized.

    Eq. 5: ``yhat_{T+1..T+h} = exp(rnn_last) * l_T * s_{T+1..T+h}`` with the
    future seasonality extended by the :func:`future_seasonal_idx` cyclic
    rule at the final position T-1 (indices T..T+H-1).
    """
    last = states.yhat_n[:, -1, :]                       # (N, H) log-space
    m = max(cfg.seasonality, 1)
    fut_idx = t_len + jnp.arange(cfg.output_size)        # targets of pos T-1
    s_fut = states.seas[:, future_seasonal_idx(fut_idx, t_len, m)]
    return jnp.exp(last) * states.levels[:, -1:] * s_fut


def quantile_sigma(states: ESRNNStates, y):
    """Per-series log-residual spread sigma (N, 1) for quantile bands.

    The multiplicative model says ``y_t = l_t * s_t * eps_t``, so the std
    of ``log(y) - log(l * s)`` over the in-sample window measures the
    series' own noise scale -- the estimator widens it random-walk style
    (``exp(z_tau * sigma * sqrt(h))``) around the point forecast. Reads the
    fitted levels/seasonality straight off the shared forward states (no
    second smoothing pass).
    """
    t_len = y.shape[1]
    fitted = states.levels * states.seas[:, :t_len]
    log_resid = jnp.log(jnp.maximum(y, 1e-8)) - jnp.log(
        jnp.maximum(fitted, 1e-8))
    return jnp.std(log_resid, axis=1, keepdims=True)


def forecast_at_origins(cfg, states: ESRNNStates,
                        origins: Tuple[int, ...], t_len: int):
    """Rolling-origin forecasts off one forward pass: (N, K, H).

    ``origins[k]`` is an observation count ``o`` (forecast as if only
    ``y[:, :o]`` had been seen). Because every state at position ``o-1``
    is causal in ``y[:, :o]``, reading the RNN output at that position and
    de-normalizing with ``levels[:, o-1]`` and the seasonal factors of a
    length-``o`` series reproduces ``esrnn_forecast(cfg, params,
    y[:, :o], cats)`` -- the ES states are re-primed per origin for free,
    no refit and no per-origin re-run.

    Each origin must satisfy ``cfg.input_size <= o <= t_len`` (the input
    window at o-1 must be fully observed). ``origins`` is static (a tuple),
    so the gather indices are compile-time constants.
    """
    w, h = cfg.input_size, cfg.output_size
    m = max(cfg.seasonality, 1)
    for o in origins:
        if not w <= o <= t_len:
            raise ValueError(
                f"backtest origin {o} outside [{w}, {t_len}]: the input "
                f"window needs {w} observations and the series has {t_len}")
    outs = []
    for o in origins:
        last = states.yhat_n[:, o - w, :]                # position o-1
        fut_idx = o + jnp.arange(h)                      # targets o..o+H-1
        s_fut = states.seas[:, future_seasonal_idx(fut_idx, o, m)]
        outs.append(jnp.exp(last) * states.levels[:, o - 1 : o] * s_fut)
    return jnp.stack(outs, axis=1)                       # (N, K, H)
