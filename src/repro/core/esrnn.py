"""The hybrid ES-RNN model (paper section 3, Eqs. 5-6) as pure functions.

Dataflow per training step, all batched over the series axis (the paper's
contribution):

  y (N, T) --hw_smooth--> levels (N, T), seas (N, T+m)
     |                                     |
     +--window/normalize/deseason/log (Eq. 6, Fig. 2)
     |        x[t] = log( y[t-W+1..t] / (l_t * s[t-W+1..t]) )
     v
  features (N, P, W + n_cat)  [P = valid window positions; one-hot category]
     |--dilated residual LSTM (Table 1) -> tanh dense -> linear
     v
  yhat_norm (N, P, H)   (de-seasonalized, normalized log-space predictions)
  loss = pinball(yhat_norm, out_window_norm) + section-8.4 penalties

Forecast (paper section 3.4 / Eq. 5):
  yhat_{T+1..T+h} = exp(rnn_last) * l_T * s_{T+1..T+h}

The per-series HW parameters and shared RNN weights are trained *jointly*
(one optimizer, two param groups with different learning rates).

The module exposes an estimator-friendly functional API:

  ``esrnn_init(key, cfg, n_series)``      -> params pytree
  ``esrnn_loss(cfg, params, y, cats)``    -> scalar training loss
  ``esrnn_forecast(cfg, params, y, cats)``-> (N, H) de-normalized forecast
  ``esrnn_loss_and_grad(cfg, params, y, cats)``

``repro.forecast.ESRNNForecaster`` wraps these; the legacy :class:`ESRNN`
class remains as a thin deprecation shim delegating to the pure functions,
so old call sites keep working (and stay bit-for-bit identical).
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.core.drnn import drnn_apply, drnn_init
from repro.core.holt_winters import HWParams, hw_init_params, hw_smooth


@dataclasses.dataclass(frozen=True)
class ESRNNConfig:
    """Frequency-specific ES-RNN hyperparameters (paper Tables 1 and text)."""

    name: str = "quarterly"
    seasonality: int = 4
    seasonality2: int = 0          # section 8.2 (e.g. hourly: 24 and 168)
    input_size: int = 8            # input window W (heuristic, section 3.1)
    output_size: int = 8           # forecast horizon H
    hidden_size: int = 40          # Table 1
    dilations: Tuple[Tuple[int, ...], ...] = ((1, 2), (4, 8))  # Table 1
    n_categories: int = 6          # M4: Demographic..Other, one-hot appended
    tau: float = 0.49              # pinball quantile
    level_penalty: float = 0.0     # section 8.4 (beyond-paper, off by default)
    cstate_penalty: float = 0.0    # section 8.4
    attention: bool = False        # section 7/8.5: Smyl's attentive variant
                                   # (yearly/weekly) -- causal dot-product
                                   # attention over the LSTM hidden sequence
    use_pallas: bool = False       # route HW scan + LSTM cell through kernels
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


# Table 1 presets + the monthly/yearly rows.
PRESETS = {
    "yearly": dict(seasonality=1, input_size=4, output_size=6, hidden_size=30,
                   dilations=((1, 2), (2, 6))),
    "quarterly": dict(seasonality=4, input_size=8, output_size=8, hidden_size=40,
                      dilations=((1, 2), (4, 8))),
    "monthly": dict(seasonality=12, input_size=12, output_size=18, hidden_size=50,
                    dilations=((1, 3), (6, 12))),
    "hourly": dict(seasonality=24, seasonality2=168, input_size=24,
                   output_size=48, hidden_size=40, dilations=((1, 4), (24, 168))),
}


def make_config(name: str, **overrides) -> ESRNNConfig:
    base = dict(PRESETS[name], name=name)
    base.update(overrides)
    return ESRNNConfig(**base)


# ---------------------------------------------------------------------------
# Pure init
# ---------------------------------------------------------------------------


def esrnn_init(key, cfg: ESRNNConfig, n_series: int):
    """Initialize the params pytree: {"hw": HWParams, "rnn": ..., "head": ...}.

    The ``hw`` subtree is the per-series table (leading axis N); everything
    else is shared across series.
    """
    rnn_key, head_key1, head_key2 = jax.random.split(key, 3)
    feat = cfg.input_size + cfg.n_categories
    hw = hw_init_params(
        n_series, cfg.seasonality, seasonality2=cfg.seasonality2, dtype=cfg.jdtype
    )
    rnn = drnn_init(rnn_key, feat, cfg.hidden_size, cfg.dilations, cfg.jdtype)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
    head = {
        "dense_w": (jax.random.uniform(head_key1, (cfg.hidden_size, cfg.hidden_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
        "dense_b": jnp.zeros((cfg.hidden_size,), cfg.jdtype),
        "out_w": (jax.random.uniform(head_key2, (cfg.hidden_size, cfg.output_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
        "out_b": jnp.zeros((cfg.output_size,), cfg.jdtype),
    }
    params = {"hw": hw, "rnn": rnn, "head": head}
    if cfg.attention:
        ka, kb, kc = jax.random.split(head_key1, 3)
        h = cfg.hidden_size
        params["attn"] = {
            "wq": (jax.random.normal(ka, (h, h)) * scale).astype(cfg.jdtype),
            "wk": (jax.random.normal(kb, (h, h)) * scale).astype(cfg.jdtype),
            "wv": (jax.random.normal(kc, (h, h)) * scale).astype(cfg.jdtype),
        }
    return params


# ---------------------------------------------------------------------------
# Pure apply internals (shared by loss and forecast)
# ---------------------------------------------------------------------------


def _smooth(cfg: ESRNNConfig, params, y):
    return hw_smooth(
        y,
        params["hw"],
        seasonality=cfg.seasonality,
        seasonality2=cfg.seasonality2,
        use_pallas=cfg.use_pallas,
    )


def _window_positions(cfg: ESRNNConfig, t_len: int):
    """Valid window positions t = W-1 .. T-1 (input window fully observed)."""
    return jnp.arange(cfg.input_size - 1, t_len)


def _future_seasonal_idx(out_idx, t_len: int, m: int):
    """Seasonality indices for targets t+1..t+H, cyclically clamped.

    ``seas`` from :func:`hw_smooth` has T+m valid entries; indices beyond
    that wrap into the last smoothed season. This single helper is the
    seasonal-extension rule for BOTH the loss targets and the forecast
    de-normalization, so the two paths cannot drift apart.
    """
    return jnp.where(
        out_idx < t_len + m,
        out_idx,
        t_len + jnp.mod(out_idx - t_len, m),
    )


def _input_windows(cfg: ESRNNConfig, y, levels, seas):
    """Normalized + de-seasonalized + log input windows (Eq. 6).

    Returns feats (N, P, W) and the position vector (P,). Every returned
    position has a fully-observed input window (positions start at W-1), so
    no input-side mask is needed; target-side validity is handled by
    :func:`_target_windows`.
    """
    w = cfg.input_size
    _, t_len = y.shape
    pos = _window_positions(cfg, t_len)                        # (P,)
    in_idx = pos[:, None] + jnp.arange(-w + 1, 1)[None, :]     # (P, W)
    y_in = y[:, in_idx]                                        # (N, P, W)
    s_in = seas[:, in_idx]
    lvl = levels[:, pos]                                       # (N, P)
    x_in = jnp.log(jnp.maximum(y_in / (lvl[:, :, None] * s_in), 1e-8))
    return x_in, pos


def _target_windows(cfg: ESRNNConfig, y, levels, seas, pos):
    """Normalized output windows + the position-validity mask.

    Output windows need y up to t+H, so the last H positions have no
    (complete) target; ``out_mask`` (N, P, H) in {0,1} marks real targets.
    Clamped (out-of-range) entries are masked out of the loss.
    """
    n, t_len = y.shape
    h = cfg.output_size
    out_idx = pos[:, None] + jnp.arange(1, h + 1)[None, :]     # (P, H)
    out_valid = out_idx < t_len                                # (P, H)
    out_idx_c = jnp.minimum(out_idx, t_len - 1)
    lvl = levels[:, pos]                                       # (N, P)
    y_out = y[:, out_idx_c]                                    # (N, P, H)
    m = max(cfg.seasonality, 1)
    s_out = seas[:, _future_seasonal_idx(out_idx, t_len, m)]
    y_out_n = jnp.log(jnp.maximum(y_out / (lvl[:, :, None] * s_out), 1e-8))
    out_mask = out_valid[None, :, :].astype(y.dtype) * jnp.ones((n, 1, 1), y.dtype)
    return y_out_n, out_mask


def _rnn_head(cfg: ESRNNConfig, params, feats):
    hid, c_sq = drnn_apply(
        params["rnn"], feats, dilations=cfg.dilations, use_pallas=cfg.use_pallas
    )
    if cfg.attention:
        ap = params["attn"]
        q = hid @ ap["wq"]
        k = hid @ ap["wk"]
        v = hid @ ap["wv"]
        s = jnp.einsum("nph,nqh->npq", q, k) / jnp.sqrt(
            jnp.asarray(cfg.hidden_size, jnp.float32)).astype(hid.dtype)
        p_idx = jnp.arange(hid.shape[1])
        mask = p_idx[:, None] >= p_idx[None, :]
        s = jnp.where(mask[None], s.astype(jnp.float32), -jnp.inf)
        hid = hid + jnp.einsum(
            "npq,nqh->nph", jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
    head = params["head"]
    z = jnp.tanh(hid @ head["dense_w"] + head["dense_b"])
    return z @ head["out_w"] + head["out_b"], c_sq


def _features(x_in, cats):
    n, p, _ = x_in.shape
    cat_feat = jnp.broadcast_to(cats[:, None, :], (n, p, cats.shape[-1]))
    return jnp.concatenate([x_in, cat_feat.astype(x_in.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Pure public apply functions
# ---------------------------------------------------------------------------


def esrnn_loss_terms_fn(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Per-batch loss *terms*: ``(pinball_sum, valid_count, penalties)``.

    The decomposed form exists for exact distributed reduction: the sharded
    loss (``repro.sharding.series.esrnn_loss_dp``) psums the masked pin-ball
    numerator and denominator across shards and divides once globally, which
    matches the single-device masked mean even when shards carry unequal
    valid-target counts (``variable_length`` data). ``penalties`` is the sum
    of the section-8.4 terms, whose reductions are over equal-shaped
    per-shard tensors (a pmean of them is already exact).
    """
    levels, seas = _smooth(cfg, params, y)
    x_in, pos = _input_windows(cfg, y, levels, seas)
    y_out_n, out_mask = _target_windows(cfg, y, levels, seas, pos)
    if mask is not None:
        valid_in = mask[:, pos - cfg.input_size + 1]          # (N, P)
        out_mask = out_mask * valid_in[:, :, None]
    feats = _features(x_in, cats)
    yhat_n, c_sq = _rnn_head(cfg, params, feats)
    pin_sum, pin_cnt = L.pinball_terms(yhat_n, y_out_n, tau=cfg.tau,
                                       mask=out_mask)
    penalties = (L.level_variability_penalty(levels, cfg.level_penalty)
                 + L.cstate_penalty(c_sq, cfg.cstate_penalty))
    return pin_sum, pin_cnt, penalties


def esrnn_loss_fn(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Unjitted loss body -- the batch-shardable entry point.

    Every operation is elementwise or reduces over the batch's own rows, so
    the function can run per-shard inside ``shard_map`` (see
    ``repro.sharding.series.esrnn_loss_dp``, which reduces the decomposed
    :func:`esrnn_loss_terms_fn` exactly). Use :func:`esrnn_loss` (the jitted
    wrapper) everywhere else.
    """
    pin_sum, pin_cnt, penalties = esrnn_loss_terms_fn(cfg, params, y, cats, mask)
    return pin_sum / jnp.maximum(pin_cnt, 1.0) + penalties


@partial(jax.jit, static_argnames=("cfg",))
def esrnn_loss(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Training loss on series y (N, T) with category one-hots (N, C).

    ``mask`` (N, T), optional: 1 where y is a real observation, 0 on the
    left-padding of variable-length series (``data.pipeline`` section-8.1
    convention). Window positions whose input window overlaps padding are
    excluded from the loss; with left-padding a window [t-W+1..t] is fully
    real iff its first element is (the mask is 0..0 1..1). ``None`` (the
    equalized default) is bit-identical to an all-ones mask.
    """
    return esrnn_loss_fn(cfg, params, y, cats, mask)


@partial(jax.jit, static_argnames=("cfg",))
def esrnn_forecast(cfg: ESRNNConfig, params, y, cats):
    """h-step forecast from the end of y: (N, H), de-normalized (3.4).

    Shares the exact window/seasonal machinery of :func:`esrnn_loss`: the
    features come from the same :func:`_input_windows` path (whose positions
    are valid by construction -- the same invariant the loss mask encodes),
    and the future seasonality uses the same :func:`_future_seasonal_idx`
    cyclic rule applied at the final position T-1, i.e. indices T..T+H-1.
    """
    n, t_len = y.shape
    levels, seas = _smooth(cfg, params, y)
    x_in, _pos = _input_windows(cfg, y, levels, seas)
    feats = _features(x_in, cats)
    yhat_n, _ = _rnn_head(cfg, params, feats)
    last = yhat_n[:, -1, :]                              # (N, H) log-space
    m = max(cfg.seasonality, 1)
    fut_idx = t_len + jnp.arange(cfg.output_size)        # targets of pos T-1
    s_fut = seas[:, _future_seasonal_idx(fut_idx, t_len, m)]
    return jnp.exp(last) * levels[:, -1:] * s_fut


def esrnn_loss_and_grad(cfg: ESRNNConfig, params, y, cats, mask=None):
    return jax.value_and_grad(
        lambda p: esrnn_loss(cfg, p, y, cats, mask))(params)


def gather_series(params, idx):
    """Per-series row gather: hw rows at ``idx``, shared weights untouched.

    The gradient scatter back to the full table happens automatically
    through the indexing when differentiated (used by the trainer and the
    serving path). Note the scattered gradient is a dense zero-padded
    (N, ...) table; the sparse-optimizer path avoids it by differentiating
    w.r.t. the gathered rows directly (see :func:`partition_series` and
    ``repro.train.engine``).
    """
    return {k: (jax.tree_util.tree_map(lambda a: a[idx], v) if k == "hw" else v)
            for k, v in params.items()}


def partition_series(params, idx):
    """Split params into (gathered per-series rows, shared weights).

    ``hw_rows`` is the per-series subtree gathered at ``idx`` (leaves
    (B, ...)); ``shared`` is everything else, untouched. Differentiating a
    loss w.r.t. ``hw_rows`` yields *per-row* gradients -- no zero-padded
    scatter over the full table -- which is what the sparse segment
    optimizer (``adam_update_sparse``) consumes.
    """
    hw_rows = jax.tree_util.tree_map(lambda a: a[idx], params["hw"])
    shared = {k: v for k, v in params.items() if k != "hw"}
    return hw_rows, shared


def combine_series(hw_rows, shared):
    """Inverse of :func:`partition_series` (batch-rows params tree)."""
    return {"hw": hw_rows, **shared}


# ---------------------------------------------------------------------------
# Legacy class shim (deprecated)
# ---------------------------------------------------------------------------


class ESRNN:
    """Deprecated thin wrapper over the pure functional API.

    Prefer ``repro.forecast.ESRNNForecaster`` (estimator API) or the pure
    functions in this module. Kept so existing call sites keep working; it
    delegates to the exact same jitted functions, so results are bit-for-bit
    identical to the functional path.
    """

    def __init__(self, config: ESRNNConfig, *, _warn: bool = True):
        if _warn:
            warnings.warn(
                "ESRNN is deprecated; use repro.forecast.ESRNNForecaster or "
                "the pure esrnn_init/esrnn_loss/esrnn_forecast functions",
                DeprecationWarning,
                stacklevel=2,
            )
        self.config = config

    def init(self, key, n_series: int):
        return esrnn_init(key, self.config, n_series)

    def loss_fn(self, params, y, cats, mask=None):
        return esrnn_loss(self.config, params, y, cats, mask)

    def forecast(self, params, y, cats):
        return esrnn_forecast(self.config, params, y, cats)

    def loss_and_grad(self, params, y, cats):
        return esrnn_loss_and_grad(self.config, params, y, cats)


def _as_config(model_or_cfg) -> ESRNNConfig:
    if isinstance(model_or_cfg, ESRNN):
        return model_or_cfg.config
    return model_or_cfg


# ---------------------------------------------------------------------------
# Per-series loop reference (the structure the paper vectorized away)
# ---------------------------------------------------------------------------


def esrnn_loss_loop_reference(model_or_cfg, params, y, cats) -> jax.Array:
    """Compute the same loss one series at a time (batch of 1 each).

    Used by the equivalence test and the Table-5 speedup benchmark: identical
    math, but the series axis is a python loop as in Smyl's original C++.
    Accepts either an :class:`ESRNNConfig` or the legacy :class:`ESRNN` shim.
    """
    cfg = _as_config(model_or_cfg)
    n = y.shape[0]
    losses = []
    for i in range(n):
        p_i = gather_series(params, slice(i, i + 1))
        losses.append(esrnn_loss(cfg, p_i, y[i : i + 1], cats[i : i + 1]))
    return jnp.mean(jnp.stack(losses))
