"""The hybrid ES-RNN model (paper section 3, Eqs. 5-6).

Dataflow per training step, all batched over the series axis (the paper's
contribution):

  y (N, T) --hw_smooth--> levels (N, T), seas (N, T+m)
     |                                     |
     +--window/normalize/deseason/log (Eq. 6, Fig. 2)
     |        x[t] = log( y[t-W+1..t] / (l_t * s[t-W+1..t]) )
     v
  features (N, P, W + n_cat)  [P = valid window positions; one-hot category]
     |--dilated residual LSTM (Table 1) -> tanh dense -> linear
     v
  yhat_norm (N, P, H)   (de-seasonalized, normalized log-space predictions)
  loss = pinball(yhat_norm, out_window_norm) + section-8.4 penalties

Forecast (paper section 3.4 / Eq. 5):
  yhat_{T+1..T+h} = exp(rnn_last) * l_T * s_{T+1..T+h}

The per-series HW parameters and shared RNN weights are trained *jointly*
(one optimizer, two param groups with different learning rates).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.core.drnn import drnn_apply, drnn_init
from repro.core.holt_winters import HWParams, extend_seasonality, hw_init_params, hw_smooth


@dataclasses.dataclass(frozen=True)
class ESRNNConfig:
    """Frequency-specific ES-RNN hyperparameters (paper Tables 1 and text)."""

    name: str = "quarterly"
    seasonality: int = 4
    seasonality2: int = 0          # section 8.2 (e.g. hourly: 24 and 168)
    input_size: int = 8            # input window W (heuristic, section 3.1)
    output_size: int = 8           # forecast horizon H
    hidden_size: int = 40          # Table 1
    dilations: Tuple[Tuple[int, ...], ...] = ((1, 2), (4, 8))  # Table 1
    n_categories: int = 6          # M4: Demographic..Other, one-hot appended
    tau: float = 0.49              # pinball quantile
    level_penalty: float = 0.0     # section 8.4 (beyond-paper, off by default)
    cstate_penalty: float = 0.0    # section 8.4
    attention: bool = False        # section 7/8.5: Smyl's attentive variant
                                   # (yearly/weekly) -- causal dot-product
                                   # attention over the LSTM hidden sequence
    use_pallas: bool = False       # route HW scan + LSTM cell through kernels
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


# Table 1 presets + the monthly/yearly rows.
PRESETS = {
    "yearly": dict(seasonality=1, input_size=4, output_size=6, hidden_size=30,
                   dilations=((1, 2), (2, 6))),
    "quarterly": dict(seasonality=4, input_size=8, output_size=8, hidden_size=40,
                      dilations=((1, 2), (4, 8))),
    "monthly": dict(seasonality=12, input_size=12, output_size=18, hidden_size=50,
                    dilations=((1, 3), (6, 12))),
    "hourly": dict(seasonality=24, seasonality2=168, input_size=24,
                   output_size=48, hidden_size=40, dilations=((1, 4), (24, 168))),
}


def make_config(name: str, **overrides) -> ESRNNConfig:
    base = dict(PRESETS[name], name=name)
    base.update(overrides)
    return ESRNNConfig(**base)


class ESRNN:
    """Functional model wrapper: ``init`` -> params pytree, pure step fns."""

    def __init__(self, config: ESRNNConfig):
        self.config = config

    # -- params ------------------------------------------------------------

    def init(self, key, n_series: int):
        cfg = self.config
        rnn_key, head_key1, head_key2 = jax.random.split(key, 3)
        feat = cfg.input_size + cfg.n_categories
        hw = hw_init_params(
            n_series, cfg.seasonality, seasonality2=cfg.seasonality2, dtype=cfg.jdtype
        )
        rnn = drnn_init(rnn_key, feat, cfg.hidden_size, cfg.dilations, cfg.jdtype)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
        head = {
            "dense_w": (jax.random.uniform(head_key1, (cfg.hidden_size, cfg.hidden_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
            "dense_b": jnp.zeros((cfg.hidden_size,), cfg.jdtype),
            "out_w": (jax.random.uniform(head_key2, (cfg.hidden_size, cfg.output_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
            "out_b": jnp.zeros((cfg.output_size,), cfg.jdtype),
        }
        params = {"hw": hw, "rnn": rnn, "head": head}
        if cfg.attention:
            ka, kb, kc = jax.random.split(head_key1, 3)
            h = cfg.hidden_size
            params["attn"] = {
                "wq": (jax.random.normal(ka, (h, h)) * scale).astype(cfg.jdtype),
                "wk": (jax.random.normal(kb, (h, h)) * scale).astype(cfg.jdtype),
                "wv": (jax.random.normal(kc, (h, h)) * scale).astype(cfg.jdtype),
            }
        return params

    # -- shared internals ---------------------------------------------------

    def _smooth(self, params, y):
        cfg = self.config
        return hw_smooth(
            y,
            params["hw"],
            seasonality=cfg.seasonality,
            seasonality2=cfg.seasonality2,
            use_pallas=cfg.use_pallas,
        )

    def _windows(self, y, levels, seas):
        """Input/output windows, normalized + de-seasonalized + log (Eq. 6).

        Positions t = W-1 .. T-1. Output windows need y up to t+H, so the
        last H positions have no (complete) target; a position-validity mask
        is returned alongside. Returns:
          feats (N, P, W), out  (N, P, H), out_mask (N, P, H) in {0,1}
        """
        cfg = self.config
        n, t_len = y.shape
        w, h = cfg.input_size, cfg.output_size
        pos = jnp.arange(w - 1, t_len)                       # (P,)
        p = pos.shape[0]

        in_idx = pos[:, None] + jnp.arange(-w + 1, 1)[None, :]     # (P, W)
        out_idx = pos[:, None] + jnp.arange(1, h + 1)[None, :]     # (P, H)
        out_valid = out_idx < t_len                                # (P, H)
        out_idx_c = jnp.minimum(out_idx, t_len - 1)

        y_in = y[:, in_idx]                                   # (N, P, W)
        s_in = seas[:, in_idx]
        lvl = levels[:, pos]                                  # (N, P)
        x_in = jnp.log(jnp.maximum(y_in / (lvl[:, :, None] * s_in), 1e-8))

        y_out = y[:, out_idx_c]                               # (N, P, H)
        # seasonality for t+1..t+H: seas has T+m entries; clamp + cyclic tile
        # is handled by indexing into the (N, T+m) array -- indices t+k with
        # k <= H. For H > m beyond T they would run past T+m; clamp into the
        # last season cyclically.
        m = max(cfg.seasonality, 1)
        s_idx = jnp.where(
            out_idx < t_len + m,
            out_idx,
            t_len + jnp.mod(out_idx - t_len, m),
        )
        s_out = seas[:, s_idx]
        y_out_n = jnp.log(jnp.maximum(y_out / (lvl[:, :, None] * s_out), 1e-8))
        out_mask = out_valid[None, :, :].astype(y.dtype) * jnp.ones((n, 1, 1), y.dtype)
        return x_in, y_out_n, out_mask, pos

    def _rnn_head(self, params, feats):
        cfg = self.config
        hid, c_sq = drnn_apply(
            params["rnn"], feats, dilations=cfg.dilations, use_pallas=cfg.use_pallas
        )
        if cfg.attention:
            ap = params["attn"]
            q = hid @ ap["wq"]
            k = hid @ ap["wk"]
            v = hid @ ap["wv"]
            s = jnp.einsum("nph,nqh->npq", q, k) / jnp.sqrt(
                jnp.asarray(cfg.hidden_size, jnp.float32)).astype(hid.dtype)
            p_idx = jnp.arange(hid.shape[1])
            mask = p_idx[:, None] >= p_idx[None, :]
            s = jnp.where(mask[None], s.astype(jnp.float32), -jnp.inf)
            hid = hid + jnp.einsum(
                "npq,nqh->nph", jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
        head = params["head"]
        z = jnp.tanh(hid @ head["dense_w"] + head["dense_b"])
        return z @ head["out_w"] + head["out_b"], c_sq

    def _features(self, x_in, cats):
        n, p, _ = x_in.shape
        cat_feat = jnp.broadcast_to(cats[:, None, :], (n, p, cats.shape[-1]))
        return jnp.concatenate([x_in, cat_feat.astype(x_in.dtype)], axis=-1)

    # -- public API ----------------------------------------------------------

    @partial(jax.jit, static_argnames=("self",))
    def loss_fn(self, params, y, cats):
        """Training loss on series y (N, T) with category one-hots (N, C)."""
        cfg = self.config
        levels, seas = self._smooth(params, y)
        x_in, y_out_n, out_mask, _pos = self._windows(y, levels, seas)
        feats = self._features(x_in, cats)
        yhat_n, c_sq = self._rnn_head(params, feats)
        loss = L.pinball_loss(yhat_n, y_out_n, tau=cfg.tau, mask=out_mask)
        loss = loss + L.level_variability_penalty(levels, cfg.level_penalty)
        loss = loss + L.cstate_penalty(c_sq, cfg.cstate_penalty)
        return loss

    @partial(jax.jit, static_argnames=("self",))
    def forecast(self, params, y, cats):
        """h-step forecast from the end of y: (N, H), de-normalized (3.4)."""
        cfg = self.config
        n, t_len = y.shape
        levels, seas = self._smooth(params, y)
        x_in, _, _, _pos = self._windows(y, levels, seas)
        feats = self._features(x_in, cats)
        yhat_n, _ = self._rnn_head(params, feats)
        last = yhat_n[:, -1, :]                              # (N, H) log-space
        s_fut = extend_seasonality(seas, t_len, cfg.output_size, cfg.seasonality)
        return jnp.exp(last) * levels[:, -1:][:, :] * s_fut

    def loss_and_grad(self, params, y, cats):
        return jax.value_and_grad(lambda p: self.loss_fn(p, y, cats))(params)


# ---------------------------------------------------------------------------
# Per-series loop reference (the structure the paper vectorized away)
# ---------------------------------------------------------------------------


def esrnn_loss_loop_reference(model: ESRNN, params, y, cats) -> jax.Array:
    """Compute the same loss one series at a time (batch of 1 each).

    Used by the equivalence test and the Table-5 speedup benchmark: identical
    math, but the series axis is a python loop as in Smyl's original C++.
    """
    n = y.shape[0]
    tree = jax.tree_util.tree_map

    losses = []
    for i in range(n):
        p_i = {k: (tree(lambda a: a[i : i + 1], v) if k == "hw" else v)
               for k, v in params.items()}
        losses.append(model.loss_fn(p_i, y[i : i + 1], cats[i : i + 1]))
    return jnp.mean(jnp.stack(losses))
