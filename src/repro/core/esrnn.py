"""The hybrid ES-RNN model (paper section 3, Eqs. 5-6) as pure functions.

Dataflow per training step, all batched over the series axis (the paper's
contribution):

  y (N, T) --hw_smooth--> levels (N, T), seas (N, T+m)
     |                                     |
     +--window/normalize/deseason/log (Eq. 6, Fig. 2)
     |        x[t] = log( y[t-W+1..t] / (l_t * s[t-W+1..t]) )
     v
  features (N, P, W + n_cat)  [P = valid window positions; one-hot category]
     |--dilated residual LSTM (Table 1) -> tanh dense -> linear
     v
  yhat_norm (N, P, H)   (de-seasonalized, normalized log-space predictions)
  loss = pinball(yhat_norm, out_window_norm) + section-8.4 penalties

Forecast (paper section 3.4 / Eq. 5):
  yhat_{T+1..T+h} = exp(rnn_last) * l_T * s_{T+1..T+h}

The per-series HW parameters and shared RNN weights are trained *jointly*
(one optimizer, two param groups with different learning rates).

The module exposes an estimator-friendly functional API:

  ``esrnn_init(key, cfg, n_series)``      -> params pytree
  ``esrnn_loss(cfg, params, y, cats)``    -> scalar training loss
  ``esrnn_forecast(cfg, params, y, cats)``-> (N, H) de-normalized forecast
  ``esrnn_forecast_at(cfg, params, y, cats, origins)`` -> (N, K, H)
  ``esrnn_loss_and_grad(cfg, params, y, cats)``

``repro.forecast.ESRNNForecaster`` wraps these. The smoothing / window /
seasonal-extension math itself lives in :mod:`repro.core.forward` -- ONE
state-space forward pass (:func:`~repro.core.forward.esrnn_states`) feeds
both the loss and every forecast path, so the two can never drift apart.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import forward as F
from repro.core import heads as H
from repro.core.holt_winters import hw_init_params


@dataclasses.dataclass(frozen=True)
class ESRNNConfig:
    """Frequency-specific ES-RNN hyperparameters (paper Tables 1 and text)."""

    name: str = "quarterly"
    seasonality: int = 4
    seasonality2: int = 0          # section 8.2 (e.g. hourly: 24 and 168)
    input_size: int = 8            # input window W (heuristic, section 3.1)
    output_size: int = 8           # forecast horizon H
    hidden_size: int = 40          # Table 1
    dilations: Tuple[Tuple[int, ...], ...] = ((1, 2), (4, 8))  # Table 1
    n_categories: int = 6          # M4: Demographic..Other, one-hot appended
    tau: float = 0.49              # pinball quantile
    level_penalty: float = 0.0     # section 8.4 (beyond-paper, off by default)
    cstate_penalty: float = 0.0    # section 8.4
    attention: bool = False        # section 7/8.5: Smyl's attentive variant
                                   # (yearly/weekly) -- causal dot-product
                                   # attention over the LSTM hidden sequence
    use_pallas: bool = False       # route HW scan + LSTM cell through kernels
    head: str = "lstm"             # repro.core.heads registry name: the
                                   # network between the Eq.-6 windows and
                                   # the Eq.-5 de-normalization ("lstm" --
                                   # the paper's head, "esn", "ssm", ...)
    dtype: str = "float32"
    precision: str = "fp32"        # compute policy: "fp32" | "bf16". Master
                                   # params, the per-series HW table, Adam
                                   # moments and the masked-mean loss
                                   # reduction always stay in ``dtype``;
                                   # "bf16" streams activations and shared
                                   # weights through the heads/kernels in
                                   # bfloat16 with fp32 dot accumulators.

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def compute_dtype(self):
        """Dtype activations/shared weights are cast to inside the forward."""
        if self.precision == "bf16":
            return jnp.dtype(jnp.bfloat16)
        if self.precision == "fp32":
            return jnp.dtype(self.dtype)
        raise ValueError(
            f"unknown precision policy {self.precision!r} (want fp32|bf16)")


# Table 1 presets + the monthly/yearly rows.
PRESETS = {
    "yearly": dict(seasonality=1, input_size=4, output_size=6, hidden_size=30,
                   dilations=((1, 2), (2, 6))),
    "quarterly": dict(seasonality=4, input_size=8, output_size=8, hidden_size=40,
                      dilations=((1, 2), (4, 8))),
    "monthly": dict(seasonality=12, input_size=12, output_size=18, hidden_size=50,
                    dilations=((1, 3), (6, 12))),
    "hourly": dict(seasonality=24, seasonality2=168, input_size=24,
                   output_size=48, hidden_size=40, dilations=((1, 4), (24, 168))),
}


def make_config(name: str, **overrides) -> ESRNNConfig:
    base = dict(PRESETS[name], name=name)
    base.update(overrides)
    return ESRNNConfig(**base)


# ---------------------------------------------------------------------------
# Pure init
# ---------------------------------------------------------------------------


def esrnn_init(key, cfg: ESRNNConfig, n_series: int):
    """Initialize the params pytree: {"hw": HWParams, <head subtrees>}.

    The ``hw`` subtree is the per-series table (leading axis N); everything
    else is shared across series and comes from the config's head
    (:mod:`repro.core.heads` -- ``"rnn"``/``"head"``(/``"attn"``) for the
    paper's lstm head, head-specific keys otherwise). The lstm head consumes
    ``key`` exactly as the pre-registry init did, so fitted checkpoints and
    the bit-for-bit goldens are unaffected.
    """
    hw = hw_init_params(
        n_series, cfg.seasonality, seasonality2=cfg.seasonality2, dtype=cfg.jdtype
    )
    return {"hw": hw, **H.get_head(cfg.head).init(cfg, key)}


# ---------------------------------------------------------------------------
# Pure public apply functions (all consume the repro.core.forward core)
# ---------------------------------------------------------------------------


def esrnn_loss_terms_fn(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Per-batch loss *terms*: ``(pinball_sum, valid_count, penalties)``.

    One :func:`repro.core.forward.esrnn_states` pass scored by
    :func:`repro.core.forward.loss_terms`. The decomposed form exists for
    exact distributed reduction: the sharded loss
    (``repro.sharding.series.esrnn_loss_dp``) psums the masked pin-ball
    numerator and denominator across shards and divides once globally, which
    matches the single-device masked mean even when shards carry unequal
    valid-target counts (``variable_length`` data). ``penalties`` is the sum
    of the section-8.4 terms, whose reductions are over equal-shaped
    per-shard tensors (a pmean of them is already exact).
    """
    states = F.esrnn_states(cfg, params, y, cats)
    return F.loss_terms(cfg, states, y, mask)


def esrnn_loss_fn(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Unjitted loss body -- the batch-shardable entry point.

    Every operation is elementwise or reduces over the batch's own rows, so
    the function can run per-shard inside ``shard_map`` (see
    ``repro.sharding.series.esrnn_loss_dp``, which reduces the decomposed
    :func:`esrnn_loss_terms_fn` exactly). Use :func:`esrnn_loss` (the jitted
    wrapper) everywhere else.
    """
    pin_sum, pin_cnt, penalties = esrnn_loss_terms_fn(cfg, params, y, cats, mask)
    return pin_sum / jnp.maximum(pin_cnt, 1.0) + penalties


@partial(jax.jit, static_argnames=("cfg",))
def esrnn_loss(cfg: ESRNNConfig, params, y, cats, mask=None):
    """Training loss on series y (N, T) with category one-hots (N, C).

    ``mask`` (N, T), optional: 1 where y is a real observation, 0 on the
    left-padding of variable-length series (``data.pipeline`` section-8.1
    convention). Window positions whose input window overlaps padding are
    excluded from the loss; with left-padding a window [t-W+1..t] is fully
    real iff its first element is (the mask is 0..0 1..1). ``None`` (the
    equalized default) is bit-identical to an all-ones mask.
    """
    return esrnn_loss_fn(cfg, params, y, cats, mask)


def esrnn_forecast_fn(cfg: ESRNNConfig, params, y, cats):
    """Unjitted forecast body -- the batch-shardable entry point.

    Like :func:`esrnn_loss_fn`, every operation is elementwise or reduces
    over the batch's own rows, so the function runs per-shard inside
    ``shard_map`` (see ``repro.sharding.series.esrnn_forecast_dp``). Use
    :func:`esrnn_forecast` (the jitted wrapper) everywhere else.
    """
    states = F.esrnn_states(cfg, params, y, cats)
    return F.forecast_from_states(cfg, states, y.shape[1])


@partial(jax.jit, static_argnames=("cfg",))
def esrnn_forecast(cfg: ESRNNConfig, params, y, cats):
    """h-step forecast from the end of y: (N, H), de-normalized (3.4).

    Shares the exact state-space machinery of :func:`esrnn_loss` -- both
    read the single :func:`repro.core.forward.esrnn_states` pass; the future
    seasonality uses the same cyclic :func:`repro.core.forward.
    future_seasonal_idx` rule applied at the final position T-1 (indices
    T..T+H-1).
    """
    return esrnn_forecast_fn(cfg, params, y, cats)


def esrnn_forecast_at_fn(cfg: ESRNNConfig, params, y, cats,
                         origins: Tuple[int, ...]):
    """Unjitted rolling-origin forecast body: (N, K, H), batch-shardable.

    ``origins[k]`` is an observation count ``o``: the k-th forecast equals
    ``esrnn_forecast(cfg, params, y[:, :o], cats)`` but all K origins come
    from ONE forward pass (the state-space core is causal, so the ES states
    at each origin are already the re-primed truncated-history states).
    """
    states = F.esrnn_states(cfg, params, y, cats)
    return F.forecast_at_origins(cfg, states, tuple(origins), y.shape[1])


@partial(jax.jit, static_argnames=("cfg", "origins"))
def esrnn_forecast_at(cfg: ESRNNConfig, params, y, cats,
                      origins: Tuple[int, ...]):
    """Jitted rolling-origin forecasts (the backtest workhorse): (N, K, H)."""
    return esrnn_forecast_at_fn(cfg, params, y, cats, origins)


def esrnn_predict_stats_fn(cfg: ESRNNConfig, params, y, cats):
    """Point forecast + per-series quantile sigma off one forward pass.

    Returns ``(fc (N, H), sigma (N, 1))``; the quantile-band spread comes
    from the same :func:`repro.core.forward.esrnn_states` the forecast
    reads (no second smoothing pass). Batch-shardable like
    :func:`esrnn_forecast_fn`.
    """
    states = F.esrnn_states(cfg, params, y, cats)
    return (F.forecast_from_states(cfg, states, y.shape[1]),
            F.quantile_sigma(states, y))


@partial(jax.jit, static_argnames=("cfg",))
def esrnn_predict_stats(cfg: ESRNNConfig, params, y, cats):
    """Jitted :func:`esrnn_predict_stats_fn` (the predict_quantiles path)."""
    return esrnn_predict_stats_fn(cfg, params, y, cats)


def esrnn_loss_and_grad(cfg: ESRNNConfig, params, y, cats, mask=None):
    return jax.value_and_grad(
        lambda p: esrnn_loss(cfg, p, y, cats, mask))(params)


def gather_series(params, idx):
    """Per-series row gather: hw rows at ``idx``, shared weights untouched.

    The gradient scatter back to the full table happens automatically
    through the indexing when differentiated (used by the trainer and the
    serving path). Note the scattered gradient is a dense zero-padded
    (N, ...) table; the sparse-optimizer path avoids it by differentiating
    w.r.t. the gathered rows directly (see :func:`partition_series` and
    ``repro.train.engine``).
    """
    return {k: (jax.tree_util.tree_map(lambda a: a[idx], v) if k == "hw" else v)
            for k, v in params.items()}


def partition_series(params, idx):
    """Split params into (gathered per-series rows, shared weights).

    ``hw_rows`` is the per-series subtree gathered at ``idx`` (leaves
    (B, ...)); ``shared`` is everything else, untouched. Differentiating a
    loss w.r.t. ``hw_rows`` yields *per-row* gradients -- no zero-padded
    scatter over the full table -- which is what the sparse segment
    optimizer (``adam_update_sparse``) consumes.
    """
    hw_rows = jax.tree_util.tree_map(lambda a: a[idx], params["hw"])
    shared = {k: v for k, v in params.items() if k != "hw"}
    return hw_rows, shared


def combine_series(hw_rows, shared):
    """Inverse of :func:`partition_series` (batch-rows params tree)."""
    return {"hw": hw_rows, **shared}


# ---------------------------------------------------------------------------
# Per-series loop reference (the structure the paper vectorized away)
# ---------------------------------------------------------------------------


def esrnn_loss_loop_reference(cfg: ESRNNConfig, params, y, cats) -> jax.Array:
    """Compute the same loss one series at a time (batch of 1 each).

    Used by the equivalence test and the Table-5 speedup benchmark: identical
    math, but the series axis is a python loop as in Smyl's original C++.
    """
    n = y.shape[0]
    losses = []
    for i in range(n):
        p_i = gather_series(params, slice(i, i + 1))
        losses.append(esrnn_loss(cfg, p_i, y[i : i + 1], cats[i : i + 1]))
    return jnp.mean(jnp.stack(losses))
