"""Pluggable forecasting heads behind ``ESRNNConfig.head``.

The ES-RNN forward core (:mod:`repro.core.forward`) is a fixed
deseasonalization pipeline -- Holt-Winters smoothing, Eq.-6 normalized
windows, Eq.-5 de-normalization -- around one learned component: the network
that maps the windowed features ``(N, P, W + C)`` to normalized log-space
predictions ``(N, P, H)``. This module makes that component a *protocol*:

    HeadSpec(
        init(cfg, key)           -> non-hw params subtree(s),
        apply(cfg, params, feats)-> (yhat_n (N, P, H), c_sq scalar),
        frozen                   -> top-level param keys excluded from
                                    training (closed over by the step fn),
    )

Every loss / forecast / backtest / serving path dispatches through
``get_head(cfg.head).apply`` inside ``forward.esrnn_states``, so a new head
is a one-file change: implement the protocol, ``register_head`` it, and the
whole estimator + CLI + sharding + serving surface picks it up.

Three heads ship:

* ``lstm`` -- the paper's dilated residual LSTM (+ optional causal
  attention) followed by the tanh-dense + linear readout. This is the exact
  pre-registry math, bit-for-bit (the golden tests in
  ``tests/core/test_forward.py`` pin it against frozen reference copies).
* ``esn`` -- an echo-state head: the *same* dilated recurrent stack, but as
  a fixed random reservoir (``frozen={"rnn"}``); only the dense readout
  (and, as always, the per-series HW table) trains. Per the M4 ESN
  benchmarking line of work, reservoirs are competitive at a fraction of
  the fit cost -- here the training step closes over the reservoir weights,
  so the backward pass skips every reservoir weight-gradient matmul.
* ``ssm`` -- a state-space head reusing :func:`repro.models.ssm.ssd_chunked`
  (the Mamba2 SSD chunked scan) over the window-position axis. Causal by
  construction (masked intra-chunk quadratic + inter-chunk recurrence), so
  rolling-origin backtests off one pass remain sound.

Every head keeps the trained readout under the ``"head"`` key and stores no
per-series state outside ``"hw"`` -- the sharding specs (hw sharded,
everything else replicated), the serving table snapshot, and the checkpoint
templates are head-agnostic by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Tuple

import jax
import jax.numpy as jnp

from repro.core.drnn import drnn_apply, drnn_init
from repro.models.ssm import ssd_chunked

__all__ = [
    "HeadSpec", "register_head", "get_head", "available_heads",
    "frozen_param_groups", "lstm_head_init", "lstm_head_apply",
    "esn_head_init", "esn_head_apply", "ssm_head_init", "ssm_head_apply",
]


@dataclasses.dataclass(frozen=True)
class HeadSpec:
    """One pluggable head: init/apply plus its trainability declaration.

    ``init(cfg, key)`` returns the head's param subtrees as a dict of
    top-level keys (never ``"hw"`` -- the per-series table belongs to the
    smoothing layer). ``apply(cfg, params, feats)`` maps features
    ``(N, P, W + C)`` to ``(yhat_n (N, P, H), c_sq scalar)`` and must be
    causal along P. ``frozen`` names the top-level param keys the training
    engines exclude from differentiation and optimizer state.
    """

    name: str
    init: Callable
    apply: Callable
    frozen: FrozenSet[str] = frozenset()


_HEADS: Dict[str, HeadSpec] = {}


def register_head(spec: HeadSpec) -> HeadSpec:
    """Add a head to the registry (last registration of a name wins)."""
    _HEADS[spec.name] = spec
    return spec


def available_heads() -> Tuple[str, ...]:
    return tuple(sorted(_HEADS))


def get_head(name: str) -> HeadSpec:
    try:
        return _HEADS[name]
    except KeyError:
        raise KeyError(
            f"unknown forecasting head {name!r}; available heads: "
            f"{list(available_heads())}") from None


def frozen_param_groups(cfg) -> FrozenSet[str]:
    """Top-level param keys the config's head declares untrainable."""
    return get_head(cfg.head).frozen


# ---------------------------------------------------------------------------
# Shared readout: tanh dense -> linear (all heads end here)
# ---------------------------------------------------------------------------


def _policy_cast(tree, dtype):
    """Cast a shared-weight subtree's float leaves to the compute dtype.

    Identity under the fp32 policy (master weights already are the state
    dtype); under bf16 this cast is where the half-width weight tiles come
    from -- gradients flow back through it and arrive in fp32 for the
    optimizer, so the master weights and Adam moments never round.
    """
    if jnp.dtype(dtype) == jnp.float32:
        return tree
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def _readout_init(cfg, key1, key2):
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
    return {
        "dense_w": (jax.random.uniform(key1, (cfg.hidden_size, cfg.hidden_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
        "dense_b": jnp.zeros((cfg.hidden_size,), cfg.jdtype),
        "out_w": (jax.random.uniform(key2, (cfg.hidden_size, cfg.output_size), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
        "out_b": jnp.zeros((cfg.output_size,), cfg.jdtype),
    }


def _readout_apply(params, hid):
    # fp32 dot accumulators regardless of the compute dtype; the final
    # linear re-emits yhat_n in fp32 (tiny tensor), so the loss reduction
    # and Eq.-5 exp downstream never see bf16 rounding. Bit-identical to
    # the pre-policy math under fp32.
    head = _policy_cast(params["head"], hid.dtype)
    # cast the fp32-accumulated pre-activation back to the stream dtype
    # *before* the pointwise tanh, so the nonlinearity (and its backward
    # mul chain) runs at stream precision; no-op under fp32
    z = jnp.tanh((
        jnp.dot(hid, head["dense_w"], preferred_element_type=jnp.float32)
        + head["dense_b"].astype(jnp.float32)).astype(hid.dtype))
    return (jnp.dot(z, head["out_w"], preferred_element_type=jnp.float32)
            + head["out_b"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# lstm: the paper's dilated residual LSTM (+ attention) head
# ---------------------------------------------------------------------------
#
# Key-consumption order and every init expression are the pre-registry
# ``esrnn_init`` body verbatim (minus the hw table), and the apply is the
# pre-registry ``forward.rnn_head`` verbatim -- the goldens in
# tests/core/test_forward.py assert bit-for-bit equality, no tolerance.


def lstm_head_init(cfg, key):
    rnn_key, head_key1, head_key2 = jax.random.split(key, 3)
    feat = cfg.input_size + cfg.n_categories
    rnn = drnn_init(rnn_key, feat, cfg.hidden_size, cfg.dilations, cfg.jdtype)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
    params = {"rnn": rnn, "head": _readout_init(cfg, head_key1, head_key2)}
    if cfg.attention:
        ka, kb, kc = jax.random.split(head_key1, 3)
        h = cfg.hidden_size
        params["attn"] = {
            "wq": (jax.random.normal(ka, (h, h)) * scale).astype(cfg.jdtype),
            "wk": (jax.random.normal(kb, (h, h)) * scale).astype(cfg.jdtype),
            "wv": (jax.random.normal(kc, (h, h)) * scale).astype(cfg.jdtype),
        }
    return params


def lstm_head_apply(cfg, params, feats):
    """Dilated residual LSTM -> (attention) -> tanh dense -> linear head.

    ``feats`` arrives in the policy's compute dtype; the recurrent stack and
    attention weights are cast to match (:func:`_policy_cast`), with the
    attention scores accumulated in fp32 so the softmax stays full
    precision under bf16.
    """
    hid, c_sq = drnn_apply(
        _policy_cast(params["rnn"], feats.dtype), feats,
        dilations=cfg.dilations, use_pallas=cfg.use_pallas
    )
    if cfg.attention:
        ap = _policy_cast(params["attn"], feats.dtype)
        q = hid @ ap["wq"]
        k = hid @ ap["wk"]
        v = hid @ ap["wv"]
        s = jnp.einsum(
            "nph,nqh->npq", q, k, preferred_element_type=jnp.float32
        ) / jnp.sqrt(jnp.asarray(cfg.hidden_size, jnp.float32))
        p_idx = jnp.arange(hid.shape[1])
        mask = p_idx[:, None] >= p_idx[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
        hid = hid + jnp.einsum(
            "npq,nqh->nph", jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
    return _readout_apply(params, hid), c_sq


# ---------------------------------------------------------------------------
# esn: fixed random reservoir (the same dilated stack), trained readout only
# ---------------------------------------------------------------------------


def esn_head_init(cfg, key):
    """Reservoir = the dilated recurrent stack, frozen; readout trains.

    Reuses ``drnn_init`` unchanged -- the LSTM gates are contractive
    (sigmoid/tanh), so the 1/sqrt(fan-in) uniform init gives a stable
    fading-memory reservoir without an explicit spectral-radius rescale.
    The attention flag is ignored: an attention layer is a trained
    component, which is exactly what this head omits.
    """
    rnn_key, head_key1, head_key2 = jax.random.split(key, 3)
    feat = cfg.input_size + cfg.n_categories
    rnn = drnn_init(rnn_key, feat, cfg.hidden_size, cfg.dilations, cfg.jdtype)
    return {"rnn": rnn, "head": _readout_init(cfg, head_key1, head_key2)}


def esn_head_apply(cfg, params, feats):
    """Frozen reservoir pass -> tanh dense -> linear readout.

    Identical forward math to the lstm head without attention; the
    difference is entirely in training (``frozen={"rnn"}``: the engines
    close over the reservoir, so no reservoir weight gradients are ever
    computed -- the dx path through it still runs because the per-series
    HW params sit upstream of the windows).
    """
    hid, c_sq = drnn_apply(
        _policy_cast(params["rnn"], feats.dtype), feats,
        dilations=cfg.dilations, use_pallas=cfg.use_pallas
    )
    return _readout_apply(params, hid), c_sq


# ---------------------------------------------------------------------------
# ssm: Mamba2 SSD chunked scan over the window positions
# ---------------------------------------------------------------------------

_SSM_STATE = 8     # per-head state size N of the SSD recurrence
_SSM_CHUNK = 32    # positions per intra-chunk quadratic block


def ssm_dims(cfg) -> Tuple[int, int]:
    """(nheads, headdim) for the SSD scan, derived from ``hidden_size``.

    The largest divisor of ``hidden_size`` that is at most
    ``hidden_size // 8`` (so headdim >= 8), floored at one head -- every
    preset (30/40/50-wide and the 8-wide smoke) gets an exact split.
    """
    hid = cfg.hidden_size
    nheads = max(d for d in range(1, max(1, hid // 8) + 1) if hid % d == 0)
    return nheads, hid // nheads


def ssm_head_init(cfg, key):
    in_key, head_key1, head_key2 = jax.random.split(key, 3)
    feat = cfg.input_size + cfg.n_categories
    nheads, _ = ssm_dims(cfg)
    scale = 1.0 / jnp.sqrt(jnp.asarray(feat, jnp.float32))
    # order: [x (hidden), B (N), C (N), dt (nheads)]; a_log=0 -> A=-1 and
    # dt_bias=0 -> dt ~ softplus(0) give a ~0.5/step decay at init
    ssm = {
        "w_in": (jax.random.uniform(in_key, (feat, cfg.hidden_size + 2 * _SSM_STATE + nheads), jnp.float32, -1, 1) * scale).astype(cfg.jdtype),
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
    }
    return {"ssm": ssm, "head": _readout_init(cfg, head_key1, head_key2)}


def ssm_head_apply(cfg, params, feats):
    """Linear proj -> SSD chunked scan over positions -> shared readout.

    The position axis P plays the SSD time axis; the scan is causal
    (masked intra-chunk scores, inter-chunk fp32 recurrence), so the
    forward core's rolling-origin contract holds. P is padded to a chunk
    multiple with dt = 0 -- a no-op step (decay exp(0)=1, update 0), so
    the padding is exact, the same trick as ``repro.models.ssm.ssm_apply``.
    """
    n, t, _ = feats.shape
    hid = cfg.hidden_size
    nheads, headdim = ssm_dims(cfg)
    sp = params["ssm"]
    cdt = feats.dtype
    # input projection: compute-dtype weight tile, fp32 accumulator; the
    # x/B/C streams drop back to the compute dtype for the SSD scan while
    # the dt gate and the decay/bias/skip params stay fp32 (they set the
    # recurrence's stability, the state-dtype part of the policy).
    proj = jnp.dot(feats, _policy_cast(sp["w_in"], cdt),
                   preferred_element_type=jnp.float32)
    x = proj[..., :hid].astype(cdt).reshape(n, t, nheads, headdim)
    bb = proj[..., hid:hid + _SSM_STATE].astype(cdt).reshape(
        n, t, 1, _SSM_STATE)
    cc = proj[..., hid + _SSM_STATE:hid + 2 * _SSM_STATE].astype(cdt).reshape(
        n, t, 1, _SSM_STATE)
    dt = jax.nn.softplus(
        proj[..., hid + 2 * _SSM_STATE:].astype(jnp.float32) + sp["dt_bias"])
    a = -jnp.exp(sp["a_log"])

    q = min(_SSM_CHUNK, t)
    pad = (-t) % q
    if pad:
        padt = lambda z: jnp.pad(
            z, ((0, 0), (0, pad)) + ((0, 0),) * (z.ndim - 2))
        xp, bp, cp, dp = padt(x), padt(bb), padt(cc), padt(dt)
    else:
        xp, bp, cp, dp = x, bb, cc, dt
    y, _ = ssd_chunked(xp, dp, a, bp, cp, chunk=q)
    y = y[:, :t] + sp["d_skip"].astype(y.dtype)[None, None, :, None] * x
    hidseq = y.reshape(n, t, hid)
    # the ssm analog of the LSTM cell-state penalty term: mean squared
    # pre-readout state magnitude (same stabilization role, section 8.4)
    c_sq = jnp.mean(jnp.square(hidseq.astype(jnp.float32)))
    return _readout_apply(params, hidseq), c_sq


register_head(HeadSpec("lstm", lstm_head_init, lstm_head_apply))
register_head(HeadSpec("esn", esn_head_init, esn_head_apply,
                       frozen=frozenset({"rnn"})))
register_head(HeadSpec("ssm", ssm_head_init, ssm_head_apply))
