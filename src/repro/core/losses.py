"""Loss and metric functions (paper sections 3.5 and 8.4).

* Pin-ball (quantile) loss -- the differentiable surrogate used for training
  (Takeuchi et al. 2006; Smyl used tau slightly below 0.5).
* sMAPE / MASE -- the (non-differentiable) M4 competition metrics, plus OWA.
* Section 8.4 penalties: level-variability penalty and hidden/cell-state
  magnitude penalty (Krueger & Memisevic) -- the "additional penalization"
  the paper lists as future work; implemented here as first-class options.
"""

from __future__ import annotations

import jax.numpy as jnp


def pinball_loss(pred, target, tau: float = 0.49, mask=None):
    """Mean pin-ball loss. pred/target broadcastable; mask 1=keep."""
    diff = target - pred
    loss = jnp.maximum(tau * diff, (tau - 1.0) * diff)
    if mask is None:
        return jnp.mean(loss)
    num, den = pinball_terms(pred, target, tau=tau, mask=mask)
    return num / jnp.maximum(den, 1.0)


def pinball_terms(pred, target, tau: float = 0.49, mask=None):
    """Masked pin-ball numerator and denominator: ``(sum, valid_count)``.

    The building block for *exact* distributed masked means: psum the two
    terms across shards and divide once globally
    (``repro.sharding.series.esrnn_loss_dp``), instead of averaging
    per-shard means -- the two only agree when every shard has the same
    valid-target count. ``pinball_loss(mask=...)`` is exactly
    ``sum / max(count, 1)`` of these terms.
    """
    diff = target - pred
    loss = jnp.maximum(tau * diff, (tau - 1.0) * diff)
    if mask is None:
        return jnp.sum(loss), jnp.asarray(loss.size, loss.dtype)
    mask = jnp.broadcast_to(mask, loss.shape)
    return jnp.sum(loss * mask), jnp.sum(mask)


def smape(pred, target, mask=None, axis=None):
    """Symmetric MAPE in percent, the M4 headline metric.

    sMAPE = 200/h * sum |y - yhat| / (|y| + |yhat|)
    """
    num = jnp.abs(target - pred)
    den = jnp.abs(target) + jnp.abs(pred)
    ratio = jnp.where(den > 0, num / den, 0.0)
    if mask is not None:
        mask = jnp.broadcast_to(mask, ratio.shape)
        return 200.0 * jnp.sum(ratio * mask, axis=axis) / jnp.maximum(
            jnp.sum(mask, axis=axis), 1.0
        )
    return 200.0 * jnp.mean(ratio, axis=axis)


def smape_terms(pred, target, mask=None):
    """sMAPE numerator and denominator: ``(ratio_sum, valid_count)``.

    ``smape == 200 * ratio_sum / max(valid_count, 1)``. Like
    :func:`pinball_terms`, this is the building block for *exact*
    distributed metric means: each shard contributes its sum and count,
    both are psum'd, and the division happens once globally
    (``repro.sharding.series.esrnn_eval_dp``) -- exact even when shards
    carry unequal valid-target counts (padded rows, ragged horizons).
    """
    num = jnp.abs(target - pred)
    den = jnp.abs(target) + jnp.abs(pred)
    ratio = jnp.where(den > 0, num / den, 0.0)
    if mask is None:
        return jnp.sum(ratio), jnp.asarray(ratio.size, ratio.dtype)
    mask = jnp.broadcast_to(mask, ratio.shape)
    return jnp.sum(ratio * mask), jnp.sum(mask)


def mase_terms(pred, target, insample, seasonality: int, mask=None):
    """MASE numerator and denominator: ``(scaled_err_sum, valid_count)``.

    ``mase == scaled_err_sum / max(valid_count, 1)``; same distributed-
    reduction contract as :func:`smape_terms` (the seasonal-naive scale is
    per-series, so it shards trivially with the rows).
    """
    m = _mase_lag(insample, seasonality)
    scale = jnp.mean(jnp.abs(insample[:, m:] - insample[:, :-m]), axis=1)
    scaled = jnp.abs(target - pred) / jnp.maximum(scale[:, None], 1e-8)
    if mask is None:
        return jnp.sum(scaled), jnp.asarray(scaled.size, scaled.dtype)
    mask = jnp.broadcast_to(mask, scaled.shape)
    return jnp.sum(scaled * mask), jnp.sum(mask)


def _mase_lag(insample, seasonality: int) -> int:
    """Scale lag for MASE: the seasonal lag, or 1 when the insample is too
    short for a single seasonal difference (e.g. a backtest origin right at
    the input-window minimum on monthly/hourly data) -- the standard
    short-series fallback; a lag-m mean over an empty axis would be NaN."""
    m = max(seasonality, 1)
    return m if insample.shape[1] > m else 1


def mase(pred, target, insample, seasonality: int, mask=None):
    """Mean Absolute Scaled Error against the seasonal-naive in-sample MAE.

    pred/target: (N, H); insample: (N, T) history used for the scale.
    """
    m = _mase_lag(insample, seasonality)
    scale = jnp.mean(jnp.abs(insample[:, m:] - insample[:, :-m]), axis=1)  # (N,)
    err = jnp.abs(target - pred)  # (N, H)
    scaled = err / jnp.maximum(scale[:, None], 1e-8)
    if mask is not None:
        mask = jnp.broadcast_to(mask, scaled.shape)
        return jnp.sum(scaled * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(scaled)


def rolling_metric_terms(fc, target, tmask, y, origins, seasonality: int):
    """Per-origin sMAPE/MASE terms for rolling-origin backtests.

    fc/target/tmask: (N, K, H) forecasts, scoring windows, and validity
    masks for K origins; y: (N, T) full history (the MASE scale at origin
    ``o`` uses the in-sample prefix ``y[:, :o]``, exactly what a truncated
    forecast would have seen). Returns ``(s_sum, s_cnt, m_sum, m_cnt)``,
    each (K,) -- divide per origin (or over the flattened sums for the
    overall metric); psum the four before dividing for the exact
    distributed mean (``repro.sharding.series.esrnn_backtest_dp``).
    """
    s_sums, s_cnts, m_sums, m_cnts = [], [], [], []
    for k, o in enumerate(origins):
        ss, sc = smape_terms(fc[:, k], target[:, k], mask=tmask[:, k])
        ms, mc = mase_terms(fc[:, k], target[:, k], y[:, :o], seasonality,
                            mask=tmask[:, k])
        s_sums.append(ss); s_cnts.append(sc)
        m_sums.append(ms); m_cnts.append(mc)
    return (jnp.stack(s_sums), jnp.stack(s_cnts),
            jnp.stack(m_sums), jnp.stack(m_cnts))


def owa(smape_model, mase_model, smape_naive2, mase_naive2):
    """Overall Weighted Average relative to Naive2 (the M4 ranking metric)."""
    return 0.5 * (smape_model / smape_naive2 + mase_model / mase_naive2)


def level_variability_penalty(levels, weight: float):
    """Section 8.4: penalize abrupt changes in the log-level *trend*.

    Smyl penalizes the variance of successive differences of log-level
    changes: d_t = log(l_{t+1}/l_t); penalty = mean (d_{t+1} - d_t)^2.
    """
    if weight == 0.0:
        return jnp.zeros(())
    log_l = jnp.log(jnp.maximum(levels, 1e-8))
    d = log_l[:, 1:] - log_l[:, :-1]
    dd = d[:, 1:] - d[:, :-1]
    return weight * jnp.mean(jnp.square(dd))


def cstate_penalty(mean_cstate_sq, weight: float):
    """Section 8.4: Krueger & Memisevic hidden-state stabilization."""
    return weight * mean_cstate_sq
