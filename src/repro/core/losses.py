"""Loss and metric functions (paper sections 3.5 and 8.4).

* Pin-ball (quantile) loss -- the differentiable surrogate used for training
  (Takeuchi et al. 2006; Smyl used tau slightly below 0.5).
* sMAPE / MASE -- the (non-differentiable) M4 competition metrics, plus OWA.
* Section 8.4 penalties: level-variability penalty and hidden/cell-state
  magnitude penalty (Krueger & Memisevic) -- the "additional penalization"
  the paper lists as future work; implemented here as first-class options.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pinball_loss(pred, target, tau: float = 0.49, mask=None):
    """Mean pin-ball loss. pred/target broadcastable; mask 1=keep."""
    diff = target - pred
    loss = jnp.maximum(tau * diff, (tau - 1.0) * diff)
    if mask is None:
        return jnp.mean(loss)
    num, den = pinball_terms(pred, target, tau=tau, mask=mask)
    return num / jnp.maximum(den, 1.0)


def pinball_terms(pred, target, tau: float = 0.49, mask=None):
    """Masked pin-ball numerator and denominator: ``(sum, valid_count)``.

    The building block for *exact* distributed masked means: psum the two
    terms across shards and divide once globally
    (``repro.sharding.series.esrnn_loss_dp``), instead of averaging
    per-shard means -- the two only agree when every shard has the same
    valid-target count. ``pinball_loss(mask=...)`` is exactly
    ``sum / max(count, 1)`` of these terms.
    """
    diff = target - pred
    loss = jnp.maximum(tau * diff, (tau - 1.0) * diff)
    if mask is None:
        return jnp.sum(loss), jnp.asarray(loss.size, loss.dtype)
    mask = jnp.broadcast_to(mask, loss.shape)
    return jnp.sum(loss * mask), jnp.sum(mask)


def smape(pred, target, mask=None, axis=None):
    """Symmetric MAPE in percent, the M4 headline metric.

    sMAPE = 200/h * sum |y - yhat| / (|y| + |yhat|)
    """
    num = jnp.abs(target - pred)
    den = jnp.abs(target) + jnp.abs(pred)
    ratio = jnp.where(den > 0, num / den, 0.0)
    if mask is not None:
        mask = jnp.broadcast_to(mask, ratio.shape)
        return 200.0 * jnp.sum(ratio * mask, axis=axis) / jnp.maximum(
            jnp.sum(mask, axis=axis), 1.0
        )
    return 200.0 * jnp.mean(ratio, axis=axis)


def mase(pred, target, insample, seasonality: int, mask=None):
    """Mean Absolute Scaled Error against the seasonal-naive in-sample MAE.

    pred/target: (N, H); insample: (N, T) history used for the scale.
    """
    m = max(seasonality, 1)
    scale = jnp.mean(jnp.abs(insample[:, m:] - insample[:, :-m]), axis=1)  # (N,)
    err = jnp.abs(target - pred)  # (N, H)
    scaled = err / jnp.maximum(scale[:, None], 1e-8)
    if mask is not None:
        mask = jnp.broadcast_to(mask, scaled.shape)
        return jnp.sum(scaled * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(scaled)


def owa(smape_model, mase_model, smape_naive2, mase_naive2):
    """Overall Weighted Average relative to Naive2 (the M4 ranking metric)."""
    return 0.5 * (smape_model / smape_naive2 + mase_model / mase_naive2)


def level_variability_penalty(levels, weight: float):
    """Section 8.4: penalize abrupt changes in the log-level *trend*.

    Smyl penalizes the variance of successive differences of log-level
    changes: d_t = log(l_{t+1}/l_t); penalty = mean (d_{t+1} - d_t)^2.
    """
    if weight == 0.0:
        return jnp.zeros(())
    log_l = jnp.log(jnp.maximum(levels, 1e-8))
    d = log_l[:, 1:] - log_l[:, :-1]
    dd = d[:, 1:] - d[:, :-1]
    return weight * jnp.mean(jnp.square(dd))


def cstate_penalty(mean_cstate_sq, weight: float):
    """Section 8.4: Krueger & Memisevic hidden-state stabilization."""
    return weight * mean_cstate_sq
