"""Vectorized Holt-Winters exponential smoothing (paper Eqs. 1-4, Smyl variant).

This is the paper's pre-processing layer (section 3.1). The contribution of
Fast ES-RNN is that the per-series smoothing parameters (alpha, gamma, and the
S initial seasonality values -- ``N * (2 + S)`` parameters for N series) live
as *batched tensors* so that the whole recurrence runs vectorized across
series and sits inside the autodiff graph, instead of one series at a time.

Two implementations are provided:

* :func:`hw_smooth` -- ``lax.scan`` over time, vectorized over the series
  axis.  This is the differentiable path used in training.
* :func:`hw_smooth_loop_reference` -- the per-series python-loop formulation
  matching Smyl's original CPU structure.  Kept as the numerical oracle for
  the paper's central claim (vectorized == sequential) and as the slow
  baseline for the Table-5 speedup benchmark.

The Smyl/M4 variant drops the linear trend (Eq. 2 is replaced by the RNN, see
paper section 3.1), leaving

    l_t = alpha * y_t / s_t      + (1 - alpha) * l_{t-1}          (level)
    s_{t+m} = gamma * y_t / l_t  + (1 - gamma) * s_t              (seasonality)

with multiplicative seasonality of period ``m``.  Multiple seasonality
(paper section 8.2, Gould et al. 2008) is supported by a second seasonal ring
with its own period/params; de-seasonalization divides by both.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HWParams:
    """Per-series Holt-Winters parameters (the paper's N*(2+S) parameters).

    All leaves have a leading series axis ``(N, ...)``.  Stored as
    unconstrained logits; constrained values are produced by
    :meth:`constrained`:

      alpha = sigmoid(alpha_logit)          in (0, 1)
      gamma = sigmoid(gamma_logit)          in (0, 1)
      init_seas = exp(init_seas_logit)      > 0   (multiplicative)

    ``init_seas_logit2`` is the optional second seasonality (section 8.2);
    ``None`` when single-seasonal.
    """

    alpha_logit: jax.Array           # (N,)
    gamma_logit: jax.Array           # (N,)
    init_seas_logit: jax.Array       # (N, m)
    gamma2_logit: Optional[jax.Array] = None       # (N,)
    init_seas_logit2: Optional[jax.Array] = None   # (N, m2)

    def constrained(self):
        out = dict(
            alpha=jax.nn.sigmoid(self.alpha_logit),
            gamma=jax.nn.sigmoid(self.gamma_logit),
            init_seas=jnp.exp(self.init_seas_logit),
        )
        if self.init_seas_logit2 is not None:
            out["gamma2"] = jax.nn.sigmoid(self.gamma2_logit)
            out["init_seas2"] = jnp.exp(self.init_seas_logit2)
        return out


def hw_init_params(
    n_series: int,
    seasonality: int,
    *,
    seasonality2: int = 0,
    alpha0: float = 0.5,
    gamma0: float = 0.5,
    dtype=jnp.float32,
) -> HWParams:
    """Primer initialization (paper section 3.3): neutral smoothing
    coefficients and flat (== 1.0) initial seasonality."""

    def logit(p):
        return float(np.log(p / (1.0 - p)))

    m = max(seasonality, 1)
    params = HWParams(
        alpha_logit=jnp.full((n_series,), logit(alpha0), dtype),
        gamma_logit=jnp.full((n_series,), logit(gamma0), dtype),
        init_seas_logit=jnp.zeros((n_series, m), dtype),
    )
    if seasonality2:
        params = dataclasses.replace(
            params,
            gamma2_logit=jnp.full((n_series,), logit(gamma0), dtype),
            init_seas_logit2=jnp.zeros((n_series, seasonality2), dtype),
        )
    return params


# ---------------------------------------------------------------------------
# The one-step recurrence (shared by the scan and the online serving path)
# ---------------------------------------------------------------------------


def hw_step(
    y_t,
    level,
    s_t,
    s2_t,
    alpha,
    gamma,
    gamma2=None,
    *,
    seasonal: bool = True,
    dual: bool = False,
):
    """One Holt-Winters update: ``(l_t, s_new, s2_new)`` from observation y_t.

        l_t     = alpha * y_t / (s_t * s2_t) + (1 - alpha) * l_{t-1}
        s_{t+m} = gamma * y_t / (l_t * s2_t) + (1 - gamma) * s_t
        s2_{t+m2} = gamma2 * y_t / (l_t * s_t) + (1 - gamma2) * s2_t

    This IS the body of the :func:`hw_smooth` scan (extracted, not
    duplicated -- the scan calls it), written in pure arithmetic so it runs
    on jax arrays inside ``lax.scan`` AND on host numpy arrays for the
    forecast server's online ``observe`` path, which rolls each series'
    (level, seasonal-ring) state forward in place as new observations
    arrive -- no refit, no re-pass over history. ``seasonal=False`` holds
    the seasonal factor fixed (m == 1 series); ``dual`` enables the second
    ring (section 8.2). Inputs are scalars or arrays with a common batch
    shape; ring rotation is the caller's job (the new factors returned here
    are s_{t+m} / s2_{t+m2}, to be pushed onto the back of the rings).
    """
    s_all = s_t * s2_t
    l_t = alpha * y_t / s_all + (1.0 - alpha) * level
    s_new = (gamma * y_t / (l_t * s2_t) + (1.0 - gamma) * s_t
             if seasonal else s_t)
    s2_new = (gamma2 * y_t / (l_t * s_t) + (1.0 - gamma2) * s2_t
              if dual else s2_t)
    return l_t, s_new, s2_new


# ---------------------------------------------------------------------------
# Vectorized scan implementation (the paper's contribution)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("seasonality", "seasonality2", "use_pallas"))
def hw_smooth(
    y: jax.Array,
    params: HWParams,
    *,
    seasonality: int,
    seasonality2: int = 0,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Run the batched Holt-Winters recurrence.

    Args:
      y: ``(N, T)`` strictly-positive series values (multiplicative model).
      params: per-series :class:`HWParams`.
      seasonality: period ``m`` (1 => non-seasonal; seasonality fixed at 1.0).
      seasonality2: optional second period (0 => disabled).
      use_pallas: route the recurrence through the Pallas TPU kernel
        (``kernels/hw_scan.py``); only the single-seasonality path has a
        kernel. Numerics are identical (kernel is tested against this path)
        and the kernel is differentiable -- its custom_vjp runs the adjoint
        recurrence time-reversed as a second kernel, so training with
        ``use_pallas=True`` works end-to-end.

    Returns:
      levels: ``(N, T)`` level l_t after observing y_t.
      seas:   ``(N, T + m)`` multiplicative seasonality aligned so that
        ``seas[:, t]`` is s_t, the factor applied to y_t; positions
        ``T .. T+m-1`` are the smoothed future factors. For ``seasonality2``
        the product of both rings is returned (what de-seasonalization uses).
    """
    if use_pallas and seasonality2 == 0:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.hw_scan(y, params, seasonality=seasonality)
    return _hw_smooth_scan(y, params, seasonality, seasonality2)


def _hw_smooth_scan(y, params, seasonality, seasonality2):
    n, t_len = y.shape
    c = params.constrained()
    alpha, gamma = c["alpha"], c["gamma"]
    m = max(seasonality, 1)
    seasonal = seasonality > 1

    # seasonality ring buffer s_{t} .. s_{t+m-1}; index 0 is "current" s_t.
    # Rings live in the *param* dtype (fp32), not y's: under the bf16 policy
    # y streams in half width but the level/seasonality recurrence must
    # accumulate in the state dtype -- each step promotes y_t, so the carry
    # never rounds through bf16.
    seas0 = c["init_seas"] if seasonal else jnp.ones((n, m), alpha.dtype)

    dual = seasonality2 > 1
    if dual:
        m2 = seasonality2
        gamma2 = c["gamma2"]
        seas20 = c["init_seas2"]
    else:
        m2 = 1
        gamma2 = jnp.zeros_like(gamma)
        seas20 = jnp.ones((n, 1), alpha.dtype)

    # initial level: first de-seasonalized observation (primer estimate).
    l0 = y[:, 0] / (seas0[:, 0] * seas20[:, 0])

    def step(carry, y_t):
        l_prev, s_ring, s2_ring = carry
        s_t = s_ring[:, 0]
        s2_t = s2_ring[:, 0]
        l_t, s_new, s2_new = hw_step(
            y_t, l_prev, s_t, s2_t, alpha, gamma, gamma2,
            seasonal=seasonal, dual=dual)
        s_ring = jnp.concatenate([s_ring[:, 1:], s_new[:, None]], axis=1)
        s2_ring = jnp.concatenate([s2_ring[:, 1:], s2_new[:, None]], axis=1)
        return (l_t, s_ring, s2_ring), (l_t, s_t * s2_t)

    (_, s_ring, s2_ring), (levels, seas_used) = jax.lax.scan(
        step, (l0, seas0, seas20), y.T
    )
    levels = levels.T                      # (N, T)
    seas_used = seas_used.T                # (N, T) -- s_t actually applied

    # future factors: remaining ring entries (s_{T} .. s_{T+m-1}); for the
    # dual ring tile the shorter one up to m.
    future = s_ring * jnp.broadcast_to(
        jnp.tile(s2_ring, (1, (m + m2 - 1) // m2))[:, :m], (n, m)
    ) if dual else s_ring
    seas = jnp.concatenate([seas_used, future], axis=1)  # (N, T+m)
    return levels, seas


# ---------------------------------------------------------------------------
# Per-series loop reference (Smyl's original CPU structure)
# ---------------------------------------------------------------------------


def hw_smooth_loop_reference(
    y: np.ndarray, params: HWParams, *, seasonality: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pure-numpy per-series sequential implementation.

    Mirrors the original C++/DyNet structure the paper vectorized: an outer
    loop over series, an inner loop over time. Used (a) as the oracle for the
    equivalence tests and (b) as the slow baseline in the Table-5 speedup
    benchmark.
    """
    y = np.asarray(y, np.float64)
    n, t_len = y.shape
    m = max(seasonality, 1)
    seasonal = seasonality > 1
    alpha = 1.0 / (1.0 + np.exp(-np.asarray(params.alpha_logit, np.float64)))
    gamma = 1.0 / (1.0 + np.exp(-np.asarray(params.gamma_logit, np.float64)))
    init_seas = np.exp(np.asarray(params.init_seas_logit, np.float64))

    levels = np.empty((n, t_len))
    seas = np.empty((n, t_len + m))
    for i in range(n):  # <- the loop the paper removes
        ring = list(init_seas[i] if seasonal else np.ones(m))
        l_prev = y[i, 0] / ring[0]
        for t in range(t_len):
            s_t = ring[0]
            l_t = alpha[i] * y[i, t] / s_t + (1 - alpha[i]) * l_prev
            if seasonal:
                s_new = gamma[i] * y[i, t] / l_t + (1 - gamma[i]) * s_t
            else:
                s_new = s_t
            ring = ring[1:] + [s_new]
            levels[i, t] = l_t
            seas[i, t] = s_t
            l_prev = l_t
        seas[i, t_len:] = ring
    return levels, seas


# ---------------------------------------------------------------------------
# Classic HW forecast (Eq. 4) -- used by the Comb benchmark and primers
# ---------------------------------------------------------------------------


def hw_forecast(
    levels: jax.Array, seas: jax.Array, horizon: int, *, seasonality: int
) -> jax.Array:
    """h-step forecast y_hat_{T+h} = l_T * s_{T+h} (Eq. 4 with b_t == 1).

    ``seas`` is the ``(N, T+m)`` array from :func:`hw_smooth`; future factors
    beyond T+m tile the last season cyclically (how ESRNN-GPU extends them).
    """
    m = max(seasonality, 1)
    last_level = levels[:, -1]                      # (N,)
    last_season = seas[:, -m:]                      # (N, m)
    reps = -(-horizon // m)
    future = jnp.tile(last_season, (1, reps))[:, :horizon]
    return last_level[:, None] * future


def extend_seasonality(seas: jax.Array, t_len: int, horizon: int, seasonality: int):
    """Seasonality factors s_{T+1} .. s_{T+h} for de-normalizing forecasts.

    ``seas`` has valid entries up to index T+m-1; beyond that the last season
    is tiled cyclically (horizon can exceed m, e.g. quarterly h=8 > m=4).
    """
    m = max(seasonality, 1)
    if horizon <= m:
        return seas[:, t_len : t_len + horizon]
    last_season = seas[:, t_len : t_len + m]
    reps = -(-horizon // m)
    return jnp.tile(last_season, (1, reps))[:, :horizon]
