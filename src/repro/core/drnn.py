"""Dilated residual LSTM stack (paper section 3.2, Table 1, Figure 1).

Structure (Chang et al., Dilated RNN): blocks of LSTM layers; the layer with
dilation ``d`` connects cell/hidden state from step ``t - d`` to step ``t``.
Blocks after the first add a residual connection from block input to block
output (dimensions match at ``hidden_size``).

Two implementations:

* :func:`drnn_apply` -- the *interleaved* formulation (also from Chang et
  al.): a dilation-d LSTM over T steps is exactly d independent LSTMs over
  the d stride-d sub-sequences. Each layer is a dense ``lax.scan`` with a
  flat ``(B*d, H)`` carry -- no ring buffers, no dynamic-index updates, d x
  fewer backward residuals, and d x larger (better MXU-shaped) gate matmuls.
  This is the production path (see EXPERIMENTS.md section Perf, ES-RNN
  hillclimb).
* :func:`drnn_apply_reference` -- the direct ring-buffer formulation kept as
  the numerical oracle (tests assert both paths agree).

Everything is pure-functional: ``drnn_init`` builds a params pytree. A single
fused-cell step is exposed (``lstm_cell``) so the Pallas kernel
(kernels/lstm_cell.py) can slot in behind the same signature.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@jax.custom_vjp
def _gates_lowp(wx, wh, b, x, h):
    """Gate pre-activations for sub-f32 streams: f32 accumulators, low-p IO.

    Two deliberate departures from the fp32 formulation, both invisible to
    it (this function is only reached for sub-f32 streams):

    * the x- and h-dots fuse into ONE concatenated dot_general -- same
      fp32 accumulator via ``preferred_element_type``, one MXU dispatch,
      and one (B, 4H) f32 emission instead of two plus an f32 add; the
      bias joins *after* the stream-dtype cast (a depth-1 pointwise add
      needs no fp32 accumulator).
    * a custom backward: XLA's native AD would transpose the trailing
      f32->bf16 cast into a bf16->f32 convert on ``dgates``, promoting
      every backward dot to full f32 operands. Here ``dgates`` stays in
      the stream dtype, each backward dot keeps low-precision operands
      with an fp32 accumulator, and emits stream-dtype cotangents
      (custom_vjp requires primal dtypes anyway). This is what makes the
      backward half of the fit roofline's byte ratio drop, not just the
      forward half.
    """
    xh = jnp.concatenate([x, h], axis=1)
    w = jnp.concatenate([wx, wh], axis=0)
    return (jnp.dot(xh, w, preferred_element_type=jnp.float32)
            .astype(x.dtype) + b.astype(x.dtype))


def _gates_lowp_fwd(wx, wh, b, x, h):
    return _gates_lowp(wx, wh, b, x, h), (wx, wh, b, x, h)


def _gates_lowp_bwd(res, dg):
    # stream-dtype emissions throughout: a bf16 dot accumulates in fp32
    # inside the MXU regardless of its output dtype, so requesting an f32
    # emission here would only round-trip the identical accumulator through
    # HBM at twice the width before the very next op rounds it anyway
    wx, wh, b, x, h = res
    i = x.shape[1]
    xh = jnp.concatenate([x, h], axis=1)
    w = jnp.concatenate([wx, wh], axis=0)
    dxh = jnp.dot(dg, w.T)
    dw = jnp.dot(xh.T, dg)
    db = jnp.sum(dg, axis=0).astype(b.dtype)
    return (dw[:i].astype(wx.dtype), dw[i:].astype(wh.dtype), db,
            dxh[:, :i].astype(x.dtype), dxh[:, i:].astype(h.dtype))


_gates_lowp.defvjp(_gates_lowp_fwd, _gates_lowp_bwd)


def lstm_cell(params, x, h_prev, c_prev, *, use_pallas: bool = False):
    """One fused LSTM step. x:(B,I) h,c:(B,H) -> (h,c):(B,H).

    Gate order (i, f, g, o) matches the Pallas kernel and ref oracle.
    """
    if use_pallas:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.lstm_cell(params["wx"], params["wh"], params["b"], x, h_prev, c_prev)
    # fp32 *accumulation*, stream-dtype elementwise: the gate pre-activations
    # are deep sums (dot_generals over I and H plus bias), so they accumulate
    # in fp32 regardless of the stream dtype -- same contract as the Pallas
    # kernel's MXU accumulators. The nonlinearities and the single-step state
    # update are pointwise (no accumulation depth), so they run in the stream
    # dtype; under bf16 this is what actually halves the cell's HBM-level
    # traffic (the roofline fit row). The fp32 branch keeps XLA's native AD
    # (bit-identical to the historical formulation); sub-f32 streams route
    # through the custom-vjp linear block so the backward dots stay in the
    # stream dtype too.
    if jnp.dtype(x.dtype) == jnp.float32:
        gates = (jnp.dot(x, params["wx"], preferred_element_type=jnp.float32)
                 + jnp.dot(h_prev, params["wh"], preferred_element_type=jnp.float32)
                 + params["b"].astype(jnp.float32)).astype(x.dtype)
    else:
        gates = _gates_lowp(params["wx"], params["wh"], params["b"], x, h_prev)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = (jax.nn.sigmoid(f) * c_prev.astype(x.dtype)
         + jax.nn.sigmoid(i) * jnp.tanh(g))
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _cell_init(key, input_size: int, hidden_size: int, dtype):
    k1, k2 = jax.random.split(key)
    scale_x = 1.0 / jnp.sqrt(jnp.asarray(input_size, jnp.float32))
    scale_h = 1.0 / jnp.sqrt(jnp.asarray(hidden_size, jnp.float32))
    return {
        "wx": (jax.random.uniform(k1, (input_size, 4 * hidden_size), jnp.float32, -1, 1) * scale_x).astype(dtype),
        "wh": (jax.random.uniform(k2, (hidden_size, 4 * hidden_size), jnp.float32, -1, 1) * scale_h).astype(dtype),
        "b": jnp.zeros((4 * hidden_size,), dtype),
    }


def drnn_init(
    key,
    input_size: int,
    hidden_size: int,
    dilations: Sequence[Sequence[int]],
    dtype=jnp.float32,
):
    """Params for the dilated stack. ``dilations`` e.g. ((1, 2), (4, 8))."""
    params = []
    in_size = input_size
    for block in dilations:
        block_params = []
        for _d in block:
            key, sub = jax.random.split(key)
            block_params.append(_cell_init(sub, in_size, hidden_size, dtype))
            in_size = hidden_size
        params.append(block_params)
    return params


# ---------------------------------------------------------------------------
# interleaved (production) formulation
# ---------------------------------------------------------------------------


def _dilated_layer(cell, xs, d: int, *, use_pallas: bool):
    """One dilation-d LSTM layer over xs (B, T, F) via stride-d interleave."""
    b, t, f = xs.shape
    hidden = cell["wh"].shape[0]
    if d == 1:
        xr = xs
        bd = b
    else:
        pad = (-t) % d
        xp = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tp = xp.shape[1]
        # (B, T/d, d, F) -> (B, d, T/d, F) -> (B*d, T/d, F): row j is the
        # stride-d sub-sequence starting at offset j -- an independent chain.
        xr = (xp.reshape(b, tp // d, d, f).transpose(0, 2, 1, 3)
              .reshape(b * d, tp // d, f))
        bd = b * d

    h0 = jnp.zeros((bd, hidden), xs.dtype)
    c0 = jnp.zeros((bd, hidden), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(cell, x_t, h, c, use_pallas=use_pallas)
        return (h, c), (h, c)

    (_, _), (hs, cs) = jax.lax.scan(step, (h0, c0), jnp.swapaxes(xr, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)                       # (B*d, T/d, H)
    cs = jnp.swapaxes(cs, 0, 1)
    if d > 1:
        tp = hs.shape[1] * d
        hs = (hs.reshape(b, d, tp // d, hidden).transpose(0, 2, 1, 3)
              .reshape(b, tp, hidden))[:, :t]
        cs = (cs.reshape(b, d, tp // d, hidden).transpose(0, 2, 1, 3)
              .reshape(b, tp, hidden))[:, :t]
    return hs, cs


@partial(jax.jit, static_argnames=("dilations", "use_pallas"))
def drnn_apply(
    params,
    xs: jax.Array,
    *,
    dilations: Tuple[Tuple[int, ...], ...],
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Run the stack over a sequence.

    Args:
      params: from :func:`drnn_init`.
      xs: ``(B, T, input_size)``.

    Returns:
      outputs ``(B, T, hidden)`` and mean squared cell-state magnitude of the
      *first layer of each block* (scalar) -- the section 8.4 Krueger &
      Memisevic stabilization penalty term.
    """
    inp = xs
    cstate_sq = jnp.zeros((), jnp.float32)
    n_terms = 0
    for bi, (block, bparams) in enumerate(zip(dilations, params)):
        block_in = inp
        for li, (d, cell) in enumerate(zip(block, bparams)):
            inp, cs = _dilated_layer(cell, inp, d, use_pallas=use_pallas)
            if li == 0:
                cstate_sq = cstate_sq + jnp.mean(jnp.square(cs.astype(jnp.float32)))
                n_terms += 1
        if bi > 0:  # residual between blocks (dims match at hidden)
            inp = inp + block_in
    return inp, cstate_sq / max(n_terms, 1)


# ---------------------------------------------------------------------------
# ring-buffer reference (numerical oracle for the interleaved path)
# ---------------------------------------------------------------------------


def drnn_apply_reference(
    params,
    xs: jax.Array,
    *,
    dilations: Tuple[Tuple[int, ...], ...],
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Direct formulation: per-layer (d, B, H) state rings, dynamic slots."""
    b = xs.shape[0]
    hidden = params[0][0]["wh"].shape[0]
    dtype = xs.dtype

    rings = []
    for block in dilations:
        for d in block:
            rings.append(
                (jnp.zeros((d, b, hidden), dtype), jnp.zeros((d, b, hidden), dtype))
            )

    flat_cells = [cp for blk in params for cp in blk]
    layer_dils = [d for blk in dilations for d in blk]
    block_sizes = [len(blk) for blk in dilations]
    first_layer_idx = []
    acc = 0
    for s in block_sizes:
        first_layer_idx.append(acc)
        acc += s

    def step(carry, x_t):
        rings, t = carry
        new_rings = []
        inp = x_t
        cstate_sq = jnp.zeros((), jnp.float32)
        li = 0
        for bi, nblk in enumerate(block_sizes):
            block_in = inp
            for _ in range(nblk):
                d = layer_dils[li]
                h_ring, c_ring = rings[li]
                slot = jnp.mod(t, d)
                h_prev = jax.lax.dynamic_index_in_dim(h_ring, slot, 0, keepdims=False)
                c_prev = jax.lax.dynamic_index_in_dim(c_ring, slot, 0, keepdims=False)
                h, c = lstm_cell(flat_cells[li], inp, h_prev, c_prev, use_pallas=use_pallas)
                h_ring = jax.lax.dynamic_update_index_in_dim(h_ring, h, slot, 0)
                c_ring = jax.lax.dynamic_update_index_in_dim(c_ring, c, slot, 0)
                new_rings.append((h_ring, c_ring))
                if li == first_layer_idx[bi]:
                    cstate_sq = cstate_sq + jnp.mean(jnp.square(c.astype(jnp.float32)))
                inp = h
                li += 1
            if bi > 0:
                inp = inp + block_in
        return (new_rings, t + 1), (inp, cstate_sq)

    (_, _), (outs, cstate_sqs) = jax.lax.scan(
        step, (rings, jnp.zeros((), jnp.int32)), jnp.swapaxes(xs, 0, 1)
    )
    return jnp.swapaxes(outs, 0, 1), jnp.mean(cstate_sqs) / max(len(block_sizes), 1)
