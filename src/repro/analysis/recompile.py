"""Recompile sentinel: count XLA backend compiles against a declared budget.

The serving path's whole performance story rests on a *bounded* jit cache:
``len(length_buckets) x len(batch_buckets)`` executables, every later
request a cache hit. ``ServeStats.compiles`` counts bucket-grid shapes the
dispatcher *intended* to compile -- but the PR-6 ``fc[:n]`` regression
showed the dangerous failure mode is the compile the dispatcher does NOT
know about: a device-array slice per distinct partial fill spawned an
unbounded executable family while the bucket counters stayed green.

This module counts what XLA actually does. A process-wide listener on the
``/jax/core/compile/backend_compile_duration`` monitoring event bumps every
*armed* :class:`CompileCounter`; the serving dispatcher arms one around each
dispatch so ``ServeStats.xla_compiles`` is ground truth, and the pytest
fixture ``compile_sentinel`` (tests/conftest.py) wraps any suspect region in
:meth:`CompileCounter.expect` so a hot path exceeding its compile budget
fails the test instead of silently burning latency in production.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Set

# the monitoring event jax records once per XLA backend compilation
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_active: Set["CompileCounter"] = set()
_lock = threading.Lock()
_listener_installed = False


class CompileBudgetExceeded(AssertionError):
    """A hot path compiled more executables than its declared budget."""


def _install_listener() -> None:
    """Register the process-wide compile listener once (idempotent)."""
    global _listener_installed
    with _lock:
        if _listener_installed:
            return
        from jax._src import monitoring

        def _on_duration(event: str, duration: float, **kwargs) -> None:
            if event != COMPILE_EVENT:
                return
            with _lock:
                counters = list(_active)
            for counter in counters:
                counter._bump()

        monitoring.register_event_duration_secs_listener(_on_duration)
        _listener_installed = True


class CompileCounter:
    """Context manager counting XLA backend compiles while armed.

    Counts every backend compile in the process during the armed window
    (that is the point: the ``fc[:n]`` family was invisible to any
    per-callable accounting). Optionally mirrors each count into a
    :class:`~repro.forecast.serving.ServeStats` via ``stats`` so serving
    telemetry reports true XLA compiles next to its bucket-grid intent.
    """

    def __init__(self, stats=None):
        self.count = 0
        self._stats = stats

    def _bump(self) -> None:
        self.count += 1
        if self._stats is not None:
            self._stats.xla_compiles += 1

    def __enter__(self) -> "CompileCounter":
        _install_listener()
        with _lock:
            _active.add(self)
        return self

    def __exit__(self, *exc) -> None:
        with _lock:
            _active.discard(self)

    @contextlib.contextmanager
    def expect(self, budget: int, what: str = "hot path"):
        """Fail if the wrapped region compiles more than ``budget`` times.

        The sentinel form the tests use::

            with counter.expect(budget=len(grid), what="serving waves"):
                drive_requests()
        """
        before = self.count
        yield self
        grew = self.count - before
        if grew > budget:
            raise CompileBudgetExceeded(
                f"{what} compiled {grew} XLA executables, over its declared "
                f"budget of {budget}: an unbounded compile family on a hot "
                f"path (the PR-6 fc[:n] bug class)")


def check_compile_budget(stats, budget: Optional[int] = None) -> int:
    """Assert a ServeStats' true-XLA compile count is within its budget.

    ``budget`` defaults to ``stats.compile_budget`` (the dispatcher declares
    it from the bucket grid at construction). Returns the compile count on
    success; raises :class:`CompileBudgetExceeded` otherwise.
    """
    if budget is None:
        budget = getattr(stats, "compile_budget", None)
    if budget is None:
        raise ValueError("no compile budget declared on stats or passed in")
    if stats.xla_compiles > budget:
        raise CompileBudgetExceeded(
            f"serving compiled {stats.xla_compiles} XLA executables, over "
            f"the declared bucket-grid budget of {budget} "
            f"({stats.compiles} intended bucket compiles, "
            f"{stats.cache_hits} cache hits)")
    return stats.xla_compiles
