"""Gradient-leak lint: prove frozen param groups stay gradient-free.

The ``esn`` head's performance claim is that its reservoir (the ``"rnn"``
group) never trains -- ``repro.train.engine.make_step_fn(frozen=...)``
differentiates the trainable subtree only, so XLA never builds reservoir
weight-gradient matmuls. That property was enforced empirically (reservoir
bit-equal across fits); this lint proves it *statically* on the traced step
jaxpr, per commit, with three independent checks:

1. **identity pass-through** -- every frozen leaf's output var IS its input
   var (the step returns the frozen subtree untouched; any update applied
   to it breaks the identity),
2. **no optimizer moments** -- the optimizer state pytree carries no leaf
   whose aval matches a frozen weight (moments for a frozen weight mean the
   optimizer was built over it),
3. **no gradient primitives** -- no equation anywhere in the program (all
   nested scans/pjits included) produces a frozen-weight-shaped value via a
   gradient-accumulating primitive (``dot_general`` weight-grad matmuls,
   ``add_any`` cotangent accumulation, ``reduce_sum`` bias grads,
   scatter-adds). The forward pass only *consumes* weights; values shaped
   like a weight can only be that weight's cotangent.

Check 3 identifies gradients by shape, so the probe batch must not collide
with weight shapes (a batch of ``hidden_size`` rows makes activation
cotangents ``(B, 4H)`` look like the ``(H, 4H)`` hidden weights).
:func:`probe_batch_size` picks a collision-free size; the lint also verifies
the choice and reports a finding if a collision makes check 3 inconclusive.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Sequence, Tuple

import jax
from jax import core as jcore
from jax.tree_util import tree_flatten_with_path

from repro.analysis.jaxpr_walk import aval_key, iter_eqns

# primitives that build/accumulate gradients; forward-only programs produce
# weight-shaped values through none of these (weights are only consumed)
GRAD_PRIMITIVES = frozenset(
    {"dot_general", "add_any", "reduce_sum", "scatter-add", "scatter_add"})


@dataclasses.dataclass
class Finding:
    """One invariant violation (shared by every lint in the package)."""

    lint: str
    message: str

    def to_dict(self):
        return {"lint": self.lint, "message": self.message}


def _frozen_leaf_positions(args_tree, frozen: FrozenSet[str],
                           params_index: int = 0) -> List[int]:
    """Flat indices of frozen-group leaves inside the step's argument tree.

    ``args_tree`` is the exact tuple traced (``(params, opt_state, idx)``);
    flattening order matches ``jax.make_jaxpr``'s invar order.
    """
    leaves = tree_flatten_with_path(args_tree)[0]
    out = []
    for i, (path, _) in enumerate(leaves):
        if not path or getattr(path[0], "idx", None) != params_index:
            continue
        if len(path) >= 2 and getattr(path[1], "key", None) in frozen:
            out.append(i)
    return out


def probe_batch_size(cfg, params, candidates: Sequence[int] = (5, 7, 11, 13),
                     frozen: FrozenSet[str] = frozenset()) -> int:
    """A batch size whose activation shapes cannot shadow frozen weights.

    Check 3 of the lint is shape-based: pick B such that no frozen leaf has
    B as a leading dimension (cotangents of batch activations lead with B).
    """
    frozen_dims = set()
    for name, group in params.items():
        if name in frozen:
            for leaf in jax.tree_util.tree_leaves(group):
                frozen_dims.update(leaf.shape)
    for b in candidates:
        if b not in frozen_dims:
            return b
    return max(frozen_dims) + 1


def gradient_leak_findings(step_fn, params, opt_state, idx,
                           frozen: FrozenSet[str]) -> Tuple[List[Finding], dict]:
    """Run the three static checks on one training-step function.

    Returns ``(findings, metrics)``; an empty findings list is the proof
    that no frozen group contributes gradient primitives to the step.
    """
    findings: List[Finding] = []
    closed = jax.make_jaxpr(step_fn)(params, opt_state, idx)
    jaxpr = closed.jaxpr
    args = (params, opt_state, idx)

    frozen_in = _frozen_leaf_positions(args, frozen)
    out_shape = jax.eval_shape(step_fn, params, opt_state, idx)
    frozen_out = _frozen_leaf_positions(out_shape, frozen)

    # 1. identity pass-through ------------------------------------------------
    passthrough_ok = 0
    if len(frozen_in) != len(frozen_out):
        findings.append(Finding(
            "gradient-leak",
            f"frozen groups have {len(frozen_in)} input leaves but "
            f"{len(frozen_out)} output leaves: the step does not return the "
            f"frozen subtree structurally unchanged"))
    else:
        for i, o in zip(frozen_in, frozen_out):
            if jaxpr.outvars[o] is jaxpr.invars[i]:
                passthrough_ok += 1
            else:
                findings.append(Finding(
                    "gradient-leak",
                    f"frozen leaf (invar {i}) is not passed through "
                    f"unchanged to output {o}: an update is applied to a "
                    f"frozen param group"))

    # 2. no optimizer moments over frozen weights -----------------------------
    frozen_avals = {aval_key(jaxpr.invars[i].aval) for i in frozen_in}
    opt_leaves = tree_flatten_with_path(opt_state)[0]
    for path, leaf in opt_leaves:
        keys = {getattr(p, "key", None) for p in path}
        if keys & set(frozen):
            findings.append(Finding(
                "gradient-leak",
                f"optimizer state carries moments for frozen group "
                f"{sorted(keys & set(frozen))} at {jax.tree_util.keystr(path)}"))

    # 3. no gradient primitives producing frozen-weight-shaped values --------
    # guard: the probe shapes must make frozen avals unambiguous
    trainable_avals = set()
    for i, (path, leaf) in enumerate(tree_flatten_with_path(args)[0]):
        if i not in frozen_in:
            trainable_avals.add(
                (tuple(getattr(leaf, "shape", ())), str(getattr(leaf, "dtype", ""))))
    collisions = frozen_avals & trainable_avals
    if collisions:
        findings.append(Finding(
            "gradient-leak",
            f"probe shapes are ambiguous: frozen and trainable leaves share "
            f"avals {sorted(collisions)}; pick distinct probe dimensions "
            f"(see probe_batch_size)"))

    # a weight cotangent may materialize one layout hop after the grad
    # primitive (``dot_general`` -> ``transpose`` is jax's standard weight
    # transpose rule), so track producers and treat layout ops fed by a
    # gradient primitive as gradient-producing themselves
    producer = {}
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            producer[v] = eqn.primitive.name
    _LAYOUT = {"transpose", "reshape", "convert_element_type", "copy"}

    def _is_grad_eqn(eqn) -> bool:
        if eqn.primitive.name in GRAD_PRIMITIVES:
            return True
        if eqn.primitive.name in _LAYOUT:
            return any(producer.get(iv) in GRAD_PRIMITIVES
                       for iv in eqn.invars
                       if not isinstance(iv, jcore.Literal))
        return False

    grad_hits = 0
    for eqn in iter_eqns(jaxpr):
        if not _is_grad_eqn(eqn):
            continue
        for v in eqn.outvars:
            if aval_key(v.aval) in frozen_avals:
                grad_hits += 1
                findings.append(Finding(
                    "gradient-leak",
                    f"gradient primitive `{eqn.primitive.name}` produces a "
                    f"frozen-weight-shaped value {aval_key(v.aval)}: a "
                    f"frozen group's weight gradient is being built"))

    metrics = {
        "frozen_leaves": len(frozen_in),
        "passthrough_ok": passthrough_ok,
        "grad_primitive_hits": grad_hits,
        "eqns_scanned": sum(1 for _ in iter_eqns(jaxpr)),
    }
    return findings, metrics
