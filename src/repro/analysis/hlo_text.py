"""Shared HLO-text parsing helpers: shapes, collectives, aliasing.

One home for the regexes that read compiled/partitioned HLO text, shared by
the roofline extractors (``repro.roofline.analysis`` /
``repro.roofline.hlo_walk``) and the graph auditor's collective and donation
lints (``repro.analysis.collectives`` / ``repro.analysis.donation``) -- the
two subsystems must never disagree about what counts as a collective or how
a shape string turns into bytes.

Everything here is pure text processing over ``compiled.as_text()`` output;
no jax import, so the roofline modules stay importable without a backend.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

# bytes per element for every HLO scalar type the repo's programs produce
DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# "f32[8,40]" / "pred[]" inside any HLO type string (tuples included)
SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

# every cross-device collective opcode XLA emits for this repo's programs
COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# "<type> all-reduce(" / "all-reduce-start(" at an op position; the async
# "-done(" halves are deliberately NOT matched (counting both would double)
_COLLECTIVE_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9\[\]{},:#\* ]+?)\s+"
    r"(" + "|".join(COLLECTIVE_KINDS) + r")"
    r"(?:-start)?\(")

# module-header input/output aliasing entries, inside the balanced block
#   input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}) }
_ALIAS_ENTRY_RE = re.compile(r"\{([0-9, ]*)\}:\s*\((\d+),")


def type_bytes(type_str: str) -> int:
    """Total bytes of every shaped value in an HLO type string."""
    total = 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_ops(hlo_text: str) -> List[Tuple[str, str]]:
    """All collective ops in the module as ``(kind, output_type)`` pairs.

    Async pairs count once (the ``-start`` op; ``-done`` never matches), so
    ``len(collective_ops(text))`` is the number of collectives the program
    executes per dispatch, and an empty list is the zero-collective proof
    the sharded-predict audit gates on.
    """
    return [(m.group(2), m.group(1))
            for m in _COLLECTIVE_OP_RE.finditer(hlo_text)]


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Collective op counts by kind (``{}`` for a collective-free program)."""
    out: Dict[str, int] = {}
    for kind, _ in collective_ops(hlo_text):
        out[kind] = out.get(kind, 0) + 1
    return out


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, int]:
    """Sum of collective *output bytes* by kind (roofline's ICI term)."""
    out: Dict[str, int] = {}
    for kind, type_str in collective_ops(hlo_text):
        out[kind] = out.get(kind, 0) + type_bytes(type_str)
    return out


def input_output_aliases(hlo_text: str) -> List[Tuple[Tuple[int, ...], int]]:
    """Parsed module-header aliasing: ``[(output_index, parameter_number)]``.

    The compiled module records which output buffers alias (reuse) which
    input buffers -- this is what ``donate_argnums`` buys when XLA actually
    honors it. A donated-but-copied buffer simply has no entry here, which
    is what the donation audit (``repro.analysis.donation``) detects.
    Only the module header is consulted (the attribute also never appears
    elsewhere in ``as_text()`` output).
    """
    header = hlo_text.split("\n", 1)[0]
    start = header.find("input_output_alias={")
    if start < 0:
        return []
    # the attribute value nests braces ({0}: (0, {}, ...)); walk to balance
    i = header.index("{", start)
    depth, j = 0, i
    for j in range(i, len(header)):
        if header[j] == "{":
            depth += 1
        elif header[j] == "}":
            depth -= 1
            if depth == 0:
                break
    block = header[i:j + 1]
    out = []
    for idx_str, param_str in _ALIAS_ENTRY_RE.findall(block):
        idx = tuple(int(t) for t in idx_str.split(",") if t.strip())
        out.append((idx, int(param_str)))
    return out
