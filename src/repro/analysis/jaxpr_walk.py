"""Recursive jaxpr traversal shared by the static lints.

``jax.make_jaxpr`` output nests: ``scan``/``while``/``cond``/``pjit``/
``custom_vjp_call`` equations carry their bodies as (Closed)Jaxpr values in
``eqn.params``. The lints (gradient-leak, dtype-policy) need every equation
and every abstract value in the whole program, so this module flattens the
nesting once and the lints stay simple linear scans.
"""

from __future__ import annotations

from typing import Iterator, List, Tuple

from jax import core as jcore


def _sub_jaxprs(value) -> Iterator[jcore.Jaxpr]:
    """Yield any (Closed)Jaxpr reachable from one ``eqn.params`` value."""
    values = value if isinstance(value, (list, tuple)) else (value,)
    for v in values:
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v


def iter_eqns(jaxpr) -> Iterator:
    """Every equation in ``jaxpr`` and all nested sub-jaxprs, depth-first."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub)


def aval_key(aval) -> Tuple[Tuple[int, ...], str]:
    """Hashable (shape, dtype) identity of an abstract value."""
    return tuple(getattr(aval, "shape", ())), str(getattr(aval, "dtype", ""))


def out_avals(jaxpr) -> List:
    """Abstract values of every equation output across the whole program."""
    return [v.aval for eqn in iter_eqns(jaxpr) for v in eqn.outvars]
