"""The graph auditor: run every static invariant lint on the real entry
points and produce one machine-readable report.

Five invariants, one per lint module, audited per commit by CI:

1. **recompile sentinel** (``repro.analysis.recompile``) -- serving stays
   within its declared bucket-grid compile budget and a warm second wave
   compiles nothing (the PR-6 ``fc[:n]`` unbounded-compile-family class),
2. **gradient leak** (``repro.analysis.gradleak``) -- frozen param groups
   (the esn reservoir) contribute zero gradient primitives to the training
   step jaxpr,
3. **donation** (``repro.analysis.donation``) -- the donated superstep's
   ``(params, opt_state)`` buffers actually alias input->output in the
   compiled module (no donated-but-copied),
4. **collectives** (``repro.analysis.collectives``) -- partitioned sharded
   predict contains zero collectives; the sharded loss gradient contains
   the expected psums and only psums,
5. **dtype policy** (``repro.analysis.dtypes``) -- no f64 promotion or
   above-policy float upcast anywhere in the forward/loss/step programs.

``repro.launch.forecast analyze`` is the CLI over :func:`run_audit`; the
report's ``metrics`` (compile counts, collective counts, aliased-buffer
counts) also land as the ``analysis`` column of the benchmark trajectory
(``BENCH_PR10.json``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.analysis.collectives import (
    collective_audit, collective_findings, probe_batch,
)
from repro.analysis.donation import donated_leaf_count, donation_findings
from repro.analysis.dtypes import accumulation_findings, dtype_findings
from repro.analysis.gradleak import (
    Finding, gradient_leak_findings, probe_batch_size,
)

PROBE_SERIES = 15     # probe table rows (odd, clear of weight dims)
PROBE_STEPS = 4       # superstep length for the donation audit


@dataclasses.dataclass
class AuditSection:
    """One audited entry point: its violations and raw metrics."""

    name: str
    violations: List[Finding]
    metrics: Dict

    def to_dict(self):
        return {"name": self.name,
                "violations": [f.to_dict() for f in self.violations],
                "metrics": self.metrics}


@dataclasses.dataclass
class AuditReport:
    """Everything ``analyze`` emits: per-section findings + metrics."""

    spec: str
    sections: List[AuditSection]

    @property
    def violations(self) -> List[Finding]:
        return [f for s in self.sections for f in s.violations]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self):
        return {"spec": self.spec, "ok": self.ok,
                "violations_total": len(self.violations),
                "sections": [s.to_dict() for s in self.sections]}

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _probe_model(spec):
    import jax

    from repro.core.esrnn import esrnn_init

    cfg = spec.model
    y, cats = probe_batch(cfg, PROBE_SERIES)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, PROBE_SERIES)
    return cfg, params, y, cats


def audit_fit(spec) -> AuditSection:
    """Gradient-leak + donation + dtype lints on the real training step."""
    import jax.numpy as jnp

    from repro.core.heads import frozen_param_groups
    from repro.train.engine import (
        lower_superstep, make_step_fn, split_frozen,
    )
    from repro.train.optimizer import AdamConfig, adam_init

    cfg, params, y, cats = _probe_model(spec)
    frozen = frozen_param_groups(cfg)
    mask = jnp.ones(y.shape, jnp.float32)
    step = make_step_fn(cfg, AdamConfig(lr=spec.rnn_lr), jnp.asarray(y),
                        jnp.asarray(cats), mask, frozen=frozen)
    opt = adam_init(split_frozen(params, frozen)[0])
    b = probe_batch_size(cfg, params, frozen=frozen)
    idx = jnp.arange(b) % PROBE_SERIES

    violations: List[Finding] = []
    leak, leak_metrics = gradient_leak_findings(step, params, opt, idx, frozen)
    violations += leak

    import jax

    # policy-aware lint: the compute dtype (bf16 under precision="bf16") is
    # the policy floor, converts up to the state dtype are the declared fp32
    # accumulation points, anything wider (and any f64) still fails
    step_jaxpr = jax.make_jaxpr(step)(params, opt, idx)
    dt, dt_metrics = dtype_findings(
        step_jaxpr, policy_dtype=cfg.compute_dtype.name, state_dtype=cfg.dtype)
    violations += dt

    # ...and the state half: HW table, Adam moments, and the loss the
    # masked-mean reduction emits must all be the state dtype
    loss_aval = jax.eval_shape(step, params, opt, idx)[2]
    acc, acc_metrics = accumulation_findings(params, opt, loss_aval,
                                             state_dtype=cfg.dtype)
    violations += acc

    sched = jnp.stack([(jnp.arange(b) + k) % PROBE_SERIES
                       for k in range(PROBE_STEPS)])
    compiled = lower_superstep(step, params, opt, sched).compile()
    don, don_metrics = donation_findings(
        compiled, donated_leaf_count(params, opt), what="superstep")
    violations += don

    return AuditSection("fit", violations, {
        "head": cfg.head, "precision": cfg.precision,
        "frozen_groups": sorted(frozen),
        "gradient_leak": leak_metrics, "dtype": dt_metrics,
        "accumulation": acc_metrics, "donation": don_metrics})


def audit_predict(spec) -> AuditSection:
    """Dtype lint over the forward forecast program."""
    import jax

    from repro.core.esrnn import esrnn_forecast_fn

    cfg, params, y, cats = _probe_model(spec)
    jaxpr = jax.make_jaxpr(
        lambda p, yy, cc: esrnn_forecast_fn(cfg, p, yy, cc))(params, y, cats)
    findings, metrics = dtype_findings(
        jaxpr, policy_dtype=cfg.compute_dtype.name, state_dtype=cfg.dtype)
    return AuditSection("predict", findings,
                        {"precision": cfg.precision, "dtype": metrics})


def audit_serve(spec, *, waves: int = 2, requests: int = 24) -> AuditSection:
    """Recompile sentinel on the real serving dispatcher.

    Drives ``waves`` identical request waves through a
    :class:`~repro.forecast.serving.BucketDispatcher` on a small bucket
    grid. Violations: total XLA compiles over the declared budget, or any
    compile at all on the warm second wave (every shape must be a cache
    hit by then -- the ``fc[:n]`` family fails exactly this).
    """
    from repro.forecast.serving import (
        BucketDispatcher, synthetic_request_stream,
    )

    cfg, params, _, _ = _probe_model(spec)
    srv = BucketDispatcher(cfg, params,
                           length_buckets=(32, 64), batch_buckets=(1, 8))
    budget = srv.compile_budget
    violations: List[Finding] = []
    wave_compiles = []
    for w in range(waves):
        before = srv.stats.xla_compiles
        reqs = synthetic_request_stream(
            cfg, requests, n_known=PROBE_SERIES, seed=0,
            len_range=(20, 60))
        out = srv.forecast_batch(reqs)
        assert all(np.isfinite(o).all() for o in out)
        wave_compiles.append(srv.stats.xla_compiles - before)
    if srv.stats.xla_compiles > budget:
        violations.append(Finding(
            "recompile",
            f"serving compiled {srv.stats.xla_compiles} XLA executables "
            f"over {waves} waves, above the declared bucket-grid budget "
            f"of {budget}"))
    if waves > 1 and wave_compiles[-1] > 0:
        violations.append(Finding(
            "recompile",
            f"warm wave still compiled {wave_compiles[-1]} executables: "
            f"an unbounded compile family on the serving hot path"))
    return AuditSection("serve", violations, {
        "compile_budget": budget,
        "xla_compiles": srv.stats.xla_compiles,
        "bucket_compiles": srv.stats.compiles,
        "cache_hits": srv.stats.cache_hits,
        "wave_xla_compiles": wave_compiles})


def audit_collectives(spec, devices: int = 8) -> AuditSection:
    """Zero-collective predict / psum-only loss grad on a series mesh."""
    counts = collective_audit(spec.model, devices=devices)
    findings, metrics = collective_findings(counts)
    return AuditSection("collectives", findings,
                        {**metrics, "counts": counts})


_ENTRY_POINTS = {
    "fit": audit_fit,
    "predict": audit_predict,
    "serve": audit_serve,
}


def run_audit(spec, entries: Sequence[str] = ("fit", "predict", "serve"),
              devices: Optional[int] = None) -> AuditReport:
    """Audit the requested entry points of one :class:`ForecastSpec`.

    ``devices`` > 1 adds the partitioned-HLO collective audit (subprocess
    with forced host devices when this process has fewer).
    """
    sections = []
    for name in entries:
        if name == "collectives":
            continue  # handled below, needs the device count
        if name not in _ENTRY_POINTS:
            raise ValueError(
                f"unknown audit entry point {name!r}; "
                f"pick from {sorted(_ENTRY_POINTS)} + ['collectives']")
        sections.append(_ENTRY_POINTS[name](spec))
    if (devices and devices > 1) or "collectives" in entries:
        sections.append(audit_collectives(spec, devices=devices or 8))
    return AuditReport(spec.name, sections)
