"""Donation audit: donated buffers must actually alias in compiled HLO.

``repro.train.engine`` donates ``(params, opt_state)`` into the per-step and
superstep executables so the HW table and Adam moments ping-pong in place.
``donate_argnums`` is a *request*: XLA silently copies when it cannot honor
an alias (dtype change, layout mismatch, an un-donatable backend), and jax
only warns -- a perf cliff with no functional symptom. This audit reads the
compiled module's ``input_output_alias`` header and fails when fewer buffers
alias than the donated argument trees require.
"""

from __future__ import annotations

from typing import List, Tuple

import jax

from repro.analysis.gradleak import Finding
from repro.analysis.hlo_text import input_output_aliases


def donated_leaf_count(*trees) -> int:
    """Number of buffers the donated argument trees contribute."""
    return sum(len(jax.tree_util.tree_leaves(t)) for t in trees)


def donation_findings(compiled, expected_aliases: int,
                      what: str = "step") -> Tuple[List[Finding], dict]:
    """Check a compiled executable's input-output aliasing.

    ``expected_aliases`` is the donated-leaf count
    (:func:`donated_leaf_count` over the donated argument subtrees);
    ``compiled`` is the AOT artifact (``jitted.lower(...).compile()``).
    """
    aliases = input_output_aliases(compiled.as_text())
    findings: List[Finding] = []
    if len(aliases) < expected_aliases:
        findings.append(Finding(
            "donation",
            f"{what}: only {len(aliases)} of {expected_aliases} donated "
            f"buffers alias input->output in the compiled module; the rest "
            f"are silently copied every call (donated-but-copied)"))
    # aliasing must be a bijection on parameter numbers -- two outputs
    # aliasing one input would be an XLA-level inconsistency worth surfacing
    params_aliased = [p for _, p in aliases]
    if len(set(params_aliased)) != len(params_aliased):
        findings.append(Finding(
            "donation",
            f"{what}: compiled module aliases one parameter to multiple "
            f"outputs: {sorted(params_aliased)}"))
    metrics = {"aliased_buffers": len(aliases),
               "expected_aliases": expected_aliases}
    return findings, metrics
