"""repro.analysis: static invariant checks over jaxprs and compiled HLO.

The graph auditor behind ``repro.launch.forecast analyze`` and the CI
zero-violation gate. Five lints prove the repo's load-bearing performance
claims per commit instead of observing them:

* :mod:`repro.analysis.recompile` -- bounded-jit-cache sentinel (true XLA
  compile counts vs a declared budget),
* :mod:`repro.analysis.gradleak` -- frozen param groups build no gradients,
* :mod:`repro.analysis.donation` -- donated buffers actually alias,
* :mod:`repro.analysis.collectives` -- sharded predict is collective-free;
  the sharded loss grad psums and does nothing else,
* :mod:`repro.analysis.dtypes` -- no f64 promotion / above-policy upcasts.

:mod:`repro.analysis.hlo_text` is the shared HLO text parsing layer (also
consumed by the roofline extractors); :mod:`repro.analysis.audit` wires the
lints to the real fit/predict/serve entry points and emits the JSON report.
"""

from repro.analysis.audit import (           # noqa: F401
    AuditReport, AuditSection, audit_collectives, audit_fit, audit_predict,
    audit_serve, run_audit,
)
from repro.analysis.gradleak import Finding  # noqa: F401
from repro.analysis.recompile import (       # noqa: F401
    CompileBudgetExceeded, CompileCounter, check_compile_budget,
)

__all__ = [
    "AuditReport", "AuditSection", "Finding",
    "CompileBudgetExceeded", "CompileCounter", "check_compile_budget",
    "audit_collectives", "audit_fit", "audit_predict", "audit_serve",
    "run_audit",
]
