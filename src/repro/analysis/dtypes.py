"""Dtype-policy lint: no f64 promotions or silent upcasts on the hot paths.

The forward core computes in the config's declared dtype (``float32`` today;
the ROADMAP's mixed-precision item makes ``bfloat16`` the next policy). Two
regression classes this lint catches statically, on the traced jaxpr:

* **f64 promotion** -- a stray ``float(...)``/numpy-f64 constant with x64
  enabled doubles every downstream buffer and silently halves throughput;
  no float64 abstract value may appear anywhere in the program.
* **silent upcast** -- a ``convert_element_type`` from a float dtype to a
  *wider* float than the policy allows means some op fell off the
  declared-precision path (under a bf16 policy, an f32 convert is exactly
  the "silent upcast to f32" failure mode mixed-precision work hunts).

Integer/bool values are exempt (indices and masks are supposed to be exact),
as are converts *down* to or within the policy width.
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.gradleak import Finding
from repro.analysis.jaxpr_walk import iter_eqns


def _is_float(dtype) -> bool:
    # jnp.issubdtype, not np: bfloat16/f8 are ml_dtypes extension types
    # that plain numpy does not classify as floating
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def dtype_findings(jaxpr, policy_dtype="float32") -> Tuple[List[Finding], dict]:
    """Lint one (Closed)Jaxpr against a float compute policy.

    Flags every float64 aval and every float->float ``convert_element_type``
    whose destination is wider than ``policy_dtype``. Returns
    ``(findings, metrics)``; findings are deduplicated by (primitive, dtype
    pair) so a single leaked constant does not produce hundreds of lines.
    """
    policy = jnp.dtype(policy_dtype)
    findings: List[Finding] = []
    seen = set()
    f64_avals = 0
    upcasts = 0
    eqns = 0
    for eqn in iter_eqns(jaxpr):
        eqns += 1
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            if dt is None:
                continue
            if _is_float(dt) and jnp.dtype(dt) == np.float64:
                f64_avals += 1
                key = ("f64", eqn.primitive.name)
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "dtype-policy",
                        f"float64 value produced by `{eqn.primitive.name}` "
                        f"(shape {tuple(v.aval.shape)}): f64 promotion on a "
                        f"{policy.name}-policy path"))
        if eqn.primitive.name == "convert_element_type":
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if (src is not None and dst is not None and _is_float(src)
                    and _is_float(dst)
                    and jnp.dtype(dst).itemsize > policy.itemsize):
                upcasts += 1
                key = ("upcast", str(src), str(jnp.dtype(dst)))
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "dtype-policy",
                        f"silent upcast {jnp.dtype(src).name} -> "
                        f"{jnp.dtype(dst).name} beyond the {policy.name} "
                        f"policy"))
    metrics = {"eqns_scanned": eqns, "f64_avals": f64_avals,
               "float_upcasts": upcasts,
               "policy_dtype": str(jnp.dtype(policy_dtype))}
    return findings, metrics
