"""Dtype-policy lint: no f64 promotions or silent upcasts on the hot paths.

The forward core computes in the config's declared dtype (``float32`` today;
the ROADMAP's mixed-precision item makes ``bfloat16`` the next policy). Two
regression classes this lint catches statically, on the traced jaxpr:

* **f64 promotion** -- a stray ``float(...)``/numpy-f64 constant with x64
  enabled doubles every downstream buffer and silently halves throughput;
  no float64 abstract value may appear anywhere in the program.
* **silent upcast** -- a ``convert_element_type`` from a float dtype to a
  *wider* float than the policy allows means some op fell off the
  declared-precision path (under a bf16 policy, an f32 convert is exactly
  the "silent upcast to f32" failure mode mixed-precision work hunts).

Integer/bool values are exempt (indices and masks are supposed to be exact),
as are converts *down* to or within the policy width.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.gradleak import Finding
from repro.analysis.jaxpr_walk import iter_eqns


def _is_float(dtype) -> bool:
    # jnp.issubdtype, not np: bfloat16/f8 are ml_dtypes extension types
    # that plain numpy does not classify as floating
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def dtype_findings(jaxpr, policy_dtype="float32",
                   state_dtype: Optional[str] = None
                   ) -> Tuple[List[Finding], dict]:
    """Lint one (Closed)Jaxpr against a float compute policy.

    Flags every float64 aval and every float->float ``convert_element_type``
    whose destination is wider than ``policy_dtype``. Under a mixed-precision
    policy (``ESRNNConfig.precision="bf16"``) pass the *state* dtype too:
    converts up to ``state_dtype`` are then legitimate (they are the declared
    fp32 accumulation points -- HW recurrence, loss reduction, dot-general
    emissions), while converts beyond it still fail, as does any f64. With
    ``state_dtype=None`` (the default) the lint is single-dtype strict --
    every convert above ``policy_dtype`` is a silent upcast.

    Returns ``(findings, metrics)``; findings are deduplicated by
    (primitive, dtype pair) so a single leaked constant does not produce
    hundreds of lines.
    """
    policy = jnp.dtype(policy_dtype)
    widest = policy
    if state_dtype is not None and jnp.dtype(state_dtype).itemsize > policy.itemsize:
        widest = jnp.dtype(state_dtype)
    findings: List[Finding] = []
    seen = set()
    f64_avals = 0
    upcasts = 0
    eqns = 0
    for eqn in iter_eqns(jaxpr):
        eqns += 1
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            if dt is None:
                continue
            if _is_float(dt) and jnp.dtype(dt) == np.float64:
                f64_avals += 1
                key = ("f64", eqn.primitive.name)
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "dtype-policy",
                        f"float64 value produced by `{eqn.primitive.name}` "
                        f"(shape {tuple(v.aval.shape)}): f64 promotion on a "
                        f"{policy.name}-policy path"))
        if eqn.primitive.name == "convert_element_type":
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if (src is not None and dst is not None and _is_float(src)
                    and _is_float(dst)
                    and jnp.dtype(dst).itemsize > widest.itemsize):
                upcasts += 1
                key = ("upcast", str(src), str(jnp.dtype(dst)))
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "dtype-policy",
                        f"silent upcast {jnp.dtype(src).name} -> "
                        f"{jnp.dtype(dst).name} beyond the {policy.name} "
                        f"policy"))
    metrics = {"eqns_scanned": eqns, "f64_avals": f64_avals,
               "float_upcasts": upcasts,
               "policy_dtype": str(jnp.dtype(policy_dtype)),
               "state_dtype": (str(jnp.dtype(state_dtype))
                               if state_dtype is not None else None)}
    return findings, metrics


def accumulation_findings(params, opt_state, loss_aval,
                          state_dtype="float32") -> Tuple[List[Finding], dict]:
    """Prove the fp32-*state* half of the precision policy on real pytrees.

    The compute half of a mixed-precision policy is checked statically on
    the jaxpr (:func:`dtype_findings`); this checks the other half -- the
    values that must NEVER drop to the compute dtype no matter what policy
    is declared:

    * the per-series Holt-Winters table (``params["hw"]``) -- the master
      copy the level/seasonality recurrence trains,
    * the Adam moments (``mu``/``nu`` in the optimizer state, including the
      sparse variant's),
    * the scalar loss the masked-mean reduction emits (``loss_aval`` from
      ``jax.eval_shape`` of the step).

    ``params``/``opt_state`` may be real arrays or ShapeDtypeStructs.
    """
    state = jnp.dtype(state_dtype)
    findings: List[Finding] = []

    def bad_leaf_dtypes(tree):
        return sorted({
            jnp.dtype(leaf.dtype).name
            for leaf in jax.tree_util.tree_leaves(tree)
            if _is_float(leaf.dtype) and jnp.dtype(leaf.dtype) != state})

    hw_bad = bad_leaf_dtypes(params.get("hw", {}) if isinstance(params, dict)
                             else params)
    if hw_bad:
        findings.append(Finding(
            "dtype-policy",
            f"per-series HW table holds {hw_bad} leaves; the master "
            f"level/seasonality state must stay {state.name}"))

    moments = {k: v for k, v in opt_state.items() if k in ("mu", "nu")} \
        if isinstance(opt_state, dict) else opt_state
    mom_bad = bad_leaf_dtypes(moments)
    if mom_bad:
        findings.append(Finding(
            "dtype-policy",
            f"Adam moments hold {mom_bad} leaves; optimizer accumulators "
            f"must stay {state.name}"))

    loss_ok = jnp.dtype(loss_aval.dtype) == state
    if not loss_ok:
        findings.append(Finding(
            "dtype-policy",
            f"loss reduction emits {jnp.dtype(loss_aval.dtype).name}; the "
            f"masked-mean pinball accumulation must stay {state.name}"))

    metrics = {"hw_table_dtypes_bad": hw_bad, "moment_dtypes_bad": mom_bad,
               "loss_dtype": jnp.dtype(loss_aval.dtype).name,
               "state_dtype": state.name}
    return findings, metrics
