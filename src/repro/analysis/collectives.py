"""Collective audit over partitioned HLO: predict is collective-free, the
loss gradient all-reduces and nothing else.

The sharded inference claim (PR 5) is that per-series rows are device-local
under ``shard_map`` -- the partitioned predict program must contain *zero*
collectives, or scaling claims based on "embarrassingly parallel" are void.
The sharded training loss, conversely, must contain the expected psums (the
decomposed masked-mean reduction plus the shard_map transpose's replicated
weight-grad all-reduce) and **only** psums: an all-gather or
collective-permute in the gradient means a sharding spec regressed into
resharding traffic. Both properties are read off ``compiled.as_text()`` of
the partitioned module with the shared :mod:`repro.analysis.hlo_text`
helpers -- the same regexes the roofline's ICI term uses.

Collectives only exist on a multi-device mesh, and XLA pins the host device
count at first jax init, so :func:`collective_audit` runs in-process when
the current process already has enough devices (the CI sharded-smoke job)
and otherwise re-executes this module in a subprocess with
``--xla_force_host_platform_device_count`` (the CLI-on-a-laptop path) --
exactly the pattern the distributed tests use.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Tuple

import numpy as np

from repro.analysis.gradleak import Finding
from repro.analysis.hlo_text import collective_counts

# the only collective the sharded training gradient is allowed to contain
# (psum/pmean lower to all-reduce); one is required, resharding kinds never
EXPECTED_GRAD_KINDS = frozenset({"all-reduce"})


def probe_batch(cfg, n: int, t: int = 60, seed: int = 0):
    """Deterministic strictly-positive probe series for lowering/tracing."""
    rng = np.random.default_rng(seed)
    y = np.abs(rng.lognormal(3.0, 0.5, (n, t))).astype(np.float32) + 1.0
    cats = np.eye(cfg.n_categories, dtype=np.float32)[
        rng.integers(0, cfg.n_categories, n)]
    return y, cats


def sharded_collective_counts(cfg, devices: int) -> Dict[str, Dict[str, int]]:
    """Compile the sharded predict + loss-grad and count their collectives.

    Requires ``devices`` jax devices in this process (force host devices on
    CPU); :func:`collective_audit` handles the subprocess fallback.
    """
    import jax

    from repro.core.esrnn import esrnn_init
    from repro.sharding.series import (
        esrnn_forecast_dp, esrnn_loss_dp, make_series_mesh,
    )

    mesh = make_series_mesh(devices)
    n = 2 * devices
    y, cats = probe_batch(cfg, n)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)

    predict = jax.jit(
        lambda p, yy, cc: esrnn_forecast_dp(cfg, p, yy, cc, mesh=mesh))
    predict_hlo = predict.lower(params, y, cats).compile().as_text()

    grad = jax.jit(jax.grad(
        lambda p: esrnn_loss_dp(cfg, p, y, cats, mesh=mesh)))
    grad_hlo = grad.lower(params).compile().as_text()

    return {"devices": devices,
            "predict": collective_counts(predict_hlo),
            "loss_grad": collective_counts(grad_hlo)}


def collective_findings(
    counts: Dict[str, Dict[str, int]],
) -> Tuple[List[Finding], dict]:
    """Evaluate the zero-collective / psum-only invariants on raw counts."""
    findings: List[Finding] = []
    predict = counts.get("predict", {})
    grad = counts.get("loss_grad", {})
    if predict:
        findings.append(Finding(
            "collectives",
            f"sharded predict compiles to collectives {predict}: per-series "
            f"rows are no longer device-local (expected zero)"))
    unexpected = {k: v for k, v in grad.items()
                  if k not in EXPECTED_GRAD_KINDS}
    if unexpected:
        findings.append(Finding(
            "collectives",
            f"sharded loss gradient contains non-psum collectives "
            f"{unexpected}: a sharding spec regressed into resharding "
            f"traffic (only all-reduce is expected)"))
    if not grad.get("all-reduce"):
        findings.append(Finding(
            "collectives",
            "sharded loss gradient contains no all-reduce: the replicated "
            "weight gradients and the global masked-mean psums are missing"))
    metrics = {
        "devices": counts.get("devices"),
        "predict_collectives": sum(predict.values()),
        "grad_all_reduces": int(grad.get("all-reduce", 0)),
        "grad_other_collectives": sum(unexpected.values()),
    }
    return findings, metrics


def collective_audit(cfg, devices: int = 8) -> Dict[str, Dict[str, int]]:
    """Collective counts for ``cfg`` at ``devices``, via subprocess if needed.

    In-process when this process already sees enough devices; otherwise
    re-runs this module under ``--xla_force_host_platform_device_count``
    with the same config fields serialized on argv.
    """
    import jax

    if len(jax.devices()) >= devices:
        return sharded_collective_counts(cfg, devices)

    import dataclasses

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")).strip()
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    payload = json.dumps(
        {"config": dataclasses.asdict(cfg), "devices": devices})
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.collectives"],
        input=payload, capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded collective audit subprocess failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _main() -> int:
    """Subprocess entry: read {config, devices} JSON on stdin, print counts."""
    from repro.core.esrnn import ESRNNConfig

    spec = json.loads(sys.stdin.read())
    cfg_dict = dict(spec["config"])
    cfg_dict["dilations"] = tuple(
        tuple(d) for d in cfg_dict.get("dilations", ()))
    cfg = ESRNNConfig(**cfg_dict)
    print(json.dumps(sharded_collective_counts(cfg, int(spec["devices"]))))
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
