"""Synthetic M4-like dataset generator.

The real M4 CSVs are not available offline, so experiments run on synthetic
series whose *statistical profile* matches the paper's Tables 2 and 3:

* Table 2: series counts per (frequency x category); we keep the category
  proportions and allow scaling the totals down.
* Table 3: per-frequency length distributions (mean/std/min/max); lengths are
  sampled from a clipped lognormal fit to those moments.

Series are generated from the same family the Holt-Winters model assumes --
multiplicative level x seasonality x noise with occasional trend changes --
plus per-category flavor (Finance: heavier noise; Demographic: smoother;
Industry: stronger trend; etc.) so the category one-hot feature carries
signal, as in the real M4.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

CATEGORIES = ["Demographic", "Finance", "Industry", "Macro", "Micro", "Other"]

# Table 2 (paper) counts per frequency x category.
TABLE2_COUNTS = {
    "yearly": [1088, 6519, 3716, 3903, 6538, 1236],
    "quarterly": [1858, 5305, 4637, 5315, 6020, 865],
    "monthly": [5728, 10987, 10017, 10016, 10975, 277],
    "weekly": [24, 164, 6, 41, 112, 12],
    "daily": [10, 1559, 422, 127, 1476, 633],
    "hourly": [0, 0, 0, 0, 0, 414],
}

# Table 3 (paper) length stats: mean, std, min, max.
TABLE3_LEN_STATS = {
    "yearly": (25, 24, 7, 829),
    "quarterly": (84, 51, 8, 858),
    "monthly": (198, 137, 24, 2776),
    "weekly": (1009, 707, 67, 2584),
    "daily": (2343, 1756, 79, 9905),
    "hourly": (805, 127, 652, 912),
}

SEASONALITY = {"yearly": 1, "quarterly": 4, "monthly": 12, "weekly": 1,
               "daily": 1, "hourly": 24}
HORIZON = {"yearly": 6, "quarterly": 8, "monthly": 18, "weekly": 13,
           "daily": 14, "hourly": 48}

# per-category generator flavor: (noise_sigma, trend_sigma, seas_strength)
_CATEGORY_FLAVOR = {
    "Demographic": (0.015, 0.002, 0.08),
    "Finance": (0.06, 0.004, 0.05),
    "Industry": (0.03, 0.006, 0.15),
    "Macro": (0.02, 0.003, 0.10),
    "Micro": (0.04, 0.004, 0.12),
    "Other": (0.05, 0.005, 0.10),
}


@dataclasses.dataclass
class M4Dataset:
    """A bag of variable-length series for one frequency."""

    frequency: str
    series: List[np.ndarray]          # each (T_i,), float32, strictly > 0
    categories: np.ndarray            # (N,) int in [0, 6)
    seasonality: int
    horizon: int

    @property
    def n_series(self) -> int:
        return len(self.series)

    def category_onehot(self) -> np.ndarray:
        eye = np.eye(len(CATEGORIES), dtype=np.float32)
        return eye[self.categories]


def _sample_lengths(rng, n, freq):
    mean, std, lo, hi = TABLE3_LEN_STATS[freq]
    # lognormal matching the first two moments, clipped to [lo, hi]
    var = std**2
    sigma2 = np.log(1.0 + var / mean**2)
    mu = np.log(mean) - 0.5 * sigma2
    lengths = rng.lognormal(mu, np.sqrt(sigma2), n)
    return np.clip(lengths.astype(int), lo, hi)


def _gen_one(rng, length, seasonality, flavor):
    noise_sigma, trend_sigma, seas_strength = flavor
    base = rng.uniform(50.0, 5000.0)
    # log-level random walk with slowly-varying drift
    drift = rng.normal(0.0, trend_sigma)
    eps = rng.normal(0.0, trend_sigma, length).cumsum()
    log_level = np.log(base) + drift * np.arange(length) + eps
    if seasonality > 1:
        profile = rng.normal(0.0, seas_strength, seasonality)
        profile -= profile.mean()
        seas = np.exp(np.tile(profile, length // seasonality + 1)[:length])
    else:
        seas = 1.0
    noise = np.exp(rng.normal(0.0, noise_sigma, length))
    y = np.exp(log_level) * seas * noise
    return np.maximum(y, 1e-3).astype(np.float32)


def generate(
    frequency: str, *, scale: float = 0.01, seed: int = 0, min_series: int = 8
) -> M4Dataset:
    """Generate a synthetic M4 slice.

    ``scale`` multiplies the Table-2 counts (1.0 == full 100k-series M4;
    default 1% keeps CPU runs fast).
    """
    rng = np.random.default_rng(seed)
    counts = [max(min_series, int(c * scale)) if c else 0 for c in TABLE2_COUNTS[frequency]]
    m = SEASONALITY[frequency]
    series, cats = [], []
    for ci, (cat, cnt) in enumerate(zip(CATEGORIES, counts)):
        flavor = _CATEGORY_FLAVOR[cat]
        lengths = _sample_lengths(rng, cnt, frequency)
        for ln in lengths:
            series.append(_gen_one(rng, int(ln), m, flavor))
            cats.append(ci)
    return M4Dataset(
        frequency=frequency,
        series=series,
        categories=np.asarray(cats, np.int32),
        seasonality=m,
        horizon=HORIZON[frequency],
    )
