"""Data preparation (paper section 5).

* Eq. 7/8 splits: ``Train_{N-O*2-C..N-O*2-1}, Val_{N-O*2..N-O-1},
  Test_{N-O..N}`` with O = horizon, C = equalized length.
* Section 5.2 length equalization: drop series shorter than the per-frequency
  threshold (72 for quarterly/monthly in the paper), keep the most recent C
  observations of the rest.
* Batching: deterministic, seeded, *stateless* (step -> batch indices), so a
  restarted job resumes the exact data order (fault-tolerance requirement).
* Section 8.1 (future work in the paper, implemented here): variable-length
  support via left-padding + masks; `equalize` remains the faithful default.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from repro.data.synthetic_m4 import M4Dataset

# Paper section 5.2: minimum-length thresholds ("we used 72 as minimum series
# value for both quarterly and monthly").
MIN_LENGTH = {"yearly": 13, "quarterly": 72, "monthly": 72, "weekly": 80,
              "daily": 93, "hourly": 700}


@dataclasses.dataclass
class PreparedData:
    """Fixed-shape arrays ready for the model.

    train:     (N, C)   training portion (ends at N-2*O-1 per Eq. 8)
    val_input: (N, C+O) train+val observations (for forecasting the test part)
    val_target:(N, O)   validation targets
    test_target:(N, O)  test targets
    mask:      (N, C)   1 where train is real data (all-ones when equalized)
    cats:      (N, n_categories) one-hot
    """

    frequency: str
    seasonality: int
    horizon: int
    train: np.ndarray
    val_input: np.ndarray
    val_target: np.ndarray
    test_target: np.ndarray
    mask: np.ndarray
    cats: np.ndarray
    categories: np.ndarray

    @property
    def n_series(self) -> int:
        return self.train.shape[0]


def prepare(
    ds: M4Dataset,
    *,
    min_length: Optional[int] = None,
    variable_length: bool = False,
) -> PreparedData:
    """Equalize + split per sections 5.1/5.2.

    A series of raw length L supplies: test = last O, val = previous O,
    train = the C observations before those (so we require
    L >= C + 2*O, with C = min_length - 2*O_adjusted... the paper's C is the
    *train* length after removing val+test; we take C = min_length so that
    train windows always have >= one full output window).
    """
    o = ds.horizon
    c = int(min_length if min_length is not None else MIN_LENGTH[ds.frequency])
    need = c + 2 * o

    keep_idx, rows_train, rows_vin, rows_vt, rows_tt, rows_mask = [], [], [], [], [], []
    for i, y in enumerate(ds.series):
        ln = len(y)
        if ln < need:
            if not variable_length or ln < (2 * o + max(2 * ds.seasonality, 8)):
                continue  # section 5.2: disregard series below the threshold
        tail = y[-need:] if ln >= need else y
        t = len(tail)
        test = tail[t - o:]
        val = tail[t - 2 * o : t - o]
        train = tail[: t - 2 * o]
        if variable_length and len(train) < c:
            pad = np.full(c - len(train), train[0], np.float32)  # left-pad
            mask = np.concatenate([np.zeros(c - len(train)), np.ones(len(train))])
            train = np.concatenate([pad, train])
        else:
            mask = np.ones(c, np.float32)
        keep_idx.append(i)
        rows_train.append(train.astype(np.float32))
        rows_vin.append(np.concatenate([train, val]).astype(np.float32))
        rows_vt.append(val.astype(np.float32))
        rows_tt.append(test.astype(np.float32))
        rows_mask.append(mask.astype(np.float32))

    if not keep_idx:
        raise ValueError(
            f"no series of {ds.frequency} met the min length {need}"
        )
    cats_int = ds.categories[np.asarray(keep_idx)]
    onehot = np.eye(ds.category_onehot().shape[1], dtype=np.float32)[cats_int]
    return PreparedData(
        frequency=ds.frequency,
        seasonality=ds.seasonality,
        horizon=o,
        train=np.stack(rows_train),
        val_input=np.stack(rows_vin),
        val_target=np.stack(rows_vt),
        test_target=np.stack(rows_tt),
        mask=np.stack(rows_mask),
        cats=onehot,
        categories=cats_int,
    )


def synthetic_prepared(
    n_series: int,
    *,
    frequency: str = "quarterly",
    seasonality: int = 4,
    horizon: int = 8,
    series_length: int = 24,
    n_categories: int = 6,
    seed: int = 0,
) -> PreparedData:
    """Fully vectorized synthetic :class:`PreparedData` at arbitrary N.

    ``prepare(generate(...))`` walks a python loop per series -- fine at M4
    scale, minutes and a second full copy at 1M rows. This builds the
    fixed-shape arrays directly (level walk x seasonal pattern x noise, one
    vectorized expression) for the million-series smoke and the
    memory-footprint bench: ~160 MB of host float32 at N=1M, T=24+2*8.
    """
    rng = np.random.default_rng(seed)
    t_total = series_length + 2 * horizon
    level = (10.0 + 5.0 * rng.random((n_series, 1))).astype(np.float32)
    drift = (0.05 * (rng.random((n_series, 1)) - 0.3)).astype(np.float32)
    phase = rng.integers(0, max(seasonality, 1), (n_series, 1))
    t = np.arange(t_total, dtype=np.float32)[None, :]
    seas = 1.0 + 0.1 * np.sin(
        2.0 * np.pi * (t + phase) / max(seasonality, 1)).astype(np.float32)
    noise = 1.0 + 0.02 * rng.standard_normal(
        (n_series, t_total)).astype(np.float32)
    y = (level * (1.0 + drift * t) * seas * noise).astype(np.float32)
    np.maximum(y, 0.1, out=y)
    cats_int = rng.integers(0, n_categories, n_series)
    return PreparedData(
        frequency=frequency,
        seasonality=seasonality,
        horizon=horizon,
        train=y[:, :series_length],
        val_input=y[:, : series_length + horizon],
        val_target=y[:, series_length : series_length + horizon],
        test_target=y[:, series_length + horizon :],
        mask=np.ones((n_series, series_length), np.float32),
        cats=np.eye(n_categories, dtype=np.float32)[cats_int],
        categories=cats_int,
    )


class _BoundedPermCache:
    """LRU permutation cache bounded by BYTES, not entry count.

    The old ``lru_cache(maxsize=64)`` bounded *entries*: at 1M series each
    epoch permutation is 8 MB, so a long run could pin 512 MB of host memory
    in permutations alone and never evict. Bounding by bytes keeps the
    small-N behavior (identity-stable hits, read-only arrays) while making
    the worst case a fixed budget. A single permutation larger than the
    whole budget is returned uncached (drawn fresh per call) -- million-row
    *global* perms are exactly what the chunk-local schedule below exists to
    avoid materializing.
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self._entries: "collections.OrderedDict[tuple, np.ndarray]" = (
            collections.OrderedDict())

    def get_or_draw(self, key: tuple, draw: Callable[[], np.ndarray]):
        arr = self._entries.get(key)
        if arr is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return arr
        self.misses += 1
        arr = draw()
        arr.flags.writeable = False
        if arr.nbytes <= self.max_bytes:
            self._entries[key] = arr
            self.nbytes += arr.nbytes
            while self.nbytes > self.max_bytes:
                _, old = self._entries.popitem(last=False)
                self.nbytes -= old.nbytes
        return arr

    def clear(self):
        self._entries.clear()
        self.nbytes = self.hits = self.misses = 0


# One shared budget for the global-epoch and the chunk-local permutations.
PERM_CACHE_BYTES = 64 << 20
_perm_cache = _BoundedPermCache(PERM_CACHE_BYTES)


def epoch_permutation(n_series: int, epoch: int, seed: int = 0) -> np.ndarray:
    """The (cached) series permutation for one epoch of the schedule.

    Bit-identical to ``np.random.default_rng(SeedSequence([seed, epoch]))
    .permutation(n_series)`` -- the contract :func:`batch_indices` has always
    had -- but materialized once per ``(n_series, epoch, seed)`` instead of
    on every call: a 300-step epoch used to re-draw the same permutation 300
    times. The returned array is marked read-only because it is shared by
    every caller of the cache; the cache itself is bounded by
    :data:`PERM_CACHE_BYTES` (LRU in bytes -- 64 cached 1M-row epochs would
    otherwise pin half a gigabyte of host memory).
    """
    def draw():
        rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
        return rng.permutation(n_series)

    return _perm_cache.get_or_draw(("epoch", n_series, epoch, seed), draw)


def chunk_permutation(
    n_rows: int, epoch: int, chunk_id: int, seed: int = 0
) -> np.ndarray:
    """Shard-local epoch permutation: rows *within* one series chunk.

    Deterministic in ``(seed, epoch, chunk_id)`` and independent of the
    total series count -- the chunked training schedule never materializes a
    global (N,) permutation per batch; each chunk draws its own
    ``(n_rows,)`` perm (bounded-cache shared with
    :func:`epoch_permutation`). The entropy tuple carries a trailing
    ``1 + chunk_id`` so no (seed, epoch) stream collides with the global
    epoch permutation or the chunk visit order.
    """
    def draw():
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, epoch, 1 + chunk_id]))
        return rng.permutation(n_rows)

    return _perm_cache.get_or_draw(
        ("chunk", n_rows, epoch, chunk_id, seed), draw)


def batch_indices(
    n_series: int, batch_size: int, step: int, *, seed: int = 0
) -> np.ndarray:
    """Stateless batch schedule: (epoch, step-within-epoch) -> series indices.

    Deterministic in (seed, step); a restarted trainer replays the same order
    without any iterator state in the checkpoint. The per-epoch permutation
    comes from the :func:`epoch_permutation` cache, so repeated calls within
    an epoch only slice.
    """
    steps_per_epoch = max(1, -(-n_series // batch_size))
    epoch, k = divmod(step, steps_per_epoch)
    perm = epoch_permutation(n_series, epoch, seed)
    sl = perm[k * batch_size : (k + 1) * batch_size]
    if len(sl) < batch_size:  # wrap to keep shapes static
        sl = np.concatenate([sl, perm[: batch_size - len(sl)]])
    return np.array(sl)  # private, writable copy (the cache stays frozen)


def batch_schedule(
    n_series: int, batch_size: int, start_step: int, n_steps: int, *,
    seed: int = 0,
) -> np.ndarray:
    """Materialize ``n_steps`` of the stateless schedule as one index array.

    Returns an ``(n_steps, batch_size)`` int array whose row ``i`` equals
    ``batch_indices(n_series, batch_size, start_step + i, seed=seed)`` -- the
    fused training engine uploads it to the device once and ``lax.scan``s
    over the rows, instead of drawing + transferring one batch per Python
    step. Stateless in ``start_step``, so a resumed run slices the same
    global schedule (fault-tolerance contract unchanged).
    """
    if n_steps <= 0:
        return np.empty((0, batch_size), dtype=np.int64)
    return np.stack([
        batch_indices(n_series, batch_size, s, seed=seed)
        for s in range(start_step, start_step + n_steps)
    ])


# ---------------------------------------------------------------------------
# Chunk-major schedule (out-of-core / streaming fit)
# ---------------------------------------------------------------------------
#
# With ``series_chunk = K`` the N series are partitioned into contiguous row
# ranges of K; an epoch visits the chunks in a per-epoch permuted order and
# runs each chunk's full within-chunk epoch (ceil(rows/batch) steps over a
# chunk-local permutation) before moving on. Batches are chunk-pure by
# construction -- the streaming trainer only ever needs ONE chunk's rows on
# device -- and the whole schedule stays stateless in the global step, so
# resume/fault-tolerance works exactly like the flat schedule.


def chunk_bounds(n_series: int, chunk: int) -> List[Tuple[int, int]]:
    """Contiguous ``[lo, hi)`` row ranges partitioning N series into chunks."""
    if chunk <= 0:
        raise ValueError(f"series chunk must be positive, got {chunk}")
    return [(lo, min(lo + chunk, n_series))
            for lo in range(0, n_series, chunk)]


def chunk_visit_order(n_chunks: int, epoch: int, seed: int = 0) -> np.ndarray:
    """The order an epoch visits the chunks in (deterministic, stateless)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch, 0]))
    return rng.permutation(n_chunks)


def chunk_layout(
    n_series: int, chunk: int, batch_size: int
) -> Tuple[List[Tuple[int, int, int, int]], int]:
    """Static shape plan of the chunk-major schedule.

    Returns ``(per_chunk, steps_per_epoch)`` where ``per_chunk[c]`` is
    ``(lo, hi, bs_c, steps_c)``: the chunk's row range, its effective batch
    size (``min(batch_size, rows)`` -- only a ragged last chunk differs, one
    extra XLA compile), and its steps per epoch ``ceil(rows / bs_c)``.
    """
    per_chunk = []
    for lo, hi in chunk_bounds(n_series, chunk):
        bs_c = min(batch_size, hi - lo)
        per_chunk.append((lo, hi, bs_c, -(-(hi - lo) // bs_c)))
    return per_chunk, sum(s for _, _, _, s in per_chunk)


def chunk_batch_indices(
    n_rows: int, batch_size: int, epoch: int, chunk_id: int, k: int, *,
    seed: int = 0,
) -> np.ndarray:
    """Chunk-LOCAL row indices for step ``k`` of a chunk's epoch visit.

    Mirrors :func:`batch_indices` (slice the cached permutation, wrap the
    short tail to keep shapes static) against the chunk-local permutation.
    Indices are relative to the chunk's ``lo``; add ``lo`` for global rows.
    """
    perm = chunk_permutation(n_rows, epoch, chunk_id, seed)
    sl = perm[k * batch_size : (k + 1) * batch_size]
    if len(sl) < batch_size:
        sl = np.concatenate([sl, perm[: batch_size - len(sl)]])
    return np.array(sl)


def chunk_batch_schedule(
    n_rows: int, batch_size: int, epoch: int, chunk_id: int, start_k: int,
    n_steps: int, *, seed: int = 0,
) -> np.ndarray:
    """``(n_steps, batch_size)`` chunk-local schedule (cf. batch_schedule)."""
    if n_steps <= 0:
        return np.empty((0, batch_size), dtype=np.int64)
    return np.stack([
        chunk_batch_indices(n_rows, batch_size, epoch, chunk_id, k, seed=seed)
        for k in range(start_k, start_k + n_steps)
    ])


@dataclasses.dataclass(frozen=True)
class ChunkVisit:
    """One chunk's (possibly partial) epoch visit in global step coordinates.

    ``start_k`` is the step offset *within* the visit (non-zero only when a
    resume lands mid-visit); ``step`` is the global step of the visit's
    first scheduled step, so ``step - start_k`` is the visit's base.
    """

    epoch: int
    chunk_id: int
    lo: int
    hi: int
    batch_size: int
    step: int
    start_k: int
    n_steps: int


def chunk_visit_plan(
    n_series: int, chunk: int, batch_size: int, start_step: int,
    n_steps: int, *, seed: int = 0,
) -> Iterator[ChunkVisit]:
    """Yield the chunk visits covering global steps [start_step, n_steps).

    Stateless in ``start_step``: a resumed run re-enters the same global
    schedule mid-visit (same chunks, same per-chunk permutations, same
    order), exactly like :func:`batch_indices` for the flat schedule.
    """
    per_chunk, spe = chunk_layout(n_series, chunk, batch_size)
    epoch = start_step // spe
    base = epoch * spe
    while base < n_steps:
        for c in chunk_visit_order(len(per_chunk), epoch, seed):
            lo, hi, bs_c, steps_c = per_chunk[c]
            s0 = max(base, start_step)
            s1 = min(base + steps_c, n_steps)
            if s1 > s0:
                yield ChunkVisit(epoch=epoch, chunk_id=int(c), lo=lo, hi=hi,
                                 batch_size=bs_c, step=s0, start_k=s0 - base,
                                 n_steps=s1 - s0)
            base += steps_c
            if base >= n_steps:
                break
        epoch += 1


def iterate_batches(
    data: PreparedData, batch_size: int, n_steps: int, *, seed: int = 0,
    start_step: int = 0,
) -> Iterator[Tuple[int, np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (step, series_idx, y, cats) minibatches; resumable at any step."""
    for step in range(start_step, n_steps):
        idx = batch_indices(data.n_series, batch_size, step, seed=seed)
        yield step, idx, data.train[idx], data.cats[idx]
