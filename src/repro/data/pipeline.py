"""Data preparation (paper section 5).

* Eq. 7/8 splits: ``Train_{N-O*2-C..N-O*2-1}, Val_{N-O*2..N-O-1},
  Test_{N-O..N}`` with O = horizon, C = equalized length.
* Section 5.2 length equalization: drop series shorter than the per-frequency
  threshold (72 for quarterly/monthly in the paper), keep the most recent C
  observations of the rest.
* Batching: deterministic, seeded, *stateless* (step -> batch indices), so a
  restarted job resumes the exact data order (fault-tolerance requirement).
* Section 8.1 (future work in the paper, implemented here): variable-length
  support via left-padding + masks; `equalize` remains the faithful default.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic_m4 import M4Dataset

# Paper section 5.2: minimum-length thresholds ("we used 72 as minimum series
# value for both quarterly and monthly").
MIN_LENGTH = {"yearly": 13, "quarterly": 72, "monthly": 72, "weekly": 80,
              "daily": 93, "hourly": 700}


@dataclasses.dataclass
class PreparedData:
    """Fixed-shape arrays ready for the model.

    train:     (N, C)   training portion (ends at N-2*O-1 per Eq. 8)
    val_input: (N, C+O) train+val observations (for forecasting the test part)
    val_target:(N, O)   validation targets
    test_target:(N, O)  test targets
    mask:      (N, C)   1 where train is real data (all-ones when equalized)
    cats:      (N, n_categories) one-hot
    """

    frequency: str
    seasonality: int
    horizon: int
    train: np.ndarray
    val_input: np.ndarray
    val_target: np.ndarray
    test_target: np.ndarray
    mask: np.ndarray
    cats: np.ndarray
    categories: np.ndarray

    @property
    def n_series(self) -> int:
        return self.train.shape[0]


def prepare(
    ds: M4Dataset,
    *,
    min_length: Optional[int] = None,
    variable_length: bool = False,
) -> PreparedData:
    """Equalize + split per sections 5.1/5.2.

    A series of raw length L supplies: test = last O, val = previous O,
    train = the C observations before those (so we require
    L >= C + 2*O, with C = min_length - 2*O_adjusted... the paper's C is the
    *train* length after removing val+test; we take C = min_length so that
    train windows always have >= one full output window).
    """
    o = ds.horizon
    c = int(min_length if min_length is not None else MIN_LENGTH[ds.frequency])
    need = c + 2 * o

    keep_idx, rows_train, rows_vin, rows_vt, rows_tt, rows_mask = [], [], [], [], [], []
    for i, y in enumerate(ds.series):
        ln = len(y)
        if ln < need:
            if not variable_length or ln < (2 * o + max(2 * ds.seasonality, 8)):
                continue  # section 5.2: disregard series below the threshold
        tail = y[-need:] if ln >= need else y
        t = len(tail)
        test = tail[t - o:]
        val = tail[t - 2 * o : t - o]
        train = tail[: t - 2 * o]
        if variable_length and len(train) < c:
            pad = np.full(c - len(train), train[0], np.float32)  # left-pad
            mask = np.concatenate([np.zeros(c - len(train)), np.ones(len(train))])
            train = np.concatenate([pad, train])
        else:
            mask = np.ones(c, np.float32)
        keep_idx.append(i)
        rows_train.append(train.astype(np.float32))
        rows_vin.append(np.concatenate([train, val]).astype(np.float32))
        rows_vt.append(val.astype(np.float32))
        rows_tt.append(test.astype(np.float32))
        rows_mask.append(mask.astype(np.float32))

    if not keep_idx:
        raise ValueError(
            f"no series of {ds.frequency} met the min length {need}"
        )
    cats_int = ds.categories[np.asarray(keep_idx)]
    onehot = np.eye(ds.category_onehot().shape[1], dtype=np.float32)[cats_int]
    return PreparedData(
        frequency=ds.frequency,
        seasonality=ds.seasonality,
        horizon=o,
        train=np.stack(rows_train),
        val_input=np.stack(rows_vin),
        val_target=np.stack(rows_vt),
        test_target=np.stack(rows_tt),
        mask=np.stack(rows_mask),
        cats=onehot,
        categories=cats_int,
    )


@functools.lru_cache(maxsize=64)
def epoch_permutation(n_series: int, epoch: int, seed: int = 0) -> np.ndarray:
    """The (cached) series permutation for one epoch of the schedule.

    Bit-identical to ``np.random.default_rng(SeedSequence([seed, epoch]))
    .permutation(n_series)`` -- the contract :func:`batch_indices` has always
    had -- but materialized once per ``(n_series, epoch, seed)`` instead of
    on every call: a 300-step epoch used to re-draw the same permutation 300
    times. The returned array is marked read-only because it is shared by
    every caller of the cache.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    perm = rng.permutation(n_series)
    perm.flags.writeable = False
    return perm


def batch_indices(
    n_series: int, batch_size: int, step: int, *, seed: int = 0
) -> np.ndarray:
    """Stateless batch schedule: (epoch, step-within-epoch) -> series indices.

    Deterministic in (seed, step); a restarted trainer replays the same order
    without any iterator state in the checkpoint. The per-epoch permutation
    comes from the :func:`epoch_permutation` cache, so repeated calls within
    an epoch only slice.
    """
    steps_per_epoch = max(1, -(-n_series // batch_size))
    epoch, k = divmod(step, steps_per_epoch)
    perm = epoch_permutation(n_series, epoch, seed)
    sl = perm[k * batch_size : (k + 1) * batch_size]
    if len(sl) < batch_size:  # wrap to keep shapes static
        sl = np.concatenate([sl, perm[: batch_size - len(sl)]])
    return np.array(sl)  # private, writable copy (the cache stays frozen)


def batch_schedule(
    n_series: int, batch_size: int, start_step: int, n_steps: int, *,
    seed: int = 0,
) -> np.ndarray:
    """Materialize ``n_steps`` of the stateless schedule as one index array.

    Returns an ``(n_steps, batch_size)`` int array whose row ``i`` equals
    ``batch_indices(n_series, batch_size, start_step + i, seed=seed)`` -- the
    fused training engine uploads it to the device once and ``lax.scan``s
    over the rows, instead of drawing + transferring one batch per Python
    step. Stateless in ``start_step``, so a resumed run slices the same
    global schedule (fault-tolerance contract unchanged).
    """
    if n_steps <= 0:
        return np.empty((0, batch_size), dtype=np.int64)
    return np.stack([
        batch_indices(n_series, batch_size, s, seed=seed)
        for s in range(start_step, start_step + n_steps)
    ])


def iterate_batches(
    data: PreparedData, batch_size: int, n_steps: int, *, seed: int = 0,
    start_step: int = 0,
) -> Iterator[Tuple[int, np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (step, series_idx, y, cats) minibatches; resumable at any step."""
    for step in range(start_step, n_steps):
        idx = batch_indices(data.n_series, batch_size, step, seed=seed)
        yield step, idx, data.train[idx], data.cats[idx]
