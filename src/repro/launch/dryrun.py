import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above is the very first
statement, before any jax import, because jax locks the device count at
first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch esrnn-quarterly --shape m4_train

Outputs one JSON per cell under experiments/dryrun/<mesh>/.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_cells, cell_applicable, get_config
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.roofline import analysis
from repro.roofline.jaxpr_cost import jaxpr_flops
from repro.sharding import specs
from repro.sharding.ctx import activation_sharding


def _shardings_for_tree(mesh, tree_abs, fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, fn(path, leaf)), tree_abs)


def lower_cell(arch: str, shape: str, mesh, *, donate: bool = True):
    """Build abstract inputs + jit with shardings; return (lowered, meta)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    model = build_model(cfg)
    axes = specs.axes_for(mesh)
    specs.set_mesh(mesh)

    specs.set_param_mode("decode" if cell.kind == "decode" else "train")
    batch_abs = steps.batch_template(cfg, cell)
    batch_sh = specs.batch_shardings(mesh, batch_abs, cell.global_batch)

    meta = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "n_params": cfg.param_count(),
        "n_params_active": cfg.active_param_count(),
    }

    with mesh, activation_sharding(mesh, dp=axes["dp"], tp=axes["tp"]):
        if cell.kind == "train":
            params_abs = steps.abstract_params(model, master_fp32=True)
            params_sh = specs.param_shardings(mesh, params_abs)
            opt_abs = steps.abstract_opt_state(params_abs)
            opt_sh = {
                "mu": params_sh, "nu": params_sh,
                "step": NamedSharding(mesh, P()),
            }
            fn = steps.make_train_step(model, cell)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1) if donate else (),
            )
            traced = jitted.trace(params_abs, opt_abs, batch_abs)
            meta["flops_jaxpr"] = jaxpr_flops(traced.jaxpr)
            lowered = traced.lower()
            meta["tokens"] = cell.global_batch * cell.seq_len
        elif cell.kind == "prefill":
            params_abs = steps.abstract_params(model, master_fp32=False)
            params_sh = specs.param_shardings(mesh, params_abs)
            caches_abs = jax.eval_shape(
                lambda: model.make_caches(cell.global_batch, cell.seq_len, jnp.bfloat16))
            caches_sh = specs.cache_shardings(mesh, caches_abs, cell.global_batch)
            fn = steps.make_prefill_step(model, cell)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, P()), caches_sh),
            )
            traced = jitted.trace(params_abs, batch_abs)
            meta["flops_jaxpr"] = jaxpr_flops(traced.jaxpr)
            lowered = traced.lower()
            meta["tokens"] = cell.global_batch * cell.seq_len
        else:  # decode
            params_abs = steps.abstract_params(model, master_fp32=False)
            params_sh = specs.param_shardings(mesh, params_abs)
            caches_abs = jax.eval_shape(
                lambda: model.make_caches(cell.global_batch, cell.seq_len, jnp.bfloat16))
            caches_sh = specs.cache_shardings(mesh, caches_abs, cell.global_batch)
            fn = steps.make_decode_step(model, cell)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, batch_sh, caches_sh),
                out_shardings=(NamedSharding(mesh, P()), caches_sh),
                donate_argnums=(2,) if donate else (),
            )
            traced = jitted.trace(params_abs, batch_abs, caches_abs)
            meta["flops_jaxpr"] = jaxpr_flops(traced.jaxpr)
            lowered = traced.lower()
            meta["tokens"] = cell.global_batch  # one token per sequence
    return lowered, meta


# ---------------------------------------------------------------------------
# ES-RNN (the paper's own model) dry-run cells
# ---------------------------------------------------------------------------

ESRNN_CELLS = {
    # N series per batch, equalized length C (paper: 72 for quarterly/monthly)
    "m4_train": dict(n_series=262144, t_len=72),
    "m4_train_monthly": dict(n_series=262144, t_len=72),
}


def lower_esrnn(arch: str, shape: str, mesh):
    from repro.core.esrnn import esrnn_init, esrnn_loss, make_config
    from repro.train.optimizer import AdamConfig, adam_init, adam_update, esrnn_group_fn

    freq = arch.split("-", 1)[1]
    cfg = make_config(freq)
    cell = ESRNN_CELLS[shape]
    n, t_len = cell["n_series"], cell["t_len"]
    axes = specs.axes_for(mesh)
    specs.set_mesh(mesh)
    dp = axes["dp"]

    params_abs = jax.eval_shape(
        lambda k: esrnn_init(k, cfg, n), jax.random.PRNGKey(0))

    def esrnn_param_spec(path, leaf):
        names = specs._path_names(path)
        if "hw" in names:  # per-series: shard on data, grads sync-free
            return P(*([dp] + [None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    params_sh = _shardings_for_tree(mesh, params_abs, esrnn_param_spec)
    opt_abs = jax.eval_shape(adam_init, params_abs)
    opt_sh = {"mu": params_sh, "nu": params_sh, "step": NamedSharding(mesh, P())}
    y_abs = jax.ShapeDtypeStruct((n, t_len), jnp.float32)
    c_abs = jax.ShapeDtypeStruct((n, cfg.n_categories), jnp.float32)
    data_sh = (NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp, None)))
    adam = AdamConfig(lr=1e-3, group_lr={"per_series": 10.0, "default": 1.0})

    def train_step(params, opt_state, y, cats):
        loss, grads = jax.value_and_grad(
            lambda p: esrnn_loss(cfg, p, y, cats))(params)
        params, opt_state = adam_update(grads, opt_state, params, adam,
                                        group_fn=esrnn_group_fn)
        return params, opt_state, loss

    with mesh, activation_sharding(mesh, dp=dp, tp=axes["tp"]):
        jitted = jax.jit(
            train_step,
            in_shardings=(params_sh, opt_sh) + data_sh,
            out_shardings=(params_sh, opt_sh, NamedSharding(mesh, P())),
        )
        traced = jitted.trace(params_abs, opt_abs, y_abs, c_abs)
        flops = jaxpr_flops(traced.jaxpr)
        lowered = traced.lower()
    meta = {"arch": arch, "shape": shape, "kind": "train", "flops_jaxpr": flops,
            "seq_len": t_len, "global_batch": n,
            "n_params": int(n * (2 + cfg.seasonality)),
            "n_params_active": int(n * (2 + cfg.seasonality)),
            "tokens": n * t_len}
    return lowered, meta


# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        if arch.startswith("esrnn-"):
            lowered, meta = lower_esrnn(arch, shape, mesh)
        else:
            lowered, meta = lower_cell(arch, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        terms = analysis.analyze(compiled, chips,
                                 flops_global=meta.get("flops_jaxpr"))
        mf = analysis.model_flops(meta["n_params_active"], meta["tokens"])
        if meta["kind"] == "train":
            mf *= 3  # fwd + bwd
        result = {
            **meta,
            "mesh": mesh_kind,
            "chips": chips,
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "roofline": terms.to_dict(),
            "model_flops": mf,
            "useful_flops_ratio": (mf / terms.flops_global
                                   if terms.flops_global else None),
        }
        mem = compiled.memory_analysis()
        result["memory_analysis"] = {
            "argument_size": mem.argument_size_in_bytes,
            "output_size": mem.output_size_in_bytes,
            "temp_size": mem.temp_size_in_bytes,
            "alias_size": mem.alias_size_in_bytes,
        }
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        result = {
            "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": chips,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch}__{shape}.json")
    with open(fname, "w") as f:
        json.dump(result, f, indent=1)
    jax.clear_caches()  # keep the long --all sweep's memory bounded
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = os.path.join(args.out, args.mesh)
    cells = []
    if args.all:
        cells = all_cells()
        cells += [("esrnn-quarterly", "m4_train")]
    else:
        ok, why = (True, "") if args.arch.startswith("esrnn-") else \
            cell_applicable(args.arch, args.shape)
        if not ok:
            print(f"SKIP {args.arch} x {args.shape}: {why}")
            return
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        r = run_cell(arch, shape, args.mesh, out_dir)
        if r["status"] == "ok":
            rt = r["roofline"]
            print(f"OK   {arch:24s} {shape:12s} {args.mesh:6s} "
                  f"compile={r['compile_s']:.0f}s "
                  f"comp={rt['compute_s']:.2e}s mem={rt['memory_s']:.2e}s "
                  f"coll={rt['collective_s']:.2e}s dom={rt['dominant']}")
        else:
            print(f"FAIL {arch:24s} {shape:12s} {args.mesh:6s} {r['error']}")


if __name__ == "__main__":
    main()
