"""Forecast launcher: the one CLI over the unified ESRNNForecaster API.

    PYTHONPATH=src python -m repro.launch.forecast specs
    PYTHONPATH=src python -m repro.launch.forecast fit      --spec esrnn-quarterly --smoke
    PYTHONPATH=src python -m repro.launch.forecast predict  --dir /tmp/fq
    PYTHONPATH=src python -m repro.launch.forecast eval     --spec esrnn-quarterly --smoke
    PYTHONPATH=src python -m repro.launch.forecast backtest --dir /tmp/fq --origins 72,80
    PYTHONPATH=src python -m repro.launch.forecast serve    --smoke --requests 64
    PYTHONPATH=src python -m repro.launch.forecast analyze  --smoke --set head=esn
    echo '{"op":"observe","series_id":0,"y":105.2}' | \\
        PYTHONPATH=src python -m repro.launch.forecast observe --smoke

``specs`` lists the registry (name, frequency, horizon, head per spec;
``--json`` for machines). Heads are pluggable (``repro.core.heads``): pick
one by spec name (``--spec esn-quarterly``) or by override
(``--set head=ssm``) -- every subcommand below works with every head.

``fit`` trains (spec-driven synthetic M4 by default) and optionally saves
the estimator; ``predict``/``eval``/``backtest`` run on a saved estimator
(``--dir``) or fit a fresh one; ``serve`` runs the continuous-batching
forecast server (bounded queue -> deadline-driven bucket fill -> jit-cached
batched dispatch; ``--engine batch`` selects the synchronous batch-at-a-
time wrapper) over a synthetic ragged request stream and reports latency
percentiles, throughput and jit-cache reuse, mirroring the prefill/decode
serving loop of ``repro.launch.serve``; ``observe`` drives the same server
as a scripted JSONL op loop over stdin (online ``observe`` ingestion +
read-your-writes forecasts + stats).

``analyze`` runs the graph auditor (``repro.analysis``): five static
invariant lints -- recompile sentinel, gradient leak, donation, collectives,
dtype policy -- over the jaxprs and compiled HLO of the real fit / predict /
serve entry points, printed as a JSON report; the exit code is the number
of violations clamped to 1, so CI gates on it directly. ``--entries``
picks the audited surfaces; add ``collectives`` (or pass ``--devices N``)
for the partitioned-HLO collective audit.

``backtest`` is the rolling-origin protocol: forecast at each ``--origins``
observation count as if the rest of the series were unseen, scored
sMAPE/MASE per origin -- all origins are read off ONE forward pass of the
state-space core (the causal ES states are already the re-primed
truncated-history states), no refit.

``--devices N`` applies to every subcommand: ``fit`` trains series-data-
parallel, and ``predict``/``eval``/``backtest``/``serve`` run sharded
inference over a series mesh (per-series HW rows device-local under
``shard_map``; eval/backtest metrics reduced as exact psum'd global means).
On CPU export ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

``--set use_pallas=true`` routes fit *and* every inference path through the
Pallas kernels (trainable via their custom_vjp backward kernels; interpret
mode off-TPU); it composes with ``--devices N``.

``--set scan_steps=K`` fuses K training steps into one donated ``lax.scan``
superstep (the dispatch-bound per-step loop is the K=1 default); eval,
checkpoints, and hooks fire at superstep boundaries, on the same absolute
steps. ``--set sparse_adam=true`` adds the sparse per-series Adam segment
update. Both compose with ``--devices N`` and ``use_pallas``.

``--set series_chunk=K`` turns on the out-of-core path: the per-series
Holt-Winters table and its sparse-Adam state live in host memory and stream
through the device K rows at a time (fit, predict, eval, and backtest all
chunk; implies ``sparse_adam``). The chunk is the outer loop and the
``--devices`` mesh the inner shard, so a million-series fit runs in
O(series_chunk) device memory while walking the exact resident trajectory.
"""

from __future__ import annotations

import argparse
import logging

import numpy as np

from repro.forecast import (
    BucketDispatcher, ESRNNForecaster, get_smoke_spec, get_spec,
    list_specs, synthetic_request_stream,
)

log = logging.getLogger("repro.launch.forecast")


def _parse_overrides(pairs):
    out = {}
    for pair in pairs or []:
        key, eq, val = pair.partition("=")
        if not eq or not key or not val:
            raise SystemExit(
                f"error: --set expects KEY=VAL, got {pair!r}")
        if val.lower() in ("true", "false"):
            out[key] = val.lower() == "true"
            continue
        try:
            out[key] = int(val)
        except ValueError:
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
    return out


def _build(args) -> ESRNNForecaster:
    over = _parse_overrides(getattr(args, "set", None))
    if getattr(args, "steps", None) is not None:
        over["n_steps"] = args.steps
    if getattr(args, "devices", None) is not None:
        over["data_parallel"] = args.devices
    spec = (get_smoke_spec(args.spec, **over) if args.smoke
            else get_spec(args.spec, **over))
    return ESRNNForecaster(spec)


def _fitted(args) -> ESRNNForecaster:
    """Saved estimator if --dir given, else a freshly fitted one."""
    if getattr(args, "dir", None):
        f = ESRNNForecaster.load(args.dir)
        f.data_ = f.make_data()
        return f
    f = _build(args)
    log.info("no --dir: fitting %s for %d steps", f.spec.name, f.spec.n_steps)
    return f.fit()


def _inference_mesh(args):
    """Series mesh for sharded predict/eval/backtest/serve (--devices N)."""
    d = getattr(args, "devices", None)
    if d and d > 1:
        from repro.sharding.series import make_series_mesh

        return make_series_mesh(d)
    return None


def cmd_specs(args):
    """List the spec registry: one row per name, with the head made visible."""
    import json

    rows = [dict(name=n, frequency=(s := get_spec(n)).frequency,
                 horizon=s.horizon, head=s.model.head)
            for n in list_specs()]
    if args.json:
        print(json.dumps(rows, indent=2))
        return 0
    w = max(len(r["name"]) for r in rows)
    print(f"{'name':{w}s}  {'frequency':9s}  {'horizon':>7s}  head")
    for r in rows:
        print(f"{r['name']:{w}s}  {r['frequency']:9s}  "
              f"{r['horizon']:7d}  {r['head']}")
    return 0


def cmd_fit(args):
    f = _build(args)
    f.fit(ckpt_dir=args.ckpt_dir)
    h = f.history_["loss"]
    if h:
        print(f"{f.spec.name}: {len(h)} steps, loss {h[0]:.4f} -> {h[-1]:.4f}, "
              f"{f.n_series_} series")
    else:
        print(f"{f.spec.name}: resumed from a finished checkpoint, "
              f"{f.n_series_} series")
    if f.history_["val_smape"]:
        step, vs = f.history_["val_smape"][-1]
        print(f"val sMAPE @ step {step}: {vs:.3f}")
    if args.out_dir:
        print("saved to", f.save(args.out_dir))
    return 0


def cmd_predict(args):
    f = _fitted(args)
    mesh = _inference_mesh(args)
    if args.quantiles:
        taus = tuple(float(t) for t in args.quantiles.split(","))
        bands = f.predict_quantiles(taus=taus, mesh=mesh)
        for tau in taus:
            print(f"tau={tau}: first series", np.round(bands[tau][0], 2))
    else:
        fc = f.predict(mesh=mesh)
        print(f"forecast {fc.shape}; first series", np.round(fc[0], 2))
    return 0


def cmd_eval(args):
    f = _fitted(args)
    scores = f.evaluate(split=args.split, mesh=_inference_mesh(args))
    print(f"{f.spec.name} [{args.split}]")
    for suffix, label in (("", "esrnn"), ("_comb", "comb"), ("_naive2", "naive2")):
        smape = scores[f"smape{suffix}"]
        mase = scores[f"mase{suffix}"]
        owa = scores.get(f"owa{suffix}")
        owa_s = f"  owa {owa:7.3f}" if owa is not None else ""
        print(f"  {label:8s} smape {smape:7.3f}  mase {mase:7.3f}{owa_s}")
    return 0


def cmd_backtest(args):
    f = _fitted(args)
    origins = (tuple(int(o) for o in args.origins.split(","))
               if args.origins else None)
    out = f.backtest(origins=origins, mesh=_inference_mesh(args))
    print(f"{f.spec.name} rolling-origin backtest "
          f"(horizon {out['horizon']}, one forward pass)")
    for row in out["per_origin"]:
        print(f"  origin {row['origin']:5d}  smape {row['smape']:7.3f}  "
              f"mase {row['mase']:7.3f}")
    print(f"  {'overall':>12s}  smape {out['smape']:7.3f}  "
          f"mase {out['mase']:7.3f}")
    return 0


def cmd_serve(args):
    import time

    f = _fitted(args)
    buckets = dict(
        length_buckets=tuple(int(b) for b in args.length_buckets.split(",")),
        batch_buckets=tuple(int(b) for b in args.batch_buckets.split(",")),
    )
    mesh = _inference_mesh(args)
    if args.engine == "batch":
        srv = BucketDispatcher(
            f.config, f.params_, max_batch=args.max_batch, mesh=mesh,
            **buckets)
        t0 = time.perf_counter()
        for w in range(args.waves):
            reqs = synthetic_request_stream(
                f.config, args.requests, n_known=f.n_series_ or 0, seed=w)
            out = srv.forecast_batch(reqs)
            assert all(np.isfinite(o).all() for o in out)
        wall = time.perf_counter() - t0
    else:
        from repro.forecast.server import ServerConfig

        srv = f.serve(
            server_config=ServerConfig(
                max_queue=args.queue_size, max_wait_ms=args.max_wait_ms,
                max_batch=args.max_batch),
            mesh=mesh, **buckets)
        t0 = time.perf_counter()
        with srv:
            for w in range(args.waves):
                reqs = synthetic_request_stream(
                    f.config, args.requests, n_known=f.n_series_ or 0, seed=w)
                futs = [srv.submit(r) for r in reqs]
                for fut in futs:
                    assert np.isfinite(fut.result(timeout=120)).all()
        wall = time.perf_counter() - t0
    s = srv.stats
    pct = s.latency_percentiles()
    print(f"[{args.engine}] served {s.requests} requests in {s.batches} "
          f"batches over {args.waves} waves: {s.requests / wall:.0f} "
          f"series/s wall ({s.requests_per_s:.0f} req/s dispatch)")
    print(f"latency p50 {pct['p50_ms']:.1f} ms  p95 {pct['p95_ms']:.1f} ms  "
          f"p99 {pct['p99_ms']:.1f} ms; queue peak {s.queue_peak}")
    print(f"jit cache: {s.compiles} compiles, {s.cache_hits} bucket hits "
          f"({s.padded_series} padded lanes, {s.truncated_series} truncated)")
    return 0


def cmd_observe(args):
    """JSONL op loop over a continuous server (scripted round-trips).

    stdin lines:  {"op": "observe", "series_id": 3, "y": 105.2}
                  {"op": "forecast", "series_id": 3}          (online history)
                  {"op": "forecast", "y": [..], "series_id": 3}  (explicit)
                  {"op": "stats"}
    One JSON result line per op; forecasts drain synchronously, so every
    forecast reads all earlier observes (read-your-writes, no thread).
    """
    import json
    import sys

    from repro.forecast import ForecastRequest
    from repro.forecast.server import ServerConfig

    f = _fitted(args)
    srv = f.serve(
        server_config=ServerConfig(
            max_queue=args.queue_size, max_wait_ms=args.max_wait_ms,
            finetune_steps=args.finetune_steps),
        mesh=_inference_mesh(args), seed_histories=args.seed_histories)
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        try:
            op = json.loads(line)
            kind = op["op"]
            if kind == "observe":
                srv.observe(int(op["series_id"]), float(op["y"]),
                            op.get("category"))
                out = {"op": "observe", "series_id": op["series_id"],
                       "ok": True}
            elif kind == "forecast":
                y = (np.asarray(op["y"], np.float32)
                     if op.get("y") is not None else None)
                fut = srv.submit(ForecastRequest(
                    y=y, category=int(op.get("category", 0)),
                    series_id=(int(op["series_id"])
                               if op.get("series_id") is not None else None)))
                srv.drain()
                out = {"op": "forecast",
                       "series_id": op.get("series_id"),
                       "forecast": [float(v) for v in fut.result(timeout=120)]}
            elif kind == "stats":
                s = srv.stats
                out = {"op": "stats", "requests": s.requests,
                       "observes": s.observes, "batches": s.batches,
                       "write_batches": s.write_batches,
                       "finetunes": s.finetunes, "compiles": s.compiles,
                       "cache_hits": s.cache_hits,
                       "truncated_series": s.truncated_series,
                       "queue_peak": s.queue_peak,
                       "tracked_series": len(srv.store),
                       **s.latency_percentiles()}
            else:
                out = {"ok": False, "error": f"unknown op {kind!r}"}
        except Exception as err:   # one bad line must not kill the loop
            out = {"ok": False, "error": f"{type(err).__name__}: {err}"}
        print(json.dumps(out), flush=True)
    srv.drain()
    return 0


def cmd_analyze(args):
    """Graph auditor: JSON report of all invariant lints on this spec."""
    import json

    from repro.analysis import run_audit

    over = _parse_overrides(args.set)
    spec = (get_smoke_spec(args.spec, **over) if args.smoke
            else get_spec(args.spec, **over))
    entries = tuple(e.strip() for e in args.entries.split(",") if e.strip())
    report = run_audit(spec, entries=entries, devices=args.devices)
    text = json.dumps(report.to_dict(), indent=2)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            fh.write(text + "\n")
        log.info("report written to %s", args.json_out)
    print(text)
    for f in report.violations:
        log.error("violation [%s]: %s", f.lint, f.message)
    return 0 if report.ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.forecast",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--spec", default="esrnn-quarterly",
                       help=f"registry name; one of {list_specs()}")
        p.add_argument("--smoke", action="store_true",
                       help="tiny model + tiny data, seconds on CPU")
        p.add_argument("--steps", type=int, help="override spec n_steps")
        p.add_argument("--devices", type=int, metavar="N",
                       help="shard the series axis over N devices: fit "
                            "trains data-parallel, predict/eval/backtest/"
                            "serve run sharded inference (CPU: export "
                            "XLA_FLAGS="
                            "--xla_force_host_platform_device_count=N)")
        p.add_argument("--set", action="append", metavar="KEY=VAL",
                       help="spec/model override, e.g. --set hidden_size=16, "
                            "--set head=esn (pluggable forecasting head: "
                            "lstm/esn/ssm), "
                            "--set use_pallas=true (trainable kernel path), "
                            "--set scan_steps=32 (fused superstep engine), "
                            "--set sparse_adam=true (segment per-series "
                            "Adam), --set series_chunk=65536 (out-of-core "
                            "host HW table, streamed fit/predict)")

    p_specs = sub.add_parser(
        "specs", help="list the spec registry (name/frequency/horizon/head)")
    p_specs.add_argument("--json", action="store_true",
                         help="machine-readable JSON rows")
    p_specs.set_defaults(fn=cmd_specs)

    p_fit = sub.add_parser("fit", help="train an estimator")
    common(p_fit)
    p_fit.add_argument("--ckpt-dir", help="mid-training checkpoint/restart dir")
    p_fit.add_argument("--out-dir", help="save the fitted estimator here")
    p_fit.set_defaults(fn=cmd_fit)

    p_pred = sub.add_parser("predict", help="point/quantile forecasts")
    common(p_pred)
    p_pred.add_argument("--dir", help="load a saved estimator")
    p_pred.add_argument("--quantiles", help="comma list of taus, e.g. 0.1,0.5,0.9")
    p_pred.set_defaults(fn=cmd_predict)

    p_eval = sub.add_parser("eval", help="sMAPE/MASE/OWA vs Comb/Naive2")
    common(p_eval)
    p_eval.add_argument("--dir", help="load a saved estimator")
    p_eval.add_argument("--split", default="test", choices=["val", "test"])
    p_eval.set_defaults(fn=cmd_eval)

    p_bt = sub.add_parser(
        "backtest",
        help="rolling-origin sMAPE/MASE at several forecast origins, all "
             "from one forward pass (no refitting)")
    common(p_bt)
    p_bt.add_argument("--dir", help="load a saved estimator")
    p_bt.add_argument("--origins", metavar="O1,O2,...",
                      help="comma list of observation counts to forecast "
                           "from (each in [input_size, T]); default: end of "
                           "train and end of validation")
    p_bt.set_defaults(fn=cmd_backtest)

    p_srv = sub.add_parser("serve", help="continuous-batching forecast serving")
    common(p_srv)
    p_srv.add_argument("--dir", help="load a saved estimator")
    p_srv.add_argument("--requests", type=int, default=64, help="per wave")
    p_srv.add_argument("--waves", type=int, default=2,
                       help="request waves (wave 2+ shows jit-cache reuse)")
    p_srv.add_argument("--length-buckets", default="32,64,128,256")
    p_srv.add_argument("--batch-buckets", default="1,4,16,64")
    p_srv.add_argument("--max-batch", type=int, default=64)
    p_srv.add_argument("--engine", choices=["continuous", "batch"],
                       default="continuous",
                       help="continuous: bounded queue + deadline-driven "
                            "bucket fill (the serving engine); batch: the "
                            "synchronous batch-at-a-time wrapper")
    p_srv.add_argument("--queue-size", type=int, default=1024,
                       help="bounded request queue (submit backpressure)")
    p_srv.add_argument("--max-wait-ms", type=float, default=5.0,
                       help="max hold before a partial bucket dispatches")
    p_srv.set_defaults(fn=cmd_serve)

    p_an = sub.add_parser(
        "analyze",
        help="graph auditor: static invariant lints (recompiles, gradient "
             "leaks, donation, collectives, dtype policy) over the compiled "
             "fit/predict/serve programs; exits nonzero on any violation")
    common(p_an)
    p_an.add_argument("--entries", default="fit,predict,serve",
                      help="comma list from fit,predict,serve,collectives "
                           "(collectives also implied by --devices N > 1)")
    p_an.add_argument("--json-out", metavar="PATH",
                      help="also write the JSON report to PATH")
    p_an.set_defaults(fn=cmd_analyze)

    p_obs = sub.add_parser(
        "observe",
        help="JSONL op loop: online observe/forecast/stats over stdin")
    common(p_obs)
    p_obs.add_argument("--dir", help="load a saved estimator")
    p_obs.add_argument("--queue-size", type=int, default=1024)
    p_obs.add_argument("--max-wait-ms", type=float, default=5.0)
    p_obs.add_argument("--finetune-steps", type=int, default=0,
                       help="idle fine-tune steps per drained busy period "
                            "(0 = off)")
    p_obs.add_argument("--seed-histories", action="store_true",
                       help="pre-register every fitted series' training "
                            "history in the online store")
    p_obs.set_defaults(fn=cmd_observe)

    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
