"""Forecast launcher: the one CLI over the unified ESRNNForecaster API.

    PYTHONPATH=src python -m repro.launch.forecast fit      --spec esrnn-quarterly --smoke
    PYTHONPATH=src python -m repro.launch.forecast predict  --dir /tmp/fq
    PYTHONPATH=src python -m repro.launch.forecast eval     --spec esrnn-quarterly --smoke
    PYTHONPATH=src python -m repro.launch.forecast backtest --dir /tmp/fq --origins 72,80
    PYTHONPATH=src python -m repro.launch.forecast serve    --smoke --requests 64

``fit`` trains (spec-driven synthetic M4 by default) and optionally saves
the estimator; ``predict``/``eval``/``backtest`` run on a saved estimator
(``--dir``) or fit a fresh one; ``serve`` runs the batched pad-to-bucket
forecast server over a synthetic ragged request stream and reports
throughput + jit-cache reuse, mirroring the prefill/decode serving loop of
``repro.launch.serve``.

``backtest`` is the rolling-origin protocol: forecast at each ``--origins``
observation count as if the rest of the series were unseen, scored
sMAPE/MASE per origin -- all origins are read off ONE forward pass of the
state-space core (the causal ES states are already the re-primed
truncated-history states), no refit.

``--devices N`` applies to every subcommand: ``fit`` trains series-data-
parallel, and ``predict``/``eval``/``backtest``/``serve`` run sharded
inference over a series mesh (per-series HW rows device-local under
``shard_map``; eval/backtest metrics reduced as exact psum'd global means).
On CPU export ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

``--set use_pallas=true`` routes fit *and* every inference path through the
Pallas kernels (trainable via their custom_vjp backward kernels; interpret
mode off-TPU); it composes with ``--devices N``.

``--set scan_steps=K`` fuses K training steps into one donated ``lax.scan``
superstep (the dispatch-bound per-step loop is the K=1 default); eval,
checkpoints, and hooks fire at superstep boundaries, on the same absolute
steps. ``--set sparse_adam=true`` adds the sparse per-series Adam segment
update. Both compose with ``--devices N`` and ``use_pallas``.
"""

from __future__ import annotations

import argparse
import logging

import numpy as np

from repro.forecast import (
    BatchedForecastServer, ESRNNForecaster, get_smoke_spec, get_spec,
    list_specs, synthetic_request_stream,
)

log = logging.getLogger("repro.launch.forecast")


def _parse_overrides(pairs):
    out = {}
    for pair in pairs or []:
        key, eq, val = pair.partition("=")
        if not eq or not key or not val:
            raise SystemExit(
                f"error: --set expects KEY=VAL, got {pair!r}")
        if val.lower() in ("true", "false"):
            out[key] = val.lower() == "true"
            continue
        try:
            out[key] = int(val)
        except ValueError:
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
    return out


def _build(args) -> ESRNNForecaster:
    over = _parse_overrides(getattr(args, "set", None))
    if getattr(args, "steps", None) is not None:
        over["n_steps"] = args.steps
    if getattr(args, "devices", None) is not None:
        over["data_parallel"] = args.devices
    spec = (get_smoke_spec(args.spec, **over) if args.smoke
            else get_spec(args.spec, **over))
    return ESRNNForecaster(spec)


def _fitted(args) -> ESRNNForecaster:
    """Saved estimator if --dir given, else a freshly fitted one."""
    if getattr(args, "dir", None):
        f = ESRNNForecaster.load(args.dir)
        f.data_ = f.make_data()
        return f
    f = _build(args)
    log.info("no --dir: fitting %s for %d steps", f.spec.name, f.spec.n_steps)
    return f.fit()


def _inference_mesh(args):
    """Series mesh for sharded predict/eval/backtest/serve (--devices N)."""
    d = getattr(args, "devices", None)
    if d and d > 1:
        from repro.sharding.series import make_series_mesh

        return make_series_mesh(d)
    return None


def cmd_fit(args):
    f = _build(args)
    f.fit(ckpt_dir=args.ckpt_dir)
    h = f.history_["loss"]
    if h:
        print(f"{f.spec.name}: {len(h)} steps, loss {h[0]:.4f} -> {h[-1]:.4f}, "
              f"{f.n_series_} series")
    else:
        print(f"{f.spec.name}: resumed from a finished checkpoint, "
              f"{f.n_series_} series")
    if f.history_["val_smape"]:
        step, vs = f.history_["val_smape"][-1]
        print(f"val sMAPE @ step {step}: {vs:.3f}")
    if args.out_dir:
        print("saved to", f.save(args.out_dir))
    return 0


def cmd_predict(args):
    f = _fitted(args)
    mesh = _inference_mesh(args)
    if args.quantiles:
        taus = tuple(float(t) for t in args.quantiles.split(","))
        bands = f.predict_quantiles(taus=taus, mesh=mesh)
        for tau in taus:
            print(f"tau={tau}: first series", np.round(bands[tau][0], 2))
    else:
        fc = f.predict(mesh=mesh)
        print(f"forecast {fc.shape}; first series", np.round(fc[0], 2))
    return 0


def cmd_eval(args):
    f = _fitted(args)
    scores = f.evaluate(split=args.split, mesh=_inference_mesh(args))
    print(f"{f.spec.name} [{args.split}]")
    for suffix, label in (("", "esrnn"), ("_comb", "comb"), ("_naive2", "naive2")):
        smape = scores[f"smape{suffix}"]
        mase = scores[f"mase{suffix}"]
        owa = scores.get(f"owa{suffix}")
        owa_s = f"  owa {owa:7.3f}" if owa is not None else ""
        print(f"  {label:8s} smape {smape:7.3f}  mase {mase:7.3f}{owa_s}")
    return 0


def cmd_backtest(args):
    f = _fitted(args)
    origins = (tuple(int(o) for o in args.origins.split(","))
               if args.origins else None)
    out = f.backtest(origins=origins, mesh=_inference_mesh(args))
    print(f"{f.spec.name} rolling-origin backtest "
          f"(horizon {out['horizon']}, one forward pass)")
    for row in out["per_origin"]:
        print(f"  origin {row['origin']:5d}  smape {row['smape']:7.3f}  "
              f"mase {row['mase']:7.3f}")
    print(f"  {'overall':>12s}  smape {out['smape']:7.3f}  "
          f"mase {out['mase']:7.3f}")
    return 0


def cmd_serve(args):
    f = _fitted(args)
    srv = BatchedForecastServer(
        f.config, f.params_,
        length_buckets=tuple(int(b) for b in args.length_buckets.split(",")),
        batch_buckets=tuple(int(b) for b in args.batch_buckets.split(",")),
        max_batch=args.max_batch,
        mesh=_inference_mesh(args),
    )
    rng_seeds = range(args.waves)
    for w in rng_seeds:
        reqs = synthetic_request_stream(
            f.config, args.requests, n_known=f.n_series_ or 0, seed=w)
        out = srv.forecast_batch(reqs)
        assert all(np.isfinite(o).all() for o in out)
    s = srv.stats
    print(f"served {s.requests} requests in {s.batches} batches over "
          f"{args.waves} waves: {s.requests_per_s:.0f} req/s")
    print(f"jit cache: {s.compiles} compiles, {s.cache_hits} bucket hits "
          f"({s.padded_series} padded lanes)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.forecast",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--spec", default="esrnn-quarterly",
                       help=f"registry name; one of {list_specs()}")
        p.add_argument("--smoke", action="store_true",
                       help="tiny model + tiny data, seconds on CPU")
        p.add_argument("--steps", type=int, help="override spec n_steps")
        p.add_argument("--devices", type=int, metavar="N",
                       help="shard the series axis over N devices: fit "
                            "trains data-parallel, predict/eval/backtest/"
                            "serve run sharded inference (CPU: export "
                            "XLA_FLAGS="
                            "--xla_force_host_platform_device_count=N)")
        p.add_argument("--set", action="append", metavar="KEY=VAL",
                       help="spec/model override, e.g. --set hidden_size=16, "
                            "--set use_pallas=true (trainable kernel path), "
                            "--set scan_steps=32 (fused superstep engine), "
                            "--set sparse_adam=true (segment per-series Adam)")

    p_fit = sub.add_parser("fit", help="train an estimator")
    common(p_fit)
    p_fit.add_argument("--ckpt-dir", help="mid-training checkpoint/restart dir")
    p_fit.add_argument("--out-dir", help="save the fitted estimator here")
    p_fit.set_defaults(fn=cmd_fit)

    p_pred = sub.add_parser("predict", help="point/quantile forecasts")
    common(p_pred)
    p_pred.add_argument("--dir", help="load a saved estimator")
    p_pred.add_argument("--quantiles", help="comma list of taus, e.g. 0.1,0.5,0.9")
    p_pred.set_defaults(fn=cmd_predict)

    p_eval = sub.add_parser("eval", help="sMAPE/MASE/OWA vs Comb/Naive2")
    common(p_eval)
    p_eval.add_argument("--dir", help="load a saved estimator")
    p_eval.add_argument("--split", default="test", choices=["val", "test"])
    p_eval.set_defaults(fn=cmd_eval)

    p_bt = sub.add_parser(
        "backtest",
        help="rolling-origin sMAPE/MASE at several forecast origins, all "
             "from one forward pass (no refitting)")
    common(p_bt)
    p_bt.add_argument("--dir", help="load a saved estimator")
    p_bt.add_argument("--origins", metavar="O1,O2,...",
                      help="comma list of observation counts to forecast "
                           "from (each in [input_size, T]); default: end of "
                           "train and end of validation")
    p_bt.set_defaults(fn=cmd_backtest)

    p_srv = sub.add_parser("serve", help="batched pad-to-bucket forecast serving")
    common(p_srv)
    p_srv.add_argument("--dir", help="load a saved estimator")
    p_srv.add_argument("--requests", type=int, default=64, help="per wave")
    p_srv.add_argument("--waves", type=int, default=2,
                       help="request waves (wave 2+ shows jit-cache reuse)")
    p_srv.add_argument("--length-buckets", default="32,64,128,256")
    p_srv.add_argument("--batch-buckets", default="1,4,16,64")
    p_srv.add_argument("--max-batch", type=int, default=64)
    p_srv.set_defaults(fn=cmd_serve)

    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
