"""Production mesh construction (a function -- importing never touches jax
device state).

Built on the current ``jax.make_mesh(shape, names)`` API; the removed
``axis_types=`` kwarg / ``jax.sharding.AxisType`` enum are gone. The ES-RNN
series-data-parallel mesh lives in :mod:`repro.sharding.series`
(re-exported here for discoverability).
"""

from __future__ import annotations

import jax

from repro.sharding.series import make_series_mesh  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
