"""Real training launcher (runs on whatever devices exist).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Synthetic token stream (seeded, stateless step->batch like the ES-RNN
pipeline), fp32 master params + bf16 compute, checkpoint/restart, straggler
watchdog. The same step builders the 512-chip dry-run lowers are used here,
so what trains on one host is exactly what compiles on the pod.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ShapeCell, get_config, get_smoke_config
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.sharding import specs
from repro.sharding.ctx import activation_sharding
from repro.train.optimizer import AdamConfig, adam_init
from repro.train.trainer import PreemptionHandler

log = logging.getLogger("repro.launch.train")


def synthetic_batch(cfg, cell, step, seed=0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    b, s = cell.global_batch, cell.seq_len
    s_text = s - (cfg.n_patches if cfg.family == "vlm" else 0)
    # zipf-ish marginals make the CE landscape non-trivial
    toks = rng.zipf(1.3, (b, s_text + 1)).clip(max=cfg.vocab_size - 1)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
    return batch


def train(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
          lr: float = 3e-4, microbatch=None, ckpt_dir=None, seed=0,
          model_parallel: int = 1, log_every: int = 10):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    cell = ShapeCell("custom", "train", seq, batch, microbatch=microbatch)
    model = build_model(cfg)
    mesh = make_host_mesh(model_parallel)
    axes = specs.axes_for(mesh)
    specs.set_mesh(mesh)

    with mesh, activation_sharding(mesh, dp=axes["dp"], tp=axes["tp"]):
        params = model.init(jax.random.PRNGKey(seed))
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
            params)
        opt_state = adam_init(params)
        step_fn = jax.jit(S.make_train_step(
            model, cell, adam=AdamConfig(lr=lr, clip_norm=1.0)))

        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            start, (params, opt_state) = ckpt.restore((params, opt_state))
            log.info("resumed from step %d", start)

        pre = PreemptionHandler()
        pre.install()
        losses, ewma = [], None
        try:
            for step in range(start, steps):
                t0 = time.perf_counter()
                b = synthetic_batch(cfg, cell, step, seed)
                params, opt_state, loss = step_fn(params, opt_state, b)
                loss = float(loss)
                losses.append(loss)
                dt = time.perf_counter() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if step > 5 and dt > 3.0 * ewma:
                    log.warning("straggler step %d: %.2fs (ewma %.2fs)", step, dt, ewma)
                if (step + 1) % log_every == 0:
                    log.info("step %d loss %.4f (%.2fs/step)", step + 1, loss, ewma)
                if ckpt and (step + 1) % 50 == 0:
                    ckpt.save(step + 1, (params, opt_state), metric=loss)
                if pre.requested:
                    if ckpt:
                        ckpt.save(step + 1, (params, opt_state))
                    log.warning("preempted; checkpointed at %d", step + 1)
                    break
        finally:
            pre.uninstall()
        if ckpt:
            ckpt.save(steps, (params, opt_state), metric=losses[-1])
    return {"losses": losses, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, microbatch=args.microbatch,
                lr=args.lr, ckpt_dir=args.ckpt_dir,
                model_parallel=args.model_parallel)
    print(f"first loss {out['losses'][0]:.4f} -> last {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
