"""Step builders: jit-able train/prefill/decode steps + abstract inputs and
shardings for every (arch x shape) cell. Used by dryrun.py (AOT compile) and
by the real launchers (train.py / serve.py).

train_step = grad-accumulation scan over microbatches (bounds activation
memory) + AdamW update, with fp32 master params and bf16 compute casts (the
FSDP all-gathers then move bf16, half the bytes).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.train.optimizer import AdamConfig, adam_init, adam_update


def cast_params_for_compute(params, dtype=jnp.bfloat16):
    """fp32 master -> bf16 compute for every matrix; small leaves stay fp32."""
    def cast(x):
        if x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(cast, params)


def abstract_params(model: Model, *, master_fp32: bool) -> Any:
    abs_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if master_fp32:
        abs_p = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32)
            if l.dtype == jnp.bfloat16 else l,
            abs_p,
        )
    return abs_p


# ---------------------------------------------------------------------------
# batch templates per cell
# ---------------------------------------------------------------------------


def batch_template(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cell.kind in ("train", "prefill"):
        s_text = s - (cfg.n_patches if cfg.family == "vlm" else 0)
        out = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
        if cell.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), bf16)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), bf16)
        return out
    # decode: one new token against a cache of length s
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "positions": jax.ShapeDtypeStruct((b, 1), i32),
    }


def abstract_caches(model: Model, cell: ShapeCell) -> Any:
    return jax.eval_shape(
        lambda: model.make_caches(cell.global_batch, cell.seq_len, jnp.bfloat16)
    )


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(model: Model, cell: ShapeCell, *,
                    adam: Optional[AdamConfig] = None):
    """(params_fp32, opt_state, batch) -> (params, opt_state, loss)."""
    adam = adam or AdamConfig(lr=3e-4, clip_norm=1.0, weight_decay=0.0)
    mb = cell.microbatch or cell.global_batch
    n_micro = max(1, cell.global_batch // mb)

    def train_step(params, opt_state, batch):
        def loss_fn(p, micro):
            return model.loss(cast_params_for_compute(p), micro)

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro_batches = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, mb) + x.shape[1:]), batch)

            def accum(carry, micro):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, micro)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros(()), zeros), micro_batches)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)

        params, opt_state = adam_update(grads, opt_state, params, adam)
        return params, opt_state, loss

    return train_step


def make_prefill_step(model: Model, cell: ShapeCell):
    def prefill(params, batch):
        return model.prefill(params, batch, cell.seq_len)
    return prefill


def make_decode_step(model: Model, cell: ShapeCell):
    def decode(params, batch, caches):
        return model.decode(params, batch, caches)
    return decode


def abstract_opt_state(params_abs):
    return jax.eval_shape(adam_init, params_abs)
