"""Serving launcher: batched prefill + greedy decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.sharding import specs
from repro.sharding.ctx import activation_sharding

log = logging.getLogger("repro.launch.serve")


def serve(arch: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0, model_parallel: int = 1):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    mesh = make_host_mesh(model_parallel)
    axes = specs.axes_for(mesh)
    specs.set_mesh(mesh)
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen + (cfg.n_patches if cfg.family == "vlm" else 0)

    with mesh, activation_sharding(mesh, dp=axes["dp"], tp=axes["tp"]):
        params = model.init(jax.random.PRNGKey(seed))
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
        batch_in = {"tokens": prompts}
        if cfg.family == "vlm":
            batch_in["image_embeds"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.n_patches, cfg.d_model)), cfg.jdtype)
        if cfg.family == "encdec":
            batch_in["frames"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.n_frames, cfg.d_model)), cfg.jdtype)

        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
        decode = jax.jit(model.decode)

        t0 = time.perf_counter()
        logits, caches = prefill(params, batch_in)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        offset = cfg.n_patches if cfg.family == "vlm" else 0
        tokens = [jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)]
        t0 = time.perf_counter()
        for i in range(gen - 1):
            pos = jnp.full((batch, 1), prompt_len + offset + i, jnp.int32)
            logits, caches = decode(
                params, {"tokens": tokens[-1][:, None], "positions": pos}, caches)
            tokens.append(jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32))
        jax.block_until_ready(tokens[-1])
        t_decode = time.perf_counter() - t0

        out = jnp.stack(tokens, axis=1)
        return {
            "generated": np.asarray(out),
            "prefill_s": t_prefill,
            "decode_s_per_tok": t_decode / max(gen - 1, 1),
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                model_parallel=args.model_parallel)
    print(f"prefill {out['prefill_s']*1e3:.1f} ms; "
          f"decode {out['decode_s_per_tok']*1e3:.2f} ms/token")
    print("sample:", out["generated"][0][:16])


if __name__ == "__main__":
    main()
