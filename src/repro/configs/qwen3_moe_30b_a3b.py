"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 128-expert top-8 MoE.

48L d_model=2048 32H (GQA kv=4, head_dim=128, QK-norm) expert d_ff=768
vocab=151936, no shared experts.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1000000.0,
    n_experts=128, top_k=8, moe_d_ff=768, norm_topk_prob=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, moe_d_ff=64, n_experts=8, top_k=2, vocab_size=128,
    capacity_factor=64.0,  # dropless at smoke sizes (exact prefill/decode match)
    dtype="float32", remat=False)
