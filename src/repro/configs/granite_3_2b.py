"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: dense GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155. Granite 3.0 uses
tied embeddings and its depth-scaled multiplier scheme.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=49155, tie_embeddings=True,
    embedding_multiplier=12.0, residual_multiplier=0.22,
    attention_multiplier=0.0078125, logits_scaling=8.0,
    rope_theta=10000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=128, dtype="float32", remat=False)
