"""deepseek-v2-lite-16b [arXiv:2405.04434]: MLA + fine-grained MoE.

27L d_model=2048 16H, MLA kv_lora=512 (qk_nope=128, qk_rope=64, v=128),
MoE 64 routed top-6 + 2 shared experts, expert d_ff=1408, vocab=102400,
first layer keeps a dense FFN (10944).

The assignment line lists both "64e top-6" and "160 routed"; we follow the
published v2-lite config (64 routed + 2 shared, top-6) -- see DESIGN.md.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    first_dense_layers=1, first_dense_d_ff=10944,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    d_ff=64, moe_d_ff=64, n_experts=8, top_k=2, n_shared_experts=1,
    first_dense_layers=1, first_dense_d_ff=128, vocab_size=128,
    capacity_factor=64.0,  # dropless at smoke sizes (exact prefill/decode match)
    dtype="float32", remat=False)
