"""mamba2-1.3b [arXiv:2405.21060]: attention-free SSD (state-space duality).

48L d_model=2048 (d_inner=4096, headdim=64 -> 64 ssm heads, ssm_state=128),
vocab=50280, no FFN (d_ff=0).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1, ssm_conv=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab_size=128, ssm_state=16,
    ssm_headdim=16, ssm_chunk=8, dtype="float32", remat=False)
