"""Architecture + shape-cell registry.

``get_config(arch)`` / ``get_smoke_config(arch)`` return the exact assigned
configs; ``SHAPES`` defines the four assigned input-shape cells and
``cell_applicable`` encodes the skip rules from the task spec (long_500k
only for sub-quadratic archs; decode shapes only for archs with a decoder).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ArchConfig

_MODULES = {
    "granite-3-2b": "granite_3_2b",
    "yi-6b": "yi_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "internvl2-2b": "internvl2_2b",
    "whisper-base": "whisper_base",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCHS: List[str] = list(_MODULES)

# ES-RNN (the paper's own model) lives behind the unified forecasting
# registry: ``repro.forecast.get_spec("esrnn-<freq>")`` (these legacy m4-*
# aliases also resolve there). The CLI is ``repro.launch.forecast``.
ESRNN_CONFIGS = ("m4-yearly", "m4-quarterly", "m4-monthly", "m4-hourly")


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: Optional[int] = None   # grad-accumulation slice (train only)


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256, microbatch=32),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# archs with sub-quadratic sequence mixing (long_500k runs only for these)
SUBQUADRATIC = {"zamba2-2.7b", "mamba2-1.3b"}


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 500k decode skipped per spec"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES if cell_applicable(a, s)[0]]


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE
