"""zamba2-2.7b [arXiv:2411.15242]: Mamba2 backbone + shared attention block.

54 Mamba2 layers d_model=2560 (d_inner=5120, headdim=64 -> 80 ssm heads,
ssm_state=64) with the shared transformer block (32H MHA kv=32, d_ff=10240)
applied after every 6th Mamba layer on concat(h, embed) -- weights shared
across the 9 applications, per-application KV caches. vocab=32000.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, attn_every=6,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1, ssm_conv=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, attn_every=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128, ssm_state=16, ssm_headdim=16, ssm_chunk=8,
    dtype="float32", remat=False)
