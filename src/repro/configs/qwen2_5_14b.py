"""qwen2.5-14b [hf:Qwen/Qwen2.5-14B]: dense GQA with QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, dtype="float32", remat=False)
