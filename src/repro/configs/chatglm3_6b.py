"""chatglm3-6b [arXiv:2406.12793]: GQA kv=2, 2d RoPE (half-dim rotary).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=65024, qkv_bias=True, rope_fraction=0.5,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, dtype="float32", remat=False)
