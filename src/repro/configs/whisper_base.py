"""whisper-base [arXiv:2212.04356]: encoder-decoder, conv frontend stubbed.

6L enc + 6L dec, d_model=512 8H (MHA) d_ff=2048 vocab=51865; 1500 encoder
frames (the 2x conv1d stem is a stub -- input_specs provides precomputed
frame embeddings).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, norm="layernorm", n_frames=1500,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128, n_frames=16, dtype="float32", remat=False)
