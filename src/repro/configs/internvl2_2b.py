"""internvl2-2b [arXiv:2404.16821]: InternViT(stub) + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. Vision frontend is a
stub per the task spec: input_specs provides precomputed patch embeddings
(B, n_patches, d_model) that are prepended to the text sequence.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, n_patches=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=128, n_patches=8, dtype="float32", remat=False)
