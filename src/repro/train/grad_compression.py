"""Error-feedback gradient compression for the data-parallel all-reduce.

Distributed-optimization trick for 1000+-node runs: compress the DP gradient
exchange (int8 stochastic quantization or top-k sparsification) with error
feedback (the residual is added back into the next step's gradient), which
keeps convergence (Karimireddy et al. 2019, "Error Feedback Fixes SignSGD").

Note the framework's structural complement (DESIGN.md section 6): ES-RNN
per-series parameters are data-sharded and *never* all-reduced -- their
compression ratio is infinite by construction. This module handles the
remaining shared-parameter traffic.

These operate on the gradient pytree *before* the mean-reduce; under pjit
the all-reduce itself is emitted by GSPMD, so "compression" here means the
values entering the collective are int8/sparse-decodable. The reference
semantics (quantize -> [all-reduce] -> dequantize + error) are exact and
unit-tested; the collective-bytes saving shows up in the roofline term.

Wired into the training engine: ``TrainConfig.compress_grads`` (or
``make_step_fn(..., compress=True)``) routes the shared-weight gradients
through :func:`compress_tree_int8` each step, carrying the error-feedback
residual in the step state alongside the Adam state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array, err: jax.Array, key) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stochastic int8 quantization with error feedback.

    Returns (q_int8, scale, new_err) with g ~= q * scale + new_err.
    """
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_compress(g: jax.Array, err: jax.Array, k_frac: float) -> Tuple[jax.Array, jax.Array]:
    """Top-k (by magnitude) sparsification with error feedback.

    Returns (sparse_g, new_err); sparse_g has the same shape with non-top-k
    entries zeroed (a dense-zeros representation -- the wire format on a real
    deployment would be (indices, values); the roofline accounting uses
    k_frac * bytes).
    """
    g = g.astype(jnp.float32) + err
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(k_frac * flat.shape[0]))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
    sparse = g * mask
    return sparse, g - sparse


def compress_tree_int8(grads, errs, key):
    """Apply int8 error-feedback compression across a gradient pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = jax.tree_util.tree_leaves(errs)
    keys = jax.random.split(key, len(leaves))
    qs, scales, new_errs = [], [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        q, s, ne = int8_compress(g, e, k)
        qs.append(int8_decompress(q, s))  # values as they exit the collective
        scales.append(s)
        new_errs.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, new_errs),
    )


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
