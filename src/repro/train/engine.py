"""Fused scan-over-steps training engine for the ES-RNN.

The per-step trainer is dispatch-bound: one jitted step per Python iteration
plus an immediate ``float(loss)`` forces a host round-trip every step, so on
fast hardware the device idles between launches (the BENCH_PR3 device sweep
showed 8 devices only ~1.4x faster than 1 -- overhead, not compute). This
module removes the Python loop from the hot path the same way the paper
removed Smyl's per-series C++ loop: compile K steps into one donated
*superstep*.

Three pieces:

* :func:`make_step_fn` -- the pure single training step
  ``(params, opt_state, idx) -> (params, opt_state, loss)``, parameterized
  over the loss path (single-device / ``shard_map`` series-data-parallel /
  Pallas kernels -- the config decides inside ``esrnn_loss_fn``) and the
  optimizer path (dense Adam over the full per-series table, or the sparse
  segment update of :func:`~repro.train.optimizer.adam_update_sparse` that
  touches only the batch's rows).
* :func:`make_superstep_fn` -- ``jax.lax.scan`` of that step over a
  ``(K, B)`` on-device batch-index schedule, jitted with
  ``donate_argnums=(params, opt_state)`` so the optimizer state ping-pongs
  in place instead of being copied every step. Returns the K per-step losses
  as one array; the host syncs once per superstep, which is where eval,
  checkpointing, the straggler EWMA, and ``on_step`` hooks run.
* :func:`segment_steps` -- chops ``[start_step, n_steps)`` into superstep
  segments that land exactly on every eval/checkpoint boundary, so the fused
  loop fires them at the same global steps as the per-step loop, and a
  mid-run resume (any ``start_step``) realigns with the same boundaries via
  the stateless schedule.

The scan carries no data -- the index schedule is materialized once per
segment by :func:`~repro.data.pipeline.batch_schedule` and the series tensors
are closed over as device constants -- so the only per-step work left is the
computation itself.
"""

from __future__ import annotations

from typing import Callable, FrozenSet, Iterator, Tuple

import jax
import jax.numpy as jnp

from repro.core.esrnn import (
    ESRNNConfig, combine_series, esrnn_loss_fn, gather_series,
    partition_series,
)
from repro.train.optimizer import (
    AdamConfig, adam_update, adam_update_sparse, esrnn_group_fn,
)

StepFn = Callable


def split_frozen(params, frozen: FrozenSet[str]):
    """Split a params dict by top-level key into (trainable, frozen).

    The head registry (``repro.core.heads.frozen_param_groups``) declares
    which top-level groups a head keeps untrainable (e.g. the esn head's
    ``"rnn"`` reservoir). The step functions differentiate and run Adam over
    the trainable subtree only, closing over the frozen one -- XLA then
    never builds the frozen groups' weight-gradient computations, which is
    where the esn head's near-free fits come from. Optimizer state is built
    over the same trainable subtree (``adam_init(split_frozen(p, f)[0])``),
    so checkpoints carry no moments for weights that never move. With
    ``frozen`` empty this is the identity partition and every trajectory is
    bit-for-bit what it was before frozen groups existed.
    """
    return ({k: v for k, v in params.items() if k not in frozen},
            {k: v for k, v in params.items() if k in frozen})


def make_step_fn(
    mcfg: ESRNNConfig,
    cfg_adam: AdamConfig,
    y_all,
    cats_all,
    mask_all,
    *,
    mesh=None,
    sparse: bool = False,
    frozen: FrozenSet[str] = frozenset(),
    compress: bool = False,
) -> StepFn:
    """Build the pure training step the per-step loop and the scan share.

    ``y_all``/``cats_all``/``mask_all`` are the full on-device series tensors
    (closed over; the step only receives the batch's row indices). ``mesh``
    switches the loss to the ``shard_map``-wrapped exact-masked-mean
    ``esrnn_loss_dp``; ``sparse`` switches the per-series update to the
    segment path: gradients are taken w.r.t. the *gathered* batch rows (so
    the backward pass never scatters a zero-padded table-sized gradient) and
    Adam touches only those rows, with closed-form moment catch-up.

    ``frozen`` names top-level param groups excluded from training (the
    config head's declaration -- see :func:`split_frozen`): the step
    differentiates and updates the trainable subtree only, and the caller's
    ``opt_state`` must cover exactly that subtree. The returned step still
    takes and returns the *full* params dict -- frozen groups ride through
    unchanged -- so the checkpoint/save/predict surface stays head-agnostic.

    ``compress`` turns on error-feedback int8 compression of the *shared*
    weight gradients (``repro.train.grad_compression``) before Adam sees
    them: the values entering the (GSPMD-emitted) gradient all-reduce are
    int8-decodable, and the quantization residual is carried in the step
    state and added back next step, which keeps convergence (Karimireddy et
    al. 2019). The per-series HW rows are data-sharded and never
    all-reduced, so they stay exact. With ``compress`` the step's
    ``opt_state`` is ``(adam_state, error_state)`` where ``error_state``
    covers the shared trainable groups (``init_error_state``). Dense
    optimizer path only.
    """
    if sparse and compress:
        raise ValueError(
            "compress=True requires the dense optimizer path: the sparse "
            "segment update only ever touches per-series HW rows locally, "
            "so there is no shared-gradient exchange to compress")
    if mesh is not None:
        from repro.sharding.series import esrnn_loss_dp

        def loss_fn(pb, yb, cb, mb):
            return esrnn_loss_dp(mcfg, pb, yb, cb, mb, mesh=mesh)
    else:
        def loss_fn(pb, yb, cb, mb):
            return esrnn_loss_fn(mcfg, pb, yb, cb, mb)

    def step(params, opt_state, idx):
        yb = y_all[idx]
        cb = cats_all[idx]
        mb = mask_all[idx]
        p_train, p_froz = split_frozen(params, frozen)

        if sparse:
            hw_rows, shared = partition_series(params, idx)
            sh_train, sh_froz = split_frozen(shared, frozen)

            def batch_loss(hw_b, sh):
                return loss_fn(
                    combine_series(hw_b, {**sh, **sh_froz}), yb, cb, mb)

            loss, (g_hw, g_sh) = jax.value_and_grad(
                batch_loss, argnums=(0, 1))(hw_rows, sh_train)
            grads = combine_series(g_hw, g_sh)
            p_train, opt_state = adam_update_sparse(
                grads, opt_state, p_train, cfg_adam, idx=idx,
                group_fn=esrnn_group_fn)
        else:
            def batch_loss(p):
                # differentiating through the gather scatters the gradient
                # back over the full N-row table (dense Adam consumes it)
                return loss_fn(gather_series({**p, **p_froz}, idx), yb, cb, mb)

            loss, grads = jax.value_and_grad(batch_loss)(p_train)
            if compress:
                from repro.train.grad_compression import compress_tree_int8

                adam_state, err = opt_state
                # deterministic per-batch quantization noise: fold the batch
                # identity into a fixed key, so a resumed/refused run
                # re-draws the same noise at the same schedule position
                key = jax.random.fold_in(
                    jax.random.PRNGKey(0), jnp.sum(idx).astype(jnp.uint32))
                g_shared = {k: v for k, v in grads.items() if k != "hw"}
                g_shared, err = compress_tree_int8(g_shared, err, key)
                grads = {**grads, **g_shared}
                p_train, adam_state = adam_update(
                    grads, adam_state, p_train, cfg_adam,
                    group_fn=esrnn_group_fn)
                opt_state = (adam_state, err)
            else:
                p_train, opt_state = adam_update(
                    grads, opt_state, p_train, cfg_adam,
                    group_fn=esrnn_group_fn)
        return {**p_train, **p_froz}, opt_state, loss

    return step


def make_online_step_fn(
    mcfg: ESRNNConfig,
    cfg_adam: AdamConfig,
    *,
    sparse: bool = True,
    frozen: FrozenSet[str] = frozenset(),
) -> StepFn:
    """Training step over an *ad-hoc* batch: the serving fine-tune hook.

    Unlike :func:`make_step_fn`, which closes over the full training tensors
    and receives only row indices, here the batch arrives as arguments --
    the forecast server builds ``(y, cats, mask)`` from its online store's
    recently-observed history tails at call time, and ``rows`` names the
    per-series HW-table rows those batch rows correspond to. With
    ``sparse=True`` (the intended serving shape) gradients are taken w.r.t.
    the gathered rows and :func:`~repro.train.optimizer.adam_update_sparse`
    touches exactly those rows with closed-form moment catch-up -- a few
    incremental steps on live series never pay for the full table. The
    returned step is pure; the caller jits it (shapes vary with the
    fine-tune batch, so the cache discipline is the caller's).
    """

    def step(params, opt_state, y, cats, mask, rows):
        p_train, p_froz = split_frozen(params, frozen)
        if sparse:
            hw_rows, shared = partition_series(params, rows)
            sh_train, sh_froz = split_frozen(shared, frozen)

            def batch_loss(hw_b, sh):
                return esrnn_loss_fn(
                    mcfg, combine_series(hw_b, {**sh, **sh_froz}), y, cats,
                    mask)

            loss, (g_hw, g_sh) = jax.value_and_grad(
                batch_loss, argnums=(0, 1))(hw_rows, sh_train)
            grads = combine_series(g_hw, g_sh)
            p_train, opt_state = adam_update_sparse(
                grads, opt_state, p_train, cfg_adam, idx=rows,
                group_fn=esrnn_group_fn)
        else:
            def batch_loss(p):
                return esrnn_loss_fn(
                    mcfg, gather_series({**p, **p_froz}, rows), y, cats, mask)

            loss, grads = jax.value_and_grad(batch_loss)(p_train)
            p_train, opt_state = adam_update(
                grads, opt_state, p_train, cfg_adam, group_fn=esrnn_group_fn)
        return {**p_train, **p_froz}, opt_state, loss

    return step


def make_perstep_fn(step_fn: StepFn, *, donate: bool = True):
    """The fallback per-step engine: one donated jit per call.

    Donating ``(params, opt_state)`` lets XLA update the full per-series HW
    table and Adam moments in place instead of allocating fresh copies every
    step (the old un-donated path did). The caller must treat the passed-in
    arrays as consumed -- the trainer rebinds them from the return value.
    ``donate=False`` opts out (the trainer does when an ``on_step`` hook is
    registered, because a hook may legitimately retain the params tree it
    is handed, and donation would delete those buffers one step later).
    """
    return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())


def make_superstep_fn(step_fn: StepFn, *, donate: bool = True):
    """Fuse K steps into one donated ``lax.scan`` superstep.

    ``(params, opt_state, idx_schedule(K, B)) ->
    (params, opt_state, losses(K,))`` -- one dispatch, one host sync, K
    optimizer updates. Compiles once per distinct K (the trainer's segment
    planner produces at most a handful of K values per run). ``donate``
    as in :func:`make_perstep_fn`.
    """
    def superstep(params, opt_state, idx_schedule):
        def body(carry, idx):
            p, o = carry
            p, o, loss = step_fn(p, o, idx)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), idx_schedule)
        return params, opt_state, losses

    return jax.jit(superstep, donate_argnums=(0, 1) if donate else ())


def make_chunk_step_fn(
    mcfg: ESRNNConfig,
    cfg_adam: AdamConfig,
    *,
    mesh=None,
    frozen: FrozenSet[str] = frozenset(),
) -> StepFn:
    """The chunked-streaming training step: data arrives as arguments.

    Identical math to the ``sparse=True`` branch of :func:`make_step_fn` --
    gathered-row gradients, segment Adam with closed-form moment catch-up --
    but ``(y_c, cats_c, mask_c)`` are the *current chunk's* series tensors
    passed as jit arguments instead of closed-over device constants, so one
    compiled executable serves every chunk of the same shape as the trainer
    streams shards out of the host table. ``params``/``opt_state`` here are
    the chunk-assembled trees: the HW leaves hold only the chunk's rows
    (``idx`` is chunk-local) while the shared head weights and the global
    ``step`` scalar persist across chunks; ``t_hw`` carries global last-touch
    steps, which is what makes the per-chunk sparse updates exact.
    """
    if mesh is not None:
        from repro.sharding.series import esrnn_loss_dp

        def loss_fn(pb, yb, cb, mb):
            return esrnn_loss_dp(mcfg, pb, yb, cb, mb, mesh=mesh)
    else:
        def loss_fn(pb, yb, cb, mb):
            return esrnn_loss_fn(mcfg, pb, yb, cb, mb)

    def step(params, opt_state, y_c, cats_c, mask_c, idx):
        yb = y_c[idx]
        cb = cats_c[idx]
        mb = mask_c[idx]
        p_train, p_froz = split_frozen(params, frozen)
        hw_rows, shared = partition_series(params, idx)
        sh_train, sh_froz = split_frozen(shared, frozen)

        def batch_loss(hw_b, sh):
            return loss_fn(
                combine_series(hw_b, {**sh, **sh_froz}), yb, cb, mb)

        loss, (g_hw, g_sh) = jax.value_and_grad(
            batch_loss, argnums=(0, 1))(hw_rows, sh_train)
        grads = combine_series(g_hw, g_sh)
        p_train, opt_state = adam_update_sparse(
            grads, opt_state, p_train, cfg_adam, idx=idx,
            group_fn=esrnn_group_fn)
        return {**p_train, **p_froz}, opt_state, loss

    return step


def make_chunk_superstep_fn(step_fn: StepFn, *, donate: bool = True):
    """Donated ``lax.scan`` superstep over one chunk's batch schedule.

    ``(params, opt_state, y_c, cats_c, mask_c, idx_schedule(K, B)) ->
    (params, opt_state, losses(K,))``. The chunk state ping-pongs in place
    (donated) while the data tensors ride through as loop invariants; the
    trainer re-dispatches the same executable for every equal-shaped chunk
    visit, so a streamed epoch costs the same compile budget as a resident
    one plus at most a ragged-tail variant.
    """
    def superstep(params, opt_state, y_c, cats_c, mask_c, idx_schedule):
        def body(carry, idx):
            p, o = carry
            p, o, loss = step_fn(p, o, y_c, cats_c, mask_c, idx)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), idx_schedule)
        return params, opt_state, losses

    return jax.jit(superstep, donate_argnums=(0, 1) if donate else ())


def lower_superstep(step_fn: StepFn, params, opt_state, idx_schedule, *,
                    donate: bool = True):
    """AOT-lower the donated superstep for the given argument shapes.

    The graph auditor's donation lint needs the *compiled* artifact (its
    ``input_output_alias`` header proves which donated buffers actually
    alias); ``make_superstep_fn`` only returns the jitted callable, whose
    executable is not inspectable until traced. Returns the ``Lowered``
    object -- call ``.compile()`` for the executable, ``.as_text()`` for
    the pre-optimization module.
    """
    return make_superstep_fn(step_fn, donate=donate).lower(
        params, opt_state, idx_schedule)


def next_boundary(step: int, n_steps: int, *everys: int) -> int:
    """First step strictly after ``step`` where eval/ckpt may fire."""
    cands = [n_steps]
    for e in everys:
        if e and e > 0:
            cands.append((step // e + 1) * e)
    return min(c for c in cands if c > step)


def segment_steps(
    start_step: int,
    n_steps: int,
    scan_steps: int,
    *everys: int,
) -> Iterator[Tuple[int, int]]:
    """Yield ``(step, K)`` superstep segments covering [start_step, n_steps).

    Every eval/checkpoint boundary (multiples of the ``everys``, plus
    ``n_steps`` itself) coincides with a segment end, so host-side work fires
    at exactly the same global steps as the per-step loop would -- and a
    resumed run (arbitrary ``start_step`` from a checkpoint) re-aligns with
    the same absolute boundaries, because segments are planned in global
    step coordinates, not relative to the resume point.
    """
    step = start_step
    while step < n_steps:
        limit = next_boundary(step, n_steps, *everys)
        k = min(max(1, scan_steps), limit - step)
        yield step, k
        step += k
