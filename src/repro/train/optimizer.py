"""Adam optimizer with parameter groups, built from scratch (no optax here).

ES-RNN trains two kinds of parameters jointly (paper section 3.2: "the RNN
and the classical Holt-Winters parameters are jointly trained"), with the
per-series statistical parameters on a (much) higher learning rate than the
shared RNN weights -- Smyl's setup. We implement this as *parameter groups*:
a label function maps each pytree path to a group name, each group has its
own lr/schedule multipliers.

Also provides: global-norm gradient clipping, cosine/exponential decay
schedules, and AdamW decoupled weight decay for the LM stack.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    # group name -> lr multiplier (group "default" always exists)
    group_lr: Optional[Dict[str, float]] = None
    schedule: str = "constant"           # constant | cosine | exp
    total_steps: int = 1000
    warmup_steps: int = 0
    min_lr_frac: float = 0.1


def _schedule_factor(cfg: AdamConfig, step):
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step_f + 1.0) / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        t = jnp.clip(step_f / max(cfg.total_steps, 1), 0.0, 1.0)
        base = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "exp":
        t = step_f / max(cfg.total_steps, 1)
        base = jnp.power(cfg.min_lr_frac, t)
    else:
        base = jnp.ones(())
    return base * (warm if cfg.warmup_steps else 1.0)


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, cfg: AdamConfig):
    if cfg.clip_norm is None:
        return grads
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def _leaf_lr(path, cfg: AdamConfig, sched, group_fn):
    mult = 1.0
    if group_fn is not None:
        mult = dict(cfg.group_lr or {}).get(group_fn(path), 1.0)
    return cfg.lr * mult * sched


def _dense_leaf_update(path, g, mu, nu, p, *, cfg, step, sched, group_fn):
    """The one copy of the per-leaf AdamW math (dense and sparse paths)."""
    g32 = g.astype(jnp.float32)
    mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
    nu_n = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
    mu_hat = mu_n / (1 - cfg.b1 ** step.astype(jnp.float32))
    nu_hat = nu_n / (1 - cfg.b2 ** step.astype(jnp.float32))
    lr = _leaf_lr(path, cfg, sched, group_fn)
    upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), mu_n, nu_n


def _flat_state(grads, opt_state, params):
    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    return flat_g, flat_mu, flat_nu, flat_p, treedef


def adam_update(
    grads,
    opt_state,
    params,
    cfg: AdamConfig,
    *,
    group_fn: Optional[Callable[[tuple], str]] = None,
):
    """One AdamW step. group_fn maps tree path -> group name for group lrs."""
    step = opt_state["step"] + 1
    sched = _schedule_factor(cfg, step)
    grads = _clip_by_global_norm(grads, cfg)

    flat_g, flat_mu, flat_nu, flat_p, treedef = _flat_state(
        grads, opt_state, params)
    new_p, new_mu, new_nu = [], [], []
    for (path, g), mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        p2, mu2, nu2 = _dense_leaf_update(
            path, g, mu, nu, p, cfg=cfg, step=step, sched=sched,
            group_fn=group_fn)
        new_p.append(p2)
        new_mu.append(mu2)
        new_nu.append(nu2)

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "mu": jax.tree_util.tree_unflatten(treedef, new_mu),
            "nu": jax.tree_util.tree_unflatten(treedef, new_nu),
            "step": step,
        },
    )


def esrnn_group_fn(path) -> str:
    """ES-RNN grouping: per-series HW params vs shared network weights."""
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key == "hw":
            return "per_series"
    return "default"


# ---------------------------------------------------------------------------
# Sparse (segment) per-series Adam
# ---------------------------------------------------------------------------
#
# The ES-RNN per-series table ``params["hw"]`` has N rows but each training
# step touches only the B rows of its batch. The dense path differentiates
# through the row gather, which scatters a zero-padded (N, ...) gradient and
# runs Adam over the full table every step -- O(N) work and memory traffic
# for O(B) information. The sparse path takes the per-row gradients directly
# (shape (B, ...)), applies Adam only to those rows, and reconciles the rows
# skipped since their last touch with the closed-form moment catch-up
# ``mu <- b1^k mu``/``nu <- b2^k nu`` (a zero gradient decays the moments
# geometrically, so k skipped dense steps collapse into one power).
#
# Semantics (asserted by tests/train/test_optimizer.py): moments and the
# touched rows' bias corrections match the dense path exactly; the one
# deliberate difference is that *untouched* rows hold still in parameter
# space, where dense Adam would keep drifting them along their decaying stale
# momentum (an update that carries no gradient information). With
# ``b1 = 0`` -- or whenever every row is in every batch -- the two paths are
# identical step for step.


def hw_table_rows(params, hw_key: str = "hw") -> int:
    """Number of per-series rows in the ``hw`` subtree (its leading axis)."""
    leaves = jax.tree_util.tree_leaves(params[hw_key])
    return leaves[0].shape[0]


def adam_init_sparse(params, hw_key: str = "hw"):
    """Adam state for :func:`adam_update_sparse`.

    Same ``mu``/``nu``/``step`` as :func:`adam_init` plus ``t_hw`` (N,), the
    global step at which each per-series row was last updated (0 = never) --
    the only extra state the closed-form catch-up needs.
    """
    state = adam_init(params)
    state["t_hw"] = jnp.zeros((hw_table_rows(params, hw_key),), jnp.int32)
    return state


def _is_hw_path(path, hw_key: str) -> bool:
    for entry in path:
        if getattr(entry, "key", getattr(entry, "name", None)) == hw_key:
            return True
    return False


def adam_update_sparse(
    grads,
    opt_state,
    params,
    cfg: AdamConfig,
    *,
    idx,
    group_fn: Optional[Callable[[tuple], str]] = None,
    hw_key: str = "hw",
):
    """One Adam step touching only the batch's per-series rows.

    ``grads`` mirrors ``params`` except that every leaf under ``hw_key`` is
    the *per-row* gradient of shape ``(B, ...)`` for the rows ``idx`` (B,)
    -- i.e. the gradient w.r.t. the gathered batch rows, not the zero-padded
    scatter over the full table. ``idx`` must not contain duplicates (the
    stateless epoch-permutation schedule never does for B <= N). Shared
    (non-hw) leaves update densely, exactly as :func:`adam_update`.

    Global-norm clipping matches the dense path bit-for-bit: the zero padding
    of the scattered gradient contributes nothing to the norm, so the norm
    over (per-row hw grads + shared grads) is the same number.
    """
    step = opt_state["step"] + 1
    step_f = step.astype(jnp.float32)
    sched = _schedule_factor(cfg, step)
    grads = _clip_by_global_norm(grads, cfg)

    t_hw = opt_state["t_hw"]
    # rows touched k steps ago: one b1^k / b2^k power replays the k zero-grad
    # moment decays the dense path performed explicitly
    k = (step - t_hw[idx]).astype(jnp.float32)                 # (B,)
    bc1 = 1 - cfg.b1 ** step_f                                 # bias corr.
    bc2 = 1 - cfg.b2 ** step_f

    def sparse_leaf(path, g, mu, nu, p):
        kb = k.reshape(k.shape + (1,) * (g.ndim - 1))          # (B, 1...)
        g32 = g.astype(jnp.float32)
        mu_rows = (cfg.b1 ** kb) * mu[idx] + (1 - cfg.b1) * g32
        nu_rows = (cfg.b2 ** kb) * nu[idx] + (1 - cfg.b2) * jnp.square(g32)
        upd = (mu_rows / bc1) / (jnp.sqrt(nu_rows / bc2) + cfg.eps)
        p_rows = p[idx].astype(jnp.float32)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p_rows
        lr = _leaf_lr(path, cfg, sched, group_fn)
        p_new = p.at[idx].set((p_rows - lr * upd).astype(p.dtype))
        return p_new, mu.at[idx].set(mu_rows), nu.at[idx].set(nu_rows)

    flat_g, flat_mu, flat_nu, flat_p, treedef = _flat_state(
        grads, opt_state, params)
    new_p, new_mu, new_nu = [], [], []
    for (path, g), mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        if _is_hw_path(path, hw_key):
            p2, mu2, nu2 = sparse_leaf(path, g, mu, nu, p)
        else:
            p2, mu2, nu2 = _dense_leaf_update(
                path, g, mu, nu, p, cfg=cfg, step=step, sched=sched,
                group_fn=group_fn)
        new_p.append(p2)
        new_mu.append(mu2)
        new_nu.append(nu2)

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "mu": jax.tree_util.tree_unflatten(treedef, new_mu),
            "nu": jax.tree_util.tree_unflatten(treedef, new_nu),
            "step": step,
            "t_hw": t_hw.at[idx].set(step),
        },
    )
