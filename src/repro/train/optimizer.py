"""Adam optimizer with parameter groups, built from scratch (no optax here).

ES-RNN trains two kinds of parameters jointly (paper section 3.2: "the RNN
and the classical Holt-Winters parameters are jointly trained"), with the
per-series statistical parameters on a (much) higher learning rate than the
shared RNN weights -- Smyl's setup. We implement this as *parameter groups*:
a label function maps each pytree path to a group name, each group has its
own lr/schedule multipliers.

Also provides: global-norm gradient clipping, cosine/exponential decay
schedules, and AdamW decoupled weight decay for the LM stack.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    # group name -> lr multiplier (group "default" always exists)
    group_lr: Optional[Dict[str, float]] = None
    schedule: str = "constant"           # constant | cosine | exp
    total_steps: int = 1000
    warmup_steps: int = 0
    min_lr_frac: float = 0.1


def _schedule_factor(cfg: AdamConfig, step):
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step_f + 1.0) / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        t = jnp.clip(step_f / max(cfg.total_steps, 1), 0.0, 1.0)
        base = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "exp":
        t = step_f / max(cfg.total_steps, 1)
        base = jnp.power(cfg.min_lr_frac, t)
    else:
        base = jnp.ones(())
    return base * (warm if cfg.warmup_steps else 1.0)


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    grads,
    opt_state,
    params,
    cfg: AdamConfig,
    *,
    group_fn: Optional[Callable[[tuple], str]] = None,
):
    """One AdamW step. group_fn maps tree path -> group name for group lrs."""
    step = opt_state["step"] + 1
    sched = _schedule_factor(cfg, step)

    if cfg.clip_norm is not None:
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm, 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    group_lr = dict(cfg.group_lr or {})

    def leaf_update(path, g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mu_hat = mu_n / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu_n / (1 - cfg.b2 ** step.astype(jnp.float32))
        mult = 1.0
        if group_fn is not None:
            mult = group_lr.get(group_fn(path), 1.0)
        lr = cfg.lr * mult * sched
        upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), mu_n, nu_n

    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    flat_p, treedef = jax.tree_util.tree_flatten(params)

    new_p, new_mu, new_nu = [], [], []
    for (path, g), mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        p2, mu2, nu2 = leaf_update(path, g, mu, nu, p)
        new_p.append(p2)
        new_mu.append(mu2)
        new_nu.append(nu2)

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "mu": jax.tree_util.tree_unflatten(treedef, new_mu),
            "nu": jax.tree_util.tree_unflatten(treedef, new_nu),
            "step": step,
        },
    )


def esrnn_group_fn(path) -> str:
    """ES-RNN grouping: per-series HW params vs shared network weights."""
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key == "hw":
            return "per_series"
    return "default"
