"""Host-resident per-series state: the out-of-core Holt-Winters table.

The paper's scaling pressure point is the per-series parameter table -- N
rows of HW logits plus, under sparse Adam, their first/second moments and
the per-row last-touch clock. Resident training keeps all of it on device
for the lifetime of ``fit``; at 1M-10M series *that*, not FLOPs, is the
binding constraint (the PR-9 roofline pegs the train step memory-bound at
intensity ~2). This module keeps the master table in host numpy and streams
device-sized row chunks through training:

* :class:`HostStateTable` -- the master copy: HW param rows, sparse-Adam
  ``mu``/``nu`` rows, and the ``t_hw`` clock, all host numpy with the series
  axis leading. ``device_slice`` issues the (async) H2D transfer of one
  chunk; ``absorb`` writes a trained chunk back (D2H). JAX's async dispatch
  gives the double-buffering for free: the trainer issues chunk k+1's
  ``device_put`` right after dispatching chunk k's superstep, so the
  transfer overlaps the compute and the retirement ``device_get`` of chunk
  k only blocks on work that was already in flight.
* :class:`ExtendedHWView` -- the serving-side view: the fitted table plus
  one virtual primer row (cold-start series), WITHOUT materializing an
  (N+1)-row concatenated copy the way the old dispatcher snapshot did.

Exactness contract: the sparse-Adam per-row clocks
(:func:`repro.train.optimizer.adam_update_sparse`) carry *global* step
numbers, so slicing rows out to device, updating them there, and writing
them back is a pure memory-placement change -- the streamed fit walks the
same trajectory as a resident fit on the same (chunk-major) schedule,
bit-for-bit on one backend (tests/train/test_chunked.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.holt_winters import HWParams


def _logit(p: float) -> float:
    return float(np.log(p / (1.0 - p)))


def hw_init_host(
    n_series: int, seasonality: int, *, seasonality2: int = 0,
    alpha0: float = 0.5, gamma0: float = 0.5, dtype=np.float32,
) -> HWParams:
    """Host-numpy mirror of :func:`repro.core.holt_winters.hw_init_params`.

    Bit-identical values (the primer init is constant per section 3.3), but
    built straight in host memory -- a 10M-row table never takes a device
    round-trip just to be initialized.
    """
    m = max(seasonality, 1)
    params = HWParams(
        alpha_logit=np.full((n_series,), _logit(alpha0), dtype),
        gamma_logit=np.full((n_series,), _logit(gamma0), dtype),
        init_seas_logit=np.zeros((n_series, m), dtype),
    )
    if seasonality2:
        params = dataclasses.replace(
            params,
            gamma2_logit=np.full((n_series,), _logit(gamma0), dtype),
            init_seas_logit2=np.zeros((n_series, seasonality2), dtype),
        )
    return params


def _host(tree):
    """Pull a pytree to host numpy (zero-copy for leaves already there)."""
    return jax.tree_util.tree_map(
        lambda a: a if isinstance(a, np.ndarray) else np.asarray(
            jax.device_get(a)), tree)


class HostStateTable:
    """The master per-series state, resident in host memory.

    ``hw`` is an :class:`HWParams` with numpy leaves; ``mu_hw``/``nu_hw``
    mirror its structure (sparse-Adam moments) and ``t_hw`` is the (N,)
    int32 last-touch clock. The moment fields are ``None`` for inference-
    only tables (predict streaming, serving snapshots).
    """

    def __init__(self, hw: HWParams, *, mu_hw: Optional[HWParams] = None,
                 nu_hw: Optional[HWParams] = None,
                 t_hw: Optional[np.ndarray] = None):
        self.hw = hw
        self.mu_hw = mu_hw
        self.nu_hw = nu_hw
        self.t_hw = t_hw

    @property
    def n_rows(self) -> int:
        return self.hw.alpha_logit.shape[0]

    @property
    def has_moments(self) -> bool:
        return self.mu_hw is not None

    def nbytes(self) -> int:
        return sum(a.nbytes for a in jax.tree_util.tree_leaves(
            (self.hw, self.mu_hw, self.nu_hw, self.t_hw)))

    # -- constructors --------------------------------------------------------

    @classmethod
    def init(cls, n_series: int, seasonality: int, *, seasonality2: int = 0,
             with_moments: bool = True, dtype=np.float32) -> "HostStateTable":
        """Fresh table: primer HW rows + zero moments + zero clocks."""
        hw = hw_init_host(n_series, seasonality, seasonality2=seasonality2,
                          dtype=dtype)
        if not with_moments:
            return cls(hw)
        zeros = jax.tree_util.tree_map(
            lambda a: np.zeros_like(a, dtype=np.float32), hw)
        return cls(hw, mu_hw=zeros,
                   nu_hw=jax.tree_util.tree_map(np.copy, zeros),
                   t_hw=np.zeros((n_series,), np.int32))

    @classmethod
    def from_hw(cls, hw: HWParams) -> "HostStateTable":
        """Inference-only table over existing HW rows (zero-copy if numpy)."""
        return cls(_host(hw))

    @classmethod
    def from_state(cls, params: Dict, opt_state: Optional[Dict] = None,
                   hw_key: str = "hw", *,
                   with_moments: bool = False) -> "HostStateTable":
        """Adopt a (params, opt_state) pair's per-series rows into the table.

        Leaves are *copied* to host (``absorb`` writes the table in place,
        and the caller's tree must stay valid). Without an ``opt_state``,
        ``with_moments=True`` starts fresh zero moments/clocks over the
        adopted rows (the warm-start shape).
        """
        copy = lambda tree: jax.tree_util.tree_map(np.array, _host(tree))
        hw = copy(params[hw_key])
        if opt_state is not None:
            return cls(hw,
                       mu_hw=copy(opt_state["mu"][hw_key]),
                       nu_hw=copy(opt_state["nu"][hw_key]),
                       t_hw=copy(opt_state["t_hw"]))
        if not with_moments:
            return cls(hw)
        zeros = jax.tree_util.tree_map(
            lambda a: np.zeros(a.shape, np.float32), hw)
        return cls(hw, mu_hw=zeros,
                   nu_hw=jax.tree_util.tree_map(np.copy, zeros),
                   t_hw=np.zeros((hw.alpha_logit.shape[0],), np.int32))

    # -- the streaming surface ----------------------------------------------

    def device_slice(self, lo: int, hi: int) -> Dict:
        """Async H2D transfer of rows [lo, hi): the chunk's device working set.

        Returns ``{"hw": HWParams, "mu": ..., "nu": ..., "t_hw": ...}`` of
        device arrays. ``jax.device_put`` only *enqueues* the copies -- call
        it for chunk k+1 while chunk k computes and the transfers overlap
        (the double-buffered prefetch ring in the trainer).
        """
        put = lambda tree: jax.tree_util.tree_map(
            lambda a: jax.device_put(a[lo:hi]), tree)
        out = {"hw": put(self.hw)}
        if self.has_moments:
            out["mu"] = put(self.mu_hw)
            out["nu"] = put(self.nu_hw)
            out["t_hw"] = jax.device_put(self.t_hw[lo:hi])
        return out

    def absorb(self, lo: int, hi: int, chunk: Dict) -> None:
        """Write a trained chunk's rows back into the master table (D2H).

        Blocks until the producing computation is done (``device_get``);
        by then the next chunk's H2D + superstep dispatch are already in
        flight, so retirement rides the pipeline rather than stalling it.
        """
        def write(dst, src):
            dst[lo:hi] = np.asarray(jax.device_get(src))
            return dst

        jax.tree_util.tree_map(write, self.hw, chunk["hw"])
        if self.has_moments and "mu" in chunk:
            jax.tree_util.tree_map(write, self.mu_hw, chunk["mu"])
            jax.tree_util.tree_map(write, self.nu_hw, chunk["nu"])
            self.t_hw[lo:hi] = np.asarray(jax.device_get(chunk["t_hw"]))

    # -- serving view --------------------------------------------------------

    def extended(self, primer: HWParams) -> "ExtendedHWView":
        """(N+1)-row view: fitted rows + a virtual primer row, no concat."""
        return ExtendedHWView(self, _host(primer))


class _ExtLeaf:
    """One leaf of :class:`ExtendedHWView`: N fitted rows + 1 primer row.

    Supports the access patterns the serving stack actually uses -- scalar
    row reads (``leaf[row]``, the online state store), vectorized row
    gathers (``leaf[idx_array]``, the dispatcher), slices, ``len``, and
    ``np.asarray`` (materializes, for small tables/tests only) -- without
    ever concatenating the (N+1)-row table.
    """

    __slots__ = ("base", "primer")

    def __init__(self, base: np.ndarray, primer: np.ndarray):
        self.base = base
        self.primer = primer          # (1, ...) row

    def __len__(self) -> int:
        return self.base.shape[0] + 1

    @property
    def shape(self):
        return (len(self),) + self.base.shape[1:]

    @property
    def dtype(self):
        return self.base.dtype

    def __getitem__(self, idx):
        n = self.base.shape[0]
        if isinstance(idx, (int, np.integer)):
            return self.primer[0] if int(idx) == n else self.base[idx]
        if isinstance(idx, slice):
            return np.concatenate([self.base, self.primer])[idx]
        idx = np.asarray(idx)
        out = np.asarray(self.base[np.minimum(idx, n - 1)])
        over = idx >= n
        if over.any():
            out = out.copy()
            out[over] = self.primer[0]
        return out

    def copy(self) -> np.ndarray:
        return np.concatenate([self.base, self.primer])

    def __array__(self, dtype=None, copy=None):
        out = self.copy()
        return out.astype(dtype) if dtype is not None else out


class ExtendedHWView:
    """The dispatcher's host HW snapshot: fitted table + primer row, by view.

    Replaces the old eager ``np.concatenate([table, primer])`` -- a second
    full host copy of the per-series table -- with per-leaf views over the
    shared :class:`HostStateTable` (itself zero-copy when the fitted params
    already live in host memory, as after a chunked fit). Attribute access
    (``view.alpha_logit[row]``) serves the online state store; ``rows(idx)``
    is the dispatcher's vectorized per-request gather.
    """

    def __init__(self, table: HostStateTable, primer: HWParams):
        self._table = table
        self._primer = primer

    @property
    def n_rows(self) -> int:
        return self._table.n_rows + 1

    def __getattr__(self, name: str):
        base = getattr(self._table.hw, name)
        if base is None:
            return None
        return _ExtLeaf(base, np.atleast_1d(getattr(self._primer, name)))

    def rows(self, idx) -> HWParams:
        """Gather rows ``idx`` (primer for ``idx == n_known``) as HWParams."""
        idx = np.asarray(idx)
        fields = {}
        for f in dataclasses.fields(HWParams):
            base = getattr(self._table.hw, f.name)
            fields[f.name] = (None if base is None
                              else getattr(self, f.name)[idx])
        return HWParams(**fields)
