"""ES-RNN trainer: joint per-series + shared-weight optimization loop.

Production posture:
* fused supersteps (``scan_steps > 1``): K steps compile into one donated
  ``lax.scan`` dispatch over a precomputed on-device batch schedule
  (``repro.train.engine``); the host syncs once per superstep, which is
  where eval, checkpointing, the straggler EWMA, and hooks run,
* checkpoint/restart (atomic, resumable mid-epoch because the batch schedule
  is stateless in ``step`` -- a resume lands on any superstep boundary and
  re-aligns with the same absolute eval/ckpt steps),
* SIGTERM/SIGINT preemption hook -> checkpoint-and-exit (how a 1000-node job
  survives maintenance evictions); with fused supersteps the request is
  honored at the next superstep boundary,
* straggler watchdog: wall-time EWMA per step (per-step normalized within a
  superstep); steps slower than ``straggler_factor``x the EWMA are logged
  (on real fleets this feeds the scheduler; here it exercises the code path),
* validation-driven best-checkpoint tracking (sMAPE on the held-out window,
  paper section 5.1).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import losses as L
from repro.core.esrnn import ESRNNConfig, esrnn_forecast, esrnn_init
from repro.core.heads import frozen_param_groups
from repro.data.pipeline import PreparedData, batch_indices, batch_schedule
from repro.train.engine import (
    make_perstep_fn, make_step_fn, make_superstep_fn, segment_steps,
    split_frozen,
)
from repro.train.optimizer import AdamConfig, adam_init, adam_init_sparse

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 256
    n_steps: int = 300
    lr: float = 1e-3
    per_series_lr_mult: float = 10.0    # HW params learn faster (Smyl setup)
    clip_norm: Optional[float] = 20.0
    seed: int = 0
    eval_every: int = 50
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    data_parallel: int = 0              # devices for the series-sharded path
                                        # (0/1 = single-device)
    scan_steps: int = 1                 # steps fused per donated superstep
                                        # (1 = per-step dispatch loop)
    sparse_adam: bool = False           # segment per-series Adam: update only
                                        # the batch's HW rows (lazy moments)
    compress_grads: bool = False        # error-feedback int8 compression of
                                        # the shared-weight gradient exchange
                                        # (per-series rows stay exact; dense
                                        # Adam only)

    @classmethod
    def from_spec(cls, spec, *, ckpt_dir: Optional[str] = None,
                  n_steps: Optional[int] = None) -> "TrainConfig":
        """Build from a ``repro.forecast.ForecastSpec``.

        The spec carries the two learning rates as first-class fields
        (``rnn_lr`` for shared weights, ``hw_lr`` for the per-series HW
        group); the trainer's group machinery consumes them as a ratio.
        """
        return cls(
            batch_size=spec.batch_size,
            n_steps=spec.n_steps if n_steps is None else n_steps,
            lr=spec.rnn_lr,
            per_series_lr_mult=spec.hw_lr / spec.rnn_lr,
            clip_norm=spec.clip_norm,
            seed=spec.seed,
            eval_every=spec.eval_every,
            ckpt_every=spec.ckpt_every,
            ckpt_dir=ckpt_dir,
            keep=spec.keep,
            data_parallel=spec.data_parallel,
            scan_steps=spec.scan_steps,
            sparse_adam=spec.sparse_adam,
            compress_grads=getattr(spec, "compress_grads", False),
        )


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a cooperative checkpoint-and-exit flag."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def train_esrnn(
    model: ESRNNConfig,
    data: PreparedData,
    cfg: TrainConfig,
    *,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Train; returns dict(params, history, resumed_from).

    ``model`` is an :class:`~repro.core.esrnn.ESRNNConfig`; training runs
    through the pure functional API.

    ``mesh``: optional 1-D series mesh (``repro.sharding.series``). With more
    than one device the loss runs series-sharded under ``shard_map``: each
    device owns its slice of the batch and of the gathered per-series HW
    rows (device-local gradients), while the shared RNN/head weights stay
    replicated with all-reduced gradients. The batch schedule, optimizer,
    and checkpoint format are identical to the single-device path, so the
    loss trajectory matches up to float summation order. If ``mesh`` is None
    a ``cfg.data_parallel > 1`` builds one over the first that many local
    devices.

    ``cfg.scan_steps > 1`` switches to the fused superstep engine
    (``repro.train.engine``): K steps per donated ``lax.scan`` dispatch over
    a precomputed on-device batch schedule, host sync + eval/ckpt/hooks at
    superstep boundaries only. The per-step loss trajectory is the same math
    in the same order, so histories match the per-step engine; the
    ``on_step`` hook fires once per superstep with the segment's loss
    *array* instead of once per step with a float. Composes with ``mesh``
    (the scan wraps the ``shard_map``-ped loss) and ``use_pallas``.

    ``cfg.sparse_adam`` switches the per-series Holt-Winters table to the
    sparse segment update (``adam_update_sparse``): only the batch's rows
    are touched each step, skipped rows catch up their Adam moments in
    closed form. Off by default -- untouched rows no longer drift along
    stale momentum, which changes trajectories slightly vs dense Adam.
    """
    mcfg = model
    if mesh is None and cfg.data_parallel and cfg.data_parallel > 1:
        from repro.sharding.series import make_series_mesh

        mesh = make_series_mesh(cfg.data_parallel)
    if mesh is not None and mesh.devices.size == 1:
        mesh = None  # 1-device mesh: identical math, skip the shard_map hop
    if mesh is not None:
        from repro.sharding.series import check_series_divisible

        check_series_divisible(min(cfg.batch_size, data.n_series), mesh)
        log.info("series-data-parallel training on %d devices (%s)",
                 mesh.devices.size, ",".join(mesh.axis_names))
    if mcfg.use_pallas:
        # trains end-to-end: hw_scan/lstm_cell carry custom_vjp backward
        # kernels (interpret mode off-TPU), so no forward-only fallback here
        log.info("training through the Pallas kernel path (backend=%s)",
                 jax.default_backend())
    cfg_adam = AdamConfig(
        lr=cfg.lr,
        clip_norm=cfg.clip_norm,
        group_lr={"per_series": cfg.per_series_lr_mult, "default": 1.0},
    )
    n = data.n_series
    if params is None:
        params = esrnn_init(jax.random.PRNGKey(cfg.seed), mcfg, n)
    else:
        # the engines donate (params, opt_state) unless hooks are present;
        # copy the caller's tree once so their reference stays valid
        params = jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True), params)
    # optimizer state covers the trainable subtree only: the head registry
    # declares groups it keeps fixed (e.g. the esn reservoir), and those
    # carry no gradients, no Adam moments, and no checkpointed moment state
    frozen = frozen_param_groups(mcfg)
    trainable, _ = split_frozen(params, frozen)
    if frozen:
        log.info("head %r freezes param group(s) %s: training %s + hw only",
                 mcfg.head, sorted(frozen),
                 sorted(k for k in trainable if k != "hw"))
    opt_state = (adam_init_sparse(trainable) if cfg.sparse_adam
                 else adam_init(trainable))
    if cfg.compress_grads:
        if cfg.sparse_adam:
            raise ValueError(
                "compress_grads requires dense Adam (sparse_adam=False): "
                "the sparse path has no shared-gradient exchange to compress")
        from repro.train.grad_compression import init_error_state

        # step state grows an error-feedback residual over the shared
        # trainable groups; checkpoints carry it like any other opt leaf
        opt_state = (opt_state, init_error_state(
            {k: v for k, v in trainable.items() if k != "hw"}))
        log.info("error-feedback int8 compression of shared grads enabled")
    start_step = 0

    ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep) if cfg.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        try:
            start_step, (params, opt_state) = ckpt.restore((params, opt_state))
        except ValueError as e:
            # checkpoints are engine-portable (scan_steps), but the sparse
            # optimizer state carries an extra per-row clock: flipping
            # sparse_adam across a resume is a real state mismatch. Other
            # restore failures (shape drift etc.) pass through untouched.
            if "tree structure mismatch" not in str(e):
                raise
            raise ValueError(
                f"cannot resume from {cfg.ckpt_dir}: {e}. If this run was "
                f"checkpointed with a different sparse_adam setting "
                f"(currently {cfg.sparse_adam}), resume with the original "
                "setting -- the dense and sparse Adam states are not "
                "interchangeable") from e
        log.info("resumed from step %d", start_step)

    y_all = jnp.asarray(data.train)
    cats_all = jnp.asarray(data.cats)
    mask_all = jnp.asarray(data.mask)
    bs = min(cfg.batch_size, n)

    # The pure step -- shared verbatim by the per-step loop and the fused
    # scan, so the two engines walk float-identical trajectories. The
    # observation mask keeps left-padded (variable-length) positions out of
    # the loss; it is all-ones for equalized data.
    step_fn = make_step_fn(mcfg, cfg_adam, y_all, cats_all, mask_all,
                           mesh=mesh, sparse=cfg.sparse_adam, frozen=frozen,
                           compress=cfg.compress_grads)

    @jax.jit
    def val_smape(params):
        fc = esrnn_forecast(mcfg, params, y_all, cats_all)
        h = min(fc.shape[1], data.val_target.shape[1])
        return L.smape(fc[:, :h], jnp.asarray(data.val_target)[:, :h])

    pre = PreemptionHandler()
    pre.install()
    history = {"loss": [], "val_smape": [], "stragglers": []}
    ewma = None

    def boundary_work(reached: int, losses: np.ndarray, fused: bool) -> bool:
        """Host-side work at a step boundary: eval, ckpt, hooks, preemption.

        ``reached`` is the number of completed steps; ``losses`` the per-step
        losses since the previous boundary (length 1 in the per-step loop).
        Returns True when the trainer should stop (preemption).
        """
        history["loss"].extend(float(l) for l in losses)
        if reached % cfg.eval_every == 0 or reached == cfg.n_steps:
            vs = float(val_smape(params))
            history["val_smape"].append((reached, vs))
            if ckpt is not None:
                ckpt.save(reached, (params, opt_state), metric=vs)
        elif ckpt is not None and reached % cfg.ckpt_every == 0:
            ckpt.save(reached, (params, opt_state))
        if hooks and "on_step" in hooks:
            # fused engine: the last completed step index + the segment's
            # loss array (always an array, even for a length-1 segment, so
            # hooks see one stable type); per-step engine: a float per
            # step, the pre-existing contract
            hooks["on_step"](reached - 1,
                             losses if fused else float(losses[0]),
                             params)
        if pre.requested:
            log.warning("preemption requested at step %d; checkpointing",
                        reached)
            if ckpt is not None:
                ckpt.save(reached, (params, opt_state))
            return True
        return False

    def track_time(first_step: int, dt_per_step: float, k: int):
        nonlocal ewma
        ewma = dt_per_step if ewma is None else 0.9 * ewma + 0.1 * dt_per_step
        if first_step > 5 and dt_per_step > cfg.straggler_factor * ewma:
            history["stragglers"].append((first_step, dt_per_step, ewma))
            log.warning("straggler step %d (x%d): %.3fs/step vs ewma %.3fs",
                        first_step, k, dt_per_step, ewma)

    # an on_step hook may retain the params tree it is handed; donation
    # would delete those buffers at the next dispatch, so hooks opt the
    # engines out of it (the pre-existing undonated behavior)
    donate = not (hooks and "on_step" in hooks)
    try:
        if cfg.scan_steps > 1:
            # fused engine: K-step donated supersteps over the on-device
            # schedule; host syncs (and eval/ckpt/hooks) only at boundaries
            superstep_fn = make_superstep_fn(step_fn, donate=donate)
            log.info("fused superstep engine: scan_steps=%d%s",
                     cfg.scan_steps,
                     ", sparse per-series adam" if cfg.sparse_adam else "")
            for step, k in segment_steps(start_step, cfg.n_steps,
                                         cfg.scan_steps, cfg.eval_every,
                                         cfg.ckpt_every):
                sched = jnp.asarray(
                    batch_schedule(n, bs, step, k, seed=cfg.seed))
                t0 = time.perf_counter()
                params, opt_state, losses = superstep_fn(
                    params, opt_state, sched)
                losses = np.asarray(losses)   # the one host sync per segment
                track_time(step, (time.perf_counter() - t0) / k, k)
                if boundary_work(step + k, losses, fused=True):
                    break
        else:
            perstep_fn = make_perstep_fn(step_fn, donate=donate)
            for step in range(start_step, cfg.n_steps):
                idx = jnp.asarray(batch_indices(n, bs, step, seed=cfg.seed))
                t0 = time.perf_counter()
                params, opt_state, loss = perstep_fn(params, opt_state, idx)
                loss_np = np.asarray(loss).reshape(1)
                track_time(step, time.perf_counter() - t0, 1)
                if boundary_work(step + 1, loss_np, fused=False):
                    break
    finally:
        pre.uninstall()

    return {"params": params, "opt_state": opt_state, "history": history,
            "resumed_from": start_step}


def train_from_spec(
    spec,
    data: PreparedData,
    *,
    ckpt_dir: Optional[str] = None,
    n_steps: Optional[int] = None,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Spec-driven entry point: ``ForecastSpec`` in, trained params out.

    This is the path ``repro.forecast.ESRNNForecaster.fit`` and the
    ``repro.launch.forecast`` CLI use; the two-group learning rates come
    straight from the spec's first-class ``rnn_lr`` / ``hw_lr`` fields.
    ``spec.data_parallel`` (or an explicit ``mesh``) turns on series-sharded
    multi-device training.
    """
    cfg = TrainConfig.from_spec(spec, ckpt_dir=ckpt_dir, n_steps=n_steps)
    return train_esrnn(spec.model, data, cfg, params=params, hooks=hooks,
                       mesh=mesh)
