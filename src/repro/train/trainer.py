"""ES-RNN trainer: joint per-series + shared-weight optimization loop.

Production posture:
* checkpoint/restart (atomic, resumable mid-epoch because the batch schedule
  is stateless in ``step``),
* SIGTERM/SIGINT preemption hook -> checkpoint-and-exit (how a 1000-node job
  survives maintenance evictions),
* straggler watchdog: per-step wall-time EWMA; steps slower than
  ``straggler_factor``x the EWMA are logged (on real fleets this feeds the
  scheduler; here it exercises the code path),
* validation-driven best-checkpoint tracking (sMAPE on the held-out window,
  paper section 5.1).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import losses as L
from repro.core.esrnn import (
    _as_config, esrnn_forecast, esrnn_init, esrnn_loss, gather_series,
)
from repro.data.pipeline import PreparedData, batch_indices
from repro.train.optimizer import AdamConfig, adam_init, adam_update, esrnn_group_fn

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 256
    n_steps: int = 300
    lr: float = 1e-3
    per_series_lr_mult: float = 10.0    # HW params learn faster (Smyl setup)
    clip_norm: Optional[float] = 20.0
    seed: int = 0
    eval_every: int = 50
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    data_parallel: int = 0              # devices for the series-sharded path
                                        # (0/1 = single-device)

    @classmethod
    def from_spec(cls, spec, *, ckpt_dir: Optional[str] = None,
                  n_steps: Optional[int] = None) -> "TrainConfig":
        """Build from a ``repro.forecast.ForecastSpec``.

        The spec carries the two learning rates as first-class fields
        (``rnn_lr`` for shared weights, ``hw_lr`` for the per-series HW
        group); the trainer's group machinery consumes them as a ratio.
        """
        return cls(
            batch_size=spec.batch_size,
            n_steps=spec.n_steps if n_steps is None else n_steps,
            lr=spec.rnn_lr,
            per_series_lr_mult=spec.hw_lr / spec.rnn_lr,
            clip_norm=spec.clip_norm,
            seed=spec.seed,
            eval_every=spec.eval_every,
            ckpt_every=spec.ckpt_every,
            ckpt_dir=ckpt_dir,
            keep=spec.keep,
            data_parallel=spec.data_parallel,
        )


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a cooperative checkpoint-and-exit flag."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def train_esrnn(
    model,
    data: PreparedData,
    cfg: TrainConfig,
    *,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Train; returns dict(params, history, resumed_from).

    ``model`` may be an :class:`~repro.core.esrnn.ESRNNConfig` (preferred) or
    the legacy ``ESRNN`` shim; training runs through the pure functional API
    either way.

    ``mesh``: optional 1-D series mesh (``repro.sharding.series``). With more
    than one device the loss runs series-sharded under ``shard_map``: each
    device owns its slice of the batch and of the gathered per-series HW
    rows (device-local gradients), while the shared RNN/head weights stay
    replicated with all-reduced gradients. The batch schedule, optimizer,
    and checkpoint format are identical to the single-device path, so the
    loss trajectory matches up to float summation order. If ``mesh`` is None
    a ``cfg.data_parallel > 1`` builds one over the first that many local
    devices.
    """
    mcfg = _as_config(model)
    if mesh is None and cfg.data_parallel and cfg.data_parallel > 1:
        from repro.sharding.series import make_series_mesh

        mesh = make_series_mesh(cfg.data_parallel)
    if mesh is not None and mesh.devices.size == 1:
        mesh = None  # 1-device mesh: identical math, skip the shard_map hop
    if mesh is not None:
        from repro.sharding.series import check_series_divisible, esrnn_loss_dp

        check_series_divisible(min(cfg.batch_size, data.n_series), mesh)
        log.info("series-data-parallel training on %d devices (%s)",
                 mesh.devices.size, ",".join(mesh.axis_names))
    if mcfg.use_pallas:
        # trains end-to-end: hw_scan/lstm_cell carry custom_vjp backward
        # kernels (interpret mode off-TPU), so no forward-only fallback here
        log.info("training through the Pallas kernel path (backend=%s)",
                 jax.default_backend())
    cfg_adam = AdamConfig(
        lr=cfg.lr,
        clip_norm=cfg.clip_norm,
        group_lr={"per_series": cfg.per_series_lr_mult, "default": 1.0},
    )
    n = data.n_series
    if params is None:
        params = esrnn_init(jax.random.PRNGKey(cfg.seed), mcfg, n)
    opt_state = adam_init(params)
    start_step = 0

    ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep) if cfg.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step, (params, opt_state) = ckpt.restore((params, opt_state))
        log.info("resumed from step %d", start_step)

    y_all = jnp.asarray(data.train)
    cats_all = jnp.asarray(data.cats)
    mask_all = jnp.asarray(data.mask)

    @jax.jit
    def step_fn(params, opt_state, idx):
        yb = y_all[idx]
        cb = cats_all[idx]
        mb = mask_all[idx]

        def batch_loss(p):
            # per-series params are gathered for the batch; gradient scatter
            # back to the full table happens automatically through indexing.
            # The observation mask keeps left-padded (variable-length)
            # positions out of the loss; it is all-ones for equalized data.
            pb = gather_series(p, idx)
            if mesh is not None:
                return esrnn_loss_dp(mcfg, pb, yb, cb, mb, mesh=mesh)
            return esrnn_loss(mcfg, pb, yb, cb, mb)

        loss, grads = jax.value_and_grad(batch_loss)(params)
        params, opt_state = adam_update(
            grads, opt_state, params, cfg_adam, group_fn=esrnn_group_fn
        )
        return params, opt_state, loss

    @jax.jit
    def val_smape(params):
        fc = esrnn_forecast(mcfg, params, jnp.asarray(data.train), cats_all)
        h = min(fc.shape[1], data.val_target.shape[1])
        return L.smape(fc[:, :h], jnp.asarray(data.val_target)[:, :h])

    pre = PreemptionHandler()
    pre.install()
    history = {"loss": [], "val_smape": [], "stragglers": []}
    ewma = None
    try:
        for step in range(start_step, cfg.n_steps):
            idx = jnp.asarray(batch_indices(n, min(cfg.batch_size, n), step, seed=cfg.seed))
            t0 = time.perf_counter()
            params, opt_state, loss = step_fn(params, opt_state, idx)
            loss = float(loss)
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if step > 5 and dt > cfg.straggler_factor * ewma:
                history["stragglers"].append((step, dt, ewma))
                log.warning("straggler step %d: %.3fs vs ewma %.3fs", step, dt, ewma)
            history["loss"].append(loss)

            if (step + 1) % cfg.eval_every == 0 or step + 1 == cfg.n_steps:
                vs = float(val_smape(params))
                history["val_smape"].append((step + 1, vs))
                if ckpt is not None:
                    ckpt.save(step + 1, (params, opt_state), metric=vs)
            elif ckpt is not None and (step + 1) % cfg.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))

            if hooks and "on_step" in hooks:
                hooks["on_step"](step, loss, params)
            if pre.requested:
                log.warning("preemption requested at step %d; checkpointing", step + 1)
                if ckpt is not None:
                    ckpt.save(step + 1, (params, opt_state))
                break
    finally:
        pre.uninstall()

    return {"params": params, "opt_state": opt_state, "history": history,
            "resumed_from": start_step}


def train_from_spec(
    spec,
    data: PreparedData,
    *,
    ckpt_dir: Optional[str] = None,
    n_steps: Optional[int] = None,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Spec-driven entry point: ``ForecastSpec`` in, trained params out.

    This is the path ``repro.forecast.ESRNNForecaster.fit`` and the
    ``repro.launch.forecast`` CLI use; the two-group learning rates come
    straight from the spec's first-class ``rnn_lr`` / ``hw_lr`` fields.
    ``spec.data_parallel`` (or an explicit ``mesh``) turns on series-sharded
    multi-device training.
    """
    cfg = TrainConfig.from_spec(spec, ckpt_dir=ckpt_dir, n_steps=n_steps)
    return train_esrnn(spec.model, data, cfg, params=params, hooks=hooks,
                       mesh=mesh)
