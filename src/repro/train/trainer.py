"""ES-RNN trainer: joint per-series + shared-weight optimization loop.

Production posture:
* fused supersteps (``scan_steps > 1``): K steps compile into one donated
  ``lax.scan`` dispatch over a precomputed on-device batch schedule
  (``repro.train.engine``); the host syncs once per superstep, which is
  where eval, checkpointing, the straggler EWMA, and hooks run,
* checkpoint/restart (atomic, resumable mid-epoch because the batch schedule
  is stateless in ``step`` -- a resume lands on any superstep boundary and
  re-aligns with the same absolute eval/ckpt steps),
* SIGTERM/SIGINT preemption hook -> checkpoint-and-exit (how a 1000-node job
  survives maintenance evictions); with fused supersteps the request is
  honored at the next superstep boundary,
* straggler watchdog: wall-time EWMA per step (per-step normalized within a
  superstep); steps slower than ``straggler_factor``x the EWMA are logged
  (on real fleets this feeds the scheduler; here it exercises the code path),
* validation-driven best-checkpoint tracking (sMAPE on the held-out window,
  paper section 5.1).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import losses as L
from repro.core.esrnn import ESRNNConfig, esrnn_forecast, esrnn_init
from repro.core.heads import frozen_param_groups
from repro.data.pipeline import (
    PreparedData, batch_indices, batch_schedule, chunk_batch_schedule,
    chunk_layout, chunk_visit_plan,
)
from repro.train.engine import (
    make_chunk_step_fn, make_chunk_superstep_fn, make_perstep_fn,
    make_step_fn, make_superstep_fn, segment_steps, split_frozen,
)
from repro.train.host_table import HostStateTable
from repro.train.optimizer import AdamConfig, adam_init, adam_init_sparse

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 256
    n_steps: int = 300
    lr: float = 1e-3
    per_series_lr_mult: float = 10.0    # HW params learn faster (Smyl setup)
    clip_norm: Optional[float] = 20.0
    seed: int = 0
    eval_every: int = 50
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    data_parallel: int = 0              # devices for the series-sharded path
                                        # (0/1 = single-device)
    scan_steps: int = 1                 # steps fused per donated superstep
                                        # (1 = per-step dispatch loop)
    sparse_adam: bool = False           # segment per-series Adam: update only
                                        # the batch's HW rows (lazy moments)
    compress_grads: bool = False        # error-feedback int8 compression of
                                        # the shared-weight gradient exchange
                                        # (per-series rows stay exact; dense
                                        # Adam only)
    series_chunk: int = 0               # > 0: partition the N series into
                                        # device-sized row chunks; the HW
                                        # table + its sparse-Adam state live
                                        # in a host-resident HostStateTable
                                        # and stream through the device one
                                        # chunk at a time (0 = resident)
    chunk_resident: bool = False        # debug reference: run the chunk-major
                                        # schedule with the full table kept on
                                        # device -- the trajectory the
                                        # streaming path must reproduce
                                        # (TrainConfig-only; not spec-exposed)

    @classmethod
    def from_spec(cls, spec, *, ckpt_dir: Optional[str] = None,
                  n_steps: Optional[int] = None) -> "TrainConfig":
        """Build from a ``repro.forecast.ForecastSpec``.

        The spec carries the two learning rates as first-class fields
        (``rnn_lr`` for shared weights, ``hw_lr`` for the per-series HW
        group); the trainer's group machinery consumes them as a ratio.
        """
        return cls(
            batch_size=spec.batch_size,
            n_steps=spec.n_steps if n_steps is None else n_steps,
            lr=spec.rnn_lr,
            per_series_lr_mult=spec.hw_lr / spec.rnn_lr,
            clip_norm=spec.clip_norm,
            seed=spec.seed,
            eval_every=spec.eval_every,
            ckpt_every=spec.ckpt_every,
            ckpt_dir=ckpt_dir,
            keep=spec.keep,
            data_parallel=spec.data_parallel,
            scan_steps=spec.scan_steps,
            sparse_adam=spec.sparse_adam,
            compress_grads=getattr(spec, "compress_grads", False),
            series_chunk=getattr(spec, "series_chunk", 0),
        )


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a cooperative checkpoint-and-exit flag."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def train_esrnn(
    model: ESRNNConfig,
    data: PreparedData,
    cfg: TrainConfig,
    *,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Train; returns dict(params, history, resumed_from).

    ``model`` is an :class:`~repro.core.esrnn.ESRNNConfig`; training runs
    through the pure functional API.

    ``mesh``: optional 1-D series mesh (``repro.sharding.series``). With more
    than one device the loss runs series-sharded under ``shard_map``: each
    device owns its slice of the batch and of the gathered per-series HW
    rows (device-local gradients), while the shared RNN/head weights stay
    replicated with all-reduced gradients. The batch schedule, optimizer,
    and checkpoint format are identical to the single-device path, so the
    loss trajectory matches up to float summation order. If ``mesh`` is None
    a ``cfg.data_parallel > 1`` builds one over the first that many local
    devices.

    ``cfg.scan_steps > 1`` switches to the fused superstep engine
    (``repro.train.engine``): K steps per donated ``lax.scan`` dispatch over
    a precomputed on-device batch schedule, host sync + eval/ckpt/hooks at
    superstep boundaries only. The per-step loss trajectory is the same math
    in the same order, so histories match the per-step engine; the
    ``on_step`` hook fires once per superstep with the segment's loss
    *array* instead of once per step with a float. Composes with ``mesh``
    (the scan wraps the ``shard_map``-ped loss) and ``use_pallas``.

    ``cfg.sparse_adam`` switches the per-series Holt-Winters table to the
    sparse segment update (``adam_update_sparse``): only the batch's rows
    are touched each step, skipped rows catch up their Adam moments in
    closed form. Off by default -- untouched rows no longer drift along
    stale momentum, which changes trajectories slightly vs dense Adam.
    """
    mcfg = model
    if cfg.series_chunk and cfg.series_chunk > 0:
        if cfg.compress_grads:
            raise ValueError(
                "series_chunk > 0 requires the sparse optimizer path and "
                "compress_grads requires the dense one: the chunked fit "
                "never materializes a shared-gradient exchange to compress")
        if not cfg.sparse_adam:
            log.info("series_chunk=%d: enabling sparse per-series Adam "
                     "(the chunked path only ever holds the batch's rows)",
                     cfg.series_chunk)
            cfg = dataclasses.replace(cfg, sparse_adam=True)
        if not cfg.chunk_resident:
            return _train_chunked(mcfg, data, cfg, params=params,
                                  hooks=hooks, mesh=mesh)
    mesh = _resolve_train_mesh(cfg, mesh)
    if mesh is not None:
        from repro.sharding.series import check_series_divisible

        if cfg.series_chunk and cfg.series_chunk > 0:
            per_chunk, _ = chunk_layout(
                data.n_series, cfg.series_chunk, cfg.batch_size)
            for _, _, bs_c, _ in per_chunk:
                check_series_divisible(bs_c, mesh)
        else:
            check_series_divisible(min(cfg.batch_size, data.n_series), mesh)
        log.info("series-data-parallel training on %d devices (%s)",
                 mesh.devices.size, ",".join(mesh.axis_names))
    if mcfg.use_pallas:
        # trains end-to-end: hw_scan/lstm_cell carry custom_vjp backward
        # kernels (interpret mode off-TPU), so no forward-only fallback here
        log.info("training through the Pallas kernel path (backend=%s)",
                 jax.default_backend())
    cfg_adam = AdamConfig(
        lr=cfg.lr,
        clip_norm=cfg.clip_norm,
        group_lr={"per_series": cfg.per_series_lr_mult, "default": 1.0},
    )
    n = data.n_series
    if params is None:
        params = esrnn_init(jax.random.PRNGKey(cfg.seed), mcfg, n)
    else:
        # the engines donate (params, opt_state) unless hooks are present;
        # copy the caller's tree once so their reference stays valid
        params = jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True), params)
    # optimizer state covers the trainable subtree only: the head registry
    # declares groups it keeps fixed (e.g. the esn reservoir), and those
    # carry no gradients, no Adam moments, and no checkpointed moment state
    frozen = frozen_param_groups(mcfg)
    trainable, _ = split_frozen(params, frozen)
    if frozen:
        log.info("head %r freezes param group(s) %s: training %s + hw only",
                 mcfg.head, sorted(frozen),
                 sorted(k for k in trainable if k != "hw"))
    opt_state = (adam_init_sparse(trainable) if cfg.sparse_adam
                 else adam_init(trainable))
    if cfg.compress_grads:
        if cfg.sparse_adam:
            raise ValueError(
                "compress_grads requires dense Adam (sparse_adam=False): "
                "the sparse path has no shared-gradient exchange to compress")
        from repro.train.grad_compression import init_error_state

        # step state grows an error-feedback residual over the shared
        # trainable groups; checkpoints carry it like any other opt leaf
        opt_state = (opt_state, init_error_state(
            {k: v for k, v in trainable.items() if k != "hw"}))
        log.info("error-feedback int8 compression of shared grads enabled")
    start_step = 0

    ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep) if cfg.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        try:
            start_step, (params, opt_state) = ckpt.restore((params, opt_state))
        except ValueError as e:
            # checkpoints are engine-portable (scan_steps), but the sparse
            # optimizer state carries an extra per-row clock: flipping
            # sparse_adam across a resume is a real state mismatch. Other
            # restore failures (shape drift etc.) pass through untouched.
            if "tree structure mismatch" not in str(e):
                raise
            raise ValueError(
                f"cannot resume from {cfg.ckpt_dir}: {e}. If this run was "
                f"checkpointed with a different sparse_adam setting "
                f"(currently {cfg.sparse_adam}), resume with the original "
                "setting -- the dense and sparse Adam states are not "
                "interchangeable") from e
        log.info("resumed from step %d", start_step)

    y_all = jnp.asarray(data.train)
    cats_all = jnp.asarray(data.cats)
    mask_all = jnp.asarray(data.mask)
    bs = min(cfg.batch_size, n)

    # The pure step -- shared verbatim by the per-step loop and the fused
    # scan, so the two engines walk float-identical trajectories. The
    # observation mask keeps left-padded (variable-length) positions out of
    # the loss; it is all-ones for equalized data.
    step_fn = make_step_fn(mcfg, cfg_adam, y_all, cats_all, mask_all,
                           mesh=mesh, sparse=cfg.sparse_adam, frozen=frozen,
                           compress=cfg.compress_grads)

    @jax.jit
    def val_smape(params):
        fc = esrnn_forecast(mcfg, params, y_all, cats_all)
        h = min(fc.shape[1], data.val_target.shape[1])
        return L.smape(fc[:, :h], jnp.asarray(data.val_target)[:, :h])

    pre = PreemptionHandler()
    pre.install()
    history = {"loss": [], "val_smape": [], "stragglers": []}
    ewma = None

    def boundary_work(reached: int, losses: np.ndarray, fused: bool) -> bool:
        """Host-side work at a step boundary: eval, ckpt, hooks, preemption.

        ``reached`` is the number of completed steps; ``losses`` the per-step
        losses since the previous boundary (length 1 in the per-step loop).
        Returns True when the trainer should stop (preemption).
        """
        history["loss"].extend(float(l) for l in losses)
        if reached % cfg.eval_every == 0 or reached == cfg.n_steps:
            vs = float(val_smape(params))
            history["val_smape"].append((reached, vs))
            if ckpt is not None:
                ckpt.save(reached, (params, opt_state), metric=vs)
        elif ckpt is not None and reached % cfg.ckpt_every == 0:
            ckpt.save(reached, (params, opt_state))
        if hooks and "on_step" in hooks:
            # fused engine: the last completed step index + the segment's
            # loss array (always an array, even for a length-1 segment, so
            # hooks see one stable type); per-step engine: a float per
            # step, the pre-existing contract
            hooks["on_step"](reached - 1,
                             losses if fused else float(losses[0]),
                             params)
        if pre.requested:
            log.warning("preemption requested at step %d; checkpointing",
                        reached)
            if ckpt is not None:
                ckpt.save(reached, (params, opt_state))
            return True
        return False

    def track_time(first_step: int, dt_per_step: float, k: int):
        nonlocal ewma
        ewma = dt_per_step if ewma is None else 0.9 * ewma + 0.1 * dt_per_step
        if first_step > 5 and dt_per_step > cfg.straggler_factor * ewma:
            history["stragglers"].append((first_step, dt_per_step, ewma))
            log.warning("straggler step %d (x%d): %.3fs/step vs ewma %.3fs",
                        first_step, k, dt_per_step, ewma)

    # an on_step hook may retain the params tree it is handed; donation
    # would delete those buffers at the next dispatch, so hooks opt the
    # engines out of it (the pre-existing undonated behavior)
    donate = not (hooks and "on_step" in hooks)
    try:
        if cfg.series_chunk and cfg.series_chunk > 0:
            # chunk-resident reference engine: walk the *chunk-major*
            # schedule (chunk-pure batches, permuted visit order) with the
            # full table still on device -- the exact trajectory the
            # streaming HostStateTable path must reproduce, via the same
            # fused superstep fed global row indices (lo + local idx)
            superstep_fn = make_superstep_fn(step_fn, donate=donate)
            log.info("chunk-resident reference engine: series_chunk=%d",
                     cfg.series_chunk)
            stop = False
            for v in chunk_visit_plan(n, cfg.series_chunk, cfg.batch_size,
                                      start_step, cfg.n_steps, seed=cfg.seed):
                for step, k in segment_steps(
                        v.step, v.step + v.n_steps, cfg.scan_steps,
                        cfg.eval_every, cfg.ckpt_every):
                    sched = jnp.asarray(v.lo + chunk_batch_schedule(
                        v.hi - v.lo, v.batch_size, v.epoch, v.chunk_id,
                        v.start_k + (step - v.step), k, seed=cfg.seed))
                    t0 = time.perf_counter()
                    params, opt_state, losses = superstep_fn(
                        params, opt_state, sched)
                    losses = np.asarray(losses)
                    track_time(step, (time.perf_counter() - t0) / k, k)
                    if boundary_work(step + k, losses, fused=True):
                        stop = True
                        break
                if stop:
                    break
        elif cfg.scan_steps > 1:
            # fused engine: K-step donated supersteps over the on-device
            # schedule; host syncs (and eval/ckpt/hooks) only at boundaries
            superstep_fn = make_superstep_fn(step_fn, donate=donate)
            log.info("fused superstep engine: scan_steps=%d%s",
                     cfg.scan_steps,
                     ", sparse per-series adam" if cfg.sparse_adam else "")
            for step, k in segment_steps(start_step, cfg.n_steps,
                                         cfg.scan_steps, cfg.eval_every,
                                         cfg.ckpt_every):
                sched = jnp.asarray(
                    batch_schedule(n, bs, step, k, seed=cfg.seed))
                t0 = time.perf_counter()
                params, opt_state, losses = superstep_fn(
                    params, opt_state, sched)
                losses = np.asarray(losses)   # the one host sync per segment
                track_time(step, (time.perf_counter() - t0) / k, k)
                if boundary_work(step + k, losses, fused=True):
                    break
        else:
            perstep_fn = make_perstep_fn(step_fn, donate=donate)
            for step in range(start_step, cfg.n_steps):
                idx = jnp.asarray(batch_indices(n, bs, step, seed=cfg.seed))
                t0 = time.perf_counter()
                params, opt_state, loss = perstep_fn(params, opt_state, idx)
                loss_np = np.asarray(loss).reshape(1)
                track_time(step, time.perf_counter() - t0, 1)
                if boundary_work(step + 1, loss_np, fused=False):
                    break
    finally:
        pre.uninstall()

    return {"params": params, "opt_state": opt_state, "history": history,
            "resumed_from": start_step}


def _resolve_train_mesh(cfg: TrainConfig, mesh):
    """Resolve ``cfg.data_parallel`` into a series mesh (None = 1 device)."""
    if mesh is None and cfg.data_parallel and cfg.data_parallel > 1:
        from repro.sharding.series import make_series_mesh

        mesh = make_series_mesh(cfg.data_parallel)
    if mesh is not None and mesh.devices.size == 1:
        mesh = None  # 1-device mesh: identical math, skip the shard_map hop
    return mesh


def _train_chunked(
    mcfg: ESRNNConfig,
    data: PreparedData,
    cfg: TrainConfig,
    *,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """The streaming chunked fit: out-of-core HW table, resident head.

    The N-series state -- HW rows, their sparse-Adam moments, the ``t_hw``
    clocks -- lives in a host :class:`~repro.train.host_table.HostStateTable`;
    only one ``series_chunk``-row slice (plus its slice of the training
    tensors) is on device at a time. Shared head weights, their moments and
    the global ``step`` scalar persist on device across chunks. Per epoch the
    chunks are visited in permuted order with chunk-pure batches
    (:func:`~repro.data.pipeline.chunk_visit_plan`); within a visit the
    donated chunk superstep runs the ordinary fused segments. The next
    visit's H2D transfers are enqueued before the current visit's compute is
    drained (double buffering via JAX async dispatch), and a retiring chunk
    is written back D2H only when the rows actually change hands.

    Because ``t_hw`` carries *global* last-touch steps and the Adam ``step``
    scalar is global, the per-chunk sparse updates are exact: this walks the
    same trajectory as ``chunk_resident=True`` (the full-table debug
    reference) bit-for-bit on one backend. Eval streams chunks through
    ``smape_terms``; checkpoints carry the same ``(params, opt_state)`` tree
    as a resident sparse fit (table leaves host-side, sharded files), so the
    two modes resume into each other. Returned ``params["hw"]`` leaves are
    host numpy.
    """
    mesh = _resolve_train_mesh(cfg, mesh)
    n = data.n_series
    per_chunk, _ = chunk_layout(n, cfg.series_chunk, cfg.batch_size)
    if mesh is not None:
        from repro.sharding.series import check_series_divisible

        for _, _, bs_c, _ in per_chunk:
            check_series_divisible(bs_c, mesh)
        log.info("chunked + series-data-parallel: %d chunks over %d devices",
                 len(per_chunk), mesh.devices.size)
    cfg_adam = AdamConfig(
        lr=cfg.lr,
        clip_norm=cfg.clip_norm,
        group_lr={"per_series": cfg.per_series_lr_mult, "default": 1.0},
    )
    frozen = frozen_param_groups(mcfg)

    # shared weights: the head init never sees n_series, so a 1-row init is
    # bit-identical to the resident esrnn_init(key, mcfg, n) shared leaves
    seed_params = esrnn_init(jax.random.PRNGKey(cfg.seed), mcfg, 1)
    if params is not None:
        # warm start: adopt the caller's rows into the host table (copied --
        # absorb writes in place) and copy the shared leaves (donation)
        table = HostStateTable.from_state(params, with_moments=True)
        shared = {k: jnp.array(v, copy=True)
                  for k, v in params.items() if k != "hw"}
    else:
        table = HostStateTable.init(
            n, mcfg.seasonality, seasonality2=mcfg.seasonality2,
            dtype=np.dtype(mcfg.dtype))
        shared = {k: v for k, v in seed_params.items() if k != "hw"}
    shared_train, _ = split_frozen(shared, frozen)
    if frozen:
        log.info("head %r freezes param group(s) %s: training %s + hw only",
                 mcfg.head, sorted(frozen),
                 sorted(k for k in shared_train))
    sh_opt = adam_init(shared_train)
    mu_sh, nu_sh, step_scalar = sh_opt["mu"], sh_opt["nu"], sh_opt["step"]
    log.info("streaming chunked fit: N=%d series_chunk=%d (%d chunks), "
             "host table %.1f MB", n, cfg.series_chunk, len(per_chunk),
             table.nbytes() / 1e6)

    def full_state():
        """The checkpoint/return tree: same structure as a resident sparse
        fit (restores interchangeably), table leaves host numpy."""
        return ({"hw": table.hw, **shared},
                {"mu": {"hw": table.mu_hw, **mu_sh},
                 "nu": {"hw": table.nu_hw, **nu_sh},
                 "step": step_scalar, "t_hw": table.t_hw})

    start_step = 0
    ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep) if cfg.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        is_table = lambda path: any(
            getattr(e, "key", getattr(e, "name", None)) in ("hw", "t_hw")
            for e in path)
        try:
            start_step, (p_full, o_full) = ckpt.restore(
                full_state(), host_paths=is_table)
        except ValueError as e:
            if "tree structure mismatch" not in str(e):
                raise
            raise ValueError(
                f"cannot resume from {cfg.ckpt_dir}: {e}. Chunked fits "
                "carry the sparse-Adam state; a checkpoint written with "
                "sparse_adam=False (dense moments) is not interchangeable "
                "-- resume with the original setting") from e
        table = HostStateTable(
            p_full["hw"], mu_hw=o_full["mu"]["hw"], nu_hw=o_full["nu"]["hw"],
            t_hw=o_full["t_hw"])
        shared = {k: v for k, v in p_full.items() if k != "hw"}
        mu_sh = {k: v for k, v in o_full["mu"].items() if k != "hw"}
        nu_sh = {k: v for k, v in o_full["nu"].items() if k != "hw"}
        step_scalar = o_full["step"]
        log.info("resumed from step %d", start_step)

    y_np = np.asarray(data.train)
    cats_np = np.asarray(data.cats)
    mask_np = np.asarray(data.mask)
    val_np = np.asarray(data.val_target)
    h_val = min(mcfg.output_size, val_np.shape[1])

    step_fn = make_chunk_step_fn(mcfg, cfg_adam, mesh=mesh, frozen=frozen)
    donate = not (hooks and "on_step" in hooks)
    superstep_fn = make_chunk_superstep_fn(step_fn, donate=donate)

    @jax.jit
    def _val_terms(sh, hw_c, y_c, cats_c, tgt_c):
        fc = esrnn_forecast(mcfg, {"hw": hw_c, **sh}, y_c, cats_c)
        return L.smape_terms(fc[:, :h_val], tgt_c[:, :h_val])

    def streamed_val_smape() -> float:
        """Validation sMAPE without full-table residency: stream every chunk
        through the forecast, accumulate the exact sum/count terms."""
        s = c = 0.0
        for lo, hi, _, _ in per_chunk:
            hw_c = jax.tree_util.tree_map(
                lambda a: jax.device_put(a[lo:hi]), table.hw)
            ds, dc = _val_terms(shared, hw_c, jnp.asarray(y_np[lo:hi]),
                                jnp.asarray(cats_np[lo:hi]),
                                jnp.asarray(val_np[lo:hi]))
            s += float(ds)
            c += float(dc)
        return 200.0 * s / max(c, 1.0)

    def _stage(lo: int, hi: int) -> Dict:
        """Enqueue one chunk's H2D transfers: table rows + data slices."""
        return {"state": table.device_slice(lo, hi),
                "y": jax.device_put(y_np[lo:hi]),
                "cats": jax.device_put(cats_np[lo:hi]),
                "mask": jax.device_put(mask_np[lo:hi])}

    pre = PreemptionHandler()
    pre.install()
    history = {"loss": [], "val_smape": [], "stragglers": []}
    ewma = None
    stop = False

    def track_time(first_step: int, dt_per_step: float, k: int):
        nonlocal ewma
        ewma = dt_per_step if ewma is None else 0.9 * ewma + 0.1 * dt_per_step
        if first_step > 5 and dt_per_step > cfg.straggler_factor * ewma:
            history["stragglers"].append((first_step, dt_per_step, ewma))
            log.warning("straggler step %d (x%d): %.3fs/step vs ewma %.3fs",
                        first_step, k, dt_per_step, ewma)

    def _sync_shared(cparams, copt):
        nonlocal shared, mu_sh, nu_sh, step_scalar
        shared = {k: x for k, x in cparams.items() if k != "hw"}
        mu_sh = {k: x for k, x in copt["mu"].items() if k != "hw"}
        nu_sh = {k: x for k, x in copt["nu"].items() if k != "hw"}
        step_scalar = copt["step"]

    def _retire(v, cparams, copt):
        """Write the visit's rows back into the host table + sync shared."""
        _sync_shared(cparams, copt)
        table.absorb(v.lo, v.hi, {
            "hw": cparams["hw"], "mu": copt["mu"]["hw"],
            "nu": copt["nu"]["hw"], "t_hw": copt["t_hw"]})

    def chunk_boundary(v, reached, losses, cparams, copt):
        nonlocal stop
        history["loss"].extend(float(l) for l in losses)
        do_eval = reached % cfg.eval_every == 0 or reached == cfg.n_steps
        do_ckpt = ckpt is not None and (
            do_eval or reached % cfg.ckpt_every == 0)
        if do_eval or do_ckpt or pre.requested:
            # checkpoint/eval see the chunk's latest rows through the table
            _retire(v, cparams, copt)
        if do_eval:
            vs = streamed_val_smape()
            history["val_smape"].append((reached, vs))
            if ckpt is not None:
                ckpt.save(reached, full_state(), metric=vs,
                          shard_rows=cfg.series_chunk)
        elif do_ckpt:
            ckpt.save(reached, full_state(), shard_rows=cfg.series_chunk)
        if hooks and "on_step" in hooks:
            hooks["on_step"](reached - 1, losses, cparams)
        if pre.requested:
            log.warning("preemption requested at step %d; checkpointing",
                        reached)
            if ckpt is not None:
                ckpt.save(reached, full_state(), shard_rows=cfg.series_chunk)
            stop = True

    visits = list(chunk_visit_plan(n, cfg.series_chunk, cfg.batch_size,
                                   start_step, cfg.n_steps, seed=cfg.seed))
    staged = _stage(visits[0].lo, visits[0].hi) if visits else None
    try:
        for i, v in enumerate(visits):
            cur = staged
            staged = None
            cparams = {"hw": cur["state"]["hw"], **shared}
            copt = {"mu": {"hw": cur["state"]["mu"], **mu_sh},
                    "nu": {"hw": cur["state"]["nu"], **nu_sh},
                    "step": step_scalar, "t_hw": cur["state"]["t_hw"]}
            nxt = visits[i + 1] if i + 1 < len(visits) else None
            if nxt is not None and (nxt.lo, nxt.hi) != (v.lo, v.hi):
                # double-buffer: enqueue the next chunk's H2D now, so it
                # rides under this visit's compute. Same-row next visits
                # skip it -- their rows would be stale -- and instead carry
                # the retiring device state forward directly.
                staged = _stage(nxt.lo, nxt.hi)
            for step, k in segment_steps(
                    v.step, v.step + v.n_steps, cfg.scan_steps,
                    cfg.eval_every, cfg.ckpt_every):
                sched = jnp.asarray(chunk_batch_schedule(
                    v.hi - v.lo, v.batch_size, v.epoch, v.chunk_id,
                    v.start_k + (step - v.step), k, seed=cfg.seed))
                t0 = time.perf_counter()
                cparams, copt, losses = superstep_fn(
                    cparams, copt, cur["y"], cur["cats"], cur["mask"], sched)
                losses = np.asarray(losses)  # the one host sync per segment
                track_time(step, (time.perf_counter() - t0) / k, k)
                chunk_boundary(v, step + k, losses, cparams, copt)
                if stop:
                    break
            if stop:
                break
            if nxt is not None and (nxt.lo, nxt.hi) == (v.lo, v.hi):
                # same rows next visit (e.g. a single chunk covering all N):
                # no round-trip, hand the device state straight across
                staged = {"state": {"hw": cparams["hw"],
                                    "mu": copt["mu"]["hw"],
                                    "nu": copt["nu"]["hw"],
                                    "t_hw": copt["t_hw"]},
                          "y": cur["y"], "cats": cur["cats"],
                          "mask": cur["mask"]}
                _sync_shared(cparams, copt)
            else:
                _retire(v, cparams, copt)
    finally:
        pre.uninstall()

    p_full, o_full = full_state()
    return {"params": p_full, "opt_state": o_full, "history": history,
            "resumed_from": start_step}


def train_from_spec(
    spec,
    data: PreparedData,
    *,
    ckpt_dir: Optional[str] = None,
    n_steps: Optional[int] = None,
    params=None,
    hooks: Optional[Dict[str, Callable]] = None,
    mesh=None,
) -> Dict:
    """Spec-driven entry point: ``ForecastSpec`` in, trained params out.

    This is the path ``repro.forecast.ESRNNForecaster.fit`` and the
    ``repro.launch.forecast`` CLI use; the two-group learning rates come
    straight from the spec's first-class ``rnn_lr`` / ``hw_lr`` fields.
    ``spec.data_parallel`` (or an explicit ``mesh``) turns on series-sharded
    multi-device training.
    """
    cfg = TrainConfig.from_spec(spec, ckpt_dir=ckpt_dir, n_steps=n_steps)
    return train_esrnn(spec.model, data, cfg, params=params, hooks=hooks,
                       mesh=mesh)
