"""Pallas TPU kernel: batched Holt-Winters exponential smoothing scan.

This is the paper's hot spot adapted to the TPU memory hierarchy. The GPU
implementation parallelizes series across CUDA threads; the TPU-native
schedule is:

* series tiled onto the **lane** dimension (128-wide VPU vectors) --
  time-major layout ``(T, N)`` so each time step is one vector op row;
* the sequential time recurrence runs as an in-kernel ``fori_loop`` with all
  state (level vector, M-row seasonality ring) resident in **VMEM** -- zero
  HBM traffic inside the loop beyond the streamed y rows and emitted outputs;
* grid over series blocks: each grid step owns a ``(T, BN)`` tile.

The seasonality ring holds rows ``s`` for times ``t === row (mod M)``; at step
``t`` slot ``t mod M`` is read (s_t) and overwritten with ``s_{t+M}``, exactly
Eq. 3 with multiplicative seasonality and no trend (Smyl variant).

Differentiation (the paper's actual workload is *training*): ``hw_scan_tm``
carries a :func:`jax.custom_vjp` whose backward pass is a second Pallas
kernel running the adjoint recurrence time-reversed. The forward already
emits the ``(levels, seas)`` residuals the adjoint needs, so nothing extra is
saved beyond the inputs. With ``lam_t`` the level cotangent and ``sig_t`` the
seasonality cotangent, reversing

    l_t     = alpha * y_t / s_t + (1 - alpha) * l_{t-1}
    s_{t+m} = gamma * y_t / l_t + (1 - gamma) * s_t

gives, for t = T-1 .. 0 (``dl``/``ds`` are the output cotangents):

    lam_t = dl_t + (1 - alpha) * lam_{t+1} - sig_{t+m} * gamma * y_t / l_t^2
    sig_t = ds_t + (1 - gamma) * sig_{t+m} - lam_t * alpha * y_t / s_t^2
    dy_t    = lam_t * alpha / s_t + sig_{t+m} * gamma / l_t
    dalpha += lam_t * (y_t / s_t - l_{t-1})
    dgamma += sig_{t+m} * (y_t / l_t - s_t)

The ``sig`` values live in the same M-row VMEM ring as the forward (slot
``t mod m`` holds ``sig_{t+m}`` before step t and ``sig_t`` after), seeded
with the trailing future-factor cotangents ``ds_{T..T+M-1}``; after the loop
the ring *is* ``d init_seas`` (slot k holds ``sig_k``). The synthetic initial
level ``l_{-1} = y_0 / s_0`` closes the recurrence: its cotangent
``(1 - alpha) * lam_0`` routes to ``y_0`` and ring slot 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Series-per-block: one full lane row. Sublane dim is time (streamed).
BLOCK_N = 128


def _hw_scan_kernel(y_ref, a_ref, g_ref, s0_ref, lev_ref, seas_ref, ring_ref,
                    *, t_len: int, m: int):
    alpha = a_ref[0, :]                     # (BN,)
    gamma = g_ref[0, :]
    # Precision policy: y may stream in bf16 (half-width VMEM tiles), but the
    # level/seasonality recurrence accumulates in the param dtype (fp32) --
    # each loaded y row is widened before use, state never rounds down.
    state_dt = alpha.dtype

    # init the seasonality ring in VMEM scratch
    ring_ref[...] = s0_ref[...]

    def body(t, l_prev):
        slot = jax.lax.rem(t, m)
        y_t = pl.load(y_ref, (pl.ds(t, 1), slice(None)))[0].astype(state_dt)
        s_t = pl.load(ring_ref, (pl.ds(slot, 1), slice(None)))[0]
        l_t = alpha * y_t / s_t + (1.0 - alpha) * l_prev
        s_new = gamma * y_t / l_t + (1.0 - gamma) * s_t
        pl.store(ring_ref, (pl.ds(slot, 1), slice(None)), s_new[None, :])
        pl.store(lev_ref, (pl.ds(t, 1), slice(None)), l_t[None, :])
        pl.store(seas_ref, (pl.ds(t, 1), slice(None)), s_t[None, :])
        return l_t

    l0 = y_ref[0, :].astype(state_dt) / s0_ref[0, :]
    jax.lax.fori_loop(0, t_len, body, l0)

    # trailing future factors s_T .. s_{T+M-1} live in ring slots (T+k) mod M
    for k in range(m):  # m is static and small (<= 24)
        slot = (t_len + k) % m
        row = pl.load(ring_ref, (pl.ds(slot, 1), slice(None)))
        pl.store(seas_ref, (pl.ds(t_len + k, 1), slice(None)), row)


def _hw_scan_bwd_kernel(y_ref, a_ref, g_ref, lev_ref, seas_ref,
                        dlev_ref, dseas_ref,
                        dy_ref, da_ref, dg_ref, ds0_ref, ring_ref,
                        *, t_len: int, m: int):
    """Adjoint recurrence, time-reversed, same (T, BN) lane layout.

    The sigma ring mirrors the forward's seasonality ring: before reverse
    step t, slot ``t mod m`` holds ``sig_{t+m}`` (the fully-accumulated
    cotangent of s_{t+m}); the step overwrites it with ``sig_t``.
    """
    alpha = a_ref[0, :]                     # (BN,)
    gamma = g_ref[0, :]
    state_dt = alpha.dtype
    # s_0 == init_seas_0: the forward emits it as seas row 0, so the
    # init_seas array itself need not be streamed into the backward.
    s00 = seas_ref[0, :]
    y0 = y_ref[0, :].astype(state_dt)

    # seed: the trailing future factors s_T .. s_{T+M-1} are pure outputs,
    # so their cotangents are exactly the incoming dseas rows.
    for k in range(m):
        slot = (t_len + k) % m
        row = pl.load(dseas_ref, (pl.ds(t_len + k, 1), slice(None)))
        pl.store(ring_ref, (pl.ds(slot, 1), slice(None)), row)

    zeros = jnp.zeros_like(alpha)

    def body(i, carry):
        lam_next, da, dg = carry
        t = t_len - 1 - i
        slot = jax.lax.rem(t, m)
        y_t = pl.load(y_ref, (pl.ds(t, 1), slice(None)))[0].astype(state_dt)
        l_t = pl.load(lev_ref, (pl.ds(t, 1), slice(None)))[0]
        s_t = pl.load(seas_ref, (pl.ds(t, 1), slice(None)))[0]
        # l_{t-1}: levels row t-1 for t > 0, else the primer l_{-1} = y_0/s_0
        l_prev = pl.load(lev_ref, (pl.ds(jnp.maximum(t - 1, 0), 1),
                                   slice(None)))[0]
        l_prev = jnp.where(t > 0, l_prev, y0 / s00)
        sig_tpm = pl.load(ring_ref, (pl.ds(slot, 1), slice(None)))[0]

        lam_t = (pl.load(dlev_ref, (pl.ds(t, 1), slice(None)))[0]
                 + (1.0 - alpha) * lam_next
                 - sig_tpm * gamma * y_t / (l_t * l_t))
        sig_t = (pl.load(dseas_ref, (pl.ds(t, 1), slice(None)))[0]
                 + (1.0 - gamma) * sig_tpm
                 - lam_t * alpha * y_t / (s_t * s_t))
        pl.store(ring_ref, (pl.ds(slot, 1), slice(None)), sig_t[None, :])

        dy_t = lam_t * alpha / s_t + sig_tpm * gamma / l_t
        # l_{-1} = y_0 / s_0 adds (1-alpha)*lam_0 / s_0 to dy_0
        dy_t = dy_t + jnp.where(t == 0, (1.0 - alpha) * lam_t / s00, 0.0)
        pl.store(dy_ref, (pl.ds(t, 1), slice(None)),
                 dy_t.astype(dy_ref.dtype)[None, :])

        da = da + lam_t * (y_t / s_t - l_prev)
        dg = dg + sig_tpm * (y_t / l_t - s_t)
        return lam_t, da, dg

    lam0, da, dg = jax.lax.fori_loop(0, t_len, body, (zeros, zeros, zeros))

    da_ref[...] = da[None, :]
    dg_ref[...] = dg[None, :]
    # after the loop, ring slot k holds sig_k == d loss / d init_seas_k
    ds0_ref[...] = ring_ref[...]
    # ... minus the primer-level term through l_{-1} = y_0 / s_0 on slot 0
    corr = (1.0 - alpha) * lam0 * y0 / (s00 * s00)
    row0 = pl.load(ds0_ref, (pl.ds(0, 1), slice(None)))[0]
    pl.store(ds0_ref, (pl.ds(0, 1), slice(None)), (row0 - corr)[None, :])


def _hw_scan_fwd_call(y_tm, alpha, gamma, init_seas_tm, *, interpret: bool):
    t_len, n = y_tm.shape
    m = init_seas_tm.shape[0]
    # outputs and the VMEM ring carry the *param* (state) dtype: under the
    # bf16 policy only the streamed y tiles are half width, the recurrence
    # state stays fp32
    dtype = alpha.dtype
    grid = (n // BLOCK_N,)

    kernel = functools.partial(_hw_scan_kernel, t_len=t_len, m=m)
    levels, seas = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((m, BLOCK_N), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((t_len, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((t_len + m, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, n), dtype),
            jax.ShapeDtypeStruct((t_len + m, n), dtype),
        ],
        scratch_shapes=[_vmem_scratch((m, BLOCK_N), dtype)],
        interpret=interpret,
    )(y_tm, alpha[None, :], gamma[None, :], init_seas_tm)
    return levels, seas


def _hw_scan_bwd_call(y_tm, alpha, gamma, levels, seas, dlev, dseas, *,
                      m: int, interpret: bool):
    t_len, n = y_tm.shape
    # param/init-seas cotangents accumulate in the state dtype; only dy
    # drops back to the (possibly bf16) observation dtype
    dtype = alpha.dtype
    grid = (n // BLOCK_N,)

    kernel = functools.partial(_hw_scan_bwd_kernel, t_len=t_len, m=m)
    col = lambda rows: pl.BlockSpec((rows, BLOCK_N), lambda i: (0, i))
    dy, da, dg, ds0 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            col(t_len),              # y
            col(1),                  # alpha
            col(1),                  # gamma
            col(t_len),              # levels
            col(t_len + m),          # seas
            col(t_len),              # dlevels
            col(t_len + m),          # dseas
        ],
        out_specs=[col(t_len), col(1), col(1), col(m)],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, n), y_tm.dtype),
            jax.ShapeDtypeStruct((1, n), dtype),
            jax.ShapeDtypeStruct((1, n), dtype),
            jax.ShapeDtypeStruct((m, n), dtype),
        ],
        scratch_shapes=[_vmem_scratch((m, BLOCK_N), dtype)],
        interpret=interpret,
    )(y_tm, alpha[None, :], gamma[None, :], levels, seas, dlev, dseas)
    return dy, da[0], dg[0], ds0


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _hw_scan_tm(interpret, y_tm, alpha, gamma, init_seas_tm):
    return _hw_scan_fwd_call(y_tm, alpha, gamma, init_seas_tm,
                             interpret=interpret)


def _hw_scan_tm_fwd(interpret, y_tm, alpha, gamma, init_seas_tm):
    levels, seas = _hw_scan_fwd_call(y_tm, alpha, gamma, init_seas_tm,
                                     interpret=interpret)
    # residuals: the inputs plus the (levels, seas) the forward already
    # emits (seas row 0 covers init_seas_0, so the ring itself is not saved)
    return (levels, seas), (y_tm, alpha, gamma, levels, seas)


def _hw_scan_tm_bwd(interpret, res, cotangents):
    y_tm, alpha, gamma, levels, seas = res
    dlev, dseas = cotangents
    dy, da, dg, ds0 = _hw_scan_bwd_call(
        y_tm, alpha, gamma, levels, seas,
        jnp.asarray(dlev, levels.dtype), jnp.asarray(dseas, seas.dtype),
        m=seas.shape[0] - y_tm.shape[0], interpret=interpret)
    return dy, da, dg, ds0


_hw_scan_tm.defvjp(_hw_scan_tm_fwd, _hw_scan_tm_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hw_scan_tm(y_tm, alpha, gamma, init_seas_tm, *, interpret: bool = False):
    """Time-major entry. y_tm: (T, N); alpha/gamma: (N,); init_seas_tm: (M, N).

    N must be a multiple of BLOCK_N (ops.py pads). Returns levels_tm (T, N)
    and seas_tm (T+M, N). Differentiable: carries a custom_vjp whose backward
    is the time-reversed adjoint kernel (see module docstring).
    """
    return _hw_scan_tm(interpret, y_tm, alpha, gamma, init_seas_tm)


def _vmem_scratch(shape, dtype):
    """VMEM scratch allocation, tolerant of pallas API surface differences."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # CPU-only interpret environments without the TPU ext
        # pl.MemorySpace.ANY is an enum member, not a constructor; wrap it in
        # a MemoryRef the way pltpu.VMEM does (see test_hw_scan fallback test)
        return pl.MemoryRef(shape, jnp.dtype(dtype), pl.MemorySpace.ANY)
