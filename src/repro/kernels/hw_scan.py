"""Pallas TPU kernel: batched Holt-Winters exponential smoothing scan.

This is the paper's hot spot adapted to the TPU memory hierarchy. The GPU
implementation parallelizes series across CUDA threads; the TPU-native
schedule is:

* series tiled onto the **lane** dimension (128-wide VPU vectors) --
  time-major layout ``(T, N)`` so each time step is one vector op row;
* the sequential time recurrence runs as an in-kernel ``fori_loop`` with all
  state (level vector, M-row seasonality ring) resident in **VMEM** -- zero
  HBM traffic inside the loop beyond the streamed y rows and emitted outputs;
* grid over series blocks: each grid step owns a ``(T, BN)`` tile.

The seasonality ring holds rows ``s`` for times ``t === row (mod M)``; at step
``t`` slot ``t mod M`` is read (s_t) and overwritten with ``s_{t+M}``, exactly
Eq. 3 with multiplicative seasonality and no trend (Smyl variant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Series-per-block: one full lane row. Sublane dim is time (streamed).
BLOCK_N = 128


def _hw_scan_kernel(y_ref, a_ref, g_ref, s0_ref, lev_ref, seas_ref, ring_ref,
                    *, t_len: int, m: int):
    alpha = a_ref[0, :]                     # (BN,)
    gamma = g_ref[0, :]

    # init the seasonality ring in VMEM scratch
    ring_ref[...] = s0_ref[...]

    def body(t, l_prev):
        slot = jax.lax.rem(t, m)
        y_t = pl.load(y_ref, (pl.ds(t, 1), slice(None)))[0]        # (BN,)
        s_t = pl.load(ring_ref, (pl.ds(slot, 1), slice(None)))[0]
        l_t = alpha * y_t / s_t + (1.0 - alpha) * l_prev
        s_new = gamma * y_t / l_t + (1.0 - gamma) * s_t
        pl.store(ring_ref, (pl.ds(slot, 1), slice(None)), s_new[None, :])
        pl.store(lev_ref, (pl.ds(t, 1), slice(None)), l_t[None, :])
        pl.store(seas_ref, (pl.ds(t, 1), slice(None)), s_t[None, :])
        return l_t

    l0 = y_ref[0, :] / s0_ref[0, :]
    jax.lax.fori_loop(0, t_len, body, l0)

    # trailing future factors s_T .. s_{T+M-1} live in ring slots (T+k) mod M
    for k in range(m):  # m is static and small (<= 24)
        slot = (t_len + k) % m
        row = pl.load(ring_ref, (pl.ds(slot, 1), slice(None)))
        pl.store(seas_ref, (pl.ds(t_len + k, 1), slice(None)), row)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hw_scan_tm(y_tm, alpha, gamma, init_seas_tm, *, interpret: bool = False):
    """Time-major entry. y_tm: (T, N); alpha/gamma: (N,); init_seas_tm: (M, N).

    N must be a multiple of BLOCK_N (ops.py pads). Returns levels_tm (T, N)
    and seas_tm (T+M, N).
    """
    t_len, n = y_tm.shape
    m = init_seas_tm.shape[0]
    dtype = y_tm.dtype
    grid = (n // BLOCK_N,)

    kernel = functools.partial(_hw_scan_kernel, t_len=t_len, m=m)
    levels, seas = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((m, BLOCK_N), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((t_len, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((t_len + m, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, n), dtype),
            jax.ShapeDtypeStruct((t_len + m, n), dtype),
        ],
        scratch_shapes=[_vmem_scratch((m, BLOCK_N), dtype)],
        interpret=interpret,
    )(y_tm, alpha[None, :], gamma[None, :], init_seas_tm)
    return levels, seas


def _vmem_scratch(shape, dtype):
    """VMEM scratch allocation, tolerant of pallas API surface differences."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - CPU-only environments
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]
