"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kernels/ is tested shape/dtype-swept against the function here
(`tests/kernels/`). These are also the implementations used when a caller
asks for the non-kernel path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hw_scan_ref(y, alpha, gamma, init_seas):
    """Constrained-space Holt-Winters recurrence (see core/holt_winters.py).

    y: (N, T) > 0; alpha, gamma: (N,) in (0,1); init_seas: (N, M) > 0.
    Returns levels (N, T), seas (N, T+M)  [seas[:, t] = s_t applied to y_t].
    """
    n, t_len = y.shape
    l0 = y[:, 0] / init_seas[:, 0]

    def step(carry, y_t):
        l_prev, ring = carry
        s_t = ring[:, 0]
        l_t = alpha * y_t / s_t + (1.0 - alpha) * l_prev
        s_new = gamma * y_t / l_t + (1.0 - gamma) * s_t
        ring = jnp.concatenate([ring[:, 1:], s_new[:, None]], axis=1)
        return (l_t, ring), (l_t, s_t)

    (_, ring), (levels, seas_used) = jax.lax.scan(step, (l0, init_seas), y.T)
    return levels.T, jnp.concatenate([seas_used.T, ring], axis=1)


def lstm_cell_ref(wx, wh, b, x, h, c):
    """Fused LSTM cell. wx:(I,4H) wh:(H,4H) b:(4H,) x:(B,I) h,c:(B,H).

    Gate order (i, f, g, o)."""
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Multi-head attention oracle with GQA head grouping.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); Hq % Hkv == 0.
    Causal offset aligns the *ends* of q and k (decode-friendly).
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(tq)[:, None] + (tk - tq)
        ki = jnp.arange(tk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
