"""Pallas TPU kernel: chunked online-softmax (flash) attention, GQA-aware.

Beyond-paper kernel used by the LM architecture stack. IO-aware schedule for
the TPU memory hierarchy: ``(BQ, D)`` query tiles stay resident in VMEM while
``(BK, D)`` key/value tiles stream; the softmax is computed online with
running (max, sum) carried in VMEM scratch across the sequential innermost
grid dimension, so the ``(Tq, Tk)`` score matrix never exists in HBM.

Grid: ``(batch*heads, Tq/BQ, Tk/BK)`` -- the last dimension is sequential on
TPU, which is what makes the scratch-carried accumulator pattern valid.
GQA is expressed in the BlockSpec index maps (q head -> kv head), no
materialized head broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, q_offset: int,
                  block_q: int, block_k: int, num_k_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (BQ, D)
    k = k_ref[0]                                  # (BK, D)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (BQ,BK)

    if causal:
        qi = pl.program_id(1)
        q_ids = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
        k_ids = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_ids <= q_ids, s, NEG_INF)

    m_prev = m_ref[...]                           # (BQ, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                # (BQ, 1)
    l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True,
    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
    interpret: bool = False,
):
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D). Tq % BQ == Tk % BK == 0.

    Causal mask aligns the ends of q and k (prefill: Tq == Tk; decode-append:
    Tq < Tk means queries sit at the end of the key timeline).
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    num_k_blocks = tk // bk
    q_offset = tk - tq

    qr = q.reshape(b * hq, tq, d)
    kr = k.reshape(b * hkv, tk, d)
    vr = v.reshape(b * hkv, tk, d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: query head bh -> kv head (bh // group) within the same batch
        batch = bh // hq
        head = (bh % hq) // group
        return (batch * hkv + head, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        block_q=bq, block_k=bk, num_k_blocks=num_k_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, tq // bq, num_k_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, tq, d)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]
