"""Pallas TPU kernel: fused LSTM cell (gates GEMM + elementwise, one pass).

One step of the dilated LSTM (paper Fig. 1). The fusion target on TPU is:
both gate matmuls hit the MXU from a single VMEM residency of ``x``/``h``,
and the gate nonlinearities + state update run on the VPU without the
``(B, 4H)`` gates tensor ever round-tripping to HBM.

Blocking: grid over batch tiles; weights are small for the paper's sizes
(H <= 50 padded to 128) and live fully in VMEM per block. ops.py pads
(B -> 8k, I/H -> 128k) and strips.

Training path: ``lstm_cell_padded`` carries a :func:`jax.custom_vjp`. Its
forward rule runs an extended kernel that additionally emits the gate
activations ``[sigmoid(i) | sigmoid(f) | tanh(g) | sigmoid(o)]`` as one
``(B, 4H)`` residual; the backward rule is a second fused kernel that turns
``(dh, dc)`` into the pre-activation gate cotangents on the VPU and runs all
four transposed GEMMs (``dx``, ``dh_prev`` and the weight gradients) from the
same VMEM residency. Weight/bias gradients accumulate across batch-grid
steps into a single revisited output block (grid is sequential on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _gates(wx_ref, wh_ref, b_ref, x, h):
    return (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[0, :][None, :].astype(jnp.float32)
    )


def _lstm_kernel(wx_ref, wh_ref, b_ref, x_ref, h_ref, c_ref, h_out_ref, c_out_ref,
                 *, hidden: int):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = _gates(wx_ref, wh_ref, b_ref, x, h)
    i = gates[:, 0 * hidden : 1 * hidden]
    f = gates[:, 1 * hidden : 2 * hidden]
    g = gates[:, 2 * hidden : 3 * hidden]
    o = gates[:, 3 * hidden : 4 * hidden]
    c_new = jax.nn.sigmoid(f) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def _lstm_fwd_kernel(wx_ref, wh_ref, b_ref, x_ref, h_ref, c_ref,
                     h_out_ref, c_out_ref, act_ref, *, hidden: int):
    """Forward that also emits the gate activations as backward residuals."""
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = _gates(wx_ref, wh_ref, b_ref, x, h)
    si = jax.nn.sigmoid(gates[:, 0 * hidden : 1 * hidden])
    sf = jax.nn.sigmoid(gates[:, 1 * hidden : 2 * hidden])
    tg = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    so = jax.nn.sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c_new = sf * c.astype(jnp.float32) + si * tg
    h_new = so * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)
    act_ref[...] = jnp.concatenate([si, sf, tg, so], axis=1).astype(act_ref.dtype)


def _lstm_bwd_kernel(wx_ref, wh_ref, x_ref, h_ref, c_ref, c_new_ref, act_ref,
                     dh_ref, dc_ref,
                     dx_ref, dhp_ref, dcp_ref, dwx_ref, dwh_ref, db_ref,
                     *, hidden: int):
    """Fused backward: (dh, dc) -> (dx, dh_prev, dc_prev, dwx, dwh, db)."""
    act = act_ref[...].astype(jnp.float32)
    si = act[:, 0 * hidden : 1 * hidden]
    sf = act[:, 1 * hidden : 2 * hidden]
    tg = act[:, 2 * hidden : 3 * hidden]
    so = act[:, 3 * hidden : 4 * hidden]
    c = c_ref[...].astype(jnp.float32)
    tc = jnp.tanh(c_new_ref[...].astype(jnp.float32))
    dh = dh_ref[...].astype(jnp.float32)
    dc = dc_ref[...].astype(jnp.float32)

    # h = so * tanh(c_new); c_new = sf * c + si * tg
    do_pre = dh * tc * so * (1.0 - so)
    dct = dc + dh * so * (1.0 - tc * tc)
    df_pre = dct * c * sf * (1.0 - sf)
    di_pre = dct * tg * si * (1.0 - si)
    dg_pre = dct * si * (1.0 - tg * tg)
    dgates = jnp.concatenate([di_pre, df_pre, dg_pre, do_pre], axis=1)  # (B,4H)

    # contract the 4H axis without materializing transposed weights
    contract_4h = (((1,), (1,)), ((), ()))
    dx_ref[...] = jax.lax.dot_general(
        dgates, wx_ref[...], contract_4h,
        preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dhp_ref[...] = jax.lax.dot_general(
        dgates, wh_ref[...], contract_4h,
        preferred_element_type=jnp.float32).astype(dhp_ref.dtype)
    dcp_ref[...] = (dct * sf).astype(dcp_ref.dtype)

    # weight/bias grads sum over the whole batch: every grid step maps to the
    # same output block, so zero it on the first step and accumulate after.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dwx_ref[...] = jnp.zeros_like(dwx_ref)
        dwh_ref[...] = jnp.zeros_like(dwh_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    contract_b = (((0,), (0,)), ((), ()))
    dwx_ref[...] += jax.lax.dot_general(
        x_ref[...], dgates, contract_b,
        preferred_element_type=jnp.float32).astype(dwx_ref.dtype)
    dwh_ref[...] += jax.lax.dot_general(
        h_ref[...], dgates, contract_b,
        preferred_element_type=jnp.float32).astype(dwh_ref.dtype)
    db_ref[...] += jnp.sum(dgates, axis=0)[None, :].astype(db_ref.dtype)


def _lstm_call_specs():
    full = lambda rows, cols: pl.BlockSpec((rows, cols), lambda i: (0, 0))
    tile = lambda cols: pl.BlockSpec((BLOCK_B, cols), lambda i: (i, 0))
    return full, tile


def _lstm_fwd_call(wx, wh, b, x, h, c, *, interpret: bool, with_acts: bool):
    bsz, input_size = x.shape
    hidden = h.shape[1]
    dtype = x.dtype
    grid = (bsz // BLOCK_B,)
    full, tile = _lstm_call_specs()
    in_specs = [
        full(input_size, 4 * hidden),
        full(hidden, 4 * hidden),
        full(1, 4 * hidden),
        tile(input_size),
        tile(hidden),
        tile(hidden),
    ]
    out_specs = [tile(hidden), tile(hidden)]
    out_shape = [
        jax.ShapeDtypeStruct((bsz, hidden), dtype),
        jax.ShapeDtypeStruct((bsz, hidden), dtype),
    ]
    if with_acts:
        kernel = functools.partial(_lstm_fwd_kernel, hidden=hidden)
        out_specs = out_specs + [tile(4 * hidden)]
        out_shape = out_shape + [jax.ShapeDtypeStruct((bsz, 4 * hidden), dtype)]
    else:
        kernel = functools.partial(_lstm_kernel, hidden=hidden)
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(wx, wh, b[None, :], x, h, c)


def _lstm_bwd_call(wx, wh, x, h, c, c_new, act, dh, dc, *, interpret: bool):
    bsz, input_size = x.shape
    hidden = h.shape[1]
    dtype = x.dtype
    grid = (bsz // BLOCK_B,)
    full, tile = _lstm_call_specs()
    kernel = functools.partial(_lstm_bwd_kernel, hidden=hidden)
    dx, dhp, dcp, dwx, dwh, db = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full(input_size, 4 * hidden),
            full(hidden, 4 * hidden),
            tile(input_size),          # x
            tile(hidden),              # h
            tile(hidden),              # c
            tile(hidden),              # c_new
            tile(4 * hidden),          # gate activations
            tile(hidden),              # dh
            tile(hidden),              # dc
        ],
        out_specs=[
            tile(input_size),
            tile(hidden),
            tile(hidden),
            full(input_size, 4 * hidden),
            full(hidden, 4 * hidden),
            full(1, 4 * hidden),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, input_size), dtype),
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
            jax.ShapeDtypeStruct((input_size, 4 * hidden), dtype),
            jax.ShapeDtypeStruct((hidden, 4 * hidden), dtype),
            jax.ShapeDtypeStruct((1, 4 * hidden), dtype),
        ],
        interpret=interpret,
    )(wx, wh, x, h, c, c_new, act, dh, dc)
    return dwx, dwh, db[0], dx, dhp, dcp


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lstm_cell_padded(interpret, wx, wh, b, x, h, c):
    return _lstm_fwd_call(wx, wh, b, x, h, c, interpret=interpret,
                          with_acts=False)


def _lstm_cell_padded_fwd(interpret, wx, wh, b, x, h, c):
    h_new, c_new, act = _lstm_fwd_call(wx, wh, b, x, h, c,
                                       interpret=interpret, with_acts=True)
    return (h_new, c_new), (wx, wh, x, h, c, c_new, act)


def _lstm_cell_padded_bwd(interpret, res, cotangents):
    wx, wh, x, h, c, c_new, act = res
    dh, dc = cotangents
    dwx, dwh, db, dx, dhp, dcp = _lstm_bwd_call(
        wx, wh, x, h, c, c_new, act,
        jnp.asarray(dh, x.dtype), jnp.asarray(dc, x.dtype),
        interpret=interpret)
    return dwx, dwh, db, dx, dhp, dcp


_lstm_cell_padded.defvjp(_lstm_cell_padded_fwd, _lstm_cell_padded_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell_padded(wx, wh, b, x, h, c, *, interpret: bool = False):
    """Padded entry: B % BLOCK_B == 0; I, H already lane-aligned by ops.py.

    Differentiable end-to-end: the custom_vjp's backward is the fused
    gradient kernel (see module docstring).
    """
    return _lstm_cell_padded(interpret, wx, wh, b, x, h, c)
