"""Pallas TPU kernel: fused LSTM cell (gates GEMM + elementwise, one pass).

One step of the dilated LSTM (paper Fig. 1). The fusion target on TPU is:
both gate matmuls hit the MXU from a single VMEM residency of ``x``/``h``,
and the gate nonlinearities + state update run on the VPU without the
``(B, 4H)`` gates tensor ever round-tripping to HBM.

Blocking: grid over batch tiles; weights are small for the paper's sizes
(H <= 50 padded to 128) and live fully in VMEM per block. ops.py pads
(B -> 8k, I/H -> 128k) and strips.

Training path: ``lstm_cell_padded`` carries a :func:`jax.custom_vjp`. Its
forward rule runs an extended kernel that additionally emits the gate
activations ``[sigmoid(i) | sigmoid(f) | tanh(g) | sigmoid(o)]`` as one
``(B, 4H)`` residual; the backward rule is a second fused kernel that turns
``(dh, dc)`` into the pre-activation gate cotangents on the VPU and runs all
four transposed GEMMs (``dx``, ``dh_prev`` and the weight gradients) from the
same VMEM residency. Weight/bias gradients accumulate across batch-grid
steps into a single revisited output block (grid is sequential on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def block_b_for(dtype) -> int:
    """Batch-tile rows per grid step, by stream dtype.

    The roofline report (``repro.roofline.esrnn`` / BENCH_PR10) puts the
    fused train step deep in the memory-bound regime (arithmetic intensity
    far below the TPU ridge point), so the tile size is bandwidth-driven:
    a bf16 stream halves every per-row VMEM tile (x/h/c plus the (B, 4H)
    activation residual), which lets a 2-byte dtype double the batch rows
    per grid step inside the same VMEM budget -- half the grid dispatches,
    and each gate GEMM sees an MXU-shaped 256-row operand. fp32 keeps the
    tuned 128.
    """
    return 2 * BLOCK_B if jnp.dtype(dtype).itemsize <= 2 else BLOCK_B


def _gates(wx_ref, wh_ref, b_ref, x, h):
    return (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[0, :][None, :].astype(jnp.float32)
    )


def _lstm_kernel(wx_ref, wh_ref, b_ref, x_ref, h_ref, c_ref, h_out_ref, c_out_ref,
                 *, hidden: int):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = _gates(wx_ref, wh_ref, b_ref, x, h)
    i = gates[:, 0 * hidden : 1 * hidden]
    f = gates[:, 1 * hidden : 2 * hidden]
    g = gates[:, 2 * hidden : 3 * hidden]
    o = gates[:, 3 * hidden : 4 * hidden]
    c_new = jax.nn.sigmoid(f) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def _lstm_fwd_kernel(wx_ref, wh_ref, b_ref, x_ref, h_ref, c_ref,
                     h_out_ref, c_out_ref, act_ref, *, hidden: int):
    """Forward that also emits the gate activations as backward residuals."""
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = _gates(wx_ref, wh_ref, b_ref, x, h)
    si = jax.nn.sigmoid(gates[:, 0 * hidden : 1 * hidden])
    sf = jax.nn.sigmoid(gates[:, 1 * hidden : 2 * hidden])
    tg = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    so = jax.nn.sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c_new = sf * c.astype(jnp.float32) + si * tg
    h_new = so * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)
    act_ref[...] = jnp.concatenate([si, sf, tg, so], axis=1).astype(act_ref.dtype)


def _lstm_bwd_kernel(wx_ref, wh_ref, x_ref, h_ref, c_ref, c_new_ref, act_ref,
                     dh_ref, dc_ref,
                     dx_ref, dhp_ref, dcp_ref, dwx_ref, dwh_ref, db_ref,
                     *, hidden: int):
    """Fused backward: (dh, dc) -> (dx, dh_prev, dc_prev, dwx, dwh, db)."""
    act = act_ref[...].astype(jnp.float32)
    si = act[:, 0 * hidden : 1 * hidden]
    sf = act[:, 1 * hidden : 2 * hidden]
    tg = act[:, 2 * hidden : 3 * hidden]
    so = act[:, 3 * hidden : 4 * hidden]
    c = c_ref[...].astype(jnp.float32)
    tc = jnp.tanh(c_new_ref[...].astype(jnp.float32))
    dh = dh_ref[...].astype(jnp.float32)
    dc = dc_ref[...].astype(jnp.float32)

    # h = so * tanh(c_new); c_new = sf * c + si * tg
    do_pre = dh * tc * so * (1.0 - so)
    dct = dc + dh * so * (1.0 - tc * tc)
    df_pre = dct * c * sf * (1.0 - sf)
    di_pre = dct * tg * si * (1.0 - si)
    dg_pre = dct * si * (1.0 - tg * tg)
    dgates = jnp.concatenate([di_pre, df_pre, dg_pre, do_pre], axis=1)  # (B,4H)

    # contract the 4H axis without materializing transposed weights
    contract_4h = (((1,), (1,)), ((), ()))
    dx_ref[...] = jax.lax.dot_general(
        dgates, wx_ref[...], contract_4h,
        preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dhp_ref[...] = jax.lax.dot_general(
        dgates, wh_ref[...], contract_4h,
        preferred_element_type=jnp.float32).astype(dhp_ref.dtype)
    dcp_ref[...] = (dct * sf).astype(dcp_ref.dtype)

    # weight/bias grads sum over the whole batch: every grid step maps to the
    # same output block, so zero it on the first step and accumulate after.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dwx_ref[...] = jnp.zeros_like(dwx_ref)
        dwh_ref[...] = jnp.zeros_like(dwh_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    contract_b = (((0,), (0,)), ((), ()))
    dwx_ref[...] += jax.lax.dot_general(
        x_ref[...], dgates, contract_b,
        preferred_element_type=jnp.float32).astype(dwx_ref.dtype)
    dwh_ref[...] += jax.lax.dot_general(
        h_ref[...], dgates, contract_b,
        preferred_element_type=jnp.float32).astype(dwh_ref.dtype)
    db_ref[...] += jnp.sum(dgates, axis=0)[None, :].astype(db_ref.dtype)


def _lstm_call_specs(block_b: int):
    full = lambda rows, cols: pl.BlockSpec((rows, cols), lambda i: (0, 0))
    tile = lambda cols: pl.BlockSpec((block_b, cols), lambda i: (i, 0))
    return full, tile


def _lstm_fwd_call(wx, wh, b, x, h, c, *, interpret: bool, with_acts: bool,
                   block_b: int = BLOCK_B):
    bsz, input_size = x.shape
    hidden = h.shape[1]
    dtype = x.dtype
    grid = (bsz // block_b,)
    full, tile = _lstm_call_specs(block_b)
    in_specs = [
        full(input_size, 4 * hidden),
        full(hidden, 4 * hidden),
        full(1, 4 * hidden),
        tile(input_size),
        tile(hidden),
        tile(hidden),
    ]
    out_specs = [tile(hidden), tile(hidden)]
    out_shape = [
        jax.ShapeDtypeStruct((bsz, hidden), dtype),
        jax.ShapeDtypeStruct((bsz, hidden), dtype),
    ]
    if with_acts:
        kernel = functools.partial(_lstm_fwd_kernel, hidden=hidden)
        out_specs = out_specs + [tile(4 * hidden)]
        out_shape = out_shape + [jax.ShapeDtypeStruct((bsz, 4 * hidden), dtype)]
    else:
        kernel = functools.partial(_lstm_kernel, hidden=hidden)
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(wx, wh, b[None, :], x, h, c)


def _lstm_bwd_call(wx, wh, x, h, c, c_new, act, dh, dc, *, interpret: bool,
                   block_b: int = BLOCK_B):
    bsz, input_size = x.shape
    hidden = h.shape[1]
    dtype = x.dtype
    grid = (bsz // block_b,)
    full, tile = _lstm_call_specs(block_b)
    kernel = functools.partial(_lstm_bwd_kernel, hidden=hidden)
    dx, dhp, dcp, dwx, dwh, db = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full(input_size, 4 * hidden),
            full(hidden, 4 * hidden),
            tile(input_size),          # x
            tile(hidden),              # h
            tile(hidden),              # c
            tile(hidden),              # c_new
            tile(4 * hidden),          # gate activations
            tile(hidden),              # dh
            tile(hidden),              # dc
        ],
        out_specs=[
            tile(input_size),
            tile(hidden),
            tile(hidden),
            full(input_size, 4 * hidden),
            full(hidden, 4 * hidden),
            full(1, 4 * hidden),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, input_size), dtype),
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
            # weight/bias grads accumulate across the sequential batch-grid
            # steps: always fp32, or a bf16 stream would round the running
            # sum at every revisit (the bf16-policy failure mode this
            # kernel exists to avoid). Cast back to the param dtype happens
            # in the vjp wrapper, after the sum is complete.
            jax.ShapeDtypeStruct((input_size, 4 * hidden), jnp.float32),
            jax.ShapeDtypeStruct((hidden, 4 * hidden), jnp.float32),
            jax.ShapeDtypeStruct((1, 4 * hidden), jnp.float32),
        ],
        interpret=interpret,
    )(wx, wh, x, h, c, c_new, act, dh, dc)
    return dwx, dwh, db[0], dx, dhp, dcp


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lstm_cell_padded(interpret, block_b, wx, wh, b, x, h, c):
    return _lstm_fwd_call(wx, wh, b, x, h, c, interpret=interpret,
                          with_acts=False, block_b=block_b)


def _lstm_cell_padded_fwd(interpret, block_b, wx, wh, b, x, h, c):
    h_new, c_new, act = _lstm_fwd_call(wx, wh, b, x, h, c, interpret=interpret,
                                       with_acts=True, block_b=block_b)
    return (h_new, c_new), (wx, wh, x, h, c, c_new, act)


def _lstm_cell_padded_bwd(interpret, block_b, res, cotangents):
    wx, wh, x, h, c, c_new, act = res
    dh, dc = cotangents
    dwx, dwh, db, dx, dhp, dcp = _lstm_bwd_call(
        wx, wh, x, h, c, c_new, act,
        jnp.asarray(dh, x.dtype), jnp.asarray(dc, x.dtype),
        interpret=interpret, block_b=block_b)
    # the kernel accumulates weight grads in fp32; drop to the (possibly
    # bf16) weight dtype only once, after the full-batch sum
    return (dwx.astype(wx.dtype), dwh.astype(wh.dtype),
            db.astype(wx.dtype), dx, dhp, dcp)


_lstm_cell_padded.defvjp(_lstm_cell_padded_fwd, _lstm_cell_padded_bwd)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def lstm_cell_padded(wx, wh, b, x, h, c, *, interpret: bool = False,
                     block_b: int = BLOCK_B):
    """Padded entry: B % block_b == 0; I, H already lane-aligned by ops.py.

    Differentiable end-to-end: the custom_vjp's backward is the fused
    gradient kernel (see module docstring). ``block_b`` is the batch tile
    per grid step (:func:`block_b_for` picks it from the stream dtype).
    """
    return _lstm_cell_padded(interpret, block_b, wx, wh, b, x, h, c)
