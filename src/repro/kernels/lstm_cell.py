"""Pallas TPU kernel: fused LSTM cell (gates GEMM + elementwise, one pass).

One step of the dilated LSTM (paper Fig. 1). The fusion target on TPU is:
both gate matmuls hit the MXU from a single VMEM residency of ``x``/``h``,
and the gate nonlinearities + state update run on the VPU without the
``(B, 4H)`` gates tensor ever round-tripping to HBM.

Blocking: grid over batch tiles; weights are small for the paper's sizes
(H <= 50 padded to 128) and live fully in VMEM per block. ops.py pads
(B -> 8k, I/H -> 128k) and strips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _lstm_kernel(wx_ref, wh_ref, b_ref, x_ref, h_ref, c_ref, h_out_ref, c_out_ref,
                 *, hidden: int):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[0, :][None, :].astype(jnp.float32)
    )
    i = gates[:, 0 * hidden : 1 * hidden]
    f = gates[:, 1 * hidden : 2 * hidden]
    g = gates[:, 2 * hidden : 3 * hidden]
    o = gates[:, 3 * hidden : 4 * hidden]
    c_new = jax.nn.sigmoid(f) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell_padded(wx, wh, b, x, h, c, *, interpret: bool = False):
    """Padded entry: B % BLOCK_B == 0; I, H already lane-aligned by ops.py."""
    bsz, input_size = x.shape
    hidden = h.shape[1]
    dtype = x.dtype
    grid = (bsz // BLOCK_B,)
    kernel = functools.partial(_lstm_kernel, hidden=hidden)
    h_new, c_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((input_size, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((1, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_B, input_size), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, hidden), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, hidden), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_B, hidden), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
            jax.ShapeDtypeStruct((bsz, hidden), dtype),
        ],
        interpret=interpret,
    )(wx, wh, b[None, :], x, h, c)
    return h_new, c_new
