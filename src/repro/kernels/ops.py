"""jit'd public wrappers around the Pallas kernels.

Handles: (a) interpret-mode dispatch (kernels execute in Python on CPU, run
natively on TPU), (b) padding to hardware-aligned shapes (lanes=128,
sublanes=8) and stripping, (c) constrained-space parameter transforms so the
kernels stay pure recurrences.

Every wrapper is differentiable: the kernels carry custom_vjp rules
(analytic backward kernels in hw_scan.py / lstm_cell.py), the constrained
transforms (sigmoid/exp) and the pad/strip plumbing here are plain jnp ops
whose transposes JAX derives, and pad lanes are gradient-isolated
(:func:`_pad_to`) so ``use_pallas=True`` trains end-to-end.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import hw_scan as _hw
from repro.kernels import lstm_cell as _lstm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    """Pad ``axis`` up to a multiple of ``mult`` with edge values.

    Edge values (not zeros) keep the HW recurrence finite in pad lanes
    (y/s/l stay positive, no 0/0). The pad block is wrapped in
    ``stop_gradient``: a plain ``jnp.pad(mode="edge")`` transposes by
    *summing* pad-lane cotangents back into the last real lane, so any
    cotangent mass landing on a duplicated pad lane would corrupt the last
    series' gradient. With the kernels now differentiable, pad lanes must be
    gradient-dead by construction (asserted padded-vs-unpadded identical in
    tests/kernels/test_hw_scan.py).
    """
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(size - 1, size)
    edge = jax.lax.stop_gradient(x[tuple(idx)])
    reps = [1] * x.ndim
    reps[axis] = rem
    return jnp.concatenate([x, jnp.tile(edge, reps)], axis=axis)


# ---------------------------------------------------------------------------


def hw_scan(y, params, *, seasonality: int):
    """Kernel-backed equivalent of core.holt_winters.hw_smooth (single ring).

    y: (N, T); params: HWParams. Returns levels (N, T), seas (N, T+m).
    """
    n, t_len = y.shape
    m = max(seasonality, 1)
    c = params.constrained()
    alpha, gamma = c["alpha"], c["gamma"]
    # flat ring in the *param* dtype: the recurrence state stays fp32 even
    # when y streams in bf16 (see hw_scan.py's precision contract)
    init_seas = (c["init_seas"] if seasonality > 1
                 else jnp.ones((n, m), alpha.dtype))
    if seasonality <= 1:
        # gamma must keep s == 1: force gamma = 0 contribution by flat ring
        gamma = jnp.zeros_like(gamma)

    bn = _hw.BLOCK_N
    y_p = _pad_to(y, bn, 0)
    a_p = _pad_to(alpha[:, None], bn, 0)[:, 0]
    g_p = _pad_to(gamma[:, None], bn, 0)[:, 0]
    s_p = _pad_to(init_seas, bn, 0)
    levels_tm, seas_tm = _hw.hw_scan_tm(
        y_p.T.copy(), a_p, g_p, s_p.T.copy(), interpret=_interpret()
    )
    return levels_tm.T[:n], seas_tm.T[:n]


# ---------------------------------------------------------------------------


def _pad_gates(w, hidden, h_pad):
    """(X, 4*H) -> (X, 4*H_pad), each gate block padded independently."""
    x = w.reshape(w.shape[0], 4, hidden)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, h_pad - hidden)))
    return x.reshape(w.shape[0], 4 * h_pad)


def lstm_cell(wx, wh, b, x, h, c):
    """Fused LSTM cell; signature mirrors ref.lstm_cell_ref."""
    bsz, input_size = x.shape
    hidden = h.shape[1]
    block_b = _lstm.block_b_for(x.dtype)
    i_pad = input_size + ((-input_size) % 128)
    h_pad = hidden + ((-hidden) % 128)
    b_pad = bsz + ((-bsz) % block_b)

    wx_p = jnp.pad(_pad_gates(wx, hidden, h_pad), ((0, i_pad - input_size), (0, 0)))
    wh_p = jnp.pad(_pad_gates(wh, hidden, h_pad), ((0, h_pad - hidden), (0, 0)))
    b_p = _pad_gates(b[None, :], hidden, h_pad)[0]
    x_p = jnp.pad(x, ((0, b_pad - bsz), (0, i_pad - input_size)))
    h_p = jnp.pad(h, ((0, b_pad - bsz), (0, h_pad - hidden)))
    c_p = jnp.pad(c, ((0, b_pad - bsz), (0, h_pad - hidden)))

    h_new, c_new = _lstm.lstm_cell_padded(
        wx_p, wh_p, b_p, x_p, h_p, c_p, interpret=_interpret(),
        block_b=block_b,
    )
    return h_new[:bsz, :hidden], c_new[:bsz, :hidden]


# ---------------------------------------------------------------------------


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = None,
                    block_k: int = None):
    """GQA flash attention wrapper.

    Keys are never padded (block_k is snapped to a divisor of Tk). Queries
    are padded on the *left* so that real queries keep their end-aligned
    causal offset; padded rows are stripped from the output.
    """
    tq, tk = q.shape[2], k.shape[2]
    bk = _largest_divisor(tk, block_k or _fa.DEFAULT_BK)
    bq = min(block_q or _fa.DEFAULT_BQ, tq) if tq >= 8 else 8
    pad_q = (-tq) % bq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (pad_q, 0), (0, 0)))
    out = _fa.flash_attention(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=_interpret(),
    )
    return out[:, :, pad_q:]
