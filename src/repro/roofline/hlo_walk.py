"""Loop-aware HLO-text walker: per-device HBM traffic + collective bytes.

The compiled (post-SPMD, post-optimization) HLO module is parsed into
computations; op costs are scaled by the product of enclosing ``while`` trip
counts (XLA annotates ``backend_config={"known_trip_count":{"n":...}}`` on
while ops -- every lax.scan has one).

Traffic model (matches HloCostAnalysis' per-op accounting, which fusions
make fusion-boundary-accurate): for every non-trivial op,
``bytes = output bytes + sum(operand bytes)``. Interiors of fusion /
reduce-apply computations are skipped (their traffic is the fusion op's
boundary). Collective bytes: output bytes of all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute (async ``-done`` skipped).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Set

from repro.analysis.hlo_text import (
    COLLECTIVE_KINDS as _COLLECTIVES,
    type_bytes as _type_bytes,
)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls|true_computation|false_computation)="
    r"%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


class _Op:
    __slots__ = ("name", "out_type", "opcode", "rest")

    def __init__(self, name, out_type, opcode, rest):
        self.name, self.out_type, self.opcode, self.rest = name, out_type, opcode, rest


_TRANSPARENT = {"convert", "copy", "bitcast", "reshape", "transpose",
                "broadcast"}


def _fusion_bytes(op: _Op, body: List[_Op]) -> float:
    """Body-based fusion traffic: each parameter is read once -- fully,
    unless every (transitively, through convert/copy/bitcast chains)
    consumer is a dynamic-slice (bill the slices) or a dynamic-update-slice
    *buffer* operand (in-place alias, 0); writes are DUS update regions plus
    root outputs that are not DUS-aliased carries. Converts around in-place
    cache updates are CPU-backend artifacts that a TPU build fuses away, so
    they are traced through rather than billed.
    """
    name2op = {o.name: o for o in body}
    consumers: Dict[str, List[_Op]] = {o.name: [] for o in body}
    operands: Dict[str, List[str]] = {}
    for o in body:
        refs = re.findall(r"%([\w\.\-]+)", o.rest.split(")")[0])
        operands[o.name] = refs
        for r in refs:
            if r in consumers:
                consumers[r].append(o)

    def classify_reads(pname: str) -> float:
        """Bytes read from parameter ``pname`` (transitive)."""
        total = 0.0
        full = _type_bytes(name2op[pname].out_type)
        seen = set()
        stack = [(pname, pname)]
        while stack:
            src, cur = stack.pop()
            for c in consumers.get(cur, ()):
                key = (c.name, cur)
                if key in seen:
                    continue
                seen.add(key)
                if c.opcode == "dynamic-slice":
                    total += _type_bytes(c.out_type)
                elif (c.opcode == "dynamic-update-slice"
                      and operands[c.name] and operands[c.name][0] == cur):
                    pass  # in-place buffer alias
                elif c.opcode in _TRANSPARENT:
                    stack.append((src, c.name))
                else:
                    return full  # genuinely consumed in full
        return min(total, full)

    reads = 0.0
    for o in body:
        if o.opcode == "parameter" and consumers.get(o.name):
            reads += classify_reads(o.name)

    writes = 0.0
    dus_names = set()
    for o in body:
        if o.opcode == "dynamic-update-slice":
            refs = operands[o.name]
            if len(refs) > 1 and refs[1] in name2op:
                writes += _type_bytes(name2op[refs[1]].out_type)
            elif len(refs) > 1:
                writes += _type_bytes(o.out_type) // max(len(body), 1)
            dus_names.add(o.name)

    def resolves_to_dus(name: str) -> bool:
        cur = name
        for _ in range(16):
            if cur in dus_names:
                return True
            o = name2op.get(cur)
            if o is None or o.opcode not in _TRANSPARENT:
                return False
            refs = operands.get(cur, ())
            if not refs:
                return False
            cur = refs[0]
        return False

    root = body[-1]
    root_elems = ([r for r in operands.get(root.name, ())]
                  if root.opcode == "tuple" else [root.name])
    for el in root_elems:
        if not resolves_to_dus(el) and el in name2op:
            writes += _type_bytes(name2op[el].out_type)
    return reads + writes


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if line.strip().startswith(("ENTRY", "%")) and "->" in line and line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def analyze_hlo(text: str) -> Dict[str, float]:
    comps = _parse_computations(text)
    entry = comps.pop("__entry_name__")
    comps.pop("__entry__")

    # classify sub-computations whose interiors are already accounted at the
    # caller's boundary (fusion bodies, reduce apply fns, ...)
    boundary_only: Set[str] = set()
    called_by_while: Dict[str, int] = {}
    branch_calls: Dict[str, List[str]] = {}
    for cname, ops in comps.items():
        for op in ops:
            called = _CALLED.findall(op.rest) + [
                c.strip().lstrip("%") for m in _BRANCHES.findall(op.rest)
                for c in m.split(",") if c.strip()]
            if op.opcode == "while":
                trip = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trip = int(mt.group(1))
                for sub in called:
                    called_by_while[sub] = trip
            elif op.opcode in ("fusion", "reduce", "reduce-window", "scatter",
                               "select-and-scatter", "sort", "map", "all-reduce",
                               "reduce-scatter"):
                boundary_only.update(called)
            else:  # call / conditional
                branch_calls.setdefault(cname, []).extend(called)

    # multiplicity propagation
    mult: Dict[str, float] = {entry: 1.0}
    changed = True
    guard = 0
    while changed and guard < 100:
        changed = False
        guard += 1
        for cname, ops in comps.items():
            m = mult.get(cname)
            if m is None:
                continue
            for op in ops:
                called = _CALLED.findall(op.rest) + [
                    c.strip().lstrip("%") for mm in _BRANCHES.findall(op.rest)
                    for c in mm.split(",") if c.strip()]
                if op.opcode == "while":
                    trip = 1
                    mt = _TRIP_RE.search(op.rest)
                    if mt:
                        trip = int(mt.group(1))
                    for sub in called:
                        new = m * trip
                        if mult.get(sub, 0) < new:
                            mult[sub] = new
                            changed = True
                elif op.opcode == "fusion" or op.opcode in ("reduce", "scatter"):
                    continue
                else:
                    for sub in called:
                        new = m
                        if mult.get(sub, 0) < new:
                            mult[sub] = new
                            changed = True

    # slice-touching ops: count only the moved region (mirrors
    # HloCostAnalysis' optimized handling; naive operand+output accounting
    # would bill a 6 GB loop carry on every iteration of a scan).
    def op_bytes(op: _Op, types, cname) -> float:
        def operand_refs():
            arglist = op.rest.split(")")[0]
            return [r for r in re.findall(r"%([\w\.\-]+)", arglist)]

        if op.opcode in ("while", "conditional", "call", "tuple-select"):
            return 0.0  # control flow: buffers alias through
        if op.opcode == "dynamic-update-slice":
            refs = operand_refs()
            upd = _type_bytes(types.get(refs[1], "")) if len(refs) > 1 else 0
            return 2.0 * upd
        if op.opcode == "dynamic-slice":
            return 2.0 * _type_bytes(op.out_type)
        if op.opcode == "gather":
            refs = operand_refs()
            idx = _type_bytes(types.get(refs[1], "")) if len(refs) > 1 else 0
            return 2.0 * _type_bytes(op.out_type) + idx
        if op.opcode == "scatter":
            refs = operand_refs()
            upd = _type_bytes(types.get(refs[-1], "")) if refs else 0
            return 3.0 * upd
        if op.opcode == "fusion":
            called = _CALLED.findall(op.rest)
            body = comps.get(called[0], []) if called else []
            if body:
                return _fusion_bytes(op, body)
        out_b = _type_bytes(op.out_type)
        opnd_b = sum(_type_bytes(types.get(r, "")) for r in operand_refs())
        return out_b + opnd_b

    bytes_total = 0.0
    coll_total = 0.0
    coll_by_kind: Dict[str, float] = {}
    bytes_by_dtype: Dict[str, float] = {}
    rows = []
    for cname, ops in comps.items():
        if cname in boundary_only:
            continue
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (fusion interiors etc.)
        types = {op.name: op.out_type for op in ops}
        for op in ops:
            if op.opcode in _SKIP_OPS:
                continue
            b = m * op_bytes(op, types, cname)
            bytes_total += b
            dt = op.out_type.split("[")[0].strip("(")
            bytes_by_dtype[dt] = bytes_by_dtype.get(dt, 0.0) + b
            rows.append((b, op.opcode, op.out_type[:80], m, cname[:40]))
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                out_b = _type_bytes(op.out_type)
                coll_total += m * out_b
                coll_by_kind[base] = coll_by_kind.get(base, 0.0) + m * out_b
    rows.sort(key=lambda r: -r[0])
    return {
        "bytes_per_device": bytes_total,
        "collective_bytes_per_device": coll_total,
        "collective_by_kind": coll_by_kind,
        "bytes_by_dtype": bytes_by_dtype,
        "top_bytes": [
            {"bytes": r[0], "opcode": r[1], "type": r[2], "mult": r[3],
             "computation": r[4]} for r in rows[:20]],
    }
