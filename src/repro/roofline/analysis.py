"""Roofline-term extraction from compiled AOT artifacts.

Per the task spec (TPU v5e targets):
    compute term    = HLO_FLOPs / (chips * 197e12)
    memory term     = HLO_bytes / (chips * 819e9)
    collective term = collective_bytes / (chips * 50e9)

``compiled.cost_analysis()`` on a GSPMD-partitioned module reports
**per-device** flops/bytes (verified empirically: a 2x4-sharded matmul
reports global/8); we therefore scale by chip count so the formulas above
hold with global quantities. Collective bytes are parsed from the
partitioned HLO text (sum of output bytes of all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute; per-device, scaled the
same way).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo_text import collective_bytes_by_kind

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def collective_bytes_per_device(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes by collective kind (async -done halves not counted)."""
    return collective_bytes_by_kind(hlo_text)


@dataclasses.dataclass
class RooflineTerms:
    chips: int
    flops_global: float
    bytes_global: float
    collective_global: float
    collective_by_kind: Dict[str, int]
    per_device_peak_memory: Optional[float]
    argument_bytes: Optional[float]
    temp_bytes: Optional[float]
    output_bytes: Optional[float]

    @property
    def compute_s(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_global / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "collective_global": self.collective_global,
            "collective_by_kind": self.collective_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "per_device_peak_memory": self.per_device_peak_memory,
            "argument_bytes": self.argument_bytes,
            "temp_bytes": self.temp_bytes,
            "output_bytes": self.output_bytes,
        }


def analyze(compiled, chips: int, flops_global: Optional[float] = None) -> RooflineTerms:
    """Terms from the compiled artifact.

    FLOPs: pass ``flops_global`` from the loop-aware jaxpr walker
    (roofline/jaxpr_cost.py) -- raw HloCostAnalysis undercounts while-loop
    bodies (counted once). Bytes/collectives: loop-aware HLO walker
    (roofline/hlo_walk.py) using XLA's known_trip_count annotations.
    """
    from repro.roofline import hlo_walk

    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    hw = hlo_walk.analyze_hlo(txt)
    bytes_dev = float(hw["bytes_per_device"])
    coll = {k: int(v) for k, v in hw["collective_by_kind"].items()}
    coll_dev = float(hw["collective_bytes_per_device"])
    if flops_global is None:
        flops_global = float(ca.get("flops", 0.0)) * chips  # fallback (raw)

    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        pass

    return RooflineTerms(
        chips=chips,
        flops_global=flops_global,
        bytes_global=bytes_dev * chips,
        collective_global=coll_dev * chips,
        collective_by_kind=coll,
        per_device_peak_memory=(
            float(ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                  ma.output_size_in_bytes - ma.alias_size_in_bytes)
            if ma is not None else None),
        argument_bytes=float(ma.argument_size_in_bytes) if ma else None,
        temp_bytes=float(ma.temp_size_in_bytes) if ma else None,
        output_bytes=float(ma.output_size_in_bytes) if ma else None,
    )


def model_flops(n_params: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (N_active for MoE -- caller chooses N)."""
    return 6.0 * n_params * tokens
