"""Roofline of the *real* ES-RNN entry points: fused train step and predict.

The ROADMAP's mixed-precision item starts from a measurement gap -- the seed
shipped a roofline package (HLO walker + jaxpr cost) that had never been
pointed at the ES-RNN path. This module closes it: it builds the actual
training step (``repro.train.engine.make_step_fn`` fused into the donated
``lower_superstep`` artifact) and the actual forecast program
(``esrnn_forecast_fn``, optionally ``shard_map``-sharded over a series
mesh), compiles them AOT, and extracts roofline terms per entry point --
FLOPs, HBM bytes, arithmetic intensity, and the compute/memory/collective
time terms of :class:`repro.roofline.analysis.RooflineTerms`.

Two byte measures are reported side by side, on purpose:

* ``hlo_bytes`` -- the loop-aware compiled-HLO walk
  (:func:`repro.roofline.hlo_walk.analyze_hlo`): what the *backend that
  compiled the module* will stream. On a CPU host this includes any f32
  converts CPU legalization inserts around bf16 ops.
* ``jaxpr_bytes`` -- the loop-aware aval walk
  (:func:`repro.roofline.jaxpr_cost.jaxpr_bytes`): backend-independent
  traffic of the program as written, the hardware-neutral yardstick for
  precision-policy comparison (the BENCH_PR10 ``roofline`` column's
  fp32-vs-bf16 per-step ratio gates on it).

What the numbers say (and what this PR did about it): at every realistic
batch size the fused step's arithmetic intensity sits far below the TPU
ridge point (PEAK_FLOPS / HBM_BW ~ 240 flops/byte) -- the ES-RNN step is
memory-bound, exactly the Hewamalage et al. observation that motivated the
bf16 policy. Halving the streamed bytes is therefore worth ~2x on the
memory term, and the Pallas batch tile doubles for 2-byte streams
(:func:`repro.kernels.lstm_cell.block_b_for`) because VMEM per row halved.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import analyze
from repro.roofline.jaxpr_cost import jaxpr_bytes, jaxpr_flops

# Probe sizes: big enough that the head/window tensors dominate constants,
# small enough to trace/compile in CI seconds.
PROBE_SERIES = 64
PROBE_T = 60
PROBE_BATCH = 32
PROBE_SCAN_STEPS = 4


@dataclasses.dataclass
class EntryRoofline:
    """One (entry point, precision) roofline row of the bench artifact."""

    entry: str                 # "fit" | "predict"
    precision: str             # cfg.precision
    steps: int                 # fused steps in the artifact (1 for predict)
    flops: float               # per-step, jaxpr walker (loop-aware, global)
    hlo_bytes: float           # per-step, compiled-HLO walker
    jaxpr_bytes: float         # per-step, aval walker (backend-independent)
    intensity: float           # flops / hlo_bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bytes_by_dtype: Dict[str, float]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _probe_inputs(cfg, n_series: int, t_len: int):
    from repro.analysis.collectives import probe_batch
    from repro.core.esrnn import esrnn_init

    y, cats = probe_batch(cfg, n_series, t=t_len)
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n_series)
    return params, jnp.asarray(y), jnp.asarray(cats)


def _row(entry, cfg, compiled, jaxpr, *, steps: int, chips: int) -> EntryRoofline:
    flops_total = jaxpr_flops(jaxpr)
    terms = analyze(compiled, chips=chips, flops_global=flops_total)
    jb = jaxpr_bytes(jaxpr)
    hlo_per_step = terms.bytes_global / steps
    return EntryRoofline(
        entry=entry,
        precision=cfg.precision,
        steps=steps,
        flops=flops_total / steps,
        hlo_bytes=hlo_per_step,
        jaxpr_bytes=jb / steps,
        intensity=(flops_total / terms.bytes_global
                   if terms.bytes_global else 0.0),
        compute_s=terms.compute_s / steps,
        memory_s=terms.memory_s / steps,
        collective_s=terms.collective_s / steps,
        dominant=terms.dominant,
        bytes_by_dtype=jaxpr_bytes_breakdown(jaxpr),
    )


def jaxpr_bytes_breakdown(jaxpr) -> Dict[str, float]:
    from repro.roofline.jaxpr_cost import jaxpr_bytes_by_dtype

    return {k: float(v) for k, v in jaxpr_bytes_by_dtype(jaxpr).items()}


def fit_roofline(cfg, *, n_series: int = PROBE_SERIES, t_len: int = PROBE_T,
                 batch: int = PROBE_BATCH,
                 scan_steps: int = PROBE_SCAN_STEPS) -> EntryRoofline:
    """Roofline of the donated fused superstep (the real training artifact).

    Builds ``make_step_fn`` over probe tensors, fuses ``scan_steps`` steps
    via ``lower_superstep`` exactly as the trainer does, compiles, and
    normalizes every term per step.
    """
    from repro.core.heads import frozen_param_groups
    from repro.train.engine import (
        lower_superstep, make_step_fn, make_superstep_fn, split_frozen,
    )
    from repro.train.optimizer import AdamConfig, adam_init

    params, y, cats = _probe_inputs(cfg, n_series, t_len)
    mask = jnp.ones(y.shape, jnp.float32)
    frozen = frozen_param_groups(cfg)
    step = make_step_fn(cfg, AdamConfig(lr=1e-3), y, cats, mask,
                        frozen=frozen)
    opt = adam_init(split_frozen(params, frozen)[0])
    sched = jnp.stack([(jnp.arange(batch) + k * batch) % n_series
                       for k in range(scan_steps)])

    compiled = lower_superstep(step, params, opt, sched).compile()
    # the jaxpr walkers need the traced (undonated) program, not the artifact
    jaxpr = jax.make_jaxpr(make_superstep_fn(step, donate=False))(
        params, opt, sched)
    return _row("fit", cfg, compiled, jaxpr, steps=scan_steps, chips=1)


def predict_roofline(cfg, *, n_series: int = PROBE_SERIES,
                     t_len: int = PROBE_T,
                     mesh=None) -> EntryRoofline:
    """Roofline of the forecast program; pass ``mesh`` for the sharded path.

    With a mesh the program is the ``shard_map`` series-data-parallel
    forecast (``esrnn_forecast_dp`` -- zero collectives by construction,
    which the collective term should confirm) and terms are global across
    the mesh's chips.
    """
    from repro.core.esrnn import esrnn_forecast_fn

    params, y, cats = _probe_inputs(cfg, n_series, t_len)
    chips = 1
    if mesh is not None:
        from repro.sharding.series import esrnn_forecast_dp

        chips = int(np.prod(mesh.devices.shape))

        def fc(p, yy, cc):
            return esrnn_forecast_dp(cfg, p, yy, cc, mesh=mesh)
    else:
        def fc(p, yy, cc):
            return esrnn_forecast_fn(cfg, p, yy, cc)

    compiled = jax.jit(fc).lower(params, y, cats).compile()
    jaxpr = jax.make_jaxpr(fc)(params, y, cats)
    return _row("predict", cfg, compiled, jaxpr, steps=1, chips=chips)


def precision_compare(base_cfg, *, mesh=None,
                      entries=("fit", "predict")) -> Dict:
    """fp32 vs bf16 rows for each entry point + the per-step byte ratios.

    This is the BENCH_PR10 ``roofline`` column: one row per
    (entry, precision), plus ``fit_jaxpr_bytes_ratio_bf16`` /
    ``fit_hlo_bytes_ratio_bf16`` -- bf16 per-step bytes over fp32 per-step
    bytes for the fused train step. The jaxpr ratio is the
    hardware-independent gate (<= 0.65 in CI); the HLO ratio is reported
    for whatever backend compiled the artifact.
    """
    import dataclasses as dc

    rows = []
    by_key: Dict[tuple, EntryRoofline] = {}
    for precision in ("fp32", "bf16"):
        cfg = dc.replace(base_cfg, precision=precision)
        if "fit" in entries:
            r = fit_roofline(cfg)
            rows.append(r)
            by_key[("fit", precision)] = r
        if "predict" in entries:
            r = predict_roofline(cfg, mesh=mesh)
            rows.append(r)
            by_key[("predict", precision)] = r

    def ratio(entry: str, field: str) -> Optional[float]:
        a, b = by_key.get((entry, "bf16")), by_key.get((entry, "fp32"))
        if a is None or b is None or not getattr(b, field):
            return None
        return getattr(a, field) / getattr(b, field)

    return {
        "probe": {"n_series": PROBE_SERIES, "t_len": PROBE_T,
                  "batch": PROBE_BATCH, "scan_steps": PROBE_SCAN_STEPS},
        "rows": [r.to_dict() for r in rows],
        "fit_jaxpr_bytes_ratio_bf16": ratio("fit", "jaxpr_bytes"),
        "fit_hlo_bytes_ratio_bf16": ratio("fit", "hlo_bytes"),
        "predict_jaxpr_bytes_ratio_bf16": ratio("predict", "jaxpr_bytes"),
    }
