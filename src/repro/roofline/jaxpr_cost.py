"""Loop-aware analytic FLOP count from a closed jaxpr.

XLA's HloCostAnalysis counts ``while`` bodies once (verified empirically:
an 8-step scanned matmul reports 1/8 of the unrolled flops), which makes the
compiled cost_analysis useless for scan-over-layers models. This walker
computes exact *global* (pre-partitioning) FLOPs from the jaxpr:

* ``dot_general``: 2 * prod(out) * prod(contracting)
* ``scan``: length x body
* ``while``: body counted once (no static trip count -- documented; the
  model stack only uses ``lax.scan``)
* ``cond``: max over branches
* anything with a sub-jaxpr (pjit, remat, custom_vjp, ...): recursed, so
  remat recompute inside the backward pass is *included* -- exactly what the
  useful-flops ratio is meant to expose.
* other primitives: 1 flop per output element (elementwise upper bound).
"""

from __future__ import annotations


import jax


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _aval_elems(v) -> int:
    aval = v.aval
    shape = getattr(aval, "shape", ())
    return _prod(shape)


def _sub_jaxprs(params):
    for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "fun_jaxpr"):
        if key in params:
            yield key, params[key]


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def jaxpr_flops(jaxpr) -> float:
    jaxpr = _as_jaxpr(jaxpr)
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            (lc, _rc), _batch = eqn.params["dimension_numbers"]
            lhs_shape = eqn.invars[0].aval.shape
            out_elems = sum(_aval_elems(v) for v in eqn.outvars)
            k = _prod(lhs_shape[i] for i in lc)
            total += 2.0 * out_elems * k
        elif name == "conv_general_dilated":
            out_elems = _aval_elems(eqn.outvars[0])
            rhs = eqn.invars[1].aval.shape  # (out_c, in_c, *spatial) varies
            total += 2.0 * out_elems * _prod(rhs) / max(rhs[0], 1)
        elif name == "scan":
            body = eqn.params["jaxpr"]
            total += int(eqn.params["length"]) * jaxpr_flops(body)
        elif name == "while":
            total += jaxpr_flops(eqn.params["body_jaxpr"])
            total += jaxpr_flops(eqn.params["cond_jaxpr"])
        elif name == "cond":
            total += max(jaxpr_flops(b) for b in eqn.params["branches"])
        else:
            recursed = False
            for _k, sub in _sub_jaxprs(eqn.params):
                total += jaxpr_flops(sub)
                recursed = True
            if not recursed:
                total += float(sum(_aval_elems(v) for v in eqn.outvars))
    return total


def flops_of(fn, *abstract_args) -> float:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_flops(closed)


# ---------------------------------------------------------------------------
# Loop-aware analytic byte traffic (the memory-side companion of jaxpr_flops)
# ---------------------------------------------------------------------------


def _aval_nbytes(v) -> float:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return 0.0
    return float(_prod(getattr(aval, "shape", ()))) * dt.itemsize


def _bytes_walk(jaxpr, acc, mult: float) -> None:
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            _bytes_walk(eqn.params["jaxpr"], acc,
                        mult * int(eqn.params["length"]))
        elif name == "while":
            _bytes_walk(eqn.params["body_jaxpr"], acc, mult)
            _bytes_walk(eqn.params["cond_jaxpr"], acc, mult)
        elif name == "cond":
            branch_accs = []
            for b in eqn.params["branches"]:
                a = {}
                _bytes_walk(b, a, mult)
                branch_accs.append(a)
            if branch_accs:
                best = max(branch_accs, key=lambda a: sum(a.values()))
                for k, v in best.items():
                    acc[k] = acc.get(k, 0.0) + v
        else:
            recursed = False
            for _k, sub in _sub_jaxprs(eqn.params):
                _bytes_walk(sub, acc, mult)
                recursed = True
            if recursed:
                continue
            for v in list(eqn.outvars) + list(eqn.invars):
                b = mult * _aval_nbytes(v)
                if b:
                    dt = str(v.aval.dtype)
                    acc[dt] = acc.get(dt, 0.0) + b


def jaxpr_bytes_by_dtype(jaxpr) -> dict:
    """Loop-aware aval-level traffic estimate, broken down by dtype.

    Per equation ``bytes = out avals + in avals``, with scan bodies scaled
    by trip count -- the same accounting family as the HLO walker but taken
    *before* XLA touches the program, so it is backend-independent: a CPU
    build that legalizes bf16 through f32 converts inflates the compiled
    HLO's traffic but not this measure. That makes it the hardware-neutral
    yardstick for precision-policy comparisons (the BENCH roofline column's
    fp32-vs-bf16 per-step byte ratio); absolute numbers are a fusionless
    upper bound, ratios between policies of the same program are meaningful.
    """
    acc: dict = {}
    _bytes_walk(jaxpr, acc, 1.0)
    return acc


def jaxpr_bytes(jaxpr) -> float:
    """Total loop-aware aval bytes (see :func:`jaxpr_bytes_by_dtype`)."""
    return float(sum(jaxpr_bytes_by_dtype(jaxpr).values()))


def bytes_of(fn, *abstract_args) -> float:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_bytes(closed)
