"""repro: Fast ES-RNN (Redd, Khin & Marini 2019) as a multi-pod JAX framework.

Public API re-exports. Importing this package never touches jax device state.
"""

__version__ = "1.0.0"

from repro.core.holt_winters import (  # noqa: F401
    HWParams,
    hw_init_params,
    hw_smooth,
    hw_forecast,
)
from repro.core.esrnn import ESRNNConfig  # noqa: F401
from repro.core.losses import pinball_loss, smape, mase  # noqa: F401
