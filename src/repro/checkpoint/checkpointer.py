"""Sharded, atomic, elastic checkpointing (no orbax/tensorstore available).

Design for 1000+-node fault tolerance:

* **Atomicity**: each checkpoint is written to ``step_<n>.tmp-<nonce>/`` and
  ``os.replace``d into ``step_<n>/`` only after every leaf + manifest is
  fsynced. A crash mid-write can never corrupt the latest checkpoint.
* **Manifest**: JSON with the flattened tree structure, per-leaf shape/dtype
  and the mesh/sharding it was saved under. Restore validates structure.
* **Elastic reshard**: leaves are saved as *global* arrays (gathered per
  leaf); restore places them under any mesh/sharding whose axes divide the
  global shapes -- a job can come back on a different pod count. On a real
  multi-host deployment the save path writes one shard-file per host and the
  manifest records the shard grid; this process-local implementation keeps
  the same on-disk schema (``leaf_<i>.npy`` (+ optional shard suffix)).
* **Retention**: ``keep`` most recent checkpoints are retained; a
  ``best`` symlink tracks the best validation metric.
* **Resume is bit-exact**: enforced by tests/train/test_checkpoint.py.
"""

from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _treedef_token(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, metric: Optional[float] = None) -> str:
        leaves, _ = _flatten(state)
        tmp = os.path.join(self.directory, f"step_{step}.tmp-{uuid.uuid4().hex[:8]}")
        final = os.path.join(self.directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "metric": metric,
            "treedef": _treedef_token(state),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            path = os.path.join(tmp, f"leaf_{i}.bin")
            with open(path, "wb") as f:
                # raw bytes (not .npy): round-trips ml_dtypes (bfloat16, fp8)
                f.write(arr.tobytes())
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(
                {"index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._update_best(step, metric)
        self._gc()
        return final

    def _update_best(self, step: int, metric: Optional[float]):
        if metric is None:
            return
        best_file = os.path.join(self.directory, "best.json")
        best = None
        if os.path.exists(best_file):
            with open(best_file) as f:
                best = json.load(f)
        if best is None or metric < best["metric"]:
            with open(best_file, "w") as f:
                json.dump({"step": step, "metric": metric}, f)

    def _gc(self):
        steps = sorted(self.all_steps())
        best = self.best_step()
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            if s == best:
                continue
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def best_step(self) -> Optional[int]:
        best_file = os.path.join(self.directory, "best.json")
        if not os.path.exists(best_file):
            return None
        with open(best_file) as f:
            return json.load(f)["step"]

    def restore(
        self,
        template: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Tuple[int, Any]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding (same structure) for
        elastic placement on the current mesh; leaves land on device with
        that sharding (any mesh whose axes divide the stored global shapes).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["treedef"] != _treedef_token(template):
            raise ValueError("checkpoint tree structure mismatch")
        t_leaves, treedef = _flatten(template)
        s_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(t_leaves)
        )
        leaves = []
        for i, (tl, sh) in enumerate(zip(t_leaves, s_leaves)):
            spec = manifest["leaves"][i]
            with open(os.path.join(d, f"leaf_{i}.bin"), "rb") as f:
                arr = np.frombuffer(f.read(), dtype=np.dtype(spec["dtype"]))
            arr = arr.reshape(spec["shape"])
            expect = tuple(getattr(tl, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(f"leaf {i}: saved {arr.shape} != expected {expect}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=getattr(tl, "dtype", arr.dtype)))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
