"""Sharded, atomic, elastic checkpointing (no orbax/tensorstore available).

Design for 1000+-node fault tolerance:

* **Atomicity**: each checkpoint is written to ``step_<n>.tmp-<nonce>/`` and
  ``os.replace``d into ``step_<n>/`` only after every leaf + manifest is
  fsynced. A crash mid-write can never corrupt the latest checkpoint.
* **Manifest**: JSON with the flattened tree structure, per-leaf shape/dtype
  and the mesh/sharding it was saved under. Restore validates structure.
* **Elastic reshard**: leaves are saved as *global* arrays (gathered per
  leaf); restore places them under any mesh/sharding whose axes divide the
  global shapes -- a job can come back on a different pod count. On a real
  multi-host deployment the save path writes one shard-file per host and the
  manifest records the shard grid; this process-local implementation keeps
  the same on-disk schema (``leaf_<i>.npy`` (+ optional shard suffix)).
* **Retention**: ``keep`` most recent checkpoints are retained; a
  ``best`` symlink tracks the best validation metric.
* **Resume is bit-exact**: enforced by tests/train/test_checkpoint.py.
"""

from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _treedef_token(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))


def _is_table_path(path) -> bool:
    """True for leaves of the per-series state: HW rows, moments, clocks."""
    for entry in path:
        if getattr(entry, "key", getattr(entry, "name", None)) in ("hw", "t_hw"):
            return True
    return False


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, metric: Optional[float] = None,
             shard_rows: Optional[int] = None) -> str:
        """Write one atomic checkpoint; returns the published directory.

        ``shard_rows``: when set, every *per-series table* leaf (any leaf
        whose tree path passes through an ``"hw"`` or ``"t_hw"`` key -- the
        HW rows, their sparse-Adam moments, the last-touch clocks) is split
        along its leading series axis into independent
        ``leaf_<i>.shard_<j>.bin`` files of ``shard_rows`` rows each, with
        the shard grid recorded in the manifest. Chunked training streams
        shards straight out of the host table, so checkpoint I/O buffers
        stay O(shard), and a restore can assemble (or stream) them row-range
        by row-range. Shared-weight leaves are never sharded. The manifest
        treedef is identical with and without sharding, so resident and
        chunked checkpoints restore into each other.
        """
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        tmp = os.path.join(self.directory, f"step_{step}.tmp-{uuid.uuid4().hex[:8]}")
        final = os.path.join(self.directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "metric": metric,
            "treedef": _treedef_token(state),
            "leaves": [],
        }

        def _write(path, payload):
            with open(path, "wb") as f:
                # raw bytes (not .npy): round-trips ml_dtypes (bfloat16, fp8)
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())

        for i, (tpath, leaf) in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            entry = {"index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            if (shard_rows and _is_table_path(tpath) and arr.ndim
                    and arr.shape[0] > shard_rows):
                n = arr.shape[0]
                bounds = [(lo, min(lo + shard_rows, n))
                          for lo in range(0, n, shard_rows)]
                for j, (lo, hi) in enumerate(bounds):
                    _write(os.path.join(tmp, f"leaf_{i}.shard_{j}.bin"),
                           np.ascontiguousarray(arr[lo:hi]).tobytes())
                entry["shard_rows"] = int(shard_rows)
                entry["n_shards"] = len(bounds)
            else:
                _write(os.path.join(tmp, f"leaf_{i}.bin"), arr.tobytes())
            manifest["leaves"].append(entry)
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._update_best(step, metric)
        self._gc()
        return final

    def _update_best(self, step: int, metric: Optional[float]):
        if metric is None:
            return
        best_file = os.path.join(self.directory, "best.json")
        best = None
        if os.path.exists(best_file):
            with open(best_file) as f:
                best = json.load(f)
        if best is None or metric < best["metric"]:
            with open(best_file, "w") as f:
                json.dump({"step": step, "metric": metric}, f)

    def _gc(self):
        steps = sorted(self.all_steps())
        best = self.best_step()
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            if s == best:
                continue
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def best_step(self) -> Optional[int]:
        best_file = os.path.join(self.directory, "best.json")
        if not os.path.exists(best_file):
            return None
        with open(best_file) as f:
            return json.load(f)["step"]

    def restore(
        self,
        template: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
        host_paths=None,
    ) -> Tuple[int, Any]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding (same structure) for
        elastic placement on the current mesh; leaves land on device with
        that sharding (any mesh whose axes divide the stored global shapes).

        ``host_paths``: optional predicate over tree paths; leaves whose path
        it accepts are returned as *writable host numpy* instead of device
        arrays -- how a chunked resume adopts the per-series table back into
        its ``HostStateTable`` without a full-table device round-trip.

        Row-sharded table leaves (``save(..., shard_rows=...)``) are
        reassembled transparently, so either save layout restores under
        either training mode.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["treedef"] != _treedef_token(template):
            raise ValueError("checkpoint tree structure mismatch")
        flat = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        s_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat)
        )
        leaves = []
        for i, ((tpath, tl), sh) in enumerate(zip(flat, s_leaves)):
            spec = manifest["leaves"][i]
            dtype = np.dtype(spec["dtype"])
            shape = tuple(spec["shape"])
            host = host_paths is not None and host_paths(tpath)
            if spec.get("n_shards"):
                arr = np.empty(shape, dtype)
                lo = 0
                for j in range(spec["n_shards"]):
                    with open(os.path.join(d, f"leaf_{i}.shard_{j}.bin"), "rb") as f:
                        part = np.frombuffer(f.read(), dtype=dtype)
                    rows = min(spec["shard_rows"], shape[0] - lo)
                    arr[lo:lo + rows] = part.reshape((rows,) + shape[1:])
                    lo += rows
            else:
                with open(os.path.join(d, f"leaf_{i}.bin"), "rb") as f:
                    arr = np.frombuffer(f.read(), dtype=dtype).reshape(shape)
                if host:
                    arr = np.array(arr)  # frombuffer is read-only; table
                                         # leaves must be absorb-writable
            expect = tuple(getattr(tl, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(f"leaf {i}: saved {arr.shape} != expected {expect}")
            if host:
                leaves.append(arr)
            elif sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=getattr(tl, "dtype", arr.dtype)))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
