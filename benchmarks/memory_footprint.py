"""Memory footprint: resident vs chunked (out-of-core) fit.

``python -m benchmarks.memory_footprint [--fast]`` fits the same synthetic
N-series problem twice -- once fully device-resident (the default sparse-Adam
path) and once streamed through ``TrainConfig.series_chunk`` with the
per-series HW table + moments living in a host :class:`HostStateTable` --
sampling peak live device bytes at every superstep boundary
(``jax.live_arrays``; host ``ru_maxrss`` recorded as the fallback signal on
backends without per-array accounting). This is the ``peak_memory`` column of
``BENCH_PR10.json``: the out-of-core claim is that device peak scales with
``series_chunk``, not N, so chunked peak must come in under resident peak at
N=65k (CI gates it).

It also re-runs both modes at small N on the *same chunk-major schedule*
(streaming vs ``chunk_resident=True``) and reports the max loss-trajectory
absdiff -- the exactness half of the claim (gated <= 1e-6; bit-exact on one
backend in practice).
"""

import argparse
import gc
import json
import os
import resource
import time

import numpy as np


def _device_bytes() -> int:
    import jax

    return sum(int(a.nbytes) for a in jax.live_arrays())


def _max_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _fit(mcfg, data, cfg):
    """One fit with a superstep-boundary device-memory sampler."""
    from repro.train.trainer import train_esrnn

    peak = {"bytes": 0}

    def on_step(step, losses, params):
        peak["bytes"] = max(peak["bytes"], _device_bytes())

    t0 = time.perf_counter()
    out = train_esrnn(mcfg, data, cfg, hooks={"on_step": on_step})
    dt = time.perf_counter() - t0
    losses = np.asarray(out["history"]["loss"], np.float64)
    del out
    gc.collect()
    return {
        "peak_device_bytes": int(peak["bytes"]),
        "fit_s": float(dt),
        "final_loss": float(losses[-1]),
    }, losses


def run(fast: bool = False) -> dict:
    import dataclasses

    from repro.core.esrnn import make_config
    from repro.data.pipeline import synthetic_prepared
    from repro.train.host_table import HostStateTable
    from repro.train.trainer import TrainConfig

    n = 8192 if fast else 65536
    chunk = n // 8
    mcfg = make_config("quarterly", hidden_size=8)
    data = synthetic_prepared(n, seasonality=mcfg.seasonality,
                              horizon=mcfg.output_size, series_length=24)
    # 3 full chunk visits' worth of steps: the streamed fit must cross
    # several prefetch/retire boundaries for the peak to be representative.
    bs = 256 if fast else 512
    steps_per_chunk = chunk // bs
    cfg = TrainConfig(batch_size=bs, n_steps=3 * steps_per_chunk,
                      scan_steps=4, sparse_adam=True,
                      eval_every=10**9, ckpt_every=10**9)

    resident, _ = _fit(mcfg, data, cfg)
    chunked, _ = _fit(mcfg, data,
                      dataclasses.replace(cfg, series_chunk=chunk))

    # -- exactness: streaming vs device-resident on the SAME chunk-major
    # schedule, small N (the BENCH gate; tests/train/test_chunked.py holds
    # the bit-exact version) --------------------------------------------------
    n_small = 512
    small = synthetic_prepared(n_small, seasonality=mcfg.seasonality,
                               horizon=mcfg.output_size, series_length=24)
    scfg = TrainConfig(batch_size=64, n_steps=24, scan_steps=4,
                       sparse_adam=True, series_chunk=128,
                       eval_every=10**9, ckpt_every=10**9)
    _, l_stream = _fit(mcfg, small, scfg)
    _, l_ref = _fit(mcfg, small,
                    dataclasses.replace(scfg, chunk_resident=True))
    absdiff = float(np.max(np.abs(l_stream - l_ref)))

    table_bytes = HostStateTable.init(
        n, mcfg.seasonality, seasonality2=mcfg.seasonality2).nbytes()
    return {
        "n_series": n,
        "series_chunk": chunk,
        "batch_size": bs,
        "n_steps": cfg.n_steps,
        "resident": resident,
        "chunked": chunked,
        "device_peak_ratio_chunked_vs_resident": (
            chunked["peak_device_bytes"] / max(resident["peak_device_bytes"], 1)),
        "host_table_bytes": int(table_bytes),
        "max_rss_mb": _max_rss_mb(),
        "trajectory": {
            "n_series": n_small,
            "series_chunk": scfg.series_chunk,
            "n_steps": scfg.n_steps,
            "max_loss_absdiff_stream_vs_resident": absdiff,
        },
    }


def print_report(r: dict) -> None:
    res, chk = r["resident"], r["chunked"]
    print(f"  N={r['n_series']} chunk={r['series_chunk']} "
          f"batch={r['batch_size']} steps={r['n_steps']}")
    print(f"  resident: peak device {res['peak_device_bytes'] / 2**20:8.2f} MiB  "
          f"fit {res['fit_s']:6.2f}s  final loss {res['final_loss']:.4f}")
    print(f"  chunked:  peak device {chk['peak_device_bytes'] / 2**20:8.2f} MiB  "
          f"fit {chk['fit_s']:6.2f}s  final loss {chk['final_loss']:.4f}")
    print(f"  -> chunked/resident device peak: "
          f"{r['device_peak_ratio_chunked_vs_resident']:.3f}  "
          f"(host table {r['host_table_bytes'] / 2**20:.2f} MiB, "
          f"max RSS {r['max_rss_mb']:.0f} MB)")
    tr = r["trajectory"]
    print(f"  exactness (N={tr['n_series']}, chunk={tr['series_chunk']}, "
          f"{tr['n_steps']} steps): stream-vs-resident loss absdiff "
          f"{tr['max_loss_absdiff_stream_vs_resident']:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sizes")
    ap.add_argument("--json", default=None, help="also dump the dict here")
    args = ap.parse_args()
    r = run(fast=args.fast)
    print("== Memory footprint: resident vs chunked fit ==")
    print_report(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(r, f, indent=1)
        print("wrote", os.path.abspath(args.json))


if __name__ == "__main__":
    main()
