"""Table 6 analog: test sMAPE broken down by M4 data category."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import save_result, train_frequency
from repro.core import losses as L
from repro.core.esrnn import esrnn_forecast
from repro.data.synthetic_m4 import CATEGORIES

FREQS = {"yearly": (0.004, 100), "quarterly": (0.004, 100), "monthly": (0.002, 100)}


def run(fast: bool = False):
    table = {}
    for freq, (scale, steps) in FREQS.items():
        if fast:
            scale, steps = scale / 2, 40
        cfg, data, params, _ = train_frequency(freq, scale=scale, steps=steps)
        fc = esrnn_forecast(cfg, params, jnp.asarray(data.val_input),
                            jnp.asarray(data.cats))
        target = jnp.asarray(data.test_target)
        col = {}
        for ci, cat in enumerate(CATEGORIES):
            sel = data.categories == ci
            if not sel.any():
                col[cat] = None
                continue
            col[cat] = float(L.smape(fc[sel], target[sel]))
        col["Overall"] = float(L.smape(fc, target))
        table[freq] = col
    save_result("table6_categories", table)
    return table


def main():
    table = run()
    freqs = list(table)
    print(f"{'Category':<14s}" + "".join(f"{f:>12s}" for f in freqs))
    for cat in CATEGORIES + ["Overall"]:
        cells = []
        for f in freqs:
            v = table[f].get(cat)
            cells.append(f"{v:12.2f}" if v is not None else f"{'-':>12s}")
        print(f"{cat:<14s}" + "".join(cells))


if __name__ == "__main__":
    main()
