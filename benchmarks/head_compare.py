"""Head comparison: the three registered heads on one synthetic M4 split.

The pluggable-head claim is twofold: every head trains and scores through
the unchanged spec/estimator surface, and the esn head's frozen reservoir
makes its fit cheaper than the lstm's at equal steps (the training step
closes over the reservoir, so XLA never builds its weight-gradient
matmuls). This benchmark fits each head for the SAME number of steps on
the SAME prepared quarterly split and reports fit wall-clock plus
sMAPE/MASE/OWA (vs Naive2, as in Table 4).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_test_smape, save_result
from repro.core import losses as L
from repro.core.comb import naive2_forecast
from repro.core.esrnn import make_config
from repro.core.heads import available_heads
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn

FREQ = "quarterly"


def run(fast: bool = False):
    scale, steps = (0.002, 40) if fast else (0.004, 120)
    data = prepare(generate(FREQ, scale=scale, seed=0))
    m, h = data.seasonality, data.horizon
    y_in = np.asarray(data.val_input)
    target = jnp.asarray(data.test_target)
    insample = jnp.asarray(y_in)
    n2 = jnp.asarray(naive2_forecast(y_in, h, m), jnp.float32)
    naive2_smape = float(L.smape(n2, target))
    naive2_mase = float(L.mase(n2, target, insample, m))

    # one-time jax/runtime warmup (device init, data transfer paths) so the
    # first head timed doesn't absorb costs the others skip
    train_esrnn(make_config(FREQ), data, TrainConfig(
        batch_size=min(64, data.n_series), n_steps=2, lr=4e-3,
        eval_every=2, ckpt_dir=None, seed=0))

    # the registered heads at the default fp32 policy, plus the lstm head
    # under the bf16 compute policy -- the equal-quality claim of the
    # mixed-precision path (CI gates lstm_bf16 OWA within 1% of fp32 lstm)
    variants = [(head, "fp32") for head in available_heads()]
    variants.append(("lstm", "bf16"))

    rows = {}
    for head, precision in variants:
        cfg = make_config(FREQ, head=head, precision=precision)
        t0 = time.perf_counter()
        out = train_esrnn(cfg, data, TrainConfig(
            batch_size=min(64, data.n_series), n_steps=steps, lr=4e-3,
            eval_every=max(steps // 3, 1), ckpt_dir=None, seed=0))
        fit_s = time.perf_counter() - t0
        smape, fc = eval_test_smape(cfg, data, out["params"])
        mase = float(L.mase(jnp.asarray(fc), target, insample, m))
        key = head if precision == "fp32" else f"{head}_{precision}"
        rows[key] = {
            "fit_s": fit_s,
            "steps": steps,
            "precision": precision,
            "smape": smape,
            "mase": mase,
            "owa": float(L.owa(smape, mase, naive2_smape, naive2_mase)),
            "final_loss": float(out["history"]["loss"][-1]),
        }

    out = {
        "frequency": FREQ,
        "n_series": data.n_series,
        "steps": steps,
        "naive2": {"smape": naive2_smape, "mase": naive2_mase},
        "per_head": rows,
        "esn_fit_speedup_vs_lstm": rows["lstm"]["fit_s"] / rows["esn"]["fit_s"],
        "bf16_owa_ratio": rows["lstm_bf16"]["owa"] / rows["lstm"]["owa"],
    }
    save_result("head_compare", out)
    return out


def main():
    out = run()
    print(f"head     {'fit_s':>8s} {'smape':>8s} {'mase':>8s} {'owa':>8s}")
    for head, r in out["per_head"].items():
        print(f"{head:8s} {r['fit_s']:8.2f} {r['smape']:8.3f} "
              f"{r['mase']:8.3f} {r['owa']:8.3f}")
    print(f"esn fit speedup vs lstm at equal steps: "
          f"{out['esn_fit_speedup_vs_lstm']:.2f}x")
    print(f"bf16 lstm OWA / fp32 lstm OWA: {out['bf16_owa_ratio']:.4f}")


if __name__ == "__main__":
    main()
