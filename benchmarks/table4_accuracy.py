"""Table 4 analog: ES-RNN vs the M4 Comb benchmark, per frequency.

Paper's headline accuracy claim: the hybrid beats Comb on average. The M4
CSVs are unavailable offline, so this runs on synthetic M4 (matched Table
2/3 statistics); sMAPE magnitudes differ from the paper, the *ordering*
(hybrid < Comb < Naive) is what reproduces. MASE/OWA vs Naive2 included.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_test_smape, save_result, train_frequency
from repro.core import losses as L
from repro.core.comb import comb_forecast, naive2_forecast, seasonal_naive_forecast

FREQS = {"yearly": (0.004, 120), "quarterly": (0.004, 120), "monthly": (0.002, 120)}


def run(fast: bool = False):
    rows = {}
    for freq, (scale, steps) in FREQS.items():
        if fast:
            scale, steps = scale / 2, 40
        cfg, data, params, _ = train_frequency(freq, scale=scale, steps=steps)
        m, h = data.seasonality, data.horizon
        y_in = np.asarray(data.val_input)
        target = jnp.asarray(data.test_target)
        insample = jnp.asarray(y_in)

        esrnn_smape, fc_esrnn = eval_test_smape(cfg, data, params)

        candidates = {
            "esrnn": fc_esrnn,
            "comb": comb_forecast(y_in, h, m),
            "snaive": seasonal_naive_forecast(y_in, h, m),
            "naive2": naive2_forecast(y_in, h, m),
        }
        row = {}
        for name, fc in candidates.items():
            fc_j = jnp.asarray(fc, jnp.float32)
            row[name] = {
                "smape": float(L.smape(fc_j, target)),
                "mase": float(L.mase(fc_j, target, insample, m)),
            }
        for name in candidates:
            row[name]["owa"] = float(L.owa(
                row[name]["smape"], row[name]["mase"],
                row["naive2"]["smape"], row["naive2"]["mase"]))
        row["n_series"] = data.n_series
        rows[freq] = row
    # weighted average (by series count) as in the paper's "Average" column
    total = sum(r["n_series"] for r in rows.values())
    avg = {
        name: sum(r[name]["smape"] * r["n_series"] for r in rows.values()) / total
        for name in ("esrnn", "comb", "snaive", "naive2")
    }
    out = {"per_frequency": rows, "weighted_smape": avg,
           "improvement_vs_comb_pct":
               100.0 * (avg["comb"] - avg["esrnn"]) / avg["comb"]}
    save_result("table4_accuracy", out)
    return out


def main():
    out = run()
    print("freq      " + "".join(f"{n:>10s}" for n in ("esrnn", "comb", "snaive", "naive2")))
    for freq, row in out["per_frequency"].items():
        print(f"{freq:10s}" + "".join(
            f"{row[n]['smape']:10.3f}" for n in ("esrnn", "comb", "snaive", "naive2")))
    print(f"weighted  " + "".join(
        f"{out['weighted_smape'][n]:10.3f}" for n in ("esrnn", "comb", "snaive", "naive2")))
    print(f"ES-RNN improvement vs Comb: {out['improvement_vs_comb_pct']:.1f}%"
          f"  (paper: 9.2-11.2% on real M4)")


if __name__ == "__main__":
    main()
