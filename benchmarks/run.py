"""Benchmark harness entry point -- one function per paper table.

``python -m benchmarks.run [--fast]`` runs Table 4/5/6 analogs, the
sustained-load serving benchmark, the pluggable-head comparison and the
roofline report, printing ``name,us_per_call,derived`` CSV lines plus the
human-readable tables, and saving JSON under experiments/bench/. It also
writes the repo-root ``BENCH_PR10.json`` trajectory point (speedup through
the public estimator, the ``use_pallas`` train-step timing column, the
fused-engine ``scan_steps`` steps/sec column, the sharded-vs-single
``predict_path`` series/sec column, the continuous-batching ``serve_load``
sustained-load column -- p50/p99 latency + series/sec for >= 2 queue
configurations vs the batch-1 baseline -- the ``head_compare`` table (fit
wall-clock + sMAPE/MASE/OWA per registered head at equal steps on the same
split, now with a bf16-policy lstm row and its OWA ratio vs fp32), the
``analysis`` column (graph-auditor metrics: true XLA compile counts vs
budget, collective counts, aliased-buffer counts), the ``roofline`` column
(FLOPs / HBM bytes / arithmetic intensity / compute-vs-memory term for the
real fused train step and predict program, fp32 vs bf16 side by side; CI
gates the bf16 fused-step byte ratio <= 0.65), the ``peak_memory`` column
(peak live device bytes for a resident vs a ``series_chunk``-streamed
out-of-core fit at the same N, plus the streamed-vs-resident loss
trajectory absdiff; CI gates chunked < resident and absdiff <= 1e-6),
sMAPE, device sweep, git sha) that CI archives as an artifact -- the perf
record the next regression gets compared against
(``BENCH_PR2.json``..``BENCH_PR9.json`` are the prior points, kept for
comparison).

Invoke through ``scripts/run_env.sh`` for pinned runtime hygiene (tcmalloc,
XLA flags, dtype bits): ``bash scripts/run_env.sh python -m benchmarks.run``.
"""

import argparse
import json
import os
import subprocess
import time

BENCH_TRAJECTORY = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_PR10.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__), timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def analysis_column() -> dict:
    """Graph-auditor metrics for the trajectory's ``analysis`` column.

    Runs the full invariant audit (``repro.analysis.run_audit``) on the esn
    smoke spec -- the head with a frozen group, so the gradient-leak lint is
    load-bearing -- including the partitioned-HLO collective audit. The
    column records the proof metrics (true XLA compile counts vs the bucket
    budget, collective counts, aliased-buffer counts) next to the perf
    numbers they protect; CI gates ``ok`` == true.
    """
    from repro.analysis import run_audit
    from repro.forecast.spec import get_smoke_spec

    report = run_audit(get_smoke_spec("esn-quarterly"),
                       entries=("fit", "predict", "serve", "collectives"))
    return {
        "ok": report.ok,
        "violations_total": len(report.violations),
        "sections": {s.name: s.metrics for s in report.sections},
    }


def write_trajectory(t5, t4, serve, heads, analysis, roofline,
                     peak_memory) -> str:
    """BENCH_PR10.json: the machine-readable perf point CI archives."""
    import jax

    payload = {
        "bench": "PR10",
        "git_sha": _git_sha(),
        "devices": len(jax.devices()),
        "speedup_vectorized_vs_loop": t5["estimator_path"]["speedup"],
        "speedup_batch_rows": [
            {"batch": r["batch"], "speedup": r["speedup"]} for r in t5["rows"]],
        # trainable-kernel column: full value_and_grad step through the
        # custom_vjp kernel path vs pure jax (interpret mode off-TPU)
        "train_step": t5["train_step"],
        # fused-engine column: steps/sec for scan_steps in {1, 32} at batch
        # 64 on the same schedule (final losses must agree; CI asserts it)
        "scan_steps": t5["scan_steps"],
        # sharded-inference column: predict-path series/sec, one device vs
        # the series mesh over all devices (CI gates >= 1.5x at 8 host
        # devices; on real multi-chip hosts this is the scaling claim)
        "predict_path": t5["predict_path"],
        # sustained-load serving column: open-loop Poisson arrivals replayed
        # against batch-1 dispatch-on-arrival vs the continuous-batching
        # server at >= 2 queue configs (CI gates: run completes, p99 finite,
        # series/sec recorded, continuous >= 1.5x at equal-or-better p99)
        "serve_load": serve,
        # pluggable-head column: every registered head fitted for the same
        # steps on the same quarterly split -- fit wall-clock + accuracy
        # (CI gates: every head's OWA finite, lstm's OWA no worse than the
        # PR6 record, esn's fit wall-clock under lstm's at equal steps)
        "head_compare": heads,
        # graph-auditor column: the invariant metrics behind the perf
        # numbers above (compile counts vs budget, collective counts,
        # aliased-buffer counts; CI gates analysis.ok == true)
        "analysis": analysis,
        # roofline column: static FLOPs / HBM bytes / intensity / roofline
        # time terms of the real fused train step and predict program, at
        # both precision policies (CI gates every term finite & non-zero
        # and the bf16 fused-step jaxpr-byte ratio <= 0.65x of fp32)
        "roofline": roofline,
        # out-of-core column: peak live device bytes for resident vs
        # series_chunk-streamed fit at the same N, the host-table size the
        # streamed fit keeps off-device, and the streamed-vs-resident loss
        # trajectory absdiff on the shared chunk-major schedule (CI gates
        # chunked peak < resident peak and absdiff <= 1e-6)
        "peak_memory": peak_memory,
        "smape_quarterly": t4["per_frequency"]["quarterly"]["esrnn"]["smape"],
        "owa_quarterly": t4["per_frequency"]["quarterly"]["esrnn"]["owa"],
        "device_sweep": t5["device_sweep"],
    }
    path = os.path.abspath(BENCH_TRAJECTORY)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sizes")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        head_compare, memory_footprint, roofline_report, serve_load,
        table4_accuracy, table5_speedup, table6_categories,
    )

    csv = []

    t0 = time.perf_counter()
    t5 = table5_speedup.run(fast=args.fast)
    dt = time.perf_counter() - t0
    best = max(r["speedup"] for r in t5["rows"])
    csv.append(("table5_speedup", dt * 1e6, f"max_speedup={best:.0f}x"))
    table5_speedup.main.__globals__  # keep import
    print("\n== Table 5: vectorization speedup ==")
    for r in t5["rows"]:
        print(f"  batch {r['batch']:5d}: loop {r['loop_s']:8.2f}s  "
              f"vectorized {r['vectorized_s']:8.4f}s  -> {r['speedup']:7.1f}x")
    ts = t5["train_step"]
    print(f"  train step (batch {ts['batch']}, backend {ts['backend']}): "
          f"pure-jax {ts['use_pallas_false']['step_s']:.4f}s  "
          f"pallas {ts['use_pallas_true']['step_s']:.4f}s")
    sc = t5["scan_steps"]
    cells = "  ".join(f"scan{r['scan_steps']}={r['steps_per_sec']:.0f}/s"
                      for r in sc["rows"])
    print(f"  fused engine (batch {sc['batch']}): {cells}  "
          f"-> {sc['speedup_scan_vs_perstep']:.2f}x, "
          f"loss diff {sc['final_loss_absdiff']:.1e}")
    pp = t5["predict_path"]
    if "sharded" in pp:
        print(f"  predict path (N={pp['n_series']}): "
              f"single {pp['single_device']['series_per_sec']:.0f} series/s  "
              f"sharded({pp['devices']}) "
              f"{pp['sharded']['series_per_sec']:.0f} series/s  "
              f"-> {pp['speedup_sharded_vs_single']:.2f}x")
    else:
        print(f"  predict path (N={pp['n_series']}): "
              f"single {pp['single_device']['series_per_sec']:.0f} series/s "
              f"(1 device)")

    t0 = time.perf_counter()
    t4 = table4_accuracy.run(fast=args.fast)
    dt = time.perf_counter() - t0
    csv.append(("table4_accuracy", dt * 1e6,
                f"improvement_vs_comb={t4['improvement_vs_comb_pct']:.1f}%"))
    print("\n== Table 4: sMAPE vs Comb benchmark (synthetic M4) ==")
    for freq, row in t4["per_frequency"].items():
        print(f"  {freq:10s} esrnn={row['esrnn']['smape']:7.3f} "
              f"comb={row['comb']['smape']:7.3f} snaive={row['snaive']['smape']:7.3f} "
              f"owa={row['esrnn']['owa']:.3f}")
    print(f"  weighted ES-RNN improvement vs Comb: "
          f"{t4['improvement_vs_comb_pct']:.1f}% (paper: 9.2-11.2%)")

    t0 = time.perf_counter()
    sv = serve_load.run(fast=args.fast)
    dt = time.perf_counter() - t0
    csv.append(("serve_load", dt * 1e6,
                f"continuous_speedup={sv['speedup_best_vs_baseline']:.2f}x"))
    print("\n== Sustained-load serving (open-loop Poisson arrivals) ==")
    base = sv["baseline_batch1"]
    print(f"  offered {sv['offered_rate_per_s']:.0f} req/s over "
          f"{sv['n_requests']} requests")
    print(f"  batch-1 baseline: {base['series_per_sec']:7.0f} series/s  "
          f"p50 {base['p50_ms']:7.1f} ms  p99 {base['p99_ms']:7.1f} ms")
    for c in sv["continuous"]:
        print(f"  continuous w={c['max_wait_ms']:4.1f}ms: "
              f"{c['series_per_sec']:7.0f} series/s  "
              f"p50 {c['p50_ms']:7.1f} ms  p99 {c['p99_ms']:7.1f} ms  "
              f"({c['batches']} batches, queue peak {c['queue_peak']})")
    print(f"  best continuous vs baseline: "
          f"{sv['speedup_best_vs_baseline']:.2f}x series/s")

    t0 = time.perf_counter()
    hc = head_compare.run(fast=args.fast)
    dt = time.perf_counter() - t0
    csv.append(("head_compare", dt * 1e6,
                f"esn_fit_speedup={hc['esn_fit_speedup_vs_lstm']:.2f}x"))
    print("\n== Pluggable heads (equal steps, same quarterly split) ==")
    for head, r in hc["per_head"].items():
        print(f"  {head:5s} fit {r['fit_s']:6.2f}s  smape {r['smape']:7.3f}  "
              f"mase {r['mase']:7.3f}  owa {r['owa']:.3f}")
    print(f"  esn fit speedup vs lstm: "
          f"{hc['esn_fit_speedup_vs_lstm']:.2f}x at {hc['steps']} steps")

    t0 = time.perf_counter()
    t6 = table6_categories.run(fast=True)
    dt = time.perf_counter() - t0
    csv.append(("table6_categories", dt * 1e6, "per-category sMAPE"))
    print("\n== Table 6: per-category sMAPE ==")
    for freq, col in t6.items():
        cells = ", ".join(f"{k[:5]}={v:.1f}" for k, v in col.items() if v is not None)
        print(f"  {freq:10s} {cells}")

    print("\n== Roofline (from dry-run artifacts) ==")
    roofline_report.main()

    t0 = time.perf_counter()
    rl = roofline_report.esrnn_section(fast=args.fast)
    dt = time.perf_counter() - t0
    csv.append(("roofline_esrnn", dt * 1e6,
                f"fit_bf16_bytes_ratio={rl['fit_jaxpr_bytes_ratio_bf16']:.3f}"))
    print("\n== Roofline (live ES-RNN entry points, fp32 vs bf16) ==")
    roofline_report.print_esrnn_section(rl)

    t0 = time.perf_counter()
    pm = memory_footprint.run(fast=args.fast)
    dt = time.perf_counter() - t0
    csv.append(("memory_footprint", dt * 1e6,
                f"device_peak_ratio="
                f"{pm['device_peak_ratio_chunked_vs_resident']:.3f}"))
    print("\n== Memory footprint: resident vs chunked fit ==")
    memory_footprint.print_report(pm)

    t0 = time.perf_counter()
    an = analysis_column()
    dt = time.perf_counter() - t0
    csv.append(("graph_audit", dt * 1e6,
                f"violations={an['violations_total']}"))
    print("\n== Graph audit (static invariant lints) ==")
    for name, m in an["sections"].items():
        print(f"  {name:12s} {m}")
    print(f"  ok={an['ok']} violations={an['violations_total']}")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.0f},{derived}")

    print("\nwrote", write_trajectory(t5, t4, sv, hc, an, rl, pm))


if __name__ == "__main__":
    main()
