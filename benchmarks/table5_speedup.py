"""Table 5 analog: vectorized-batch vs per-series-loop training time.

The paper reports 322x (quarterly) / 113x (monthly) GPU-vs-CPU for 15
epochs. Offline we measure the same *mechanism* -- removing the per-series
loop -- on this host: one full loss+grad evaluation over N series, batched
vs looped (looped time measured on a subset and scaled linearly; the loop
is embarrassingly linear in N, so this under-states loop overhead if
anything). Batch sizes sweep up to 2048 as in the paper's discussion.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.esrnn import (
    esrnn_forecast, esrnn_init, esrnn_loss, esrnn_loss_and_grad,
    esrnn_loss_fn, gather_series, make_config,
)
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.forecast import ESRNNForecaster, get_spec

BATCH_SIZES = (64, 256, 512, 1024, 2048)
LOOP_SAMPLE = 16  # series actually looped; scaled to N
DEVICE_SWEEP = (1, 2, 4, 8)


def _measure(cfg, params, y, cats, loop_sample):
    n = y.shape[0]

    def batched(p):
        return esrnn_loss_and_grad(cfg, p, y, cats)

    # warm + time the batched step
    batched(params)
    t0 = time.perf_counter()
    loss, grads = batched(params)
    jax.block_until_ready(loss)
    t_vec = time.perf_counter() - t0

    # per-series loop (the original CPU structure): loss+grad one at a time
    one = jax.jit(lambda p, yy, cc: jax.value_and_grad(
        lambda q: esrnn_loss(cfg, q, yy, cc))(p))
    one(gather_series(params, slice(0, 1)), y[:1], cats[:1])  # warm
    t0 = time.perf_counter()
    for i in range(loop_sample):
        l, g = one(gather_series(params, slice(i, i + 1)),
                   y[i:i + 1], cats[i:i + 1])
    jax.block_until_ready(l)
    t_loop = (time.perf_counter() - t0) / loop_sample * n
    return t_vec, t_loop


def _estimator_path(fast: bool = False):
    """The paper's headline mechanism measured through the *public* API.

    Forecast all N series in one vectorized ``ESRNNForecaster.predict`` call
    vs one series at a time through the same estimator (``series_idx`` row
    gather) -- the supported surface a user would actually hit, so the
    speedup number is reproducible without touching internals.
    """
    spec = get_spec("esrnn-quarterly",
                    data_scale=0.01 if fast else 0.04, n_steps=5,
                    batch_size=64)
    f = ESRNNForecaster(spec).fit()
    n = f.n_series_
    y, cats = f.data_.train, f.data_.cats

    f.predict()  # warm the batched jit
    t0 = time.perf_counter()
    f.predict()
    t_vec = time.perf_counter() - t0

    sample = min(LOOP_SAMPLE, n)
    f.predict(y[:1], cats[:1], series_idx=[0])  # warm the per-series jit
    t0 = time.perf_counter()
    for i in range(sample):
        f.predict(y[i:i + 1], cats[i:i + 1], series_idx=[i])
    t_loop = (time.perf_counter() - t0) / sample * n
    return {"n": n, "loop_s": t_loop, "vectorized_s": t_vec,
            "speedup": t_loop / t_vec}


def _hw_component(n_max: int = 512):
    """The pre-processing layer alone: numpy per-series loop (the original
    C++ structure, interpreted) vs the vectorized scan. This isolates the
    paper's mechanism from shared matmul cost."""
    import time as _t

    from repro.core.holt_winters import (
        hw_init_params, hw_smooth, hw_smooth_loop_reference)

    rng = np.random.default_rng(0)
    y = np.abs(rng.lognormal(3, 0.5, (n_max, 72))).astype(np.float32) + 1
    p = hw_init_params(n_max, 4)
    yj = jnp.asarray(y)
    jax.block_until_ready(hw_smooth(yj, p, seasonality=4))
    t0 = _t.perf_counter()
    jax.block_until_ready(hw_smooth(yj, p, seasonality=4))
    t_vec = _t.perf_counter() - t0
    sample = min(32, n_max)
    t0 = _t.perf_counter()
    hw_smooth_loop_reference(y[:sample], jax.tree_util.tree_map(
        lambda a: a[:sample] if a is not None and a.ndim else a, p), seasonality=4)
    t_loop = (_t.perf_counter() - t0) / sample * n_max
    return {"n": n_max, "loop_s": t_loop, "vectorized_s": t_vec,
            "speedup": t_loop / t_vec}


def train_step_timing(fast: bool = False):
    """Trainable-kernel column: one jitted ``value_and_grad`` train step,
    pure-jax dispatch vs the Pallas kernel path (``use_pallas=True``).

    The kernels carry custom_vjp backward kernels, so this times the full
    forward+backward through them. Off-TPU the kernels run in interpret
    mode -- the number then tracks dispatch correctness cost, not a
    speedup; on TPU the same column is the paper's train-step claim.
    """
    n, t = (64, 60) if fast else (256, 72)
    rng = np.random.default_rng(0)
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, t))).astype(np.float32) + 1)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    out = {"backend": jax.default_backend(), "batch": n, "t_len": t}
    for label, use_pallas in (("use_pallas_false", False),
                              ("use_pallas_true", True)):
        cfg = make_config("quarterly", use_pallas=use_pallas)
        params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
        step = jax.jit(jax.value_and_grad(
            lambda p, c=cfg: esrnn_loss_fn(c, p, y, cats)))
        jax.block_until_ready(step(params))  # warm/compile
        iters = 3
        t0 = time.perf_counter()
        for _ in range(iters):
            loss, _ = step(params)
        jax.block_until_ready(loss)
        out[label] = {"step_s": (time.perf_counter() - t0) / iters,
                      "loss": float(loss)}
    return out


def scan_steps_timing(fast: bool = False, scan_steps=(1, 32)):
    """Fused-superstep column: steps/sec for ``scan_steps`` in {1, 32}.

    Times the two training engines on the same stateless schedule: the
    per-step loop (one donated jit dispatch + one host sync per step -- the
    dispatch-bound baseline) vs the fused ``lax.scan`` superstep (one
    dispatch + one sync per 32 steps). The operating point is deliberately
    *small* (batch 64 of a 256-row table, T=8, hidden 4, one LSTM layer):
    per-step compute then sits at dispatch-overhead scale, which is the
    regime the fusion targets -- on a big model the same column measures the
    host-sync stall instead. Both engines must land on the same final loss
    (``final_loss_absdiff``; the scan is the same step math in the same
    order), which the CI gate asserts.

    Also reports the sparse per-series Adam variant (``scan32_sparse_bigN``)
    on an M4-sized table (16k rows fast / 65k full): the segment update
    touches only the batch's 64 rows where dense Adam walks the whole table
    every step.
    """
    from repro.data.pipeline import batch_indices, batch_schedule
    from repro.train.engine import (
        make_perstep_fn, make_step_fn, make_superstep_fn,
    )
    from repro.train.optimizer import (
        AdamConfig, adam_init, adam_init_sparse,
    )

    def build(n, t, sparse):
        rng = np.random.default_rng(0)
        y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, t))).astype(np.float32) + 1)
        cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
        mask = jnp.ones((n, t), jnp.float32)
        cfg = make_config("quarterly", hidden_size=4, input_size=4,
                          output_size=4, dilations=((1,),))
        cfg_adam = AdamConfig(lr=1e-3, clip_norm=20.0,
                              group_lr={"per_series": 10.0, "default": 1.0})
        step = make_step_fn(cfg, cfg_adam, y, cats, mask, sparse=sparse)
        params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
        opt = adam_init_sparse(params) if sparse else adam_init(params)
        return step, params, opt

    n, t, bs = 256, 8, 64
    steps = 64 if fast else 128
    repeats = 3                         # best-of-3: shields the CI runner's
                                        # scheduler noise out of the ratio
    out = {"backend": jax.default_backend(), "batch": bs, "n_series": n,
           "t_len": t, "steps_timed": steps, "repeats": repeats, "rows": []}
    final_losses = {}
    for k in scan_steps:
        step, _, _ = build(n, t, sparse=False)
        fn = make_perstep_fn(step) if k <= 1 else make_superstep_fn(step)
        best = float("inf")
        for _ in range(repeats):
            params, opt = build(n, t, sparse=False)[1:]
            if k <= 1:
                # warm outside the clock (compiles on the first repeat only)
                params, opt, l = fn(params, opt,
                                    jnp.asarray(batch_indices(n, bs, 0)))
                params, opt = build(n, t, sparse=False)[1:]
                t0 = time.perf_counter()
                for s in range(steps):
                    idx = jnp.asarray(batch_indices(n, bs, s))
                    params, opt, l = fn(params, opt, idx)
                    final_losses[k] = float(l)  # host sync, as the trainer does
                best = min(best, time.perf_counter() - t0)
            else:
                params, opt, ls = fn(params, opt,
                                     jnp.asarray(batch_schedule(n, bs, 0, k)))
                params, opt = build(n, t, sparse=False)[1:]
                t0 = time.perf_counter()
                for s0 in range(0, steps, k):
                    sched = jnp.asarray(batch_schedule(n, bs, s0, k))
                    params, opt, ls = fn(params, opt, sched)
                    losses = np.asarray(ls)     # one host sync per superstep
                best = min(best, time.perf_counter() - t0)
                final_losses[k] = float(losses[-1])
        out["rows"].append({"scan_steps": k, "steps_per_sec": steps / best,
                            "step_s": best / steps,
                            "final_loss": final_losses[k]})
    if len(final_losses) >= 2:
        # key by scan_steps, not argument order: the ratio is always
        # most-fused over least-fused no matter how the tuple was passed
        by_k = {r["scan_steps"]: r for r in out["rows"]}
        lo, hi = min(by_k), max(by_k)
        out["speedup_scan_vs_perstep"] = (
            by_k[hi]["steps_per_sec"] / by_k[lo]["steps_per_sec"])
        out["final_loss_absdiff"] = abs(final_losses[lo] - final_losses[hi])

    # sparse per-series Adam on an M4-sized table: dense Adam walks every
    # row every step (plus the zero-padded scatter gradient), the segment
    # update touches only the batch's 64 -- the gap widens linearly with N
    # (measured here: ~2x at 16k rows, ~4.7x at 65k)
    n_big = 16384 if fast else 65536
    k = max(scan_steps)
    for label, sparse in (("scan32_dense_bigN", False), ("scan32_sparse_bigN", True)):
        step, params, opt = build(n_big, t, sparse=sparse)
        fn = make_superstep_fn(step)
        params, opt, ls = fn(params, opt,
                             jnp.asarray(batch_schedule(n_big, bs, 0, k)))  # warm
        t0 = time.perf_counter()
        params, opt, ls = fn(params, opt,
                             jnp.asarray(batch_schedule(n_big, bs, k, k)))
        np.asarray(ls)
        dt = time.perf_counter() - t0
        out[label] = {"n_series": n_big, "steps_per_sec": k / dt}
    return out


def predict_path_timing(fast: bool = False):
    """Predict-path series/sec: sharded vs single-device (the PR-5 column).

    One full ``esrnn_forecast`` over N series on one device vs the same
    batch series-sharded over every available device
    (``esrnn_forecast_dp``): per-series HW rows device-local, no
    collectives in the program at all, so this is the embarrassing
    parallelism of the paper's per-series structure continued across
    devices. On a CPU host with forced host devices the "devices" share
    cores, so the measured speedup is a *lower bound* on real multi-chip
    scaling; CI still gates it >= 1.5x at 8 host devices.

    Measurement: the two paths alternate within one loop (a scheduler
    contention spike then lands on both, not just one) and each path keeps
    its best-of-``repeats`` time -- same noise shielding as the fused-engine
    column.
    """
    from repro.sharding.series import esrnn_forecast_dp, make_series_mesh

    # N=512 is the gated point in --fast too: smaller batches leave the
    # per-call time near scheduler-noise scale on 2-core CI hosts and the
    # measured ratio gets flaky around the 1.5x gate
    n, t = 512, 72
    repeats = 8
    d = len(jax.devices())
    n -= n % d  # the shard_map path needs the batch to divide the mesh
    rng = np.random.default_rng(0)
    y = jnp.asarray(np.abs(rng.lognormal(3, 0.5, (n, t))).astype(np.float32) + 1)
    cats = jnp.asarray(np.eye(6, dtype=np.float32)[rng.integers(0, 6, n)])
    cfg = make_config("quarterly")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    out = {"backend": jax.default_backend(), "n_series": n, "t_len": t,
           "devices": d, "repeats": repeats}

    def single():
        return esrnn_forecast(cfg, params, y, cats)

    jax.block_until_ready(single())  # warm/compile
    if d > 1:
        mesh = make_series_mesh(d)
        sharded = jax.jit(lambda p, yy, cc: esrnn_forecast_dp(
            cfg, p, yy, cc, mesh=mesh))
        jax.block_until_ready(sharded(params, y, cats))
    best1 = bestd = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(single())
        best1 = min(best1, time.perf_counter() - t0)
        if d > 1:
            t0 = time.perf_counter()
            jax.block_until_ready(sharded(params, y, cats))
            bestd = min(bestd, time.perf_counter() - t0)
    out["single_device"] = {"predict_s": best1, "series_per_sec": n / best1}
    if d > 1:
        out["sharded"] = {"predict_s": bestd, "series_per_sec": n / bestd}
        out["speedup_sharded_vs_single"] = best1 / bestd
    return out


def device_sweep(devices=DEVICE_SWEEP, *, fast: bool = False):
    """--devices sweep: the vectorized loss+grad step, series-sharded.

    Times one jitted ``value_and_grad`` of the shard_map data-parallel loss
    (``repro.sharding.series.esrnn_loss_dp``) for each device count that is
    actually available. On a CPU host run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the 8 "devices"
    share the same cores, so this measures the *path* (sharded params,
    collectives in the backward pass), not a speedup; on real multi-chip
    hosts the same sweep is the scaling trajectory.
    """
    from repro.sharding.series import esrnn_loss_dp, make_series_mesh

    avail = len(jax.devices())
    ks = sorted({k for k in devices if k <= avail})
    if not ks:
        ks = [1]
    data = prepare(generate("quarterly", scale=0.05 if fast else 0.2, seed=0))
    kmax = max(ks)
    n = max(kmax, data.n_series - data.n_series % kmax)
    cfg = make_config("quarterly")
    params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
    y = jnp.asarray(data.train[:n])
    c = jnp.asarray(data.cats[:n])

    rows = []
    for k in ks:
        mesh = make_series_mesh(k)
        if k == 1:
            step = jax.jit(jax.value_and_grad(
                lambda p: esrnn_loss(cfg, p, y, c)))
        else:
            step = jax.jit(jax.value_and_grad(
                lambda p, m=mesh: esrnn_loss_dp(cfg, p, y, c, mesh=m)))
        jax.block_until_ready(step(params))  # warm/compile
        t0 = time.perf_counter()
        for _ in range(3):
            loss, grads = step(params)
        jax.block_until_ready(loss)
        rows.append({"devices": k, "batch": n,
                     "step_s": (time.perf_counter() - t0) / 3,
                     "loss": float(loss)})
    return rows


def run(fast: bool = False, devices=DEVICE_SWEEP):
    # the predict-path column is timing-gated in CI (>= 1.5x): measure it
    # first, on a clean process, before the heavier stages fragment memory
    # and leave background threads behind
    predict_path = predict_path_timing(fast)
    data = prepare(generate("quarterly", scale=0.35, seed=0))
    cfg = make_config("quarterly")
    sizes = BATCH_SIZES[:3] if fast else BATCH_SIZES
    rows = []
    seen = set()
    for bs in sizes:
        n = min(bs, data.n_series)
        if n in seen:
            continue
        seen.add(n)
        params = esrnn_init(jax.random.PRNGKey(0), cfg, n)
        y = jnp.asarray(data.train[:n])
        c = jnp.asarray(data.cats[:n])
        t_vec, t_loop = _measure(cfg, params, y, c, min(LOOP_SAMPLE, n))
        rows.append({"batch": n, "vectorized_s": t_vec, "loop_s": t_loop,
                     "speedup": t_loop / t_vec})
    out = {"rows": rows,
           "hw_component": _hw_component(256 if fast else 2048),
           "estimator_path": _estimator_path(fast),
           "train_step": train_step_timing(fast),
           "scan_steps": scan_steps_timing(fast),
           "predict_path": predict_path,
           "device_sweep": device_sweep(devices, fast=fast),
           "paper_speedups": {"quarterly": 322, "monthly": 113},
           "note": ("single-core host: both paths share one core, so the "
                    "full-model speedup reflects dispatch/loop overhead "
                    "removal only; hw_component (interpreted per-series "
                    "loop, the original C++ structure) shows the "
                    "vectorization factor the accelerator multiplies")}
    save_result("table5_speedup", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", help="reduced sizes")
    ap.add_argument("--devices", default=",".join(map(str, DEVICE_SWEEP)),
                    help="comma list of device counts to sweep the "
                         "series-sharded step over (counts beyond the "
                         "available devices are skipped; on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    args = ap.parse_args(argv)
    devices = tuple(int(k) for k in args.devices.split(","))
    out = run(fast=args.fast, devices=devices)
    print(f"{'batch':>8s} {'loop_s':>12s} {'vectorized_s':>14s} {'speedup':>9s}")
    for r in out["rows"]:
        print(f"{r['batch']:8d} {r['loop_s']:12.3f} {r['vectorized_s']:14.4f} "
              f"{r['speedup']:8.1f}x")
    hw = out["hw_component"]
    print(f"HW layer alone (N={hw['n']}): loop {hw['loop_s']:.2f}s vs "
          f"vectorized {hw['vectorized_s']:.4f}s -> {hw['speedup']:.0f}x")
    est = out["estimator_path"]
    print(f"public estimator predict (N={est['n']}): loop {est['loop_s']:.2f}s "
          f"vs vectorized {est['vectorized_s']:.4f}s -> {est['speedup']:.0f}x")
    ts = out["train_step"]
    print(f"train step (batch {ts['batch']}, backend {ts['backend']}): "
          f"pure-jax {ts['use_pallas_false']['step_s']:.4f}s vs "
          f"pallas {ts['use_pallas_true']['step_s']:.4f}s")
    sc = out["scan_steps"]
    for r in sc["rows"]:
        print(f"engine scan_steps={r['scan_steps']:3d} (batch {sc['batch']}): "
              f"{r['steps_per_sec']:8.1f} steps/s  final loss {r['final_loss']:.6f}")
    print(f"fused-vs-perstep speedup {sc['speedup_scan_vs_perstep']:.2f}x, "
          f"final-loss absdiff {sc['final_loss_absdiff']:.2e}; sparse Adam on "
          f"{sc['scan32_sparse_bigN']['n_series']} rows: "
          f"{sc['scan32_sparse_bigN']['steps_per_sec']:.1f} steps/s vs dense "
          f"{sc['scan32_dense_bigN']['steps_per_sec']:.1f}")
    pp = out["predict_path"]
    if "sharded" in pp:
        print(f"predict path (N={pp['n_series']}): single "
              f"{pp['single_device']['series_per_sec']:.0f} series/s vs "
              f"{pp['devices']}-device sharded "
              f"{pp['sharded']['series_per_sec']:.0f} series/s -> "
              f"{pp['speedup_sharded_vs_single']:.2f}x")
    else:
        print(f"predict path (N={pp['n_series']}): single "
              f"{pp['single_device']['series_per_sec']:.0f} series/s "
              f"(1 device; sharded column needs forced host devices)")
    for r in out["device_sweep"]:
        print(f"series-sharded step on {r['devices']} device(s), "
              f"batch {r['batch']}: {r['step_s']:.4f}s")
    print("(paper: 322x quarterly / 113x monthly, GPU batch vs CPU loop)")


if __name__ == "__main__":
    main()
