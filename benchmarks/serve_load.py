"""Sustained-load serving benchmark: continuous batching vs batch-1 dispatch.

Decode-microbenchmark methodology applied to forecast serving: a synthetic
**open-loop** arrival process (Poisson, rate fixed up front -- arrivals do
NOT slow down when the server falls behind, exactly like real traffic) is
replayed against two serving engines over the identical request stream:

* **baseline** -- ``BucketDispatcher.forecast_batch`` fed one request per call, i.e.
  dispatch-on-arrival with no cross-request batching. Replayed on a
  *virtual clock*: each request's service time is measured for real, queue
  wait is simulated (``start = max(arrival, prev_done)``), so the baseline
  needs no sleeping and is deterministic given the measured durations.
* **continuous** -- :class:`repro.forecast.server.ForecastServer` (bounded
  queue, ``max_wait_ms`` deadline bucket fill), replayed in *real time*:
  the driver sleeps to each arrival and ``submit``s; per-request latency is
  submit -> result as recorded by ``ServeStats``.

The offered rate is calibrated to ``rate_multiple``x the baseline's
measured capacity, so the baseline saturates (queueing delay grows without
bound over the run) while continuous batching has headroom -- the measured
gap *is* the batching win, the same story as the paper's batch-size sweep
but for latency-bound serving. Both engines pre-warm every
(batch bucket x length bucket) jit shape and reset stats before timing, so
compiles never pollute the percentiles.

Run directly (``python -m benchmarks.serve_load [--fast]``) or through
``benchmarks.run``, which folds the result into ``BENCH_PR10.json``.
"""

from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from repro.forecast import (
    BucketDispatcher, ESRNNForecaster, ForecastRequest, get_smoke_spec,
    synthetic_request_stream,
)
from repro.forecast.server import ServerConfig

# the >= 2 queue configurations the trajectory file must carry
QUEUE_CONFIGS = (
    {"max_wait_ms": 2.0, "max_queue": 4096},
    {"max_wait_ms": 10.0, "max_queue": 4096},
)


def _percentiles(lat_s: np.ndarray) -> dict:
    p50, p95, p99 = np.percentile(np.asarray(lat_s, np.float64) * 1e3,
                                  [50.0, 95.0, 99.0])
    return {"p50_ms": float(p50), "p95_ms": float(p95), "p99_ms": float(p99)}


def _prewarm(dispatcher, config, seed: int = 12345) -> None:
    """Compile every (batch bucket, length bucket) shape before timing."""
    for bucket in dispatcher.length_buckets:
        for bb in dispatcher.batch_buckets:
            if bb > dispatcher.max_batch:
                continue
            reqs = synthetic_request_stream(
                config, bb, seed=seed, len_range=(bucket, bucket + 1))
            dispatcher.run_bucket(reqs, bucket)


def _fit_estimator(fast: bool) -> ESRNNForecaster:
    spec = get_smoke_spec("esrnn-quarterly", n_steps=4 if fast else 8)
    return ESRNNForecaster(spec).fit()


def _baseline(f, requests, arrivals) -> dict:
    """Batch-1 dispatch-on-arrival on a virtual clock (measured service)."""
    srv = BucketDispatcher(f.config, f.params_)
    _prewarm(srv, f.config)
    srv.stats.reset()
    done = 0.0
    lat = np.empty(len(requests))
    t_service0 = time.perf_counter()
    for i, (r, a) in enumerate(zip(requests, arrivals)):
        t0 = time.perf_counter()
        out = srv.forecast_batch([r])
        dur = time.perf_counter() - t0
        assert np.isfinite(out[0]).all()
        done = max(done, a) + dur
        lat[i] = done - a
    service_s = time.perf_counter() - t_service0
    wall = max(done, arrivals[-1])
    return {
        "engine": "batch1",
        "series_per_sec": len(requests) / wall,
        "wall_s": wall,
        "service_s": service_s,
        **_percentiles(lat),
    }


def _continuous(f, requests, arrivals, *, max_wait_ms: float,
                max_queue: int) -> dict:
    """Real-time open-loop replay through the continuous server."""
    srv = f.serve(server_config=ServerConfig(
        max_queue=max_queue, max_wait_ms=max_wait_ms))
    _prewarm(srv.dispatcher, f.config)
    srv.stats.reset()
    lags = np.empty(len(requests))
    with srv:
        t0 = time.perf_counter()
        futs = []
        for i, (r, a) in enumerate(zip(requests, arrivals)):
            delay = a - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            lags[i] = (time.perf_counter() - t0) - a
            futs.append(srv.submit(r))
        for fut in futs:
            assert np.isfinite(fut.result(timeout=300)).all()
        wall = time.perf_counter() - t0
    s = srv.stats
    return {
        "engine": "continuous",
        "max_wait_ms": max_wait_ms,
        "max_queue": max_queue,
        "series_per_sec": len(requests) / wall,
        "wall_s": wall,
        "batches": s.batches,
        "queue_peak": s.queue_peak,
        # open-loop honesty: how far the submitting driver drifted behind
        # the arrival schedule (should be ~0; large values mean the measured
        # latencies understate true arrival->result latency)
        "mean_submit_lag_ms": float(np.mean(np.maximum(lags, 0.0)) * 1e3),
        **s.latency_percentiles(),
    }


def run(fast: bool = False, *, n_requests: Optional[int] = None,
        rate_multiple: float = 3.0, seed: int = 0) -> dict:
    """Full sweep: baseline + every queue config on one offered schedule."""
    import jax

    f = _fit_estimator(fast)
    n = n_requests or (160 if fast else 320)
    requests: List[ForecastRequest] = synthetic_request_stream(
        f.config, n, n_known=f.n_series_ or 0, seed=seed)

    # calibrate: warm batch-1 service time -> offered rate (open loop)
    cal = BucketDispatcher(f.config, f.params_)
    _prewarm(cal, f.config)
    t0 = time.perf_counter()
    n_cal = min(32, n)
    for r in requests[:n_cal]:
        cal.forecast_batch([r])
    per_req = (time.perf_counter() - t0) / n_cal
    rate = rate_multiple / per_req
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))

    baseline = _baseline(f, requests, arrivals)
    continuous = [
        _continuous(f, requests, arrivals, **qc) for qc in QUEUE_CONFIGS]

    best = max(continuous, key=lambda c: c["series_per_sec"])
    return {
        "backend": jax.default_backend(),
        "n_requests": n,
        "offered_rate_per_s": float(rate),
        "calibrated_batch1_s": per_req,
        "baseline_batch1": baseline,
        "continuous": continuous,
        "speedup_best_vs_baseline":
            best["series_per_sec"] / baseline["series_per_sec"],
        "best_p99_ms": best["p99_ms"],
        "baseline_p99_ms": baseline["p99_ms"],
    }


def main() -> None:
    import argparse
    import json

    from benchmarks.common import save_result

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()
    out = run(fast=args.fast)
    print(json.dumps(out, indent=1))
    save_result("serve_load", out)


if __name__ == "__main__":
    main()
