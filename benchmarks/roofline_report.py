"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_row(r):
    if r.get("status") != "ok":
        return f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:60]} |"
    t = r["roofline"]
    dom = t["dominant"]
    total = max(t["compute_s"], t["memory_s"], t["collective_s"])
    frac = t["compute_s"] / total if total else 0.0
    ratio = r.get("useful_flops_ratio")
    mem_gb = (r.get("memory_analysis", {}).get("argument_size", 0)
              + r.get("memory_analysis", {}).get("temp_size", 0)) / 2**30
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {dom} | "
            f"{frac:.3f} | {ratio:.2f} | {mem_gb:.1f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {dom} | "
            f"{frac:.3f} | - | {mem_gb:.1f} |")


def markdown_table(mesh: str) -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | roofline frac | 6ND/HLO | GB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in rows)


def main():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        if not rows:
            print(f"[{mesh}] no dry-run results yet "
                  f"(run: python -m repro.launch.dryrun --all --mesh {mesh})")
            continue
        ok = sum(1 for r in rows if r.get("status") == "ok")
        print(f"\n== {mesh} mesh: {ok}/{len(rows)} cells compiled ==")
        print(markdown_table(mesh))


if __name__ == "__main__":
    main()
